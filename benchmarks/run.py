"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline
number of that table/figure) and writes detailed CSVs next to this file
under ``benchmarks/out/``.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import csv
import os
import time

import numpy as np

from repro.core import dataflows as dfl
from repro.core import dnn_models as zoo
from repro.core import tensor_analysis as ta
from repro.core.dataflows import table3_for_layer
from repro.core.dse import DSEConfig, merge_results, run_dse_full
from repro.core.model import analyze, analyze_network, network_totals
from repro.core.performance import HWConfig
from repro.core.tensor_analysis import algorithmic_max_reuse

OUT = os.path.join(os.path.dirname(__file__), "out")
FLOWS = ["C-P", "X-P", "YX-P", "YR-P", "KC-P"]
# paper Fig. 10 setup: 256 PEs, 32 GBps NoC (32 elems/cycle at 1 GHz, 8-bit)
HW = HWConfig(num_pes=256, noc_bw=32.0, noc_latency=2.0)


def _csv(name: str, header: list[str], rows: list[list]):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def _write_bench(name: str, payload: dict) -> None:
    """Emit a BENCH_<name>.json perf artifact through the unified
    ``repro.api.Report`` schema — under ``benchmarks/out`` (CI artifact)
    AND at the repo root (perf trajectory tracker).  Payload keys stay at
    top level, so historical readers keep working.

    Every artifact carries the process-wide ``repro.obs`` metrics
    snapshot (compiles per family, cache hits, chunk occupancy, ...) and
    the environment provenance block ``Report.bench`` injects — perf
    numbers ship with the counters that explain them."""
    import json
    from repro import obs
    from repro.api import Report
    payload = dict(payload)
    payload.setdefault("metrics", obs.metrics().snapshot())
    doc = Report.bench(name, payload).to_json()
    os.makedirs(OUT, exist_ok=True)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for path in (os.path.join(OUT, f"BENCH_{name}.json"),
                 os.path.join(root, f"BENCH_{name}.json")):
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)


# ----------------------------------------------------------------------
# Fig. 9 — runtime-model validation workloads (MAERI 64 PEs / Eyeriss 168)
# ----------------------------------------------------------------------

def bench_fig9_validation(quick: bool) -> None:
    t0 = time.perf_counter()
    rows = []
    # MAERI setup: 64 PEs, VGG16 conv layers
    hw64 = HWConfig(num_pes=64, noc_bw=32.0, noc_latency=2.0)
    layers = [l for l in zoo.vgg16() if l.op_type == "CONV2D"]
    if quick:
        layers = layers[:4]
    for l in layers:
        s = analyze(l, table3_for_layer("YR-P", l), hw64)
        rows.append([l.name, "maeri-64pe", s.runtime, s.utilization])
    # Eyeriss setup: 168 PEs, AlexNet
    hw168 = HWConfig(num_pes=168, noc_bw=32.0, noc_latency=2.0)
    for l in zoo.alexnet():
        if l.op_type != "CONV2D":
            continue
        s = analyze(l, table3_for_layer("YR-P", l), hw168)
        rows.append([l.name, "eyeriss-168pe", s.runtime, s.utilization])
    _csv("fig9_validation.csv", ["layer", "setup", "cycles", "util"], rows)
    us = (time.perf_counter() - t0) / max(len(rows), 1) * 1e6
    _emit("fig9_validation", us, f"layers={len(rows)}")


# ----------------------------------------------------------------------
# Fig. 10 — five dataflows × five DNN models (runtime + energy)
# ----------------------------------------------------------------------

def bench_fig10_tradeoffs(quick: bool) -> dict:
    t0 = time.perf_counter()
    models = ["resnet50", "vgg16", "resnext50", "mobilenet_v2", "unet"]
    if quick:
        models = ["vgg16", "mobilenet_v2"]
    rows, table = [], {}
    n_layers = 0
    for m in models:
        layers = zoo.MODELS[m]()
        if quick:
            layers = layers[::4]
        n_layers += len(layers)
        per_layer = {f: [analyze(l, table3_for_layer(f, l), HW)
                         for l in layers] for f in FLOWS}
        for flow in FLOWS:
            rt = sum(s.runtime for s in per_layer[flow])
            en = sum(s.energy_pj for s in per_layer[flow])
            thr = sum(s.total_macs for s in per_layer[flow]) / max(rt, 1)
            table[(m, flow)] = {"runtime": rt, "energy_pj": en}
            rows.append([m, flow, rt, en, thr])
        # adaptive dataflow: per-layer best (paper Fig. 10f)
        ada_rt = sum(min(per_layer[f][i].runtime for f in FLOWS)
                     for i in range(len(layers)))
        ada_en = sum(min(per_layer[f][i].energy_pj for f in FLOWS)
                     for i in range(len(layers)))
        rows.append([m, "adaptive", ada_rt, ada_en, ""])
        table[(m, "adaptive")] = {"runtime": ada_rt, "energy_pj": ada_en}
    _csv("fig10_tradeoffs.csv",
         ["model", "dataflow", "cycles", "energy_pj", "macs_per_cycle"],
         rows)
    # headline: adaptive vs best-single-average reductions (paper: 37%/10%)
    best_fixed_rt = min(
        sum(table[(m, f)]["runtime"] for m in models) for f in FLOWS)
    ada_rt = sum(table[(m, "adaptive")]["runtime"] for m in models)
    best_fixed_en = min(
        sum(table[(m, f)]["energy_pj"] for m in models) for f in FLOWS)
    ada_en = sum(table[(m, "adaptive")]["energy_pj"] for m in models)
    rt_red = 1 - ada_rt / best_fixed_rt
    en_red = 1 - ada_en / best_fixed_en
    us = (time.perf_counter() - t0) / max(n_layers * 5, 1) * 1e6
    _emit("fig10_tradeoffs", us,
          f"adaptive_runtime_reduction={rt_red:.2f};"
          f"adaptive_energy_reduction={en_red:.2f}")
    return table


# ----------------------------------------------------------------------
# Fig. 11 — reuse factors + NoC bandwidth requirements per operator
# ----------------------------------------------------------------------

def bench_fig11_reuse_bw(quick: bool) -> None:
    t0 = time.perf_counter()
    rows = []
    ops = zoo.fig11_operators()
    for name, op in ops.items():
        amax = algorithmic_max_reuse(op)
        for flow in FLOWS:
            s = analyze(op, table3_for_layer(flow, op), HW)
            rows.append([name, flow, s.reuse_factor["I"],
                         s.reuse_factor["F"], s.peak_bw.get(0, 0.0)])
        rows.append([name, "A", amax["I"], amax["F"], ""])
    _csv("fig11_reuse_bw.csv",
         ["operator", "dataflow", "act_reuse", "filt_reuse",
          "bw_req_elems_per_cycle"], rows)
    # headline: YR-P vs KC-P reuse advantage on the early layer
    early = {r[1]: r for r in rows if r[0] == "early"}
    act_ratio = early["YR-P"][2] / max(early["KC-P"][2], 1e-9)
    fil_ratio = early["YR-P"][3] / max(early["KC-P"][3], 1e-9)
    us = (time.perf_counter() - t0) / (len(ops) * 5) * 1e6
    _emit("fig11_reuse_bw", us,
          f"early_act_reuse_YRvsKC={act_ratio:.1f}x;"
          f"early_filt_reuse_YRvsKC={fil_ratio:.1f}x")


# ----------------------------------------------------------------------
# Fig. 12 — energy breakdown (MAC / L1 / L2), normalized to C-P MACs
# ----------------------------------------------------------------------

def bench_fig12_energy_breakdown(quick: bool) -> None:
    t0 = time.perf_counter()
    op = ta.conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)
    rows = []
    base_mac = None
    for flow in FLOWS:
        s = analyze(op, table3_for_layer(flow, op), HW)
        bd = s.energy_breakdown
        if base_mac is None:
            base_mac = bd["mac"]
        rows.append([flow] + [bd.get(k, 0.0) / base_mac
                              for k in ("mac", "l1", "l2", "noc")])
    _csv("fig12_energy_breakdown.csv",
         ["dataflow", "mac", "l1", "l2", "noc"], rows)
    us = (time.perf_counter() - t0) / 5 * 1e6
    l1_significant = all(r[2] >= r[1] * 0.5 for r in rows)
    _emit("fig12_energy_breakdown", us, f"l1_significant={l1_significant}")


# ----------------------------------------------------------------------
# Fig. 13 + Table 5 — hardware DSE
# ----------------------------------------------------------------------

def bench_fig13_dse(quick: bool) -> None:
    t0 = time.perf_counter()
    op_early = ta.conv2d("vgg16-conv2", k=64, c=64, y=226, x=226, r=3, s=3)
    op_late = ta.conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)
    step = 32 if quick else 8
    cfg = DSEConfig(pe_range=tuple(range(8, 513, step)),
                    bw_range=tuple(float(b) for b in range(2, 65, 2)))
    rows = []
    n_eval = 0
    elapsed = 0.0
    for layer, lname in ((op_early, "early"), (op_late, "late")):
        for flow in ("KC-P", "YR-P"):
            res = run_dse_full(layer, flow, cfg,
                               scales=(1, 2) if quick else (1, 2, 4, 8))
            agg = merge_results(res)
            n_eval += agg["n_evaluated"]
            elapsed += agg["elapsed_s"]
            for obj in ("throughput", "energy", "edp"):
                p = agg["best"][obj]
                if p:
                    rows.append([lname, flow, obj, p["num_pes"],
                                 p["noc_bw"], p["l2_kb"], p["throughput"],
                                 p["energy_pj"], p["power_mw"],
                                 p["area_mm2"], p["tile_tag"]])
    _csv("fig13_dse.csv",
         ["layer", "dataflow", "objective", "pes", "bw", "l2_kb",
          "throughput", "energy_pj", "power_mw", "area_mm2", "tile"],
         rows)
    rate = n_eval / max(elapsed, 1e-9)
    us = (time.perf_counter() - t0) * 1e6 / max(n_eval, 1)
    _emit("fig13_dse", us,
          f"designs={n_eval};rate={rate / 1e6:.2f}M/s;paper=0.17M/s")


def bench_dse_rate(quick: bool) -> None:
    """Steady-state DSE throughput (the paper's 0.17M designs/s)."""
    import jax.numpy as jnp
    from repro.core.vectorized import batched_evaluator
    op = ta.conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)
    df = table3_for_layer("KC-P", op)
    f = batched_evaluator(op, df)
    # 16k blocks: the §Perf-A optimum (cache-resident intermediates)
    blk = 16384
    reps = (8 if quick else 64)
    rng = np.random.default_rng(0)
    pes = jnp.asarray(rng.integers(2, 1024, blk))
    bws = jnp.asarray(rng.uniform(1, 128, blk).astype(np.float32))
    f(pes, bws).block_until_ready()   # compile + warm at the timed shape
    t0 = time.perf_counter()
    for _ in range(reps):
        f(pes, bws).block_until_ready()
    dt = time.perf_counter() - t0
    n = reps * blk
    _emit("dse_rate", dt / n * 1e6,
          f"rate={n / dt / 1e6:.2f}M_designs_per_s;paper=0.17M/s")


def bench_mapspace(quick: bool) -> None:
    """Mapping-space auto-search (repro.mapspace) on the gene pipeline:

      * best-found-vs-Table-3 EDP improvement per VGG16/ResNet50 layer;
      * the headline ``search(budget=5000)`` end-to-end mappings/s on the
        VGG16 conv13 72-group space — gene pipeline vs the legacy
        tuple-point baseline, same machine, warm executables (cold wall
        and compile count recorded separately);
      * the steady eval-only rate (comparable to the paper's 0.17M
        designs/s DSE rate) and the device count the pipeline striped
        over;
      * a paper-scale joint ``co_search`` sweep (mapping x hardware cross
        product through the fused device-resident reduction): >= 10M
        designs in full mode.

    The universal-evaluator compile count must stay O(1) per (layer,
    level-count, batch shape) — ``compile_budget`` in the JSON is the
    closed-form bound CI asserts against.

    Writes ``BENCH_mapspace.json`` both under ``benchmarks/out`` (CI
    artifact) and at the REPO ROOT (perf trajectory tracker)."""
    import json
    import jax
    from repro.core.dse import DSEConfig
    from repro.mapspace import build_space, co_search, measure_rate, search
    from repro.mapspace.universal import compile_count
    t0 = time.perf_counter()
    vgg = [l for l in zoo.vgg16() if l.op_type == "CONV2D"]
    conv13 = vgg[-1]
    # the PR-2 headline space: 72 (spatial x perm x cluster) groups
    space13 = build_space(conv13, dims=("K", "C", "X"), perm_mode="all",
                          cluster_sizes=(32, 64))
    if quick:
        layers = [conv13]
        mk_space = lambda l: build_space(l, dims=("K", "C"), cluster=False)
        budget, sweep_budget = 200, 400
        cfg = DSEConfig(pe_range=(64, 128, 256),
                        bw_range=(8.0, 16.0, 32.0))
        joint_genes = 32
    else:
        rn = [l for l in zoo.resnet50() if l.op_type == "CONV2D"]
        layers = [vgg[1], conv13, rn[len(rn) // 2]]
        # the auto space: all searchable dims spatial-eligible — early
        # layers need the Y/X spatial maps to beat Table 3 (the old
        # K/C-only recipe capped conv2 below the best fixed dataflow)
        mk_space = build_space
        budget, sweep_budget = 600, 5000
        cfg = DSEConfig()                       # 128 x 128 hardware grid
        joint_genes = 640                       # -> 10.5M joint designs
    compile_budget = 0
    rows = []
    min_imp = float("inf")
    n_eval = 0
    n_compiles = 0
    compile_s = 0.0
    c_before = compile_count()

    # --- per-layer search quality (gene pipeline) ---------------------
    for li, l in enumerate(layers):
        space = mk_space(l)
        r = search(l, objective="edp", budget=budget, space=space,
                   seed=0, num_pes=HW.num_pes, noc_bw=HW.noc_bw)
        compile_budget += 2
        n_eval += r.n_evaluated
        n_compiles += r.n_compiles
        compile_s += r.compile_s
        best_t3 = min(float(analyze(l, table3_for_layer(f, l), HW).edp)
                      for f in FLOWS)
        imp = best_t3 / r.best_value
        min_imp = min(min_imp, imp)
        rows.append([l.name, space.size, space.n_groups, r.strategy,
                     r.n_evaluated, r.n_compiles, r.best_value, best_t3,
                     imp])
    _csv("mapspace_search.csv",
         ["layer", "space_size", "n_groups", "strategy", "evaluated",
          "compiles", "best_edp", "best_table3_edp", "improvement"], rows)

    # --- headline: search(budget) e2e rate, gene vs legacy baseline ---
    kw = dict(objective="edp", budget=sweep_budget, space=space13,
              num_pes=HW.num_pes, noc_bw=HW.noc_bw, strategy="random",
              block=1024)
    cold = search(conv13, pipeline="gene", seed=0, **kw)
    compile_budget += 2
    warm = search(conv13, pipeline="gene", seed=1, **kw)
    legacy = search(conv13, pipeline="legacy", seed=0, **kw)  # compile
    compile_budget += 2
    legacy = search(conv13, pipeline="legacy", seed=1, **kw)  # warm
    n_eval += cold.n_evaluated + warm.n_evaluated \
        + 2 * legacy.n_evaluated
    n_compiles += cold.n_compiles
    compile_s += cold.compile_s
    e2e = warm.end_to_end_mappings_per_s
    e2e_legacy = legacy.end_to_end_mappings_per_s
    speedup = e2e / max(e2e_legacy, 1e-9)

    # --- checkpoint overhead on the headline warm search --------------
    # Same seed as `warm`, warm executables, sweep checkpointing on: the
    # resumable-sweep machinery must cost <= 5% of headline wall time
    # (CI asserts checkpoint_overhead_frac from this block).  The robust
    # estimate is time-spent-saving / checkpointed wall — the paired
    # wall delta is recorded too but is noisier than 5% on small runs.
    import shutil
    import tempfile
    from repro import obs as _obs
    met = _obs.metrics()
    ck_kw = dict(kw)
    if quick:
        # the quick sweep's warm wall (~20 ms) is smaller than a couple
        # of checkpoint commits — measure the <= 5% contract on a run
        # long enough for the ratio to be signal (still warm-executable,
        # so this only adds eval time)
        ck_kw["budget"] = 4000
        base = search(conv13, pipeline="gene", seed=1, **ck_kw)
        n_eval += base.n_evaluated
    else:
        base = warm
    ck_s0 = met.value("resilience.checkpoint_save_s")
    ck_n0 = met.value("resilience.checkpoint_saves")
    ckdir = tempfile.mkdtemp(prefix="bench-ckpt-")
    try:
        ck = search(conv13, pipeline="gene", seed=1, ckpt_dir=ckdir,
                    **ck_kw)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    n_eval += ck.n_evaluated
    ck_save_s = met.value("resilience.checkpoint_save_s") - ck_s0
    ckpt_overhead = ck_save_s / max(ck.elapsed_s, 1e-9)
    checkpoint = {
        "saves": int(met.value("resilience.checkpoint_saves") - ck_n0),
        "save_s": round(ck_save_s, 4),
        "baseline_wall_s": round(base.elapsed_s, 3),
        "ckpt_wall_s": round(ck.elapsed_s, 3),
        "wall_overhead_frac": round(
            max(0.0, ck.elapsed_s - base.elapsed_s)
            / max(base.elapsed_s, 1e-9), 4),
        "deterministic": bool(ck.best_value == base.best_value
                              and tuple(ck.best_point)
                              == tuple(base.best_point)),
    }

    # --- observability overhead on the headline warm search -----------
    # The tracing/metrics spine must cost <= 1% of headline wall when ON
    # (CI asserts obs_overhead_frac).  Robust estimate: enabled per-span
    # cost (microbenchmark) x events a traced identical run emits, over
    # the UNtraced wall — the paired wall delta is noisier than 1%.
    n_cal = 20_000
    t_cal0 = time.perf_counter()
    for _ in range(n_cal):
        with _obs.span("bench-cal"):
            pass
    disabled_span_s = (time.perf_counter() - t_cal0) / n_cal
    tr = _obs.enable_tracing()
    try:
        t_cal0 = time.perf_counter()
        for _ in range(n_cal):
            with _obs.span("bench-cal"):
                pass
        traced_span_s = (time.perf_counter() - t_cal0) / n_cal
        ev0 = len(tr.events())
        traced = search(conv13, pipeline="gene", seed=1, **ck_kw)
        n_events = len(tr.events()) - ev0
    finally:
        _obs.disable_tracing()
    n_eval += traced.n_evaluated
    obs_overhead = traced_span_s * n_events / max(base.elapsed_s, 1e-9)
    obs_cost = {
        "disabled_span_ns": round(disabled_span_s * 1e9, 1),
        "traced_span_ns": round(traced_span_s * 1e9, 1),
        "trace_events": n_events,
        "baseline_wall_s": round(base.elapsed_s, 3),
        "traced_wall_s": round(traced.elapsed_s, 3),
        "deterministic": bool(traced.best_value == base.best_value
                              and tuple(traced.best_point)
                              == tuple(base.best_point)),
    }

    # --- steady eval-only rate over mixed-structure rows --------------
    rate = measure_rate(conv13, space13, num_pes=HW.num_pes,
                        noc_bw=HW.noc_bw, seconds=1.5)
    compile_budget += 2

    # --- paper-scale joint mapping x hardware co-DSE sweep ------------
    co = co_search(conv13, objective="edp", mapping_budget=budget,
                   top_k=4, cfg=cfg, num_pes=HW.num_pes,
                   noc_bw=HW.noc_bw, space=space13,
                   joint_genes=joint_genes,
                   joint_block=1024 if quick else 8192,
                   search_kwargs={"block": 1024})
    compile_budget += 2 + 2 * len(co.dse)   # joint sweep + top-k grids
    n_compiles += co.n_compiles
    joint = co.joint

    elapsed = time.perf_counter() - t0

    # trace-only audit of every executable family: the primitive counts
    # sit next to the compile budget so CI gates BOTH compile count and
    # traced program size from the same artifact (zero compiles, so it
    # cannot perturb universal_compiles_process)
    from repro.analysis import jaxpr_audit
    audit_findings, audit_report = jaxpr_audit.audit((1,))
    payload = {
        "quick": quick,
        "layers": [l.name for l in layers],
        "n_evaluated": n_eval,
        "n_compiles": n_compiles,
        "universal_compiles_process": compile_count() - c_before,
        "compile_budget": compile_budget,
        "jaxpr_primitive_counts": audit_report["primitive_counts"],
        "jaxpr_primitive_budget": audit_report["primitive_budget"],
        "jaxpr_findings": [f.to_json() for f in audit_findings],
        "compile_s": round(compile_s, 3),
        "elapsed_s": round(elapsed, 3),
        "n_devices": jax.local_device_count(),
        "search_budget": sweep_budget,
        "end_to_end_mappings_per_s": e2e,
        "legacy_end_to_end_mappings_per_s": e2e_legacy,
        "e2e_speedup_vs_legacy": round(speedup, 2),
        "cold_wall_s": round(cold.elapsed_s, 3),
        "checkpoint_overhead_frac": round(ckpt_overhead, 4),
        "checkpoint": checkpoint,
        "obs_overhead_frac": round(obs_overhead, 5),
        "obs": obs_cost,
        "steady_rate_mappings_per_s": rate,
        "min_improvement_vs_table3": min_imp,
        "joint_sweep": None if joint is None else {
            "n_designs": joint.n_designs,
            "n_mappings": joint.n_mappings,
            "n_hw": joint.n_hw,
            "n_valid": joint.n_valid,
            "designs_per_s": joint.designs_per_s,
            "elapsed_s": round(joint.elapsed_s, 3),
            "n_compiles": joint.n_compiles,
            "frontier_points": len(joint.pareto),
            "n_devices": joint.n_devices,
        },
    }
    _write_bench("mapspace", payload)
    us = elapsed / max(n_eval, 1) * 1e6
    _emit("mapspace", us,
          f"e2e={e2e / 1e6:.2f}M/s;legacy_e2e={e2e_legacy / 1e6:.3f}M/s;"
          f"speedup={speedup:.1f}x;eval_rate={rate / 1e6:.2f}M/s;"
          f"paper=0.17M/s;"
          f"joint={0 if joint is None else joint.n_designs}designs"
          f"@{0 if joint is None else joint.designs_per_s / 1e6:.2f}M/s;"
          f"compiles={payload['universal_compiles_process']};"
          f"min_improvement_vs_table3={min_imp:.2f}x")


def bench_netspace(quick: bool) -> None:
    """Whole-network, fusion-aware schedule search (repro.netspace):

      * the HEADLINE: an end-to-end VGG16 schedule (16 layers — 13 convs
        + 3 FCs, 12 unique shapes, 2 op-classes) searched in a single
        process with <= 2 XLA compiles per (op-class, level-count), whose
        network EDP beats the best single uniform Table-3 dataflow
        applied network-wide (same cost model, off-chip boundary terms
        included for both);
      * the fusion ablation: the same frontiers re-composed with fusion
        forbidden, isolating what DeFiNES-style fused stacks buy;
      * composer throughput (partial-schedule extensions/s) and the
        evaluator's candidate rows/s.

    Writes ``BENCH_netspace.json`` under ``benchmarks/out`` (CI artifact)
    and at the REPO ROOT (perf trajectory tracker); CI asserts the
    compile budget and the EDP win."""
    import json
    import jax
    from repro.core.performance import HWConfig
    from repro.mapspace.universal import compile_count
    from repro.netspace import (best_uniform, compose_dp, search_network,
                                uniform_baseline)
    t0 = time.perf_counter()
    layers = zoo.vgg16()
    budget = 128 if quick else 512
    frontier_k = 4 if quick else 8
    hw = HWConfig(num_pes=int(HW.num_pes), noc_bw=HW.noc_bw,
                  noc_latency=2.0, reconfig_latency=1000.0)
    c_before = compile_count()
    r = search_network(layers, objective="edp", budget=budget,
                       num_pes=int(HW.num_pes), noc_bw=HW.noc_bw,
                       seed=0, frontier_k=frontier_k, fuse=True, hw=hw)
    compiles = compile_count() - c_before
    compile_budget = 2 * r.n_classes      # 1- + 2-level family per class

    base = uniform_baseline(layers, r.model)
    flow, b = best_uniform(base, "edp")
    edp_win = b["edp"] / r.schedule.network_edp

    # fusion ablation: identical frontiers/cost model, fusion forbidden
    frontiers = [r.frontiers[r.netspace.index[i]]
                 for i in range(r.n_layers)]
    out_vols = [float(op.output.volume(op.dims)) for op in layers]
    no_fuse, _ = compose_dp(frontiers, out_vols,
                            [False] * (r.n_layers - 1), r.model,
                            [op.name for op in layers],
                            r.schedule.total_macs)
    fusion_gain = no_fuse.network_edp / r.schedule.network_edp

    elapsed = time.perf_counter() - t0
    payload = {
        "quick": quick,
        "model": "vgg16",
        "n_layers": r.n_layers,
        "n_unique_shapes": r.n_unique,
        "n_op_classes": r.n_classes,
        "budget_per_layer": budget,
        "frontier_k": frontier_k,
        "n_evaluated": r.n_evaluated,
        "n_compiles": compiles,
        "universal_compiles_process": compiles,
        "compile_budget": compile_budget,
        "compile_s": round(r.compile_s, 3),
        "eval_s": round(r.eval_s, 3),
        "compose_s": round(r.compose_s, 3),
        "schedules_per_s": r.schedules_per_s,
        "n_devices": jax.local_device_count(),
        "network_edp": r.schedule.network_edp,
        "network_runtime": r.schedule.runtime,
        "network_energy_pj": r.schedule.energy_pj,
        "n_fused_stacks": len(r.schedule.segments),
        "n_reconfigs": r.schedule.n_reconfigs,
        "best_uniform_flow": flow,
        "best_uniform_edp": b["edp"],
        "edp_win_vs_best_uniform": edp_win,
        "no_fusion_edp": no_fuse.network_edp,
        "fusion_edp_gain": fusion_gain,
        "elapsed_s": round(elapsed, 3),
    }
    _write_bench("netspace", payload)
    us = elapsed / max(r.n_evaluated, 1) * 1e6
    _emit("netspace", us,
          f"edp_win_vs_uniform={edp_win:.2f}x;"
          f"fusion_gain={fusion_gain:.2f}x;"
          f"compiles={compiles}/{compile_budget};"
          f"stacks={len(r.schedule.segments)};"
          f"sched_exts_per_s={r.schedules_per_s / 1e3:.0f}k")


def bench_api(quick: bool) -> None:
    """The declarative front door (repro.api) and its HEADLINE number:
    ``Session.run_many`` on a mixed batch of >= 6 heterogeneous layer
    queries (conv + GEMM classes, different shapes, objectives AND fixed
    hardware points) must

      * compile at most ONE executable per unique (op-class,
        level-count) family — the coalesced gene-tensor pass through the
        shape-as-operand executables, vs 2 compiles per DISTINCT layer
        on the sequential path; and
      * beat sequential per-query ``mapspace.search()`` wall time by
        >= 2x (both paths cold: the query layers are unique to this
        bench, so neither side reuses earlier benches' executables);

    plus the coalesced-vs-sequential determinism check (same family
    spaces, per-query passes) riding the already-warm executables.

    Writes ``BENCH_api.json`` (repo root + benchmarks/out) through
    ``Report.to_json()``; ci.sh asserts the compile budget, the speedup
    and determinism."""
    import jax
    from repro.api import Hardware, Query, SearchSpec, Session, Workload
    from repro.mapspace import search
    from repro.mapspace.universal import compile_count
    t0 = time.perf_counter()
    budget = 96 if quick else 512
    block = 128 if quick else 1024
    sc = 1 if quick else 2
    ops = [
        ta.conv2d("api-conv1", k=16 * sc, c=8 * sc, y=16, x=16, r=3, s=3),
        ta.conv2d("api-conv2", k=8 * sc, c=16 * sc, y=12, x=12, r=3, s=3),
        ta.conv2d("api-conv3", k=12 * sc, c=12 * sc, y=20, x=20, r=3,
                  s=3),
        ta.conv2d("api-conv4", k=24 * sc, c=4 * sc, y=10, x=10, r=3, s=3),
        ta.gemm("api-gemm1", m=16, n=64 * sc, k=32 * sc),
        ta.fc("api-fc1", k=48 * sc, c=64 * sc),
    ]
    objectives = ["edp", "runtime", "energy", "edp", "energy", "edp"]
    queries = [
        Query(Workload.of_layer(op),
              Hardware(num_pes=64 + 32 * (i % 3),
                       noc_bw=8.0 * (1 + i % 2)),
              SearchSpec(objective=objectives[i], budget=budget,
                         strategy="random", block=block, top_k=4))
        for i, op in enumerate(ops)]

    session = Session()
    c0 = compile_count()
    t = time.perf_counter()
    reports = session.run_many(queries)
    batch_wall = time.perf_counter() - t
    batch = dict(session.last_batch)
    batch_compiles = compile_count() - c0

    # determinism oracle: per-query passes through the SAME family
    # spaces (warm executables) must reproduce the coalesced answers
    reports_seq = session.run_many(queries, coalesce=False)
    deterministic = all(a.results_json() == b.results_json()
                        for a, b in zip(reports, reports_seq))

    # the old way: sequential per-query search() — per-op executables,
    # cold (these layer shapes appear nowhere else in the bench suite)
    c1 = compile_count()
    t = time.perf_counter()
    seq_compile_s = 0.0
    for q, op in zip(queries, ops):
        r = search(op, objective=q.search.objective, budget=budget,
                   num_pes=q.hardware.num_pes, noc_bw=q.hardware.noc_bw,
                   strategy="random", seed=0, block=block, top_k=4)
        seq_compile_s += r.compile_s
    seq_wall = time.perf_counter() - t
    seq_compiles = compile_count() - c1
    speedup = seq_wall / max(batch_wall, 1e-9)

    payload = {
        "quick": quick,
        "n_queries": len(queries),
        "n_evaluated": sum(r.n_evaluated for r in reports),
        "n_families": batch["n_families"],
        "compile_budget": batch["compile_budget"],
        "n_compiles": batch_compiles,
        "compile_s": batch["compile_s"],
        "batch_wall_s": round(batch_wall, 3),
        "coalesced_deterministic": deterministic,
        "sequential_search_wall_s": round(seq_wall, 3),
        "sequential_search_compiles": seq_compiles,
        "sequential_search_compile_s": round(seq_compile_s, 3),
        "run_many_speedup_vs_sequential_search": round(speedup, 2),
        "n_devices": jax.local_device_count(),
        "elapsed_s": round(time.perf_counter() - t0, 3),
    }
    _write_bench("api", payload)
    us = (time.perf_counter() - t0) / max(len(queries), 1) * 1e6
    _emit("api", us,
          f"speedup_vs_sequential={speedup:.1f}x;"
          f"compiles={batch_compiles}/{batch['n_families']}families;"
          f"seq_compiles={seq_compiles};deterministic={deterministic}")


def bench_serve(quick: bool) -> None:
    """The serving tier under concurrent load: an in-process
    ``DSEServer`` (ephemeral port, coalescing on) driven by the stdlib
    load generator at 10 and — full mode — 100 and 1000 concurrent
    clients, all posting the coalescible ``examples/queries.json``
    layer queries round-robin.

    Headline numbers per client count: request p50/p99 latency and
    sustained queries/s, plus the terminal-status accounting (every
    request must end in a report or an explicit shed — zero transport
    errors, zero hangs) and the server-side counter invariant
    ``serve.shed + serve.completed == serve.admitted``.

    Writes ``BENCH_serve.json`` (repo root + benchmarks/out) through
    ``Report.bench``; ci.sh asserts terminal accounting and the
    invariant."""
    import asyncio
    import json as _json

    from repro.api import Session
    from repro.serve import DSEServer, ServeConfig, run_loadgen

    t0 = time.perf_counter()
    qpath = os.path.join(os.path.dirname(__file__), os.pardir,
                         "examples", "queries.json")
    with open(qpath) as f:
        wire = [q for q in _json.load(f)["queries"]
                if "op" in q.get("workload", {})]   # coalescible layers
    client_counts = [10] if quick else [10, 100, 1000]
    if not quick:
        # the 1000-client tier holds ~1000 sockets open on each side of
        # the loopback (connection-per-request clients + server) — raise
        # the soft fd limit up front so the tier measures the server,
        # not the harness's default ulimit
        import resource
        soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
        want = 8192 if hard == resource.RLIM_INFINITY \
            else min(8192, hard)
        if soft < want:
            resource.setrlimit(resource.RLIMIT_NOFILE, (want, hard))

    async def drive() -> dict:
        session = Session()
        # queue bound sized for the largest client wave: the tier
        # measures latency under load, not shed behaviour (shed
        # correctness is ci.sh/test_serve territory)
        server = DSEServer(session, ServeConfig(
            port=0, exit_on_kill=False,
            max_queue=max(256, 4 * max(client_counts)), max_batch=64,
            flush_interval_s=0.05, default_deadline_s=300.0))
        await server.start()
        out: dict = {}
        try:
            for clients in client_counts:
                res = await run_loadgen(
                    "127.0.0.1", server.port, wire, clients=clients,
                    requests_per_client=4, timeout=300.0)
                s = res.summary()
                s["all_terminal"] = (res.transport_errors == 0
                                     and res.n_terminal
                                     == res.n_requests)
                out[f"clients_{clients}"] = s
            c = server.metrics()["counters"]
            out["counters"] = {
                k: c[k] for k in sorted(c)
                if k.startswith("serve.") and "[" not in k}
            out["invariant_holds"] = (
                c.get("serve.shed", 0.0) + c.get("serve.completed", 0.0)
                == c.get("serve.admitted", 0.0))
        finally:
            await server.stop()
        return out

    payload = asyncio.run(drive())
    payload["quick"] = quick
    payload["n_query_kinds"] = len(wire)
    payload["elapsed_s"] = round(time.perf_counter() - t0, 3)
    _write_bench("serve", payload)
    head = payload[f"clients_{client_counts[-1]}"]
    us = head["p50_s"] * 1e6
    _emit("serve", us,
          f"clients={client_counts[-1]};p99_s={head['p99_s']};"
          f"qps={head['queries_per_s']};"
          f"all_terminal={head['all_terminal']};"
          f"invariant={payload['invariant_holds']}")


def bench_kernels(quick: bool) -> None:
    """Interpret-mode kernel validation timings (correctness gate)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import attention_ref, flash_attention
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(key, (1, 256, 2, 64))
    v = jax.random.normal(key, (1, 256, 2, 64))
    t0 = time.perf_counter()
    out = flash_attention(q, k, v, interpret=True)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(out - attention_ref(q, k, v))))
    _emit("kernel_flash_attention", us, f"max_err={err:.1e}")


BENCHES = [bench_fig9_validation, bench_fig10_tradeoffs,
           bench_fig11_reuse_bw, bench_fig12_energy_breakdown,
           bench_fig13_dse, bench_dse_rate, bench_mapspace,
           bench_netspace, bench_api, bench_serve, bench_kernels]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for b in BENCHES:
        if args.only and args.only not in b.__name__:
            continue
        b(args.quick)


if __name__ == "__main__":
    main()
