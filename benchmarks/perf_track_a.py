"""§Perf-A: DSE-rate hypothesis→change→measure log (the paper's own
headline metric: 0.17M designs/s on a desktop CPU).

Runs every iteration of the hillclimb and prints the log table.  Each
iteration states its hypothesis; the measurement confirms or refutes it.

    PYTHONPATH=src python -m benchmarks.perf_track_a [--n 1000000]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import tensor_analysis as ta
from repro.core.dataflows import table3_for_layer
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.core.vectorized import batched_evaluator

OP = ta.conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)
DF = table3_for_layer("KC-P", OP)


def measure(fn, pes, bws, reps=3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(pes, bws)
        best = min(best, time.perf_counter() - t0)
    return len(pes) / best


def iter0_faithful_loop(n: int) -> float:
    """Baseline: paper-faithful per-design evaluation (python loop over
    the exact engine — the reproduction of the paper's C++ tool's
    semantics)."""
    rng = np.random.default_rng(0)
    pes = rng.integers(2, 1024, n)
    bws = rng.uniform(1, 128, n)

    def run(p, b):
        for i in range(len(p)):
            analyze(OP, DF, HWConfig(num_pes=int(p[i]), noc_bw=float(b[i]),
                                     noc_latency=2.0))
    t0 = time.perf_counter()
    run(pes, bws)
    return n / (time.perf_counter() - t0)


def iterN_vectorized(n: int, block: int) -> float:
    """jit+vmap closed form, evaluated in ``block``-sized chunks."""
    f = batched_evaluator(OP, DF)
    rng = np.random.default_rng(0)
    pes = jnp.asarray(rng.integers(2, 1024, block))
    bws = jnp.asarray(rng.uniform(1, 128, block).astype(np.float32))
    f(pes, bws).block_until_ready()      # compile + warm
    reps = max(1, n // block)
    t0 = time.perf_counter()
    for _ in range(reps):
        f(pes, bws).block_until_ready()
    return reps * block / (time.perf_counter() - t0)


def iter_pallas_interpret(n: int) -> float:
    """The maestro_eval kernel (TPU artifact) in interpret mode on a
    single-level dataflow — correctness demo, not a CPU speed claim."""
    from repro.kernels.maestro_eval import build_tables, maestro_eval
    op = OP
    df = table3_for_layer("C-P", op)
    T = build_tables(op, df)
    rng = np.random.default_rng(0)
    m = min(n, 65536)
    pes = jnp.asarray(rng.integers(2, 1024, m).astype(np.int32))
    bws = jnp.asarray(rng.uniform(1, 128, m).astype(np.float32))
    maestro_eval(pes, bws, tables=T, interpret=True).block_until_ready()
    t0 = time.perf_counter()
    maestro_eval(pes, bws, tables=T, interpret=True).block_until_ready()
    return m / (time.perf_counter() - t0)


def iter_ref_closed_form(n: int, block: int = 262144) -> float:
    """The kernel's closed form as plain jit'd jnp (single-level C-P):
    upper bound for what the TPU kernel's math costs per design."""
    from repro.kernels.maestro_eval import build_tables, maestro_eval_ref
    df = table3_for_layer("C-P", OP)
    T = build_tables(OP, df)
    f = jax.jit(lambda p, b: maestro_eval_ref(p, b, tables=T))
    rng = np.random.default_rng(0)
    pes = jnp.asarray(rng.integers(2, 1024, block).astype(np.int32))
    bws = jnp.asarray(rng.uniform(1, 128, block).astype(np.float32))
    f(pes, bws).block_until_ready()
    reps = max(1, n // block)
    t0 = time.perf_counter()
    for _ in range(reps):
        f(pes, bws).block_until_ready()
    return reps * block / (time.perf_counter() - t0)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--n-faithful", type=int, default=2_000)
    args = ap.parse_args(argv)

    print("== §Perf-A: DSE designs/second (paper: 0.17M/s) ==")
    r0 = iter0_faithful_loop(args.n_faithful)
    print(f"iter0 faithful python loop      : {r0 / 1e3:10.2f} K/s "
          f"(x{r0 / 0.17e6:.2f} of paper)")
    for block in (8192, 65536, 262144, 1048576):
        r = iterN_vectorized(args.n, block)
        print(f"iter1 jit+vmap block={block:>8d}  : {r / 1e6:10.2f} M/s "
              f"(x{r / 0.17e6:.1f} of paper)")
    r = iter_ref_closed_form(args.n)
    print(f"iter2 single-level closed form  : {r / 1e6:10.2f} M/s "
          f"(x{r / 0.17e6:.1f} of paper)  [C-P; kernel math]")
    r = iter_pallas_interpret(args.n)
    print(f"iter3 pallas interpret (CPU sim): {r / 1e3:10.2f} K/s "
          f"[correctness path only; TPU projection in EXPERIMENTS.md]")


if __name__ == "__main__":
    main()
