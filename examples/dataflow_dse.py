"""Hardware design-space exploration (paper §5.2 / Fig. 13 / Table 5).

Sweeps (#PEs × NoC bandwidth × tile variants) for KC-P and YR-P under the
Eyeriss area/power budget, reporting throughput-/energy-/EDP-optimal
designs and the pareto frontier.

    PYTHONPATH=src python examples/dataflow_dse.py [--quick]
"""
import argparse

import numpy as np

from repro.core import conv2d
from repro.core.dse import DSEConfig, merge_results, run_dse_full

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true")
args = ap.parse_args()

layer = conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)
step = 32 if args.quick else 8
cfg = DSEConfig(pe_range=tuple(range(8, 513, step)),
                bw_range=tuple(float(b) for b in range(2, 65, 2)))

for flow in ("KC-P", "YR-P"):
    results = run_dse_full(layer, flow, cfg,
                           scales=(1, 2) if args.quick else (1, 2, 4, 8))
    agg = merge_results(results)
    print(f"\n=== {flow}: {agg['n_evaluated']} designs evaluated, "
          f"{agg['n_valid']} valid, "
          f"{agg['rate_designs_per_s'] / 1e6:.2f}M designs/s "
          f"(paper: 0.17M/s) ===")
    for obj in ("throughput", "energy", "edp"):
        p = agg["best"][obj]
        if not p:
            continue
        print(f"  {obj:10s}: {p['num_pes']:4d} PEs, bw {p['noc_bw']:5.1f}, "
              f"L2 {p['l2_kb']:7.1f} KB, tile {p['tile_tag']}, "
              f"thr {p['throughput']:6.1f} MAC/cyc, "
              f"E {p['energy_pj'] / 1e9:7.2f} mJ, "
              f"{p['power_mw']:6.1f} mW, {p['area_mm2']:5.2f} mm2")
    # pareto frontier of the base-tile sweep
    front = results[0].pareto()
    print(f"  pareto frontier ({len(front)} points), first 5:")
    for i in front[:5]:
        pt = results[0].point(int(i))
        print(f"    pes={pt['num_pes']:4d} bw={pt['noc_bw']:5.1f} "
              f"thr={pt['throughput']:6.1f} E={pt['energy_pj']/1e9:7.2f}mJ")
