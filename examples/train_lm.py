"""End-to-end driver: train a ~100M-parameter llama-style model for a few
hundred steps on CPU with the full substrate (sharded-state AdamW,
deterministic pipeline, async checkpoints, fault-tolerant loop).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import batch_for_step
from repro.ft import FaultTolerantLoop, FTConfig
from repro.models import registry
from repro.models.param import count_params, init_params
from repro.optim import adamw
from repro.training import TrainConfig, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M llama-style config (deliverable b: train ~100M for a few hundred
# steps)
cfg = ModelConfig(
    name="llama-100m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=8192,
    norm="rms", mlp_type="swiglu", pos="rope", remat="none",
    dtype=jnp.float32, chunk_size=64,
)
print(f"params: {count_params(registry.specs(cfg)) / 1e6:.1f}M")

tc = TrainConfig(opt=adamw.AdamWConfig(
    lr=6e-4, warmup_steps=20, total_steps=args.steps, weight_decay=0.01))
params = init_params(registry.specs(cfg), jax.random.PRNGKey(0))
opt = adamw.init_state(params)
jstep = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))


def batch_fn(i):
    return {k: jnp.asarray(v) for k, v in batch_for_step(
        i, global_batch=args.batch, seq=args.seq, vocab=cfg.vocab).items()}


def wrapped(state, b):
    p, o = state
    p, o, m = jstep(p, o, b)
    return (p, o), m


losses = []
orig = wrapped


def logging_step(state, b):
    state, m = orig(state, b)
    losses.append(float(m["loss"]))
    i = len(losses)
    if i % 25 == 0 or i == 1:
        print(f"step {i:4d} loss {losses[-1]:.4f} "
              f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}")
    return state, m


loop = FaultTolerantLoop(
    logging_step, Checkpointer(args.ckpt, keep=2),
    FTConfig(checkpoint_every=100, async_save=True))
t0 = time.time()
(state, step) = loop.run((params, opt), batch_fn, 0, args.steps)
dt = time.time() - t0
first = np.mean(losses[:10])
last = np.mean(losses[-10:])
print(f"\n{args.steps} steps in {dt / 60:.1f} min "
      f"({args.batch * args.seq * args.steps / dt / 1e3:.1f}K tok/s)")
print(f"loss {first:.3f} -> {last:.3f} "
      f"({'LEARNED' if last < 0.8 * first else 'check hyperparams'})")
