"""Beyond-paper: the pod as a MAESTRO accelerator — DSE over pod size and
ICI bandwidth for an LM training GEMM.

The paper sweeps (#PEs, NoC bw) for a conv accelerator under an area
budget; here the identical engine sweeps (#chips, ICI bw per chip) for
llama3-8b's MLP GEMM at train_4k scale, with the KC-P-style tensor-
parallel dataflow (the Megatron mapping of DESIGN.md §2).  The knee of
the throughput-vs-chips curve is the scaling limit the roofline table
shows from the compiled side.

    PYTHONPATH=src python examples/pod_dse.py
"""
import numpy as np

from repro.core.directives import Cluster, Dataflow, SpatialMap, TemporalMap
from repro.core.mapper import V5E_ICI_BW, V5E_PEAK_FLOPS, gemm_op
from repro.core.vectorized import evaluate_grid

# llama3-8b MLP up-projection, one train_4k step's tokens
tokens, d, ff = 256 * 4096, 4096, 14336
op = gemm_op("llama3-mlp-up", m=tokens, n=ff, k=d)

# data parallel over chips at level 0 (4096-token tiles), tensor parallel
# (K-partitioned, 896 features/chip) inside 16-chip "clusters" (the model
# axis); contraction tiled at 512
df = Dataflow("dp-tp16", (
    SpatialMap(4096, 4096, "N"),
    TemporalMap(512, 512, "C"),
    Cluster(16),
    SpatialMap(896, 896, "K"),
))

macs_per_chip = int(V5E_PEAK_FLOPS / 2 / 1e9)  # MACs/cycle at 1 GHz
chips = np.array([16, 32, 64, 128, 256, 512, 1024], np.int64)
for ici_gbps in (25, 50, 100):
    bw_elems = ici_gbps * 1e9 / 1e9 / 2      # elements/cycle @1GHz bf16
    # float design points: pod-scale trip products overflow int32 in the
    # traced engine; float64-ish precision is ample for step estimates
    bs = evaluate_grid(op, df, chips.astype(np.float32),
                       np.full(len(chips), bw_elems, np.float32),
                       macs_per_pe=macs_per_chip)
    print(f"ICI {ici_gbps} GB/s/chip:")
    for i, c in enumerate(chips):
        cycles = float(bs.runtime[i])
        util = float(bs.util[i])
        eff = float(bs.macs[i]) / (cycles * c * macs_per_chip)
        print(f"  chips={c:5d}  step={cycles / 1e9 * 1e3:8.2f} ms "
              f"util={util:5.2f} scaling-eff={eff:5.1%}")
