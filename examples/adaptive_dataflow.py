"""Adaptive dataflow study (paper Fig. 10f): pick the best dataflow per
DNN operator and compare with the best fixed dataflow.

    PYTHONPATH=src python examples/adaptive_dataflow.py
"""
from repro.core import HWConfig, analyze
from repro.core.dataflows import table3_for_layer
from repro.core.dnn_models import MODELS, layer_class

HW = HWConfig(num_pes=256, noc_bw=32.0, noc_latency=2.0)
FLOWS = ("C-P", "X-P", "YX-P", "YR-P", "KC-P")
MODEL_SET = ("resnet50", "vgg16", "resnext50", "mobilenet_v2", "unet")

fixed_rt = {f: 0.0 for f in FLOWS}
fixed_en = {f: 0.0 for f in FLOWS}
ada_rt = ada_en = 0.0
choice_hist: dict[str, dict[str, int]] = {}

for m in MODEL_SET:
    for layer in MODELS[m]():
        stats = {f: analyze(layer, table3_for_layer(f, layer), HW)
                 for f in FLOWS}
        for f in FLOWS:
            fixed_rt[f] += stats[f].runtime
            fixed_en[f] += stats[f].energy_pj
        best = min(FLOWS, key=lambda f: stats[f].runtime)
        ada_rt += stats[best].runtime
        ada_en += min(stats[f].energy_pj for f in FLOWS)
        cls = layer_class(layer)
        choice_hist.setdefault(cls, {}).setdefault(best, 0)
        choice_hist[cls][best] += 1

best_f_rt = min(fixed_rt, key=fixed_rt.get)
best_f_en = min(fixed_en, key=fixed_en.get)
print(f"best fixed dataflow (runtime): {best_f_rt} "
      f"({fixed_rt[best_f_rt]:.3e} cycles)")
print(f"adaptive runtime: {ada_rt:.3e} cycles "
      f"-> {1 - ada_rt / fixed_rt[best_f_rt]:.1%} reduction "
      f"(paper: ~37%)")
print(f"best fixed dataflow (energy): {best_f_en} "
      f"({fixed_en[best_f_en]:.3e} pJ)")
print(f"adaptive energy: {ada_en:.3e} pJ "
      f"-> {1 - ada_en / fixed_en[best_f_en]:.1%} reduction (paper: ~10%)")
print("\npreferred dataflow by operator class (runtime):")
for cls, hist in sorted(choice_hist.items()):
    total = sum(hist.values())
    top = max(hist, key=hist.get)
    print(f"  {cls:10s}: {top:6s} ({hist[top]}/{total} layers)")
