"""Mapping-space auto-search walkthrough (repro.mapspace).

Three stages, mirroring how the paper's co-design story generalizes beyond
the five fixed Table 3 dataflows:

  1. define + search the mapping space of one VGG16 conv layer;
  2. compare the found mapping against every Table 3 dataflow;
  3. joint co-DSE: cross the winners with the hardware grid and print the
     merged Pareto frontier.

Run:  PYTHONPATH=src python examples/mapspace_search.py
"""
import numpy as np

from repro.core import tensor_analysis as ta
from repro.core.dataflows import TABLE3, table3_for_layer
from repro.core.dse import DSEConfig
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.mapspace import build_space, co_search, search

PES, BW = 256, 32.0

# VGG16 conv5-class layer (the paper's Fig. 12/13 workhorse).
op = ta.conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)

# ----------------------------------------------------------------------
# 1. Space definition + search.  The universal structure-as-operand
#    evaluator compiles at most twice (1-level + 2-level families), so
#    structure groups are free to explore — only the budget matters.
# ----------------------------------------------------------------------
space = build_space(op, dims=("K", "C", "X"), spatial_dims=("K", "C"),
                    perm_mode="rotations", cluster_sizes=(64,))
print(f"space: {space.size} legal mappings "
      f"({space.n_groups} structure groups)")

result = search(op, objective="edp", budget=600, space=space,
                num_pes=PES, noc_bw=BW, seed=0)
print(f"searched {result.n_evaluated} mappings "
      f"({result.strategy}; {result.mappings_per_s / 1e6:.2f}M mappings/s "
      f"steady-state, {result.n_compiles} XLA compiles / "
      f"{result.compile_s:.0f}s one-off jit)")
print(f"\nbest EDP = {result.best_value:.3e}")
print(result.best_dataflow)

# ----------------------------------------------------------------------
# 2. Table 3 comparison at the same hardware point.
# ----------------------------------------------------------------------
hw = HWConfig(num_pes=PES, noc_bw=BW, noc_latency=2.0)
print("\nTable 3 baselines:")
best_t3 = np.inf
for name in TABLE3:
    s = analyze(op, table3_for_layer(name, op), hw)
    print(f"  {name:5s} edp={float(s.edp):.3e}")
    best_t3 = min(best_t3, float(s.edp))
print(f"mapping search vs best Table 3: {best_t3 / result.best_value:.2f}x "
      f"better EDP")

# ----------------------------------------------------------------------
# 3. Joint mapping x hardware co-DSE on a coarse grid.
# ----------------------------------------------------------------------
cfg = DSEConfig(pe_range=tuple(range(64, 513, 64)),
                bw_range=(8.0, 16.0, 32.0, 64.0))
co = co_search(op, objective="edp", mapping_budget=600, top_k=3, cfg=cfg,
               num_pes=PES, noc_bw=BW, seed=0, space=space,
               include_table3=("KC-P",))
print(f"\nco-DSE: {co.n_evaluated} total designs; merged Pareto frontier:")
for p in co.pareto[:10]:
    print(f"  {p['mapping']:28s} pes={p['num_pes']:4d} bw={p['noc_bw']:5.1f}"
          f" energy={p['energy_pj']:.3e} thr={p['throughput']:.1f}")
print(f"best EDP design: {co.best['edp']['mapping']} "
      f"@ pes={co.best['edp']['num_pes']} bw={co.best['edp']['noc_bw']}")
