"""Quickstart: analyze one (layer × dataflow × hardware) with MAESTRO.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import HWConfig, analyze, conv2d
from repro.core.dataflows import table3_for_layer

# VGG16 conv11 — the paper's running example (Table 5 / Fig. 12)
layer = conv2d("vgg16-conv11", k=512, c=512, y=16, x=16, r=3, s=3)

# An Eyeriss-class accelerator: 256 PEs, 32 elements/cycle NoC
hw = HWConfig(num_pes=256, noc_bw=32.0, noc_latency=2.0)

print(f"layer {layer.name}: {layer.total_macs / 1e6:.0f}M MACs\n")
print(f"{'dataflow':8s} {'cycles':>12s} {'MACs/cyc':>9s} {'util':>6s} "
      f"{'energy(mJ)':>11s} {'L1KB':>6s} {'L2KB':>7s} {'bw req':>7s}")
for name in ("C-P", "X-P", "YX-P", "YR-P", "KC-P"):
    df = table3_for_layer(name, layer)
    s = analyze(layer, df, hw)
    print(f"{name:8s} {s.runtime:12.0f} {s.throughput:9.2f} "
          f"{s.utilization:6.2f} {s.energy_pj / 1e9:11.3f} "
          f"{s.l1_req_kb:6.2f} {s.l2_req_kb:7.1f} "
          f"{s.peak_bw.get(0, 0):7.1f}")

print("\nReuse classes at the top cluster level (KC-P):")
s = analyze(layer, table3_for_layer("KC-P", layer), hw)
for tensor, r in s.reuse[0].items():
    print(f"  {tensor}: spatial={r.spatial:10s} temporal={r.temporal}")
