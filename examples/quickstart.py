"""Quickstart: the declarative front door (``repro.api``).

One ``Query`` = workload x hardware x search spec; a ``Session`` routes
it to the right engine and answers in the unified ``Report`` schema.
Batches of heterogeneous queries coalesce into shared device passes.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Hardware, Query, SearchSpec, Session, Workload
from repro.core import HWConfig, analyze, conv2d
from repro.core.dataflows import table3_for_layer

# VGG16 conv11 at reduced channel counts (keeps the demo snappy) — the
# paper's running example shape (Table 5 / Fig. 12)
layer = conv2d("demo-conv11", k=64, c=64, y=16, x=16, r=3, s=3)

# -- the paper's fixed Table-3 dataflows, via the core analysis engine --
hw = HWConfig(num_pes=256, noc_bw=32.0, noc_latency=2.0)
print(f"layer {layer.name}: {layer.total_macs / 1e6:.0f}M MACs\n")
print(f"{'dataflow':8s} {'cycles':>12s} {'MACs/cyc':>9s} "
      f"{'energy(uJ)':>11s} {'L1KB':>6s} {'L2KB':>7s}")
for name in ("C-P", "X-P", "YX-P", "YR-P", "KC-P"):
    s = analyze(layer, table3_for_layer(name, layer), hw)
    print(f"{name:8s} {s.runtime:12.0f} {s.throughput:9.2f} "
          f"{s.energy_pj / 1e6:11.3f} {s.l1_req_kb:6.2f} "
          f"{s.l2_req_kb:7.1f}")

# -- the declarative front door: search the mapping space instead ------
session = Session()                     # owns caches + warm executables
query = Query(Workload.of_layer(layer),
              Hardware(num_pes=256, noc_bw=32.0),
              SearchSpec(objective="edp", budget=300, top_k=3))
report = session.run(query)
print(f"\nsearched {report.n_evaluated} mappings "
      f"({report.n_compiles} XLA compiles): "
      f"best EDP = {report.best['value']:.4g}")
print(report.raw.best_dataflow)

# -- batch mode: heterogeneous queries share family executables --------
batch = [
    Query(Workload.of_layer(
        conv2d("demo-early", k=32, c=16, y=32, x=32, r=3, s=3)),
        Hardware(num_pes=128, noc_bw=16.0),
        SearchSpec(objective="runtime", budget=200)),
    Query(Workload.of_layer(
        conv2d("demo-late", k=96, c=96, y=8, x=8, r=3, s=3)),
        Hardware(num_pes=256, noc_bw=32.0),
        SearchSpec(objective="edp", budget=200)),
]
reports = session.run_many(batch)       # ONE device pass per op-class
for rep in reports:
    print(f"{rep.name}: best {rep.objective} = "
          f"{rep.best['value']:.4g} (coalesced={rep.coalesced})")
print(f"batch stats: {session.last_batch}")

# every report serializes through one schema
print(f"\nreport JSON keys: {sorted(report.to_json())}")
