"""MAESTRO as a TPU sharding advisor (DESIGN.md §2): score candidate
directive programs for an LM's matmuls on the production mesh, predict
the collectives XLA will insert, and rank by the modeled step delay.

    PYTHONPATH=src python examples/sharding_advisor.py
"""
import jax

from repro.core.mapper import (analyze_tpu_mapping, contraction_tp,
                               fsdp_dp, gemm_op, megatron_tp)

mesh = jax.make_mesh((1,), ("model",))   # abstract: chips = PE count below

# llama3-8b MLP up-projection at train_4k per-step scale
tokens, d, ff = 256 * 4096, 4096, 14336
op = gemm_op("llama3-mlp-up", m=tokens, n=ff, k=d)

print(f"GEMM {op.name}: M={tokens} N={ff} K={d} "
      f"({op.total_macs / 1e12:.1f}T MACs)\n")
for mk in (megatron_tp, contraction_tp, fsdp_dp):
    df = mk(mesh)
    tm = analyze_tpu_mapping(df, op, mesh)
    print(f"{df.name:18s} collectives={tm.expected_collectives or '(none)'}")
    print(f"{'':18s} pspecs: lhs={tm.pspec_lhs} rhs={tm.pspec_rhs} "
          f"out={tm.pspec_out}")
print("\nTable-1 reading: K-partitioned = Megatron TP (input multicast "
      "= all-gather);\nC-partitioned = contraction sharding (output "
      "reduction = psum);\nN-partitioned = DP/FSDP (weight multicast "
      "forward, gradient reduction backward).")
