#!/usr/bin/env bash
# Smoke gate: quick tier-1 subset + quick benchmarks + sharded smoke.
# Full tier-1 is `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 quick subset =="
python -m pytest -x -q \
    tests/test_directives.py \
    tests/test_reuse.py \
    tests/test_engine.py \
    tests/test_mapper.py \
    tests/test_mapspace.py \
    tests/test_universal.py \
    tests/test_genes.py \
    tests/test_netspace.py \
    tests/test_api.py \
    tests/test_obs.py \
    tests/test_resilience.py \
    tests/test_serve.py \
    tests/test_analysis.py

echo "== static analysis gate: repro.launch.lint =="
# Zero-findings gate: the jaxpr auditor (f64/widen/callback/weak-type/
# const-fold/donation/primitive-budget over every universal-executable
# family), the concurrency linter, and the dataflow/spec linter must all
# come back clean modulo the checked-in waivers — and every waiver must
# still match something (unused waivers fail the gate too).
python -m repro.launch.lint --json --out benchmarks/out/lint_findings.json

echo "== 4-host-device sharded smoke =="
# The gene pipeline stripes chunks over all local devices; forcing four
# host CPU devices exercises the pmap path and the 1-vs-N-device
# determinism assertions inside tests/test_genes.py, tests/test_netspace.py
# and tests/test_api.py (coalesced run_many) for real.
# tests/test_resilience.py rides along so kill-and-resume bit-identity
# is asserted at 4 devices too (its kill/resume test parametrizes over
# the available device count).  tests/test_analysis.py rides along so
# the jaxpr auditor's shipped-families-clean assertion runs against the
# real pmap executables (1 AND 4 devices), not just the jit path.
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_genes.py tests/test_netspace.py \
    tests/test_api.py tests/test_resilience.py tests/test_analysis.py

echo "== small-budget netsearch smoke =="
# End-to-end network schedule search through the CLI shim: VGG16 at a
# tiny budget must complete with the shape-as-operand executables and
# print a schedule + baseline comparison.
python -m repro.launch.netsearch --model vgg16 --quick --jax-cache-dir ''

echo "== declarative batch front door (--file) smoke =="
# Serving-style mixed batch through repro.launch.query: 4 coalescible
# layer queries (conv + GEMM classes, heterogeneous objectives AND fixed
# hardware points), one adaptive-budget network query, one hardware-grid
# co-DSE query.  Runs with --trace + --metrics, so the compile and
# cache budgets below are asserted from the STRUCTURED obs snapshot
# embedded in the --out payload (not grepped from stdout), and the
# Chrome trace_event timeline is validated and uploaded as a CI
# artifact.
python -m repro.launch.query --file examples/queries.json \
    --out benchmarks/out/api_batch_smoke.json \
    --trace benchmarks/out/api_batch_trace.json --metrics \
    --cache-dir '' --jax-cache-dir ''
python - <<'EOF'
import json
d = json.load(open("benchmarks/out/api_batch_smoke.json"))
b = d["batch"]
print(json.dumps(b, indent=2))
assert b["n_queries"] == 6, b
# the 4 layer queries coalesce; network + grid queries route to their
# engines
assert b["n_coalesced"] == 4, b
assert b["n_families"] <= 4, b
assert b["n_compiles"] <= b["compile_budget"], b
kinds = [r["kind"] for r in d["reports"]]
assert kinds.count("layer") == 4, kinds
assert "network" in kinds and "layer_codse" in kinds, kinds
assert all(r["schema_version"] == 2 for r in d["reports"])

# --- obs metrics snapshot: the budget asserts read ONE structured
# payload now ------------------------------------------------------
m = d["metrics"]
c = m["counters"]
assert m["schema_version"] == 1, m["schema_version"]
fam = {k: v for k, v in c.items()
       if k.startswith("universal.compiles_by_family[")}
# single-writer parity: the process total == the per-family sum
assert c["universal.compiles"] == sum(fam.values()), (c, fam)
# the 4 coalesced families (conv + gemm class reps x 1/2 levels)
# compiled EXACTLY once each — the coalescing headline, asserted
# per family instead of as one opaque total
for f in ("q-conv1:L1", "q-conv1:L2", "q-gemm1:L1", "q-gemm1:L2"):
    k = f"universal.compiles_by_family[family={f}]"
    assert fam.get(k) == 1, (k, fam)
assert c["session.queries"] == 6, c
assert c["session.queries_by_kind[kind=layer_coalesced]"] == 4, c
# environment provenance rides with every payload
assert d["environment"]["backend"], d.get("environment")

# --- the trace renders the whole batch as a timeline ---------------
t = json.load(open("benchmarks/out/api_batch_trace.json"))
evs = t["traceEvents"]
assert evs and t["displayTimeUnit"] == "ms", "empty/invalid trace"
names = {e["name"] for e in evs}
for want in ("run_many", "coalesce", "encode", "compile",
             "device-pass", "topk-merge", "compose", "query"):
    assert want in names, (want, sorted(names))
n_compile_spans = sum(e["name"] == "compile" for e in evs)
assert n_compile_spans == b["n_compiles"], \
    (n_compile_spans, b["n_compiles"],
     "one compile span per actual XLA compile")
print(f"trace OK: {len(evs)} events, {n_compile_spans} compile spans")
EOF

echo "== fault-injection kill/resume smoke =="
# The resilience headline, end to end through the CLI: a batch run is
# killed mid-chunk by deterministic fault injection, the re-launch with
# the same flags resumes from the sweep checkpoint, and the resumed
# reports are BIT-IDENTICAL to an undisturbed reference run.  The
# resilience.* recovery counters are asserted from the structured --out
# payload, not grepped from logs.
RES_OUT=benchmarks/out
RES_CKPT="$RES_OUT/resilience_ckpt"
rm -rf "$RES_CKPT"
mkdir -p "$RES_OUT"
cat > "$RES_OUT/resilience_queries.json" <<'EOF'
[
  {"workload": {"op": {"type": "conv2d", "name": "r-conv1",
                       "k": 8, "c": 6, "y": 12, "x": 12, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"budget": 96, "block": 32, "strategy": "random", "seed": 3}},
  {"workload": {"op": {"type": "conv2d", "name": "r-conv2",
                       "k": 16, "c": 8, "y": 10, "x": 10, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"budget": 64, "block": 32, "strategy": "random", "seed": 1}}
]
EOF
python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --out "$RES_OUT/resilience_ref.json" --cache-dir '' --jax-cache-dir ''
if python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --checkpoint-dir "$RES_CKPT" --faults kill@chunk:1 \
    --cache-dir '' --jax-cache-dir '' 2> "$RES_OUT/resilience_kill.log"
then
    echo "FAIL: injected kill@chunk:1 did not kill the sweep"
    exit 1
fi
grep -q SweepKilled "$RES_OUT/resilience_kill.log"
ls "$RES_CKPT"/sweep-batch-*.npz > /dev/null   # checkpoint survived
python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --checkpoint-dir "$RES_CKPT" \
    --out "$RES_OUT/resilience_resumed.json" --cache-dir '' \
    --jax-cache-dir ''
python - <<'EOF'
import json
DET = ("kind", "name", "objective", "strategy", "best", "top_k",
       "pareto", "n_evaluated")
ref = json.load(open("benchmarks/out/resilience_ref.json"))
res = json.load(open("benchmarks/out/resilience_resumed.json"))
for a, b in zip(ref["reports"], res["reports"]):
    for k in DET:
        assert a.get(k) == b.get(k), (k, a.get(k), b.get(k))
c = res["metrics"]["counters"]
assert c.get("resilience.checkpoint_resumes", 0) >= 1, c
assert c.get("resilience.checkpoint_saves", 0) >= 1, c
print("kill/resume bit-identical across process restarts; "
      f"resumes={c['resilience.checkpoint_resumes']}")
EOF
# a completed sweep clears its checkpoint
if ls "$RES_CKPT"/sweep-*.npz 2>/dev/null; then
    echo "FAIL: checkpoint not cleared after completed resume"
    exit 1
fi

echo "== DSE serving smoke: loadgen + counter invariant =="
# The serving headline, end to end through the CLIs: a real
# repro.launch.serve process on a free port absorbs a 10-client load
# burst; EVERY request must reach a terminal status, request p99 must
# stay under the server deadline, and the admission ledger must balance
# (serve.shed + serve.completed == serve.admitted) — all asserted from
# the STRUCTURED /metricsz snapshot the loadgen appends, not from logs.
SERVE_OUT=benchmarks/out
SERVE_CKPT="$SERVE_OUT/serve_ckpt"
rm -rf "$SERVE_CKPT"
mkdir -p "$SERVE_OUT"
cat > "$SERVE_OUT/serve_queries.json" <<'EOF'
[
  {"tag": "s-a",
   "workload": {"op": {"type": "conv2d", "name": "s-conv1",
                       "k": 8, "c": 6, "y": 10, "x": 10, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"objective": "edp", "budget": 32, "block": 64}},
  {"tag": "s-b",
   "workload": {"op": {"type": "conv2d", "name": "s-conv2",
                       "k": 12, "c": 6, "y": 10, "x": 10, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"objective": "runtime", "budget": 32, "block": 64}}
]
EOF
SERVE_PORT=$(python - <<'EOF'
import socket
s = socket.socket()
s.bind(("127.0.0.1", 0))
print(s.getsockname()[1])
s.close()
EOF
)
SERVE_DEADLINE=120
python -m repro.launch.serve --port "$SERVE_PORT" \
    --deadline "$SERVE_DEADLINE" --checkpoint-dir "$SERVE_CKPT" \
    --cache-dir '' --jax-cache-dir '' 2> "$SERVE_OUT/serve.log" &
SERVE_PID=$!
python - "$SERVE_PORT" <<'EOF'
import asyncio, sys, time
from repro.serve import http_json
async def wait_ready(port):
    for _ in range(120):
        try:
            st, body = await http_json("127.0.0.1", port, "GET", "/readyz")
            if st == 200:
                return
        except OSError:
            pass
        await asyncio.sleep(0.5)
    raise SystemExit("server never became ready")
asyncio.run(wait_ready(int(sys.argv[1])))
EOF
python -m repro.launch.loadgen --port "$SERVE_PORT" \
    --file "$SERVE_OUT/serve_queries.json" --clients 10 --requests 2 \
    --metricsz --out "$SERVE_OUT/serve_load.json"
SERVE_DEADLINE="$SERVE_DEADLINE" python - <<'EOF'
import json, os
d = json.load(open("benchmarks/out/serve_load.json"))
assert d["transport_errors"] == 0, d
assert d["n_terminal"] == d["n_requests"] == 20, d
assert set(d["statuses"]) <= {"200", "429", "503"}, d["statuses"]
assert d["p99_s"] < float(os.environ["SERVE_DEADLINE"]), d["p99_s"]
c = d["server_metrics"]["counters"]
shed = c.get("serve.shed", 0)
assert shed + c["serve.completed"] == c["serve.admitted"], c
print(f"serve loadgen OK: p50={d['p50_s']}s p99={d['p99_s']}s "
      f"qps={d['queries_per_s']} shed={shed}")
EOF
# graceful SIGTERM: nothing pending -> clean drain, exit 0
kill -TERM "$SERVE_PID"
SERVE_RC=0; wait "$SERVE_PID" || SERVE_RC=$?
test "$SERVE_RC" -eq 0
if [ -f "$SERVE_CKPT/serve-pending.json" ]; then
    echo "FAIL: clean drain left a pending file"
    exit 1
fi

echo "== DSE serving kill@serve-drain restart drill =="
# Chaos drill: the server dies mid-drain (deterministic fault between
# persisting the unanswered queue and the final flush), a restart with
# the same checkpoint dir recovers the debt, and the recovered answers
# are BIT-IDENTICAL to the offline --file oracle on the same queries —
# the server and the oracle share one execution path.
python -m repro.launch.serve --port "$SERVE_PORT" \
    --checkpoint-dir "$SERVE_CKPT" --faults kill@serve-drain:0 \
    --flush-interval 30 --max-batch 64 --deadline 5 \
    --cache-dir '' --jax-cache-dir '' 2>> "$SERVE_OUT/serve.log" &
SERVE_PID=$!
python - "$SERVE_PORT" "$SERVE_OUT/serve_queries.json" <<'EOF'
import asyncio, json, sys
from repro.serve import http_json
async def main(port, qfile):
    for _ in range(120):
        try:
            st, _ = await http_json("127.0.0.1", port, "GET", "/readyz")
            if st == 200:
                break
        except OSError:
            pass
        await asyncio.sleep(0.5)
    else:
        raise SystemExit("server never became ready")
    # park two requests in the (slow-flush) buffer; fire-and-forget —
    # the drill kills the server before they would be answered
    for q in json.load(open(qfile)):
        r, w = await asyncio.open_connection("127.0.0.1", port)
        body = json.dumps(q).encode()
        w.write(b"POST /query HTTP/1.1\r\nHost: x\r\n"
                b"Content-Length: %d\r\nConnection: close\r\n\r\n"
                % len(body) + body)
        await w.drain()
        await asyncio.sleep(0.3)       # let the server admit it
        w.close()
asyncio.run(main(int(sys.argv[1]), sys.argv[2]))
EOF
kill -TERM "$SERVE_PID"
SERVE_RC=0; wait "$SERVE_PID" || SERVE_RC=$?
test "$SERVE_RC" -eq 17   # os._exit(17): death mid-drain IS the drill
test -f "$SERVE_CKPT/serve-pending.json"
# restart (no faults): recovery replays the persisted queue at start
python -m repro.launch.serve --port "$SERVE_PORT" \
    --checkpoint-dir "$SERVE_CKPT" \
    --cache-dir '' --jax-cache-dir '' 2>> "$SERVE_OUT/serve.log" &
SERVE_PID=$!
python - "$SERVE_PORT" <<'EOF'
import asyncio, sys
from repro.serve import http_json
async def wait_ready(port):
    for _ in range(240):
        try:
            st, _ = await http_json("127.0.0.1", port, "GET", "/readyz")
            if st == 200:
                return
        except OSError:
            pass
        await asyncio.sleep(0.5)
    raise SystemExit("restarted server never became ready")
asyncio.run(wait_ready(int(sys.argv[1])))
EOF
kill -TERM "$SERVE_PID"
SERVE_RC=0; wait "$SERVE_PID" || SERVE_RC=$?
test "$SERVE_RC" -eq 0
test -f "$SERVE_CKPT/serve-recovered.json"
if [ -f "$SERVE_CKPT/serve-pending.json" ]; then
    echo "FAIL: recovery did not clear the pending file"
    exit 1
fi
python -m repro.launch.query --file "$SERVE_OUT/serve_queries.json" \
    --out "$SERVE_OUT/serve_oracle.json" --cache-dir '' --jax-cache-dir ''
python - <<'EOF'
import json
DET = ("kind", "name", "objective", "strategy", "best", "top_k",
       "pareto", "n_evaluated")
rec = json.load(open("benchmarks/out/serve_ckpt/serve-recovered.json"))
oracle = json.load(open("benchmarks/out/serve_oracle.json"))
by_name = {r["name"]: r for r in rec["reports"]}
assert len(by_name) == 2, by_name.keys()
for ref in oracle["reports"]:
    got = by_name[ref["name"]]
    for k in DET:
        assert got.get(k) == ref.get(k), (k, got.get(k), ref.get(k))
print("killed drain recovered bit-identical to the offline oracle")
EOF

echo "== observability smoke: request tracing + SLO histograms =="
# The obs v2 headline, end to end through the CLIs: a traced server
# absorbs a 100-client burst; then (a) ONE client-minted request id must
# thread server -> coalescer -> engine spans in the saved Perfetto
# trace, and (b) every report's extras.timing phases must sum to its
# measured wall latency, and the per-phase Prometheus histogram sums
# must reconcile with the per-report breakdowns — all from structured
# artifacts (the loadgen --out payload + the trace file), not logs.
OBS_OUT=benchmarks/out
OBS_CKPT="$OBS_OUT/obs_serve_ckpt"
rm -rf "$OBS_CKPT"
python -m repro.launch.serve --port "$SERVE_PORT" \
    --checkpoint-dir "$OBS_CKPT" --max-queue 512 --deadline 120 \
    --trace "$OBS_OUT/obs_serve_trace.json" \
    --cache-dir '' --jax-cache-dir '' 2> "$OBS_OUT/obs_serve.log" &
SERVE_PID=$!
python - "$SERVE_PORT" <<'EOF'
import asyncio, sys
from repro.serve import http_json
async def wait_ready(port):
    for _ in range(120):
        try:
            st, _ = await http_json("127.0.0.1", port, "GET", "/readyz")
            if st == 200:
                return
        except OSError:
            pass
        await asyncio.sleep(0.5)
    raise SystemExit("server never became ready")
asyncio.run(wait_ready(int(sys.argv[1])))
EOF
python -m repro.launch.loadgen --port "$SERVE_PORT" \
    --file "$SERVE_OUT/serve_queries.json" --clients 100 --requests 1 \
    --metricsz --prometheus --save-reports \
    --out "$OBS_OUT/obs_load.json"
kill -TERM "$SERVE_PID"
SERVE_RC=0; wait "$SERVE_PID" || SERVE_RC=$?
test "$SERVE_RC" -eq 0
# SIGTERM drain must save the trace + metrics snapshot (the fix this
# PR ships): both files land in the checkpoint dir
test -f "$OBS_CKPT/serve-trace.json"
test -f "$OBS_CKPT/serve-metrics.json"
python - <<'EOF'
import json, re
d = json.load(open("benchmarks/out/obs_load.json"))
assert d["transport_errors"] == 0, d
assert d["n_terminal"] == d["n_requests"] == 100, d
c = d["server_metrics"]["counters"]
assert c.get("serve.shed", 0) + c["serve.completed"] \
    == c["serve.admitted"], c
reports = [e["report"] for e in d["reports"]]
assert len(reports) == d["statuses"].get("200", 0) and reports, \
    d["statuses"]

# --- (b) per-report timing: phases sum to measured wall (<=10%) ----
# (Report.to_json flattens extras to the top level on the wire)
phase_sums: dict[str, float] = {}
for rep in reports:
    tim = rep["timing"]
    assert tim["request_id"].startswith("lg-"), tim
    wall, s = tim["wall_s"], sum(tim["phases"].values())
    assert abs(s - wall) <= max(0.10 * wall, 1e-3), (rep["name"], s, wall)
    for p, v in tim["phases"].items():
        phase_sums[p] = phase_sums.get(p, 0.0) + v
assert "queue_wait" in phase_sums, phase_sums

# --- the Prometheus histograms reconcile with the reports ----------
text = d["server_prometheus"]
assert "# TYPE serve_latency_s histogram" in text, "no latency histogram"
assert 'le="+Inf"' in text
assert re.search(r'# \{request_id="lg-\d{4}-\d{3}"\}', text), \
    "no client request-id exemplars in the exposition"
prom_sums = {m.group(1): float(m.group(2)) for m in re.finditer(
    r'serve_phase_s_sum\{phase="(\w+)"\} ([0-9.eE+-]+)', text)}
for p, want in phase_sums.items():
    got = prom_sums.get(p, 0.0)
    assert abs(got - want) <= max(0.10 * want, 0.05), (p, got, want)
n_count = sum(int(float(m.group(1))) for m in re.finditer(
    r'serve_latency_s_count\{[^}]*\} ([0-9.eE+-]+)', text))
assert n_count == len(reports), (n_count, len(reports))

# --- (a) one request id threads server -> coalescer -> engine ------
t = json.load(open("benchmarks/out/obs_serve_trace.json"))
rid = reports[0]["timing"]["request_id"]
def has_rid(e):
    r = e.get("args", {}).get("rid")
    return r == rid or (isinstance(r, list) and rid in r)
names = {e["name"] for e in t["traceEvents"] if has_rid(e)}
for want in ("request", "queue-wait", "flush"):
    assert want in names, (rid, want, sorted(names))
assert names & {"query", "run_many", "encode", "compile", "dispatch",
                "device-pass", "topk-merge"}, \
    (rid, "no engine spans carry the request id", sorted(names))
print(f"observability smoke OK: {len(reports)} reports reconciled; "
      f"rid {rid} threads {len(names)} span names")
EOF

echo "== crash@serve-worker flight-recorder drill =="
# Chaos drill for the always-on flight recorder: a deterministic crash
# in the flush worker must (1) still answer the in-flight request with
# an error report (no hang), and (2) dump the recorder ring to
# flight-<ts>.json naming the failing request id, with the error entry
# and the request's spans inside.
OBS_FLIGHT="$OBS_OUT/obs_flight"
rm -rf "$OBS_FLIGHT"
mkdir -p "$OBS_FLIGHT"
python -m repro.launch.serve --port "$SERVE_PORT" \
    --faults crash@serve-worker:0 --flight-dir "$OBS_FLIGHT" \
    --deadline 60 --cache-dir '' --jax-cache-dir '' \
    2>> "$OBS_OUT/obs_serve.log" &
SERVE_PID=$!
python - "$SERVE_PORT" "$SERVE_OUT/serve_queries.json" <<'EOF'
import asyncio, json, sys
from repro.serve import http_json
async def main(port, qfile):
    for _ in range(120):
        try:
            st, _ = await http_json("127.0.0.1", port, "GET", "/readyz")
            if st == 200:
                break
        except OSError:
            pass
        await asyncio.sleep(0.5)
    else:
        raise SystemExit("server never became ready")
    q = json.load(open(qfile))[0]
    st, body = await http_json("127.0.0.1", port, "POST", "/query", q,
                               headers={"X-Request-Id": "ci-crash-1"})
    assert st == 200 and body["kind"] == "error", (st, body)
asyncio.run(main(int(sys.argv[1]), sys.argv[2]))
EOF
kill -TERM "$SERVE_PID"
SERVE_RC=0; wait "$SERVE_PID" || SERVE_RC=$?
test "$SERVE_RC" -eq 0
python - <<'EOF'
import glob, json
paths = sorted(glob.glob("benchmarks/out/obs_flight/flight-*.json"))
assert paths, "crash drill produced no flight-recorder dump"
doc = json.load(open(paths[0]))
assert doc["reason"] == "flush-error", doc["reason"]
assert "ci-crash-1" in doc.get("request_ids", ()), doc.get("request_ids")
ents = doc["entries"]
assert any(e["name"] == "serve-flush-error" for e in ents), \
    [e["name"] for e in ents]
def rid_has(e):
    r = e.get("rid")
    return r == "ci-crash-1" or (isinstance(r, list) and "ci-crash-1" in r)
assert any(rid_has(e) for e in ents), \
    "no flight entries attributed to the failing request"
print(f"flight drill OK: {paths[0]} ({len(ents)} entries)")
EOF

echo "== benchmarks --quick =="
python -m benchmarks.run --quick

echo "== bench_mapspace smoke artifact =="
# BENCH_mapspace.json (written by the mapspace benchmark above) tracks the
# perf trajectory per PR: end-to-end + eval-only mappings/s, gene-vs-legacy
# speedup, joint-sweep designs/s, universal-evaluator compile count, device
# count.  It lands BOTH under benchmarks/out (CI artifact upload) and at
# the repo root (perf trajectory tracker).
test -f benchmarks/out/BENCH_mapspace.json
test -f BENCH_mapspace.json
python - <<'EOF'
import json
d = json.load(open("BENCH_mapspace.json"))
print(json.dumps(d, indent=2))
# the gene pipeline must keep the <= 2-compiles-per-(op, level-count,
# batch-shape) model: `compile_budget` is the closed-form bound the bench
# derives from the evaluation contexts it runs — O(1) per layer family,
# never O(structure groups)
assert d["universal_compiles_process"] <= d["compile_budget"], \
    (d["universal_compiles_process"], d["compile_budget"],
     "compile count must stay O(1) per (layer, level-count), not O(groups)")
# the gene pipeline must beat the legacy tuple-point path end to end
assert d["e2e_speedup_vs_legacy"] >= 1.0, d["e2e_speedup_vs_legacy"]
# checkpointing the headline search must cost <= 5% of its wall time,
# and the checkpointed run must reproduce the uncheckpointed answer
assert d["checkpoint_overhead_frac"] <= 0.05, d["checkpoint_overhead_frac"]
assert d["checkpoint"]["deterministic"] is True, d["checkpoint"]
assert d["checkpoint"]["saves"] >= 1, d["checkpoint"]
# tracing the headline search must cost <= 1% of its wall time, and the
# traced run must reproduce the untraced answer bit-identically
assert d["obs_overhead_frac"] <= 0.01, (d["obs_overhead_frac"], d["obs"])
assert d["obs"]["deterministic"] is True, d["obs"]
assert d["obs"]["trace_events"] > 0, d["obs"]
# every BENCH artifact ships the obs metrics snapshot + environment
# provenance (schema_version 2)
assert d["schema_version"] == 2, d["schema_version"]
assert d["environment"]["backend"], d.get("environment")
c = d["metrics"]["counters"]
fam = {k: v for k, v in c.items()
       if k.startswith("universal.compiles_by_family[")}
assert c["universal.compiles"] == sum(fam.values()), (c, fam)
# the jaxpr audit rides with the artifact: every traced family must be
# finding-free AND within its primitive-count budget, so a PR that
# bloats the traced program (or sneaks in an f64 upcast / host
# callback) fails here even if wall-clock noise hides the slowdown
assert d["jaxpr_findings"] == [], d["jaxpr_findings"]
counts, budget = d["jaxpr_primitive_counts"], d["jaxpr_primitive_budget"]
# counts are per traced case ("family/kind"); budgets are per family —
# every budgeted family must be covered, and every case must fit
fams = {case.rsplit("/", 1)[0] for case in counts}
assert counts and fams >= set(budget), (sorted(fams), sorted(budget))
for case, n in counts.items():
    cap = budget.get(case.rsplit("/", 1)[0])
    assert cap is None or n <= cap, (case, n, cap)
print(f"jaxpr audit OK: {len(counts)} traced cases within primitive budget")
EOF

echo "== BENCH_netspace smoke artifact =="
test -f benchmarks/out/BENCH_netspace.json
test -f BENCH_netspace.json
python - <<'EOF'
import json
d = json.load(open("BENCH_netspace.json"))
print(json.dumps(d, indent=2))
# whole-network search must stay on the <= 2-compiles-per-(op-class,
# level-count) model: compile_budget = 2 * n_op_classes
assert d["universal_compiles_process"] <= d["compile_budget"], \
    (d["universal_compiles_process"], d["compile_budget"],
     "netspace compile count must be O(op-classes), not O(layers)")
# the searched schedule's network EDP must beat the best single uniform
# Table-3 dataflow applied network-wide
assert d["edp_win_vs_best_uniform"] >= 1.0, d["edp_win_vs_best_uniform"]
assert d["schema_version"] == 2 and d["environment"]["backend"], d
assert "universal.compiles" in d["metrics"]["counters"], d["metrics"]
EOF

echo "== BENCH_api smoke artifact =="
test -f benchmarks/out/BENCH_api.json
test -f BENCH_api.json
python - <<'EOF'
import json
d = json.load(open("BENCH_api.json"))
print(json.dumps(d, indent=2))
# Session.run_many on the mixed heterogeneous batch must compile at most
# ONE executable per unique (op-class, level-count) family ...
assert d["n_compiles"] <= d["n_families"], \
    (d["n_compiles"], d["n_families"],
     "coalesced batch must stay within the family compile budget")
# ... answer identically whether queries are coalesced or run one at a
# time through the same family spaces ...
assert d["coalesced_deterministic"] is True
# ... and beat sequential per-query search() wall time by >= 2x (the
# compile amortization IS the headline)
assert d["run_many_speedup_vs_sequential_search"] >= 2.0, \
    d["run_many_speedup_vs_sequential_search"]
assert d["schema_version"] == 2 and d["environment"]["backend"], d
assert "universal.compiles" in d["metrics"]["counters"], d["metrics"]
EOF

echo "== BENCH_serve smoke artifact =="
test -f benchmarks/out/BENCH_serve.json
test -f BENCH_serve.json
python - <<'EOF'
import json
d = json.load(open("BENCH_serve.json"))
print(json.dumps(d, indent=2))
# every load-burst request must reach a terminal status, and the
# admission ledger must balance: shed + completed == admitted
for key in (k for k in d if k.startswith("clients_")):
    s = d[key]
    assert s["all_terminal"] is True, (key, s)
    assert s["p50_s"] > 0 and s["p99_s"] >= s["p50_s"], (key, s)
    assert s["queries_per_s"] > 0, (key, s)
assert d["invariant_holds"] is True, d["counters"]
assert d["schema_version"] == 2 and d["environment"]["backend"], d
EOF

echo "== bench regression gate =="
# Fresh quick-mode artifacts vs the committed baselines (read from git,
# since the bench run overwrites the root copies).  Full-mode-only
# baselines (BENCH_serve) are skipped automatically on quick runs.
python scripts/bench_check.py --out-dir benchmarks/out

echo "CI smoke gate passed."
