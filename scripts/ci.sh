#!/usr/bin/env bash
# Smoke gate: quick tier-1 subset + quick benchmarks.
# Full tier-1 is `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 quick subset =="
python -m pytest -x -q \
    tests/test_directives.py \
    tests/test_reuse.py \
    tests/test_engine.py \
    tests/test_mapper.py \
    tests/test_mapspace.py

echo "== benchmarks --quick =="
python -m benchmarks.run --quick

echo "CI smoke gate passed."
