#!/usr/bin/env bash
# Smoke gate: quick tier-1 subset + quick benchmarks + sharded smoke.
# Full tier-1 is `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 quick subset =="
python -m pytest -x -q \
    tests/test_directives.py \
    tests/test_reuse.py \
    tests/test_engine.py \
    tests/test_mapper.py \
    tests/test_mapspace.py \
    tests/test_universal.py \
    tests/test_genes.py \
    tests/test_netspace.py \
    tests/test_api.py \
    tests/test_obs.py \
    tests/test_resilience.py

echo "== 4-host-device sharded smoke =="
# The gene pipeline stripes chunks over all local devices; forcing four
# host CPU devices exercises the pmap path and the 1-vs-N-device
# determinism assertions inside tests/test_genes.py, tests/test_netspace.py
# and tests/test_api.py (coalesced run_many) for real.
# tests/test_resilience.py rides along so kill-and-resume bit-identity
# is asserted at 4 devices too (its kill/resume test parametrizes over
# the available device count).
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_genes.py tests/test_netspace.py \
    tests/test_api.py tests/test_resilience.py

echo "== small-budget netsearch smoke =="
# End-to-end network schedule search through the CLI shim: VGG16 at a
# tiny budget must complete with the shape-as-operand executables and
# print a schedule + baseline comparison.
python -m repro.launch.netsearch --model vgg16 --quick --jax-cache-dir ''

echo "== declarative batch front door (--file) smoke =="
# Serving-style mixed batch through repro.launch.query: 4 coalescible
# layer queries (conv + GEMM classes, heterogeneous objectives AND fixed
# hardware points), one adaptive-budget network query, one hardware-grid
# co-DSE query.  Runs with --trace + --metrics, so the compile and
# cache budgets below are asserted from the STRUCTURED obs snapshot
# embedded in the --out payload (not grepped from stdout), and the
# Chrome trace_event timeline is validated and uploaded as a CI
# artifact.
python -m repro.launch.query --file examples/queries.json \
    --out benchmarks/out/api_batch_smoke.json \
    --trace benchmarks/out/api_batch_trace.json --metrics \
    --cache-dir '' --jax-cache-dir ''
python - <<'EOF'
import json
d = json.load(open("benchmarks/out/api_batch_smoke.json"))
b = d["batch"]
print(json.dumps(b, indent=2))
assert b["n_queries"] == 6, b
# the 4 layer queries coalesce; network + grid queries route to their
# engines
assert b["n_coalesced"] == 4, b
assert b["n_families"] <= 4, b
assert b["n_compiles"] <= b["compile_budget"], b
kinds = [r["kind"] for r in d["reports"]]
assert kinds.count("layer") == 4, kinds
assert "network" in kinds and "layer_codse" in kinds, kinds
assert all(r["schema_version"] == 2 for r in d["reports"])

# --- obs metrics snapshot: the budget asserts read ONE structured
# payload now ------------------------------------------------------
m = d["metrics"]
c = m["counters"]
assert m["schema_version"] == 1, m["schema_version"]
fam = {k: v for k, v in c.items()
       if k.startswith("universal.compiles_by_family[")}
# single-writer parity: the process total == the per-family sum
assert c["universal.compiles"] == sum(fam.values()), (c, fam)
# the 4 coalesced families (conv + gemm class reps x 1/2 levels)
# compiled EXACTLY once each — the coalescing headline, asserted
# per family instead of as one opaque total
for f in ("q-conv1:L1", "q-conv1:L2", "q-gemm1:L1", "q-gemm1:L2"):
    k = f"universal.compiles_by_family[family={f}]"
    assert fam.get(k) == 1, (k, fam)
assert c["session.queries"] == 6, c
assert c["session.queries_by_kind[kind=layer_coalesced]"] == 4, c
# environment provenance rides with every payload
assert d["environment"]["backend"], d.get("environment")

# --- the trace renders the whole batch as a timeline ---------------
t = json.load(open("benchmarks/out/api_batch_trace.json"))
evs = t["traceEvents"]
assert evs and t["displayTimeUnit"] == "ms", "empty/invalid trace"
names = {e["name"] for e in evs}
for want in ("run_many", "coalesce", "encode", "compile",
             "device-pass", "topk-merge", "compose", "query"):
    assert want in names, (want, sorted(names))
n_compile_spans = sum(e["name"] == "compile" for e in evs)
assert n_compile_spans == b["n_compiles"], \
    (n_compile_spans, b["n_compiles"],
     "one compile span per actual XLA compile")
print(f"trace OK: {len(evs)} events, {n_compile_spans} compile spans")
EOF

echo "== fault-injection kill/resume smoke =="
# The resilience headline, end to end through the CLI: a batch run is
# killed mid-chunk by deterministic fault injection, the re-launch with
# the same flags resumes from the sweep checkpoint, and the resumed
# reports are BIT-IDENTICAL to an undisturbed reference run.  The
# resilience.* recovery counters are asserted from the structured --out
# payload, not grepped from logs.
RES_OUT=benchmarks/out
RES_CKPT="$RES_OUT/resilience_ckpt"
rm -rf "$RES_CKPT"
mkdir -p "$RES_OUT"
cat > "$RES_OUT/resilience_queries.json" <<'EOF'
[
  {"workload": {"op": {"type": "conv2d", "name": "r-conv1",
                       "k": 8, "c": 6, "y": 12, "x": 12, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"budget": 96, "block": 32, "strategy": "random", "seed": 3}},
  {"workload": {"op": {"type": "conv2d", "name": "r-conv2",
                       "k": 16, "c": 8, "y": 10, "x": 10, "r": 3, "s": 3}},
   "hardware": {"num_pes": 48, "noc_bw": 12.0},
   "search": {"budget": 64, "block": 32, "strategy": "random", "seed": 1}}
]
EOF
python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --out "$RES_OUT/resilience_ref.json" --cache-dir '' --jax-cache-dir ''
if python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --checkpoint-dir "$RES_CKPT" --faults kill@chunk:1 \
    --cache-dir '' --jax-cache-dir '' 2> "$RES_OUT/resilience_kill.log"
then
    echo "FAIL: injected kill@chunk:1 did not kill the sweep"
    exit 1
fi
grep -q SweepKilled "$RES_OUT/resilience_kill.log"
ls "$RES_CKPT"/sweep-batch-*.npz > /dev/null   # checkpoint survived
python -m repro.launch.query --file "$RES_OUT/resilience_queries.json" \
    --checkpoint-dir "$RES_CKPT" \
    --out "$RES_OUT/resilience_resumed.json" --cache-dir '' \
    --jax-cache-dir ''
python - <<'EOF'
import json
DET = ("kind", "name", "objective", "strategy", "best", "top_k",
       "pareto", "n_evaluated")
ref = json.load(open("benchmarks/out/resilience_ref.json"))
res = json.load(open("benchmarks/out/resilience_resumed.json"))
for a, b in zip(ref["reports"], res["reports"]):
    for k in DET:
        assert a.get(k) == b.get(k), (k, a.get(k), b.get(k))
c = res["metrics"]["counters"]
assert c.get("resilience.checkpoint_resumes", 0) >= 1, c
assert c.get("resilience.checkpoint_saves", 0) >= 1, c
print("kill/resume bit-identical across process restarts; "
      f"resumes={c['resilience.checkpoint_resumes']}")
EOF
# a completed sweep clears its checkpoint
if ls "$RES_CKPT"/sweep-*.npz 2>/dev/null; then
    echo "FAIL: checkpoint not cleared after completed resume"
    exit 1
fi

echo "== benchmarks --quick =="
python -m benchmarks.run --quick

echo "== bench_mapspace smoke artifact =="
# BENCH_mapspace.json (written by the mapspace benchmark above) tracks the
# perf trajectory per PR: end-to-end + eval-only mappings/s, gene-vs-legacy
# speedup, joint-sweep designs/s, universal-evaluator compile count, device
# count.  It lands BOTH under benchmarks/out (CI artifact upload) and at
# the repo root (perf trajectory tracker).
test -f benchmarks/out/BENCH_mapspace.json
test -f BENCH_mapspace.json
python - <<'EOF'
import json
d = json.load(open("BENCH_mapspace.json"))
print(json.dumps(d, indent=2))
# the gene pipeline must keep the <= 2-compiles-per-(op, level-count,
# batch-shape) model: `compile_budget` is the closed-form bound the bench
# derives from the evaluation contexts it runs — O(1) per layer family,
# never O(structure groups)
assert d["universal_compiles_process"] <= d["compile_budget"], \
    (d["universal_compiles_process"], d["compile_budget"],
     "compile count must stay O(1) per (layer, level-count), not O(groups)")
# the gene pipeline must beat the legacy tuple-point path end to end
assert d["e2e_speedup_vs_legacy"] >= 1.0, d["e2e_speedup_vs_legacy"]
# checkpointing the headline search must cost <= 5% of its wall time,
# and the checkpointed run must reproduce the uncheckpointed answer
assert d["checkpoint_overhead_frac"] <= 0.05, d["checkpoint_overhead_frac"]
assert d["checkpoint"]["deterministic"] is True, d["checkpoint"]
assert d["checkpoint"]["saves"] >= 1, d["checkpoint"]
# every BENCH artifact ships the obs metrics snapshot + environment
# provenance (schema_version 2)
assert d["schema_version"] == 2, d["schema_version"]
assert d["environment"]["backend"], d.get("environment")
c = d["metrics"]["counters"]
fam = {k: v for k, v in c.items()
       if k.startswith("universal.compiles_by_family[")}
assert c["universal.compiles"] == sum(fam.values()), (c, fam)
EOF

echo "== BENCH_netspace smoke artifact =="
test -f benchmarks/out/BENCH_netspace.json
test -f BENCH_netspace.json
python - <<'EOF'
import json
d = json.load(open("BENCH_netspace.json"))
print(json.dumps(d, indent=2))
# whole-network search must stay on the <= 2-compiles-per-(op-class,
# level-count) model: compile_budget = 2 * n_op_classes
assert d["universal_compiles_process"] <= d["compile_budget"], \
    (d["universal_compiles_process"], d["compile_budget"],
     "netspace compile count must be O(op-classes), not O(layers)")
# the searched schedule's network EDP must beat the best single uniform
# Table-3 dataflow applied network-wide
assert d["edp_win_vs_best_uniform"] >= 1.0, d["edp_win_vs_best_uniform"]
assert d["schema_version"] == 2 and d["environment"]["backend"], d
assert "universal.compiles" in d["metrics"]["counters"], d["metrics"]
EOF

echo "== BENCH_api smoke artifact =="
test -f benchmarks/out/BENCH_api.json
test -f BENCH_api.json
python - <<'EOF'
import json
d = json.load(open("BENCH_api.json"))
print(json.dumps(d, indent=2))
# Session.run_many on the mixed heterogeneous batch must compile at most
# ONE executable per unique (op-class, level-count) family ...
assert d["n_compiles"] <= d["n_families"], \
    (d["n_compiles"], d["n_families"],
     "coalesced batch must stay within the family compile budget")
# ... answer identically whether queries are coalesced or run one at a
# time through the same family spaces ...
assert d["coalesced_deterministic"] is True
# ... and beat sequential per-query search() wall time by >= 2x (the
# compile amortization IS the headline)
assert d["run_many_speedup_vs_sequential_search"] >= 2.0, \
    d["run_many_speedup_vs_sequential_search"]
assert d["schema_version"] == 2 and d["environment"]["backend"], d
assert "universal.compiles" in d["metrics"]["counters"], d["metrics"]
EOF

echo "CI smoke gate passed."
