#!/usr/bin/env bash
# Smoke gate: quick tier-1 subset + quick benchmarks.
# Full tier-1 is `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 quick subset =="
python -m pytest -x -q \
    tests/test_directives.py \
    tests/test_reuse.py \
    tests/test_engine.py \
    tests/test_mapper.py \
    tests/test_mapspace.py \
    tests/test_universal.py

echo "== benchmarks --quick =="
python -m benchmarks.run --quick

echo "== bench_mapspace smoke artifact =="
# BENCH_mapspace.json (written by the mapspace benchmark above) tracks the
# perf trajectory per PR: mappings/s, universal-evaluator compile count,
# and wall-clock.  CI uploads everything matching benchmarks/out/BENCH_*.
test -f benchmarks/out/BENCH_mapspace.json
python - <<'EOF'
import json
d = json.load(open("benchmarks/out/BENCH_mapspace.json"))
print(json.dumps(d, indent=2))
# <= 2 per (layer, level-count) + 2 for the rate-measure batch shapes;
# the point is O(1) per layer family, never O(structure groups)
assert d["universal_compiles_process"] <= 2 * len(d["layers"]) + 2, \
    "compile count must stay O(1) per (layer, level-count), not O(groups)"
EOF

echo "CI smoke gate passed."
