#!/usr/bin/env python
"""Bench regression gate: fresh BENCH_* artifacts vs committed baselines.

Compares the artifacts a just-finished ``benchmarks.run`` wrote to
``benchmarks/out/BENCH_<name>.json`` against the baselines committed at
the repo root — read via ``git show HEAD:BENCH_<name>.json``, because
the bench run overwrites the working-tree root copies in place.

Direction-aware checks with a relative tolerance (default 20%,
``BENCH_CHECK_TOL`` overrides): latency-like metrics fail when they grow
past ``baseline * (1 + tol)``, throughput-like metrics fail when they
shrink below ``baseline * (1 - tol)``.  A fresh/baseline ``quick`` flag
mismatch skips that artifact with a note — quick-mode and full-mode
numbers are not comparable — as does a missing file on either side.
Exits 1 when any comparable metric regressed.

Usage::

    python scripts/bench_check.py [--out-dir benchmarks/out] [--ref HEAD]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Any

# (artifact, dotted key path, direction) — direction "low" = lower is
# worse (throughput/speedup), "high" = higher is worse (latency/wall)
CHECKS: list[tuple[str, str, str]] = [
    ("mapspace", "end_to_end_mappings_per_s", "low"),
    ("mapspace", "steady_rate_mappings_per_s", "low"),
    ("mapspace", "e2e_speedup_vs_legacy", "low"),
    ("api", "run_many_speedup_vs_sequential_search", "low"),
    ("netspace", "edp_win_vs_best_uniform", "low"),
    ("serve", "clients_10.p99_s", "high"),
    ("serve", "clients_10.queries_per_s", "low"),
    ("serve", "clients_100.p99_s", "high"),
    ("serve", "clients_100.queries_per_s", "low"),
    ("serve", "clients_1000.p99_s", "high"),
    ("serve", "clients_1000.queries_per_s", "low"),
]

DEFAULT_TOL = 0.20


def _dig(payload: dict, dotted: str) -> Any:
    cur: Any = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _baseline(name: str, ref: str) -> dict | None:
    """The committed artifact at ``ref`` (None when it does not exist —
    e.g. a brand-new benchmark with no baseline yet)."""
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:BENCH_{name}.json"],
            capture_output=True, check=True, text=True)
    except (subprocess.CalledProcessError, OSError):
        return None
    try:
        return json.loads(out.stdout)
    except ValueError:
        return None


def _fresh(out_dir: str, name: str) -> dict | None:
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check(out_dir: str, ref: str, tol: float) -> int:
    failures = 0
    skipped: dict[str, str] = {}
    fresh_cache: dict[str, dict | None] = {}
    base_cache: dict[str, dict | None] = {}
    for name, key, direction in CHECKS:
        if name in skipped:
            continue
        if name not in fresh_cache:
            fresh_cache[name] = _fresh(out_dir, name)
            base_cache[name] = _baseline(name, ref)
        fresh, base = fresh_cache[name], base_cache[name]
        if fresh is None:
            skipped[name] = "no fresh artifact (bench not run)"
            continue
        if base is None:
            skipped[name] = f"no committed BENCH_{name}.json baseline"
            continue
        if bool(fresh.get("quick")) != bool(base.get("quick")):
            skipped[name] = (
                f"quick-mode mismatch (fresh={fresh.get('quick')}, "
                f"baseline={base.get('quick')}) — not comparable")
            continue
        got, want = _dig(fresh, key), _dig(base, key)
        if got is None or want is None or not isinstance(got, (int, float)) \
                or not isinstance(want, (int, float)) or want == 0:
            print(f"  skip  {name}.{key}: missing on one side "
                  f"(fresh={got}, baseline={want})")
            continue
        if direction == "high":
            bad = got > want * (1.0 + tol)
            rel = (got - want) / want
        else:
            bad = got < want * (1.0 - tol)
            rel = (want - got) / want
        verdict = "FAIL" if bad else "ok"
        print(f"  {verdict:4s}  {name}.{key}: fresh={got:g} "
              f"baseline={want:g} ({'+' if rel >= 0 else ''}"
              f"{rel * 100:.1f}% {'worse' if rel > 0 else 'better'}, "
              f"tol {tol * 100:.0f}%)")
        failures += int(bad)
    for name, why in sorted(skipped.items()):
        print(f"  skip  {name}: {why}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="benchmarks/out",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref the committed baselines are read from")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_CHECK_TOL",
                                                 DEFAULT_TOL)),
                    help="relative regression tolerance (default 0.20 "
                         "or $BENCH_CHECK_TOL)")
    args = ap.parse_args(argv)
    print(f"bench_check: fresh={args.out_dir} vs {args.ref} "
          f"(tol {args.tol * 100:.0f}%)")
    failures = check(args.out_dir, args.ref, args.tol)
    if failures:
        print(f"bench_check: {failures} regression(s) beyond tolerance")
        return 1
    print("bench_check: no regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
