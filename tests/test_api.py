"""Tests for the declarative front door (``repro.api``).

Load-bearing properties:

  * the legacy entry points (``mapspace.search``/``co_search``,
    ``netspace.search_network``) are thin wrappers over the session
    path and stay BIT-EQUAL to `Session.run` on the equivalent query;
  * ``Session.run_many`` answers a coalesced heterogeneous batch with at
    most one executable per unique (op-class, level-count) family, and
    its results are identical to per-query passes through the same
    family spaces — at any device count;
  * ``Report`` JSON round-trips exactly; query fingerprints feed the
    disk-cache key, and stale (old-version) cache entries are never
    replayed;
  * the adaptive per-layer budget policy refines the dominant layers
    deterministically with zero extra compiles.
"""
import json

import numpy as np
import pytest
import jax

from repro.core import tensor_analysis as ta
from repro.api import (Hardware, Query, Report, SearchSpec, Session,
                       Workload)
from repro.mapspace import cache as ms_cache
from repro.mapspace import co_search, search
from repro.mapspace.space import build_space
from repro.mapspace.universal import compile_count
from repro.core.dse import DSEConfig
from repro.netspace import search_network

PES, BW = 48, 12.0
BLOCK = 64


@pytest.fixture(scope="module")
def conv():
    return ta.conv2d("api-t-c1", k=8, c=4, y=12, x=12, r=3, s=3)


@pytest.fixture(scope="module")
def chain():
    return [ta.conv2d("api-t-n1", k=8, c=4, y=12, x=12, r=3, s=3),
            ta.conv2d("api-t-n2", k=12, c=8, y=14, x=14, r=3, s=3),
            ta.fc("api-t-f1", k=16, c=32)]


@pytest.fixture(scope="module")
def batch_queries():
    ops = [ta.conv2d("api-b-c1", k=8, c=4, y=12, x=12, r=3, s=3),
           ta.conv2d("api-b-c2", k=12, c=8, y=10, x=10, r=3, s=3),
           ta.conv2d("api-b-c3", k=6, c=6, y=8, x=8, r=3, s=3),
           ta.fc("api-b-f1", k=16, c=32),
           ta.gemm("api-b-g1", m=8, n=24, k=16),
           ta.conv2d("api-b-c4", k=4, c=8, y=14, x=14, r=3, s=3)]
    objectives = ["edp", "energy", "runtime", "throughput", "edp",
                  "energy"]
    return [Query(Workload.of_layer(op),
                  Hardware(num_pes=32 + 16 * (i % 2),
                           noc_bw=8.0 + 4 * (i % 3)),
                  SearchSpec(objective=objectives[i], budget=50,
                             block=BLOCK, top_k=3))
            for i, op in enumerate(ops)]


@pytest.fixture(scope="module")
def session():
    return Session()


@pytest.fixture(scope="module")
def batch_reports(session, batch_queries):
    """One coalesced run shared by the batching tests."""
    c0 = compile_count()
    reports = session.run_many(batch_queries)
    return reports, dict(session.last_batch), compile_count() - c0


# ----------------------------------------------------------------------
# Spec machinery
# ----------------------------------------------------------------------

def test_query_kinds(conv, chain):
    fixed, grid = Hardware(), Hardware(pe_range=(32, 64))
    assert Query(Workload.of_layer(conv), fixed).kind == "layer"
    assert Query(Workload.of_layer(conv), grid).kind == "layer_codse"
    assert Query(Workload.of_layers(chain), fixed).kind == "network"
    assert Query(Workload.of_network("vgg16"), grid).kind == \
        "network_codse"
    assert Query(Workload(model="vgg16", layer="conv13"),
                 fixed).kind == "layer"


def test_query_json_and_fingerprint(conv):
    d = {"tag": "t", "workload": {"op": {"type": "conv2d", "name": "j1",
                                         "k": 8, "c": 4, "y": 12,
                                         "x": 12, "r": 3, "s": 3}},
         "hardware": {"num_pes": 64, "pe_range": [32, 64]},
         "search": {"objective": "energy", "budget": 77}}
    q = Query.from_json(d)
    assert q.kind == "layer_codse"
    assert q.hardware.pe_range == (32, 64)
    assert q.search.budget == 77
    # fingerprint is stable and sensitive to every component
    assert q.fingerprint() == Query.from_json(d).fingerprint()
    d2 = json.loads(json.dumps(d))
    d2["search"]["budget"] = 78
    assert Query.from_json(d2).fingerprint() != q.fingerprint()
    # invalid specs are rejected loudly
    with pytest.raises(ValueError):
        Query.from_json({"workload": {"op": {"type": "nope"}}})
    with pytest.raises(ValueError):
        Query.from_json({"workload": {"model": "vgg16"},
                         "search": {"not_a_knob": 1}})


def test_workload_validation(conv):
    with pytest.raises(ValueError):
        Workload()
    with pytest.raises(ValueError):
        Workload(model="vgg16", ops=(conv,))
    with pytest.raises(ValueError):
        Workload.of_network("not-a-model")


# ----------------------------------------------------------------------
# Old-API vs Session bit-equal parity (the wrapper contract)
# ----------------------------------------------------------------------

def test_search_parity(session, conv):
    q = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
              SearchSpec(objective="edp", budget=60, block=BLOCK,
                         top_k=4))
    rep = session.run(q)
    r = search(conv, objective="edp", budget=60, num_pes=PES, noc_bw=BW,
               block=BLOCK, top_k=4)
    assert list(r.best_point) == rep.best["point"]
    assert r.best_value == rep.best["value"]
    assert [list(e["point"]) for e in r.top_k] == \
        [e["point"] for e in rep.top_k]
    assert [e["value"] for e in r.top_k] == \
        [e["value"] for e in rep.top_k]
    assert r.best_stats == rep.best["stats"]
    assert rep.kind == "layer" and rep.raw.n_evaluated == r.n_evaluated


def test_co_search_parity(session, conv):
    cfg = DSEConfig(pe_range=(16, 32, 64), bw_range=(4.0, 8.0, 16.0))
    q = Query(Workload.of_layer(conv),
              Hardware(num_pes=PES, noc_bw=BW, pe_range=(16, 32, 64),
                       bw_range=(4.0, 8.0, 16.0)),
              SearchSpec(objective="edp", budget=60, block=BLOCK,
                         top_k=4, codse_top_k=2))
    rep = session.run(q)
    co = co_search(conv, objective="edp", mapping_budget=60, top_k=2,
                   cfg=cfg, num_pes=PES, noc_bw=BW, seed=0,
                   search_kwargs=dict(strategy="auto", top_k=4,
                                      population=None, block=BLOCK,
                                      multicast=True,
                                      spatial_reduction=True,
                                      l1_budget_kb=None,
                                      l2_budget_kb=None, devices=None))
    assert rep.kind == "layer_codse"
    assert rep.pareto == json.loads(json.dumps(
        Report.from_codse(co).pareto))
    assert rep.best["per_objective"] == Report.from_codse(co).best[
        "per_objective"]
    assert rep.n_evaluated == co.n_evaluated


def test_search_network_parity(session, chain):
    hw = Hardware(num_pes=PES, noc_bw=BW, reconfig_latency=100.0)
    q = Query(Workload.of_layers(chain), hw,
              SearchSpec(objective="edp", budget=80, block=BLOCK,
                         frontier_k=3, budget_policy="uniform"))
    rep = session.run(q)
    r = search_network(chain, objective="edp", budget=80,
                       frontier_k=3, block=BLOCK, hw=hw.hwconfig(),
                       build_kwargs={"cluster": True})
    assert rep.kind == "network"
    assert rep.best["cost"] == r.schedule.cost
    assert rep.best["edp"] == r.schedule.network_edp
    assert tuple(tuple(g) for g in
                 (pl["gene"] for pl in rep.best["per_layer"])) == \
        tuple(tuple(pl["gene"]) for pl in r.schedule.per_layer)
    assert rep.n_evaluated == r.n_evaluated


# ----------------------------------------------------------------------
# run_many: coalescing, determinism, compile budget
# ----------------------------------------------------------------------

def test_run_many_compile_budget(batch_reports, batch_queries):
    reports, batch, compiles = batch_reports
    assert len(reports) == len(batch_queries)
    assert batch["n_coalesced"] == len(batch_queries)
    # at most ONE executable per unique (op-class, level-count) family
    assert compiles <= batch["n_families"]
    assert batch["n_compiles"] <= batch["compile_budget"]
    for q, rep in zip(batch_queries, reports):
        assert rep.kind == "layer" and rep.coalesced
        assert rep.objective == q.search.objective
        assert rep.n_evaluated > 0
        assert len(rep.top_k) <= q.search.top_k
        assert np.isfinite(rep.best["value"])
        # winning genes stay decodable: raw ships the family space
        assert rep.raw.best_dataflow.directives
        # top-k is sorted on the query's own objective
        vals = [e["value"] for e in rep.top_k]
        if q.search.objective == "throughput":
            assert vals == sorted(vals, reverse=True)
        else:
            assert vals == sorted(vals)


def test_run_many_coalesced_vs_sequential(session, batch_queries,
                                          batch_reports):
    reports, _, _ = batch_reports
    seq = session.run_many(batch_queries, coalesce=False)
    assert session.last_batch["n_compiles"] == 0   # families stay warm
    for a, b in zip(reports, seq):
        assert a.results_json() == b.results_json()
        assert a.coalesced and not b.coalesced


def test_run_many_device_determinism(batch_queries, batch_reports):
    """With XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
    smoke job) this compares a real multi-device pmap batch against the
    1-device pass."""
    reports, _, _ = batch_reports
    s_one = Session(devices=1)
    s_many = Session(devices=jax.local_device_count())
    one = s_one.run_many(batch_queries)
    many = s_many.run_many(batch_queries)
    for a, b, c in zip(one, many, reports):
        assert a.results_json() == b.results_json()
        # and both match the module-fixture session's answers
        assert a.results_json() == c.results_json()


def test_submit_flush(session, batch_queries):
    pending = [session.submit(q) for q in batch_queries[:3]]
    assert not any(p.done() for p in pending)
    first = pending[0].result()          # triggers the flush
    assert all(p.done() for p in pending)
    assert first.results_json() == pending[0].result().results_json()
    assert session.last_batch["n_queries"] == 3


def test_mixed_batch_routes_non_coalescible(session, conv, chain):
    qs = [Query(Workload.of_layer(conv),
                Hardware(num_pes=PES, noc_bw=BW),
                SearchSpec(budget=40, block=BLOCK)),
          Query(Workload.of_layers(chain),
                Hardware(num_pes=PES, noc_bw=BW),
                SearchSpec(budget=40, block=BLOCK, frontier_k=2,
                           budget_policy="uniform"))]
    reports = session.run_many(qs)
    assert [r.kind for r in reports] == ["layer", "network"]
    assert reports[0].coalesced and not reports[1].coalesced
    assert session.last_batch["n_coalesced"] == 1


# ----------------------------------------------------------------------
# Report JSON round trip
# ----------------------------------------------------------------------

def test_report_roundtrip(session, conv, batch_reports):
    reports, _, _ = batch_reports
    q = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
              SearchSpec(budget=40, block=BLOCK), tag="rt")
    for rep in [session.run(q)] + list(reports):
        d = rep.to_json()
        rt = Report.from_json(json.loads(json.dumps(d)))
        assert rt.to_json() == d
        assert rt.best == rep.best and rt.kind == rep.kind
    bench = Report.bench("x", {"n_compiles": 3, "custom_key": 1.5})
    d = bench.to_json()
    assert d["n_compiles"] == 3 and d["custom_key"] == 1.5
    assert Report.from_json(d).to_json() == d
    with pytest.raises(ValueError):
        Report(kind="bench", extras={"best": {}}).to_json()


def test_report_from_json_forward_compat(session, conv):
    """A NEWER writer's payload loads on this reader: unknown top-level
    fields land in ``extras`` (and survive re-serialization); only a
    schema_version mismatch is a hard, one-line SpecError."""
    q = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
              SearchSpec(budget=40, block=BLOCK), tag="fwd")
    d = session.run(q).to_json()
    d["a_future_field"] = {"nested": [1, 2]}
    d["another_one"] = "hello"
    rep = Report.from_json(d)
    assert rep.extras["a_future_field"] == {"nested": [1, 2]}
    assert rep.extras["another_one"] == "hello"
    assert rep.to_json()["a_future_field"] == {"nested": [1, 2]}

    from repro.resilience import SpecError
    bad = dict(d, schema_version=d["schema_version"] + 99)
    with pytest.raises(SpecError, match="schema_version") as ei:
        Report.from_json(bad)
    assert ei.value.field == "schema_version"


def test_report_timeout_constructor(conv):
    q = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
              SearchSpec(budget=40, block=BLOCK), tag="to")
    rep = Report.timeout(q, deadline_s=1.5, waited_s=1.7, where="flush")
    assert rep.kind == "timeout" and rep.tag == "to"
    d = rep.to_json()
    assert d["timeout"] == {"deadline_s": 1.5, "waited_s": 1.7,
                            "where": "flush"}
    assert Report.from_json(d).extras["timeout"]["where"] == "flush"


# ----------------------------------------------------------------------
# Disk-cache keying: schema version + query hash
# ----------------------------------------------------------------------

def test_cache_version_invalidates_stale_entries(tmp_path, conv):
    space = build_space(conv, dims=("K", "C"), cluster=False)
    key = ms_cache.search_key(conv, space, PES, BW, "edp", 50, "auto", 0)
    # a stale PR-4-era payload (version 2) under the same key must NOT
    # be replayed
    ms_cache.store(str(tmp_path), key, {"best_value": 1.0})
    import os
    path = os.path.join(str(tmp_path), f"mapsearch-{key}.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["version"] == ms_cache.CACHE_VERSION
    payload["version"] = 2
    with open(path, "w") as f:
        json.dump(payload, f)
    assert ms_cache.load(str(tmp_path), key) is None
    # current-version entries load fine
    ms_cache.store(str(tmp_path), key, {"best_value": 2.0})
    assert ms_cache.load(str(tmp_path), key)["best_value"] == 2.0


def test_cache_key_carries_schema_and_query_hash(conv):
    space = build_space(conv, dims=("K", "C"), cluster=False)
    base = ms_cache.search_key(conv, space, PES, BW, "edp", 50, "auto",
                               0, extra="q=aaa")
    assert ms_cache.search_key(conv, space, PES, BW, "edp", 50, "auto",
                               0, extra="q=bbb") != base
    # the session feeds the query fingerprint through cache_extra: a
    # result cached under one query never answers a different one
    import dataclasses
    q1 = Query(Workload.of_layer(conv), Hardware(num_pes=PES),
               SearchSpec(budget=50))
    q2 = dataclasses.replace(q1, tag="other")
    assert q1.fingerprint() != q2.fingerprint()


def test_session_cache_hit_via_query_fingerprint(tmp_path, conv):
    s = Session(cache_dir=str(tmp_path))
    q = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
              SearchSpec(budget=40, block=BLOCK))
    a = s.run(q)
    assert not a.extras["cached"]
    b = s.run(q)
    assert b.extras["cached"]
    assert a.best == b.best and a.top_k == b.top_k
    # a different query (new fingerprint) misses
    q2 = Query(Workload.of_layer(conv), Hardware(num_pes=PES, noc_bw=BW),
               SearchSpec(budget=40, block=BLOCK), tag="different")
    assert not s.run(q2).extras["cached"]


# ----------------------------------------------------------------------
# Adaptive per-layer budgets
# ----------------------------------------------------------------------

def test_adaptive_budget_policy(session, chain):
    hw = Hardware(num_pes=PES, noc_bw=BW)
    mk = lambda policy, budget: Query(
        Workload.of_layers(chain), hw,
        SearchSpec(objective="edp", budget=budget, block=BLOCK,
                   frontier_k=3, budget_policy=policy))
    uni = session.run(mk("uniform", 120))
    c0 = compile_count()
    ada = session.run(mk("adaptive", 120))
    # refinement rides the warm family executables: zero extra compiles
    assert compile_count() == c0
    assert ada.extras["budget_policy"] == "adaptive"
    assert ada.extras["refined"], "adaptive refined no layer"
    # adaptive spends less than uniform-at-full-budget but more than the
    # cheap first pass alone
    n_unique = ada.extras["n_unique"]
    cheap = max(16, 120 // 4)
    assert ada.n_evaluated <= uni.n_evaluated
    assert ada.n_evaluated > cheap * n_unique
    # deterministic
    ada2 = session.run(mk("adaptive", 120))
    assert ada.results_json() == ada2.results_json()
    # refined layers actually received extra candidates: the refined
    # layer's frontier can only improve on the cheap pass
    cheap_only = session.run(mk("uniform", cheap))
    assert ada.best["cost"] <= cheap_only.best["cost"] * (1 + 1e-9)
    with pytest.raises(ValueError):
        session.run(mk("not-a-policy", 120))
