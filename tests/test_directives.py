"""Directive IR: parsing, validation, resolution, completion (paper §3)."""
import pytest

from repro.core import directives as dv
from repro.core.directives import (Cluster, Dataflow, DataflowError,
                                   SpatialMap, Sz, TemporalMap, complete,
                                   parse, resolve)


def test_parse_paper_syntax():
    df = parse("""
        SpatialMap(1,1) K
        TemporalMap(64,64) C
        TemporalMap(Sz(R),Sz(R)) R
        TemporalMap(Sz(S),Sz(S)) S
        TemporalMap(Sz(R),1) Y
        TemporalMap(Sz(S),1) X
        Cluster(64)
        SpatialMap(1,1) C
    """, name="kc-p")
    assert df.cluster_sizes == (64,)
    assert isinstance(df.directives[0], SpatialMap)
    assert df.directives[2].size == Sz("R")
    assert df.directives[4] == TemporalMap(Sz("R"), 1, "Y")
    assert len(df.levels) == 2


def test_parse_roundtrip():
    from repro.core.dataflows import KC_P
    df2 = parse(str(KC_P).split("{")[1].rsplit("}")[0], name="rt")
    assert df2.directives == KC_P.directives


def test_validation_rejects_bad_programs():
    with pytest.raises(DataflowError):
        Dataflow("bad", (TemporalMap(0, 1, "K"),))
    with pytest.raises(DataflowError):
        Dataflow("bad", (TemporalMap(1, 1, "K"), TemporalMap(2, 2, "K")))
    with pytest.raises(DataflowError):
        Dataflow("bad", (Cluster(0),))


def test_dim_mapped_twice_allowed_across_levels():
    # same dim at different cluster levels is legal (YR-P maps Y twice)
    Dataflow("ok", (SpatialMap(3, 1, "Y"), Cluster(3),
                    SpatialMap(1, 1, "Y")))


def test_resolve_sz_references_other_dim():
    df = Dataflow("t", (TemporalMap(Sz("R"), 1, "Y"),))
    r = resolve(df, {"Y": 16, "R": 3})
    assert r.directives[0].size == 3          # Sz(R) -> 3, not 16
    assert r.directives[0].offset == 1


def test_resolve_clamps_to_dim():
    df = Dataflow("t", (TemporalMap(100, 100, "Y"),))
    r = resolve(df, {"Y": 16})
    assert r.directives[0].size == 16


def test_complete_adds_missing_dims_and_extends():
    df = Dataflow("t", (SpatialMap(1, 1, "C"),))
    c = complete(df, {"C": 8, "K": 4})
    assert {d.dim for d in c.directives} == {"C", "K"}
    # K must come first (outermost implicit temporal map)
    assert c.directives[0].dim == "K"
    assert isinstance(c.directives[0], TemporalMap)


def test_complete_handles_dataflow_dims_missing_from_layer():
    # KC-P applied to a depthwise conv (no K dim): K resolves to extent 1
    from repro.core.dataflows import KC_P
    dims = dv.extended_dims(KC_P, {"C": 8, "Y": 8, "X": 8, "R": 3, "S": 3,
                                   "N": 1})
    assert dims["K"] == 1
    c = complete(KC_P, {"C": 8, "Y": 8, "X": 8, "R": 3, "S": 3, "N": 1})
    k_dirs = [d for d in c.directives
              if not isinstance(d, Cluster) and d.dim == "K"]
    assert k_dirs and k_dirs[0].size == 1


def test_levels_split():
    from repro.core.dataflows import YR_P
    levels = YR_P.levels
    assert len(levels) == 2
    assert [d.dim for d in levels[1]] == ["Y", "R"]
