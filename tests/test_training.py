"""Training substrate: loss goes down, microbatch equivalence, gradient
compression, checkpoint/restart, fault tolerance, straggler detection,
data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import REGISTRY
from repro.data import SyntheticLMDataset, batch_for_step
from repro.ft import FaultTolerantLoop, FTConfig
from repro.models import registry as R
from repro.models.param import init_params
from repro.optim import adamw
from repro.training import TrainConfig, make_train_step

CFG = REGISTRY["olmo-1b"].reduced().replace(vocab=64)
KEY = jax.random.PRNGKey(0)


def batch(step=0, B=8, S=32):
    return {k: jnp.asarray(v) for k, v in batch_for_step(
        step, global_batch=B, seq=S, vocab=CFG.vocab).items()}


def fresh_state(tc=None):
    params = init_params(R.specs(CFG), KEY)
    opt = adamw.init_state(params)
    if tc and tc.compress_grads:
        opt["error_feedback"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt


def test_loss_decreases_over_training():
    tc = TrainConfig(opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=60))
    step = jax.jit(make_train_step(CFG, tc))
    params, opt = fresh_state()
    losses = []
    for i in range(40):
        params, opt, m = step(params, opt, batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9


def test_microbatch_equivalence():
    """4 microbatches == 1 big batch (same grads up to accumulation fp)."""
    tc1 = TrainConfig(microbatches=1)
    tc4 = TrainConfig(microbatches=4)
    s1 = make_train_step(CFG, tc1)
    s4 = make_train_step(CFG, tc4)
    b = batch(0, B=8)
    p1, o1, m1 = s1(*fresh_state(), b)
    p4, o4, m4 = s4(*fresh_state(), b)
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-3)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_grad_compression_trains():
    tc = TrainConfig(compress_grads=True,
                     opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=60))
    step = jax.jit(make_train_step(CFG, tc))
    params, opt = fresh_state(tc)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.95
    # error feedback buffers carry the residual
    ef_norm = sum(float(jnp.sum(jnp.abs(e)))
                  for e in jax.tree.leaves(opt["error_feedback"]))
    assert ef_norm > 0


# ----------------------------------------------------------------------
# checkpoint / restart / elasticity
# ----------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params, opt = fresh_state()
    ck.save(3, (params, opt), extra={"note": 1})
    restored, manifest = ck.restore((params, opt))
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves((params, opt)),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params, _ = fresh_state()
    for s in (1, 2, 3, 4):
        ck.save(s, params, async_save=True)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_restart_resumes_identically(tmp_path):
    """Crash-and-restore must reproduce the uninterrupted run exactly
    (deterministic data pipeline + checkpointed state)."""
    tc = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=50))
    jstep = jax.jit(make_train_step(CFG, tc))

    def wrapped(state, b):
        p, o = state
        p, o, m = jstep(p, o, b)
        return (p, o), m

    def batch_fn(i):
        return batch(i)

    # uninterrupted run
    ck_a = Checkpointer(str(tmp_path / "a"), keep=5)
    loop_a = FaultTolerantLoop(wrapped, ck_a,
                               FTConfig(checkpoint_every=2,
                                        async_save=False))
    state_a, _ = loop_a.run(fresh_state(), batch_fn, 0, 8)

    # run that crashes at step 5 once
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected worker failure")

    ck_b = Checkpointer(str(tmp_path / "b"), keep=5)
    loop_b = FaultTolerantLoop(wrapped, ck_b,
                               FTConfig(checkpoint_every=2,
                                        async_save=False),
                               fault_injector=injector)
    state_b, _ = loop_b.run(fresh_state(), batch_fn, 0, 8)
    assert loop_b.restarts == 1

    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_watchdog(tmp_path):
    import time
    ck = Checkpointer(str(tmp_path), keep=1)
    calls = {"n": 0}

    def slow_step(state, b):
        calls["n"] += 1
        if calls["n"] == 6:
            time.sleep(0.3)          # injected straggler
        else:
            time.sleep(0.01)
        return state, {"loss": jnp.asarray(0.0)}

    flagged = []
    loop = FaultTolerantLoop(
        slow_step, ck, FTConfig(checkpoint_every=1000,
                                straggler_threshold=5.0),
        on_straggler=lambda ev: flagged.append(ev.step))
    loop.run((), lambda i: None, 0, 10)
    assert loop.straggler_steps == [5]
    assert flagged == [5]


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint restores under a different device layout: leaves are
    global arrays; shardings are applied on restore."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path), keep=1)
    x = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, x)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = ck.restore(x, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(x["w"]))
    assert restored["w"].sharding == sh["w"]


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------

def test_data_deterministic_and_shardable():
    full = batch_for_step(7, global_batch=8, seq=16, vocab=64)
    parts = [batch_for_step(7, global_batch=8, seq=16, vocab=64,
                            shard=(i, 4)) for i in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], merged)


def test_dataset_state_roundtrip():
    ds = SyntheticLMDataset(global_batch=4, seq=8, vocab=64)
    next(ds)
    next(ds)
    state = ds.state_dict()
    b3 = next(ds)
    ds2 = SyntheticLMDataset(global_batch=4, seq=8, vocab=64)
    ds2.load_state_dict(state)
    b3b = next(ds2)
    np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])
