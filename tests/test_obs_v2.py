"""Tests for the request-scoped observability layer (obs v2).

Load-bearing properties:

  * request-id and phase scopes ride contextvars: spans inside a
    ``request_scope`` carry the rid(s), mapped leaf spans accumulate
    into the active ``PhaseBreakdown``, and both reset cleanly;
  * ``timing_breakdown`` phases sum to measured wall latency EXACTLY
    (``other`` is the residual by construction);
  * the flight recorder is a bounded ring — wrap-around keeps the most
    recent entries — and its dump file carries reason, environment, and
    the recorded entries (with rids);
  * the Prometheus text exposition is strictly line-format valid (under
    concurrent writers), bucket counts are cumulative-monotonic with a
    closing ``le="+Inf"``, and counter samples agree exactly with the
    JSON snapshot they render from.
"""
import json
import re
import threading

import pytest

from repro import obs
from repro.obs.metrics import LATENCY_BUCKETS_S, Metrics
from repro.obs.prom import prometheus_text


@pytest.fixture(autouse=True)
def _tracer_off():
    """The module toggles tracing/flight-span capture; leave the
    process pristine for later tests."""
    yield
    obs.disable_tracing()
    obs.enable_flight_spans(False)


# ----------------------------------------------------------------------
# Context: request scope + phase accumulation
# ----------------------------------------------------------------------

def test_request_scope_attaches_rid_to_spans_and_resets():
    t = obs.enable_tracing()
    with obs.request_scope("rid-1"):
        assert obs.current_request_ids() == ("rid-1",)
        with obs.span("compile", family="f"):
            pass
    assert obs.current_request_ids() == ()
    with obs.span("compile", family="f"):     # outside any scope
        pass
    obs.disable_tracing()
    evs = [e for e in t.events() if e["name"] == "compile"]
    assert evs[0]["args"]["rid"] == "rid-1"
    assert "rid" not in evs[1]["args"]


def test_request_scope_multi_rid_and_nesting():
    t = obs.enable_tracing()
    with obs.request_scope("a", "b"):
        with obs.span("encode", rows=1):
            pass
        with obs.request_scope("c"):           # inner scope shadows
            assert obs.current_request_ids() == ("c",)
        assert obs.current_request_ids() == ("a", "b")
    obs.disable_tracing()
    ev = next(e for e in t.events() if e["name"] == "encode")
    assert ev["args"]["rid"] == ["a", "b"]


def test_phase_scope_accumulates_mapped_leaf_spans_only():
    with obs.phase_scope() as acc:
        with obs.span("compile", family="f"):
            pass
        with obs.span("device-pass", rows=4):
            pass
        with obs.span("dispatch", rows=4):     # also -> device_pass
            pass
        with obs.span("run_many", queries=2):  # container: unmapped
            pass
    phases = acc.snapshot()
    assert set(phases) == {"compile", "device_pass"}
    assert all(v >= 0.0 for v in phases.values())
    assert obs.current_phases() is None


def test_phase_breakdown_is_thread_safe():
    acc = obs.PhaseBreakdown()

    def work():
        for _ in range(500):
            acc.add("compile", 0.001)
    threads = [threading.Thread(target=work) for _ in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert acc.snapshot()["compile"] == pytest.approx(8 * 500 * 0.001)


def test_timing_breakdown_phases_sum_to_wall_exactly():
    doc = obs.timing_breakdown(
        1.0, {"compile": 0.3, "device_pass": 0.25, "encode": 0.05},
        request_id="r1")
    assert doc["request_id"] == "r1"
    assert doc["phases"]["other"] == pytest.approx(0.4)
    assert sum(doc["phases"].values()) == pytest.approx(doc["wall_s"])
    # zero-valued phases are dropped; other never goes negative
    doc = obs.timing_breakdown(0.1, {"compile": 0.0})
    assert set(doc["phases"]) == {"other"}
    doc = obs.timing_breakdown(0.1, {"compile": 0.2})
    assert doc["phases"]["other"] == 0.0
    for p in doc["phases"]:
        assert p in obs.PHASE_NAMES


def test_disabled_span_stays_null_without_any_sink():
    from repro.obs.trace import NULL_SPAN
    assert obs.span("anything", x=1) is NULL_SPAN
    with obs.phase_scope():
        assert obs.span("anything") is not NULL_SPAN
    obs.enable_flight_spans(True)
    try:
        assert obs.span("anything") is not NULL_SPAN
    finally:
        obs.enable_flight_spans(False)
    assert obs.span("anything") is NULL_SPAN


# ----------------------------------------------------------------------
# Flight recorder: bounded ring + dump
# ----------------------------------------------------------------------

def test_flight_ring_wraps_keeping_most_recent():
    rec = obs.FlightRecorder(capacity=16)
    for i in range(40):
        rec.record("event", f"e{i}", i=i)
    entries = rec.entries()
    assert len(entries) == 16
    assert [e["seq"] for e in entries] == list(range(24, 40))
    assert entries[-1]["name"] == "e39"


def test_flight_record_attaches_rid_and_survives_key_collisions():
    rec = obs.FlightRecorder(capacity=8)
    with obs.request_scope("rid-9"):
        # span args may collide with structural entry keys — the
        # structural keys must win, not raise
        rec.record("span", "query", kind="layer", name="shadow",
                   t=123, dur_s=0.5)
    (e,) = rec.entries()
    assert e["rid"] == "rid-9"
    assert e["kind"] == "span" and e["name"] == "query"
    assert e["dur_s"] == 0.5


def test_flight_dump_writes_reason_env_and_entries(tmp_path):
    rec = obs.FlightRecorder(capacity=8)
    with obs.request_scope("rid-d"):
        rec.record("error", "boom", detail="x")
    path = rec.dump(str(tmp_path), "unit-test", request_ids=["rid-d"])
    doc = json.load(open(path))
    assert doc["reason"] == "unit-test"
    assert doc["request_ids"] == ["rid-d"]
    assert "environment" in doc
    (e,) = [d for d in doc["entries"] if d["name"] == "boom"]
    assert e["rid"] == "rid-d" and e["detail"] == "x"
    # rate-limited variant: an immediate second dump is suppressed
    assert rec.maybe_dump(str(tmp_path), "again") is None


def test_flight_span_capture_feeds_ring_when_enabled():
    rec = obs.flight_recorder()
    seq0 = [e["seq"] for e in rec.entries()][-1] if rec.entries() else -1
    obs.enable_flight_spans(True)
    try:
        with obs.request_scope("rid-s"):
            with obs.span("device-pass", rows=2):
                pass
    finally:
        obs.enable_flight_spans(False)
    new = [e for e in rec.entries() if e["seq"] > seq0]
    spans = [e for e in new if e["kind"] == "span"
             and e["name"] == "device-pass"]
    assert spans and spans[-1]["rid"] == "rid-s"
    assert spans[-1]["rows"] == 2


# ----------------------------------------------------------------------
# Prometheus exposition: strict format, monotonicity, parity
# ----------------------------------------------------------------------

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABELS = r'\{' + _NAME + r'="(?:[^"\\]|\\.)*"' + \
    r'(?:,' + _NAME + r'="(?:[^"\\]|\\.)*")*\}'
_VALUE = r"-?(?:[0-9]+(?:\.[0-9]+)?(?:e[+-]?[0-9]+)?|inf|nan)"
_EXEMPLAR = r'(?: # \{request_id="(?:[^"\\]|\\.)*"\} ' + _VALUE + r')?'
SAMPLE_RE = re.compile(
    f"^{_NAME}(?:{_LABELS})? {_VALUE}{_EXEMPLAR}$", re.IGNORECASE)
TYPE_RE = re.compile(
    f"^# TYPE {_NAME} (counter|gauge|summary|histogram)$")


def _assert_valid_exposition(text: str) -> None:
    assert text.endswith("\n")
    for line in text.rstrip("\n").split("\n"):
        if line.startswith("#"):
            assert TYPE_RE.match(line), f"bad TYPE line: {line!r}"
        else:
            assert SAMPLE_RE.match(line), f"bad sample line: {line!r}"


def _populated() -> Metrics:
    m = Metrics()
    m.inc("serve.requests", 7)
    m.inc("serve.shed_detail", 2, reason="queue")
    m.gauge("serve.queue_depth", 3)
    m.gauge("result_cache.bytes", 4096)
    m.observe("serve.batch_size", 5)
    m.observe_bucketed("serve.latency_s", 0.093, kind="layer",
                       exemplar="ab12")
    m.observe_bucketed("serve.latency_s", 31.0, kind="layer",
                       exemplar="cd34")
    m.observe_bucketed("serve.phase_s", 0.004, phase="compile")
    return m


def test_prometheus_exposition_is_strictly_line_valid():
    text = prometheus_text(_populated().snapshot())
    _assert_valid_exposition(text)
    assert "# TYPE serve_requests counter" in text
    assert "serve_requests 7" in text
    assert 'serve_shed_detail{reason="queue"} 2' in text
    assert "# TYPE serve_latency_s histogram" in text
    assert 'le="+Inf"' in text
    assert '# {request_id="ab12"} 0.093' in text


def test_prometheus_under_concurrent_writers_stays_valid():
    m = _populated()
    stop = threading.Event()
    errs: list[BaseException] = []

    def writer(i: int):
        try:
            while not stop.is_set():
                m.inc("load.counter", worker=str(i))
                m.observe_bucketed("load.lat_s", 0.01 * i,
                                   exemplar=f"w{i}")
        except BaseException as e:  # noqa: BLE001 — reported below
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(8)]
    for th in threads:
        th.start()
    try:
        for _ in range(50):
            _assert_valid_exposition(prometheus_text(m.snapshot()))
    finally:
        stop.set()
        for th in threads:
            th.join()
    assert not errs


def test_bucket_histogram_cumulative_monotone_with_inf_closing():
    m = Metrics()
    for v in (0.0005, 0.003, 0.003, 0.7, 200.0):
        m.observe_bucketed("lat_s", v)
    h = m.snapshot()["bucket_histograms"]["lat_s"]
    cums = [c for _, c in h["buckets"]]
    assert cums == sorted(cums)
    assert h["buckets"][-1][0] == "+Inf"
    assert h["buckets"][-1][1] == h["count"] == 5
    assert len(h["buckets"]) == len(LATENCY_BUCKETS_S) + 1
    # le is an INCLUSIVE upper bound: 0.001 lands in the 0.001 bucket
    m2 = Metrics()
    m2.observe_bucketed("x", 0.001)
    assert m2.snapshot()["bucket_histograms"]["x"]["buckets"][0] \
        == [0.001, 1]


def test_prometheus_counters_agree_with_json_snapshot():
    snap = _populated().snapshot()
    text = prometheus_text(snap)
    sampled: dict[str, float] = {}
    for line in text.rstrip("\n").split("\n"):
        if line.startswith("#"):
            continue
        head, _, rest = line.partition(" ")
        sampled[head] = float(rest.split(" # ")[0])
    for key, want in snap["counters"].items():
        name = key.split("[")[0].replace(".", "_")
        labels = ""
        if "[" in key:
            inner = key[key.index("[") + 1:-1]
            labels = "{" + ",".join(
                f'{k}="{v}"' for k, v in
                (p.split("=", 1) for p in inner.split(","))) + "}"
        assert sampled[name + labels] == float(want), key
    # histogram sum/count parity too
    h = snap["bucket_histograms"]["serve.latency_s[kind=layer]"]
    assert sampled['serve_latency_s_count{kind="layer"}'] == h["count"]
    assert sampled['serve_latency_s_sum{kind="layer"}'] \
        == pytest.approx(h["total"])


def test_metric_name_sanitization():
    m = Metrics()
    m.inc("weird.name-with/slash", 1)
    text = prometheus_text(m.snapshot())
    _assert_valid_exposition(text)
    assert "weird_name_with_slash 1" in text
