"""Serving engine: slot recycling, lockstep decode, completion."""
import jax
import numpy as np

from repro.configs import REGISTRY
from repro.inference import ServeEngine
from repro.models import registry as R
from repro.models.param import init_params


def test_engine_completes_requests():
    cfg = REGISTRY["olmo-1b"].reduced()
    params = init_params(R.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab, 8), max_new=4)
            for _ in range(3)]  # 3 requests > 2 slots -> forces recycling
    done = eng.run(max_steps=100)
    assert len(done) == 3
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab for t in r.generated)
