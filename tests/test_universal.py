"""Tests for the universal structure-as-operand evaluator
(repro.mapspace.universal) and its consumers.

The load-bearing properties:

  * features match the faithful integer engine exactly (integer
    quantities) / within float32 tolerance, across permutations, spatial
    choices and cluster options — structure lives in operands;
  * a multi-group ``evaluate_points`` call triggers at most TWO XLA
    compiles (one per level-count family), however many structure groups
    the points span;
  * permutation dedupe is lossless and budget pruning is sound;
  * the joint mapping × hardware sweep agrees with the legacy staged DSE.
"""
import numpy as np
import pytest

from repro.core import tensor_analysis as ta
from repro.core.dse import DSEConfig, run_dse
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.core.vectorized import FEATURES
from repro.mapspace import (build_space, buffer_estimate_kb,
                            dedupe_equivalent_points, enumerate_points,
                            evaluate_points, point_dataflow,
                            prune_by_budget, sample_points, search)
from repro.mapspace.universal import compile_count

HW = HWConfig(num_pes=48, noc_bw=12.0, noc_latency=2.0)

# pure-product integer quantities are asserted exactly; quantities built
# from divisions/accumulations within float32 tolerance (the batched
# evaluators run in f32, the faithful engine in exact Python numbers)
_INT_FEATURES = ("macs",)
_REL = 1e-3


@pytest.fixture(scope="module")
def conv_op():
    return ta.conv2d("uni-conv", k=8, c=6, y=12, x=12, r=3, s=3)


@pytest.fixture(scope="module")
def conv_space(conv_op):
    # window-outer axis (Y) + sliding cluster inner: the hard cases
    return build_space(conv_op, dims=("K", "C", "Y"), cluster_sizes=(8,),
                      perm_mode="all")


def _assert_matches_faithful(op, space, pts, feats, hw):
    for i, pt in enumerate(pts):
        s = analyze(op, point_dataflow(space, pt), hw)
        ref = {"runtime": float(s.runtime), "energy_pj": float(s.energy_pj),
               "macs": float(s.total_macs), "l1_kb": float(s.l1_req_kb),
               "l2_kb": float(s.l2_req_kb), "util": float(s.utilization),
               "bw_req": float(s.peak_bw.get(0, 0)), "edp": float(s.edp)}
        got = dict(zip(FEATURES, feats[i]))
        for k, v in ref.items():
            if k in _INT_FEATURES:
                assert got[k] == v, (pt, k)
            else:
                assert got[k] == pytest.approx(v, rel=_REL), (pt, k)


def test_universal_matches_faithful_across_structures(conv_op, conv_space):
    """Every structure group — permutations, spatial choices, cluster
    options — through ONE executable pair, matching the faithful engine."""
    rng = np.random.default_rng(0)
    pts = sample_points(conv_space, rng, 48)
    groups = {conv_space.group_key(p) for p in pts}
    assert len(groups) > 10  # genuinely multi-structure
    feats, stats = evaluate_points(conv_op, conv_space, pts,
                                   num_pes=HW.num_pes, noc_bw=HW.noc_bw,
                                   block=64)
    _assert_matches_faithful(conv_op, conv_space, pts, feats, HW)


def test_at_most_two_compiles_for_multigroup_eval():
    """Regression: a fresh multi-group space costs <= 2 XLA compiles (its
    1-level and 2-level families), not one per structure group."""
    op = ta.conv2d("uni-compiles", k=8, c=4, y=10, x=10, r=3, s=3)
    space = build_space(op, dims=("K", "C"), cluster_sizes=(4,),
                        perm_mode="all")
    assert space.n_groups >= 8
    rng = np.random.default_rng(1)
    pts = sample_points(space, rng, 64)
    assert len({space.group_key(p) for p in pts}) >= 6
    before = compile_count()
    feats, stats = evaluate_points(op, space, pts, num_pes=32,
                                   noc_bw=8.0, block=64)
    assert compile_count() - before <= 2
    assert stats.n_compiles <= 2
    # second call: fully warm, zero compiles
    before = compile_count()
    evaluate_points(op, space, pts[:16], num_pes=32, noc_bw=8.0, block=64)
    assert compile_count() - before == 0


def test_strided_conv_and_fc_match_faithful():
    rng = np.random.default_rng(2)
    cases = [
        (ta.conv2d("uni-stride", k=4, c=4, y=11, x=11, r=3, s=3, stride=2),
         dict(dims=("K", "C", "Y"), cluster_sizes=(4,))),
        (ta.fc("uni-fc", n=4, k=16, c=12),
         dict(dims=("K", "C", "N"), cluster_sizes=(4,), perm_mode="all")),
    ]
    for op, kw in cases:
        space = build_space(op, **kw)
        pts = sample_points(space, rng, 24)
        feats, _ = evaluate_points(op, space, pts, num_pes=HW.num_pes,
                                   noc_bw=HW.noc_bw, block=32)
        _assert_matches_faithful(op, space, pts, feats, HW)


def test_grouped_engine_agrees_with_universal(conv_op, conv_space):
    """The legacy per-group engine stays as an independent cross-check."""
    rng = np.random.default_rng(3)
    pts = sample_points(conv_space, rng, 12)
    fu, _ = evaluate_points(conv_op, conv_space, pts, num_pes=HW.num_pes,
                            noc_bw=HW.noc_bw, block=16,
                            engine="universal")
    fg, _ = evaluate_points(conv_op, conv_space, pts, num_pes=HW.num_pes,
                            noc_bw=HW.noc_bw, block=16, engine="grouped")
    np.testing.assert_allclose(fu, fg, rtol=1e-5)


# ----------------------------------------------------------------------
# Space pruning satellites
# ----------------------------------------------------------------------

def test_dedupe_is_lossless(conv_op, conv_space):
    """Points collapsed onto one representative have identical faithful
    stats (permutations differing only in trip-count-1 loops)."""
    pts = list(enumerate_points(conv_space))
    reps, back = dedupe_equivalent_points(conv_op, conv_space, pts)
    assert len(reps) < len(pts)  # something was actually pruned
    rng = np.random.default_rng(4)
    checked = 0
    for i in rng.permutation(len(pts)):
        pt, rep = pts[i], reps[back[i]]
        if pt == rep:
            continue
        a = analyze(conv_op, point_dataflow(conv_space, pt), HW)
        b = analyze(conv_op, point_dataflow(conv_space, rep), HW)
        assert float(a.runtime) == float(b.runtime)
        assert float(a.energy_pj) == pytest.approx(float(b.energy_pj))
        assert float(a.total_macs) == float(b.total_macs)
        checked += 1
        if checked >= 20:
            break
    assert checked > 0


def test_budget_pruning_is_sound(conv_op, conv_space):
    """The working-set estimate is a lower bound: pruning never drops a
    mapping that actually fits the budget."""
    rng = np.random.default_rng(5)
    pts = sample_points(conv_space, rng, 32)
    feats, _ = evaluate_points(conv_op, conv_space, pts,
                               num_pes=HW.num_pes, noc_bw=HW.noc_bw,
                               block=32)
    l1_col = FEATURES.index("l1_kb")
    l2_col = FEATURES.index("l2_kb")
    for i, pt in enumerate(pts):
        e1, e2 = buffer_estimate_kb(conv_op, conv_space, pt)
        assert e1 <= feats[i, l1_col] * (1 + 1e-5)
        assert e2 <= feats[i, l2_col] * (1 + 1e-5)
    budget = float(np.median(feats[:, l1_col]))
    kept = prune_by_budget(conv_op, conv_space, pts, l1_kb=budget)
    for i, pt in enumerate(pts):
        if feats[i, l1_col] <= budget:      # actually fits
            assert pt in kept               # ... must not be pruned


# ----------------------------------------------------------------------
# Search-level satellites
# ----------------------------------------------------------------------

def test_genetic_strategy_deterministic_and_competitive(conv_op):
    space = build_space(conv_op, dims=("K", "C"), cluster_sizes=(4,))
    kw = dict(objective="edp", budget=150, space=space,
              num_pes=HW.num_pes, noc_bw=HW.noc_bw, strategy="genetic",
              block=64)
    a = search(conv_op, seed=7, **kw)
    b = search(conv_op, seed=7, **kw)
    assert a.best_point == b.best_point
    assert a.best_value == b.best_value
    assert a.n_evaluated <= 150
    exhaustive = search(conv_op, objective="edp", budget=10_000,
                        space=space, num_pes=HW.num_pes, noc_bw=HW.noc_bw,
                        strategy="exhaustive", block=64)
    # genetic explores structure freely; must land within 2x of optimum
    assert a.best_value <= exhaustive.best_value * 2.0


def test_greedy_structural_moves_unrestricted(conv_op):
    """Neighbors now mutate structural genes freely — the search visits
    groups far beyond any legacy max_groups clamp."""
    space = build_space(conv_op, dims=("K", "C", "Y"), cluster=False,
                        perm_mode="all")
    assert space.n_groups > 12
    r = search(conv_op, objective="edp", budget=400, space=space,
               num_pes=HW.num_pes, noc_bw=HW.noc_bw, strategy="greedy",
               seed=0, block=64)
    assert r.n_groups > 12  # legacy default clamp was 12


def test_mappings_per_s_single_definition(conv_op):
    """EvalStats and SearchResult quote the same steady-state rate."""
    space = build_space(conv_op, dims=("K", "C"), cluster=False)
    rng = np.random.default_rng(8)
    pts = sample_points(space, rng, 40)
    _, stats = evaluate_points(conv_op, space, pts, num_pes=HW.num_pes,
                               noc_bw=HW.noc_bw, block=64)
    assert stats.n_steady == len(pts)
    assert stats.mappings_per_s == pytest.approx(
        stats.n_steady / max(stats.eval_s, 1e-9))
    r = search(conv_op, objective="edp", budget=60, space=space,
               num_pes=HW.num_pes, noc_bw=HW.noc_bw, strategy="random",
               seed=0, block=64)
    assert r.mappings_per_s == pytest.approx(
        r.n_steady / max(r.eval_s, 1e-9))
    # steady rows never exceed evaluated mappings (dedupe only shrinks)
    assert r.n_steady <= r.n_evaluated


def test_joint_codse_matches_staged_dse(conv_op):
    """The merged mapping × hardware frontier (pes/bw as operands of the
    universal executable) reproduces run_dse's staged numbers."""
    from repro.mapspace import co_search
    space = build_space(conv_op, dims=("K", "C"), cluster_sizes=(4,))
    cfg = DSEConfig(pe_range=(16, 32, 64), bw_range=(4.0, 8.0))
    co = co_search(conv_op, objective="edp", mapping_budget=100, top_k=2,
                   cfg=cfg, num_pes=HW.num_pes, noc_bw=HW.noc_bw,
                   space=space, search_kwargs={"block": 64})
    assert co.pareto, "joint frontier is empty"
    label, joint = co.dse[0]
    pt = co.search.top_k[0]["point"]
    legacy = run_dse(conv_op, point_dataflow(space, pt), cfg)
    np.testing.assert_allclose(np.asarray(joint.stats.energy_pj),
                               np.asarray(legacy.stats.energy_pj),
                               rtol=1e-5)
    np.testing.assert_array_equal(joint.valid, legacy.valid)


# ----------------------------------------------------------------------
# Hypothesis property test (optional dependency)
# ----------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False


if _HAS_HYPOTHESIS:
    import hypothesis.strategies as hst
    from hypothesis import given, settings

    # one fixed op/space so the whole property run reuses the same two
    # compiled executables; hypothesis drives the *mapping structure*
    # (permutation, spatial choice, cluster option, tiles) and hardware
    _PROP_OP = ta.conv2d("uni-prop", k=8, c=4, y=10, x=10, r=3, s=3)
    _PROP_SPACE = build_space(_PROP_OP, dims=("K", "C", "Y"),
                              cluster_sizes=(4,), perm_mode="all")

    @hst.composite
    def legal_point(draw):
        return tuple(draw(hst.integers(0, r - 1))
                     for r in _PROP_SPACE.gene_ranges())

    @given(legal_point(),
           hst.integers(min_value=2, max_value=128),
           hst.floats(min_value=1.0, max_value=64.0))
    @settings(max_examples=40, deadline=None)
    def test_property_universal_matches_faithful(pt, pes, bw):
        hw = HWConfig(num_pes=pes, noc_bw=bw, noc_latency=2.0)
        feats, _ = evaluate_points(_PROP_OP, _PROP_SPACE, [pt],
                                   num_pes=pes, noc_bw=bw, block=8)
        _assert_matches_faithful(_PROP_OP, _PROP_SPACE, [pt], feats, hw)
