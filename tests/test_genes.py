"""Tests for the device-resident gene-matrix DSE pipeline.

Load-bearing properties:

  * gene-matrix machinery (enumerate/sample/decode, vectorized dedupe +
    budget pruning, operand encoding) is exactly equivalent to the legacy
    per-point tuple path;
  * the fused on-device reduction (objective column, top-k, Pareto mask)
    matches a host numpy reference computed from full feature matrices;
  * `search(pipeline="gene")` reproduces `search(pipeline="legacy")`
    top-k values and stats on fixed seeds, for 1- and 2-level spaces;
  * the sharded path is deterministic: striping over N local devices
    (run CI-side with XLA_FLAGS=--xla_force_host_platform_device_count=4)
    returns exactly the single-device results;
  * the whole pipeline still costs <= 2 XLA compiles per (op,
    level-count) family;
  * the paper-scale joint sweep reproduces the staged run_dse accounting.
"""
import numpy as np
import pytest
import jax

from repro.core import tensor_analysis as ta
from repro.core.dse import DSEConfig, run_dse
from repro.core.vectorized import FEATURES
from repro.mapspace import (build_space, buffer_estimate_kb,
                            buffer_estimates_genes, decode_indices,
                            dedupe_equivalent_genes,
                            dedupe_equivalent_points, encode_genes,
                            enumerate_genes, enumerate_points,
                            evaluate_genes, evaluate_points, flat_index,
                            genes_from_points, joint_sweep,
                            point_dataflow, points_from_genes,
                            prune_by_budget, prune_genes_by_budget,
                            sample_genes, search)
from repro.mapspace.universal import (compile_count, encode_points,
                                      universal_specs)
from repro.mapspace.space import gene_tables

PES, BW = 48, 12.0


@pytest.fixture(scope="module")
def conv_op():
    return ta.conv2d("gene-conv", k=8, c=6, y=12, x=12, r=3, s=3)


@pytest.fixture(scope="module")
def conv_space(conv_op):
    # window-outer axis (Y) + sliding cluster inner + 2-level options:
    # the hard cases
    return build_space(conv_op, dims=("K", "C", "Y"), cluster_sizes=(8,),
                       perm_mode="all")


@pytest.fixture(scope="module")
def flat_space(conv_op):
    return build_space(conv_op, dims=("K", "C"), cluster=False)


# ----------------------------------------------------------------------
# Gene-matrix machinery vs the legacy tuple-point loops
# ----------------------------------------------------------------------

def test_enumerate_genes_matches_points(conv_space):
    pts = list(enumerate_points(conv_space))
    g = enumerate_genes(conv_space)
    assert np.array_equal(g, genes_from_points(pts))
    assert points_from_genes(g) == pts
    # mixed-radix decode/encode roundtrip
    assert np.array_equal(flat_index(conv_space, g),
                          np.arange(conv_space.size))
    sl = enumerate_genes(conv_space, 100, 163)
    assert np.array_equal(sl, g[100:163])
    assert np.array_equal(decode_indices(conv_space, [0]), g[:1])


def test_sample_genes_deterministic_distinct_excluding(conv_space):
    a = sample_genes(conv_space, np.random.default_rng(7), 50)
    b = sample_genes(conv_space, np.random.default_rng(7), 50)
    assert np.array_equal(a, b)
    fa = flat_index(conv_space, a)
    assert len(np.unique(fa)) == len(a) == 50
    c = sample_genes(conv_space, np.random.default_rng(8), 50,
                     exclude_flat=fa)
    assert not set(flat_index(conv_space, c).tolist()) & set(fa.tolist())


def test_dedupe_genes_matches_legacy_partition(conv_op, conv_space):
    pts = list(enumerate_points(conv_space))
    reps, back = dedupe_equivalent_points(conv_op, conv_space, pts)
    g = enumerate_genes(conv_space)
    rrows, gback = dedupe_equivalent_genes(conv_op, conv_space, g)
    assert [pts[i] for i in rrows] == reps
    assert np.array_equal(gback, np.asarray(back))
    assert len(rrows) < len(pts)        # something actually collapsed


def test_buffer_estimates_and_pruning_match_legacy(conv_op, conv_space):
    g = enumerate_genes(conv_space)
    pts = points_from_genes(g)
    l1, l2 = buffer_estimates_genes(conv_op, conv_space, g)
    ref = np.asarray([buffer_estimate_kb(conv_op, conv_space, p)
                      for p in pts])
    np.testing.assert_allclose(l1, ref[:, 0], rtol=0, atol=0)
    np.testing.assert_allclose(l2, ref[:, 1], rtol=0, atol=0)
    budget = float(np.median(l1))
    kept = prune_genes_by_budget(conv_op, conv_space, g, l1_kb=budget)
    assert points_from_genes(kept) == \
        prune_by_budget(conv_op, conv_space, pts, l1_kb=budget)


def test_encode_genes_matches_encode_points(conv_op, conv_space):
    rng = np.random.default_rng(0)
    g = sample_genes(conv_space, rng, 64)
    pts = points_from_genes(g)
    spec1, spec2 = universal_specs(conv_op, conv_space)
    is2 = ~gene_tables(conv_op, conv_space).cluster_is_none[g[:, 2]]
    for spec, mask in ((spec1, ~is2), (spec2, is2)):
        sub = g[mask]
        subp = [p for p, m in zip(pts, mask) if m]
        assert len(subp) > 4
        a = encode_genes(conv_op, conv_space, sub, spec,
                         num_pes=PES, noc_bw=BW)
        b = encode_points(conv_op, conv_space, subp, spec,
                          num_pes=PES, noc_bw=BW)
        assert set(a) == set(b)
        for k in b:
            assert np.array_equal(a[k], b[k]), (bool(spec.cluster), k)
    with pytest.raises(ValueError):
        encode_genes(conv_op, conv_space, g[is2], spec1,
                     num_pes=PES, noc_bw=BW)


# ----------------------------------------------------------------------
# On-device reduction tail vs host numpy reference
# ----------------------------------------------------------------------

def test_on_device_topk_and_pareto_match_numpy(conv_op, conv_space):
    rng = np.random.default_rng(1)
    g = sample_genes(conv_space, rng, 200)
    ev = evaluate_genes(conv_op, conv_space, g, objective="edp", k=8,
                        num_pes=PES, noc_bw=BW, block=64)
    feats, _ = evaluate_points(conv_op, conv_space, points_from_genes(g),
                               num_pes=PES, noc_bw=BW, block=64)
    ref = feats[:, FEATURES.index("edp")].astype(np.float64)
    ref = np.where(np.isfinite(ref), ref, np.inf)
    np.testing.assert_allclose(ev.vals, ref, rtol=1e-6)
    order = np.lexsort((np.arange(len(ref)), ref))
    assert [t["row"] for t in ev.top] == list(order[:8])
    for t in ev.top:
        np.testing.assert_allclose(t["feats"], feats[t["row"]], rtol=1e-6)
    # host-refined frontier == exact frontier over the full columns
    e = feats[:, FEATURES.index("energy_pj")].astype(np.float64)
    th = feats[:, FEATURES.index("throughput")].astype(np.float64)
    o = np.lexsort((np.arange(len(e)), -th, e))
    best, front = -np.inf, []
    for i in o:
        if th[i] > best and np.isfinite(e[i]):
            best = th[i]
            front.append(int(i))
    assert [p["row"] for p in ev.pareto] == front
    assert ev.run.n_valid == int(np.isfinite(ref).sum())


def test_gene_pipeline_at_most_two_compiles():
    op = ta.conv2d("gene-compiles", k=8, c=4, y=10, x=10, r=3, s=3)
    space = build_space(op, dims=("K", "C"), cluster_sizes=(4,),
                        perm_mode="all")
    assert space.n_groups >= 8
    g = sample_genes(space, np.random.default_rng(2), 96)
    before = compile_count()
    ev = evaluate_genes(op, space, g, objective="edp", k=4,
                        num_pes=32, noc_bw=8.0, block=64)
    assert compile_count() - before <= 2
    assert ev.run.n_compiles <= 2
    # second call (any subset, same block): fully warm
    before = compile_count()
    evaluate_genes(op, space, g[:20], objective="edp", k=4,
                   num_pes=32, noc_bw=8.0, block=64)
    assert compile_count() - before == 0


# ----------------------------------------------------------------------
# Sharded path: determinism at any device count
# ----------------------------------------------------------------------

def test_sharded_matches_single_device(conv_op, conv_space):
    """With XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
    smoke job) this compares a real 4-device pmap against the 1-device
    jit; on one device it still exercises the full merge path."""
    rng = np.random.default_rng(3)
    g = sample_genes(conv_space, rng, 150)
    kw = dict(objective="edp", k=8, num_pes=PES, noc_bw=BW, block=32)
    one = evaluate_genes(conv_op, conv_space, g, n_devices=1, **kw)
    many = evaluate_genes(conv_op, conv_space, g,
                          n_devices=jax.local_device_count(), **kw)
    assert many.run.n_devices == jax.local_device_count()
    np.testing.assert_array_equal(one.vals, many.vals)
    assert [t["row"] for t in one.top] == [t["row"] for t in many.top]
    assert [t["value"] for t in one.top] == [t["value"] for t in many.top]
    for a, b in zip(one.top, many.top):
        np.testing.assert_array_equal(a["feats"], b["feats"])
    assert one.pareto == many.pareto
    assert one.run.n_valid == many.run.n_valid


def test_search_sharded_deterministic(conv_op, conv_space):
    kw = dict(objective="edp", budget=120, space=conv_space, num_pes=PES,
              noc_bw=BW, strategy="greedy", seed=5, block=32)
    one = search(conv_op, devices=1, **kw)
    many = search(conv_op, devices=jax.local_device_count(), **kw)
    assert one.best_point == many.best_point
    assert one.best_value == many.best_value
    assert [e["point"] for e in one.top_k] == \
        [e["point"] for e in many.top_k]


# ----------------------------------------------------------------------
# search(): gene pipeline vs legacy tuple-point parity on fixed seeds
# ----------------------------------------------------------------------

def _assert_search_parity(a, b):
    assert a.strategy == b.strategy
    assert a.n_evaluated == b.n_evaluated
    assert a.n_groups == b.n_groups
    assert a.best_point == b.best_point
    assert a.best_value == pytest.approx(b.best_value, rel=1e-6)
    assert [e["point"] for e in a.top_k] == [e["point"] for e in b.top_k]
    for ea, eb in zip(a.top_k, b.top_k):
        assert ea["value"] == pytest.approx(eb["value"], rel=1e-6)
        for k in ea["stats"]:
            assert ea["stats"][k] == pytest.approx(
                eb["stats"][k], rel=1e-5, abs=1e-9), k


@pytest.mark.parametrize("strategy", ["exhaustive", "random", "greedy"])
def test_gene_matches_legacy_two_level(conv_op, conv_space, strategy):
    budget = 10_000 if strategy == "exhaustive" else 150
    kw = dict(objective="edp", budget=budget, space=conv_space,
              num_pes=PES, noc_bw=BW, strategy=strategy, seed=0, block=64)
    _assert_search_parity(search(conv_op, pipeline="gene", **kw),
                          search(conv_op, pipeline="legacy", **kw))


def test_gene_matches_legacy_one_level(conv_op, flat_space):
    kw = dict(objective="edp", budget=10_000, space=flat_space,
              num_pes=PES, noc_bw=BW, strategy="exhaustive", seed=0,
              block=64)
    a = search(conv_op, pipeline="gene", **kw)
    _assert_search_parity(a, search(conv_op, pipeline="legacy", **kw))
    assert a.n_evaluated == flat_space.size


def test_gene_genetic_deterministic_and_competitive(conv_op, flat_space):
    kw = dict(objective="edp", budget=150, space=flat_space, num_pes=PES,
              noc_bw=BW, strategy="genetic", seed=7, block=64)
    a = search(conv_op, pipeline="gene", **kw)
    b = search(conv_op, pipeline="gene", **kw)
    assert a.best_point == b.best_point
    assert a.best_value == b.best_value
    assert a.n_evaluated <= 150
    exhaustive = search(conv_op, objective="edp", budget=10_000,
                        space=flat_space, num_pes=PES, noc_bw=BW,
                        strategy="exhaustive", block=64)
    assert a.best_value <= exhaustive.best_value * 2.0


def test_search_budget_pruning_gene_matches_legacy(conv_op, conv_space):
    l1 = float(np.median(buffer_estimates_genes(
        conv_op, conv_space, enumerate_genes(conv_space))[0]))
    kw = dict(objective="edp", budget=120, space=conv_space, num_pes=PES,
              noc_bw=BW, strategy="random", seed=2, block=64,
              l1_budget_kb=l1)
    _assert_search_parity(search(conv_op, pipeline="gene", **kw),
                          search(conv_op, pipeline="legacy", **kw))


def test_search_reports_end_to_end_rate(conv_op, flat_space):
    r = search(conv_op, objective="edp", budget=60, space=flat_space,
               num_pes=PES, noc_bw=BW, strategy="random", seed=0,
               block=64)
    assert r.pipeline == "gene"
    assert r.end_to_end_mappings_per_s > 0
    assert r.elapsed_s >= r.encode_s
    assert r.wall_s > 0
    assert r.end_to_end_mappings_per_s == pytest.approx(
        r.n_evaluated / (r.wall_s - r.compile_s))
    assert r.n_devices >= 1


# ----------------------------------------------------------------------
# Paper-scale joint sweep vs staged run_dse accounting
# ----------------------------------------------------------------------

def test_joint_sweep_matches_staged_run_dse(conv_op):
    space = build_space(conv_op, dims=("K", "C"), cluster_sizes=(4,))
    g = sample_genes(space, np.random.default_rng(0), 5)
    cfg = DSEConfig(pe_range=(16, 32, 64), bw_range=(4.0, 8.0, 16.0))
    js = joint_sweep(conv_op, space, g, cfg, objective="edp", k=6,
                     block=32)
    assert js.n_designs == 5 * 9
    assert js.n_compiles <= 2
    # staged reference: run_dse per mapping (host numpy accounting)
    cands = []
    for pt in points_from_genes(g):
        r = run_dse(conv_op, point_dataflow(space, pt), cfg)
        for i in np.where(r.valid)[0]:
            cands.append((float(np.asarray(r.stats.edp)[i]),
                          float(np.asarray(r.stats.energy_pj)[i]),
                          float(np.asarray(r.stats.throughput)[i]),
                          pt, int(r.num_pes[i]), float(r.noc_bw[i])))
    assert js.n_valid == len(cands)
    cands.sort(key=lambda c: c[0])
    best = cands[0]
    assert js.top[0]["value"] == pytest.approx(best[0], rel=1e-4)
    assert (js.top[0]["point"], js.top[0]["num_pes"],
            js.top[0]["noc_bw"]) == (best[3], best[4], best[5])
    # frontier parity
    by_et = sorted(cands, key=lambda c: (c[1], -c[2]))
    bt, front = -np.inf, []
    for c in by_et:
        if c[2] > bt:
            bt = c[2]
            front.append(c)
    assert len(js.pareto) == len(front)
    for got, ref in zip(js.pareto, front):
        assert got["energy_pj"] == pytest.approx(ref[1], rel=1e-4)
        assert got["point"] == ref[3]
        assert (got["num_pes"], got["noc_bw"]) == (ref[4], ref[5])


def test_co_search_joint_genes(conv_op):
    from repro.mapspace import co_search
    space = build_space(conv_op, dims=("K", "C"), cluster_sizes=(4,))
    cfg = DSEConfig(pe_range=(16, 32, 64), bw_range=(4.0, 8.0))
    co = co_search(conv_op, objective="edp", mapping_budget=60, top_k=2,
                   cfg=cfg, num_pes=32, noc_bw=8.0, space=space,
                   joint_genes=6, joint_block=64,
                   search_kwargs={"block": 64})
    assert co.joint is not None
    assert co.joint.n_designs == (6 + 2) * 6
    assert co.joint.designs_per_s > 0
    assert co.pareto, "merged frontier is empty"
    # joint designs are counted in the total
    assert co.n_evaluated >= co.joint.n_designs
