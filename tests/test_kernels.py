"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.linear_scan import linear_scan, linear_scan_ref
from repro.kernels.maestro_eval import (build_tables, maestro_eval,
                                        maestro_eval_ref)

KEY = jax.random.PRNGKey(7)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,D,causal",
    [
        (1, 128, 128, 2, 2, 64, True),
        (2, 256, 256, 4, 1, 64, True),     # MQA
        (1, 256, 256, 8, 2, 128, True),    # GQA group 4
        (2, 128, 128, 2, 2, 64, False),    # bidirectional (encoder)
        (1, 512, 512, 2, 2, 64, True),     # multiple k blocks
    ])
def test_flash_attention_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=128, blk_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_shape_independent():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    a = flash_attention(q, k, v, blk_q=64, blk_k=64, interpret=True)
    b = flash_attention(q, k, v, blk_q=128, blk_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ----------------------------------------------------------------------
# linear scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,K,V,post,use_u,chunk",
    [
        (1, 64, 1, 16, 16, False, True, 16),
        (2, 128, 2, 32, 32, False, True, 32),    # RWKV-6 shape
        (1, 256, 4, 64, 64, True, False, 64),    # Mamba-2 shape
        (2, 128, 2, 16, 48, True, False, 64),    # K != V
    ])
def test_linear_scan_matches_ref(B, T, H, K, V, post, use_u, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K), dtype)
    k = jax.random.normal(ks[1], (B, T, H, K), dtype)
    v = jax.random.normal(ks[2], (B, T, H, V), dtype)
    lw = -jnp.abs(jax.random.normal(ks[3], (B, T, H, K))) * 0.2
    u = jax.random.normal(ks[4], (H, K)) if use_u else None
    s0 = jnp.zeros((B, H, K, V))
    o, sT = linear_scan(r, k, v, lw, u, s0, chunk=chunk, post_update=post,
                        interpret=True)
    orf, srf = linear_scan_ref(r, k, v, lw, u=u, state0=s0, chunk=chunk,
                               post_update=post)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(srf),
                               atol=tol, rtol=tol)


def test_linear_scan_matches_stepwise_recurrence():
    """Chunked form == literal per-token recurrence."""
    from repro.models.ssm import linear_attn_step
    B, T, H, K, V = 1, 32, 2, 8, 8
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, V))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, T, H, K))) * 0.3
    o, sT = linear_scan(r, k, v, lw, chunk=8, post_update=True,
                        interpret=True)
    s = jnp.zeros((B, H, K, V))
    outs = []
    for t in range(T):
        ot, s = linear_attn_step(r[:, t], k[:, t], v[:, t], lw[:, t],
                                 state=s, post_update=True)
        outs.append(ot)
    o_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s), atol=1e-4)


# ----------------------------------------------------------------------
# maestro_eval
# ----------------------------------------------------------------------

def _cases():
    from repro.core import dataflows as dfl
    from repro.core import tensor_analysis as ta
    ops = [
        ta.conv2d("late", k=128, c=96, y=14, x=14, r=3, s=3),
        ta.fc("fc", k=512, c=1024),
        ta.conv2d("early", k=64, c=3, y=112, x=112, r=7, s=7, stride=2),
    ]
    for op in ops:
        for flow in ("C-P", "X-P"):
            yield op, dfl.table3_for_layer(flow, op)


@pytest.mark.parametrize("op,df", list(_cases()),
                         ids=lambda x: getattr(x, "name", None))
def test_maestro_eval_kernel_vs_ref(op, df):
    T = build_tables(op, df)
    rng = np.random.default_rng(0)
    pes = rng.integers(2, 1024, 64).astype(np.int32)
    bw = rng.uniform(1, 128, 64).astype(np.float32)
    krn = np.asarray(maestro_eval(jnp.asarray(pes), jnp.asarray(bw),
                                  tables=T, interpret=True))
    ref = np.asarray(maestro_eval_ref(pes, bw, tables=T))
    np.testing.assert_allclose(krn, ref, rtol=1e-6)


@pytest.mark.parametrize("op,df", list(_cases()),
                         ids=lambda x: getattr(x, "name", None))
def test_maestro_eval_matches_engine(op, df):
    from repro.core.model import analyze
    from repro.core.performance import HWConfig
    T = build_tables(op, df)
    rng = np.random.default_rng(1)
    pes = rng.integers(2, 512, 8).astype(np.int32)
    bw = rng.uniform(2, 64, 8).astype(np.float32)
    feats = np.asarray(maestro_eval_ref(pes, bw, tables=T))
    for i in range(len(pes)):
        s = analyze(op, df, HWConfig(num_pes=int(pes[i]),
                                     noc_bw=float(bw[i]),
                                     noc_latency=2.0))
        assert np.isclose(feats[i, 0], s.runtime, rtol=1e-4)
        assert np.isclose(feats[i, 1], s.total_macs, rtol=1e-4)
        assert np.isclose(feats[i, 3], s.utilization, atol=1e-5)
