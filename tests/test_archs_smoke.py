"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step and a prefill→decode roundtrip
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import registry as R
from repro.models.param import count_params, init_params

ARCHS = sorted(REGISTRY)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, with_labels=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    if cfg.is_encdec:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = REGISTRY[arch].reduced()
    params = init_params(R.specs(cfg), KEY)
    loss = R.loss_fn(params, make_batch(cfg), cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_params(arch):
    from repro.optim import adamw
    from repro.training import TrainConfig, make_train_step
    cfg = REGISTRY[arch].reduced()
    params = init_params(R.specs(cfg), KEY)
    opt = adamw.init_state(params)
    step = make_train_step(cfg, TrainConfig(
        opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)))
    p2, o2, m = step(params, opt, make_batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # at least one leaf must have moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch):
    cfg = REGISTRY[arch].reduced()
    params = init_params(R.specs(cfg), KEY)
    B, S, M = 2, 16, 24
    batch = make_batch(cfg, B, S, with_labels=False)
    logits, cache = R.prefill(params, batch, cfg, M)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(3):
        logits, cache = R.decode_step(params, {"tokens": tok}, cache, cfg)
        assert logits.shape[:2] == (B, 1)
        assert logits.shape[-1] == cfg.vocab
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1], -1)[:, None]


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-1.6b", "zamba2-7b"])
def test_prefill_matches_decode_path(arch):
    """Decoding token t with cache(prefix<t) must match full-sequence
    forward logits at t (KV-cache / recurrent-state correctness)."""
    cfg = REGISTRY[arch].reduced()
    params = init_params(R.specs(cfg), KEY)
    B, S = 1, 12
    batch = make_batch(cfg, B, S, with_labels=False)
    toks = batch["tokens"]
    # full-sequence logits via prefill over S
    from repro.models import transformer
    full_logits, _ = transformer.forward(
        params, batch, cfg,
        cache=transformer.empty_cache(params, batch, cfg, train=False,
                                      max_len=S + 4))
    # prefix prefill + one decode step for position S-1
    prefix = {"tokens": toks[:, :S - 1]}
    _, cache = R.prefill(params, prefix, cfg, S + 4)
    step_logits, _ = R.decode_step(
        params, {"tokens": toks[:, S - 1:S]}, cache, cfg)
    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(step_logits[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_scale(arch):
    """Full configs instantiate (spec-level only) at the published scale."""
    cfg = REGISTRY[arch]
    n = count_params(R.specs(cfg))
    expected = {
        "olmo-1b": 1.2e9, "granite-20b": 20e9, "qwen2-72b": 73e9,
        "llama3-8b": 8e9, "moonshot-v1-16b-a3b": 29e9, "dbrx-132b": 132e9,
        "rwkv6-1.6b": 1.6e9, "phi-3-vision-4.2b": 3.8e9,
        "seamless-m4t-medium": 0.9e9, "zamba2-7b": 6.8e9,
    }[arch]
    assert 0.7 * expected < n < 1.35 * expected
