"""Hypothesis property tests on the analytical engine's invariants."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install hypothesis)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import tensor_analysis as ta
from repro.core.directives import (Cluster, Dataflow, SpatialMap,
                                   TemporalMap)
from repro.core.model import analyze
from repro.core.performance import HWConfig

dim_sizes = st.integers(min_value=1, max_value=24)
map_sizes = st.integers(min_value=1, max_value=8)
pes = st.integers(min_value=2, max_value=128)
bws = st.floats(min_value=1.0, max_value=64.0)


@st.composite
def fc_ops(draw):
    return ta.fc("p", n=draw(dim_sizes), k=draw(dim_sizes) * 2,
                 c=draw(dim_sizes) * 2)


@st.composite
def conv_ops(draw):
    r = draw(st.integers(1, 4))
    s = draw(st.integers(1, 4))
    stride = draw(st.integers(1, 2))
    y = r + stride * draw(st.integers(0, 12))
    x = s + stride * draw(st.integers(0, 12))
    return ta.conv2d("p", k=draw(dim_sizes), c=draw(dim_sizes),
                     y=y, x=x, r=r, s=s, stride=stride)


@st.composite
def random_dataflows(draw, dims=("K", "C", "N")):
    """Random single- or two-level dataflow over FC dims."""
    order = list(draw(st.permutations(dims)))
    spatial_dim = draw(st.sampled_from(dims))
    dirs = []
    for d in order:
        size = draw(map_sizes)
        offset = draw(st.integers(1, size))
        if d == spatial_dim:
            dirs.append(SpatialMap(size, offset, d))
        else:
            dirs.append(TemporalMap(size, offset, d))
    if draw(st.booleans()):
        inner_dim = draw(st.sampled_from([d for d in dims
                                          if d != spatial_dim]))
        dirs.append(Cluster(draw(st.integers(2, 8))))
        dirs.append(SpatialMap(1, 1, inner_dim))
    return Dataflow("prop", tuple(dirs))


@given(fc_ops(), random_dataflows(), pes, bws)
@settings(max_examples=120, deadline=None)
def test_fc_macs_conserved_and_bounds(op, df, p, bw):
    hw = HWConfig(num_pes=p, noc_bw=bw, noc_latency=2.0)
    s = analyze(op, df, hw)
    overlapped = any(
        not isinstance(d, (Cluster,)) and isinstance(d.size, int)
        and isinstance(d.offset, int) and d.offset < d.size
        for d in df.directives)
    if overlapped:
        # offset < size on a non-window dim revisits iteration points —
        # the engine honestly charges the recompute
        assert s.total_macs >= op.total_macs
    else:
        assert s.total_macs == op.total_macs
    assert s.runtime >= 1
    assert 0.0 <= s.utilization <= 1.0 + 1e-9
    assert s.energy_pj > 0
    assert s.l1_req_kb > 0


@given(conv_ops(), st.sampled_from(["C-P", "X-P", "KC-P", "YR-P", "YX-P"]),
       pes, bws)
@settings(max_examples=80, deadline=None)
def test_conv_table3_invariants(op, flow, p, bw):
    from repro.core.dataflows import table3_for_layer
    df = table3_for_layer(flow, op)
    hw = HWConfig(num_pes=p, noc_bw=bw, noc_latency=2.0)
    s = analyze(op, df, hw)
    if p >= 8:
        # well-provisioned: every Table-3 cluster fits -> exact coverage
        assert s.total_macs == op.total_macs
    else:
        # under-provisioned aligned clusters honestly drop the tail
        assert s.total_macs <= op.total_macs
    assert s.throughput <= p + 1e-6


@given(conv_ops())
@settings(max_examples=40, deadline=None)
def test_reuse_leq_algorithmic_max(op):
    from hypothesis import assume
    from repro.core.dataflows import table3_for_layer
    from repro.core.tensor_analysis import algorithmic_max_reuse
    # strided convs have never-touched input elements, so per-fetch reuse
    # can exceed the whole-tensor algorithmic max; restrict to stride 1
    assume(all(getattr(e, "stride", 1) == 1
               for e in op.output.entries))
    amax = algorithmic_max_reuse(op)
    hw = HWConfig(num_pes=32, noc_bw=16.0, noc_latency=2.0)
    s = analyze(op, table3_for_layer("KC-P", op), hw)
    for t in ("F", "I"):
        assert s.reuse_factor[t] <= amax[t] * (1 + 1e-6)


@given(st.integers(1, 512), st.integers(1, 16), st.integers(1, 16),
       st.integers(2, 64))
@settings(max_examples=100, deadline=None)
def test_spatial_phase_coverage(D, size, offset, n):
    """Folding covers every index exactly: sum of per-unit extents of the
    iteration partition equals the dim for offset == size (tiled case)."""
    from repro.core.cluster_analysis import py_backend, spatial_phases
    xp = py_backend()
    size = min(size, D)
    offset = size  # disjoint tiling
    st_, ed = spatial_phases(xp, D, size, offset, n)
    covered = (st_.count * st_.active * size
               + ed.count * (ed.active * size + ed.partial_size))
    assert covered == D


@given(st.integers(1, 512), st.integers(1, 16), st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_temporal_phase_coverage(D, size, offset):
    from repro.core.cluster_analysis import py_backend, temporal_phases
    xp = py_backend()
    size = min(size, D)
    offset = min(offset, size)
    st_, ed = temporal_phases(xp, D, size, offset)
    # steps advance by offset and the final step reaches the end
    n = st_.count + ed.count
    last_start = (n - 1) * offset
    last_size = ed.size if ed.count else st_.size
    assert last_start + last_size == D or D <= size


@given(st.lists(st.floats(-10, 10), min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_grad_compression_error_feedback(vals):
    """quantize+EF: the carried error equals the exact residual."""
    import jax.numpy as jnp
    import numpy as np
    from repro.training.grad_compression import dequantize, quantize
    g = jnp.asarray(np.array(vals, np.float32))
    q, s = quantize(g, 8)
    deq = dequantize(q, s)
    err = g - deq
    assert float(jnp.max(jnp.abs(err))) <= float(s) * 0.5 + 1e-6
