"""Tests for repro.analysis: the static-verification layer.

Load-bearing properties:

  * each analyzer *detects* its target defect class on a
    deliberately-broken fixture, with the right finding code — a linter
    that cannot catch the planted bug is worse than none;
  * the shipped tree is CLEAN: repo lint + dataflow corpus + jaxpr
    audit produce zero unwaived findings, and every checked-in waiver
    still matches something (unused waivers fail);
  * the jaxpr audit covers every (op-class, level-count) family variant
    CI compiles, at 1 and ``jax.local_device_count()`` devices, with
    the traced primitive count inside the checked-in budget;
  * the found-by-linter fixes hold under concurrency: the result-cache
    occupancy gauges track every directory transition exactly, and
    ``FlightRecorder.maybe_dump`` dumps once per interval no matter how
    many threads race it.
"""
import json
import os
import threading
import textwrap

import pytest

from repro.analysis import (CODES, Finding, Waiver, apply_waivers,
                            load_waivers, run_repo_lint)
from repro.analysis import concurrency, speclint
from repro.analysis.concurrency import ModulePolicy, lint_source
from repro.core.directives import Cluster, Dataflow, SpatialMap, TemporalMap
from repro.core.tensor_analysis import conv2d


CONV = conv2d("an-conv", k=64, c=64, y=28, x=28, r=3, s=3)


# ----------------------------------------------------------------------
# Finding / waiver schema
# ----------------------------------------------------------------------

def test_finding_schema_validates():
    f = Finding(code="SPEC-TILE", site="x.py::f", message="m",
                severity="warn")
    assert f.code in CODES and "SPEC-TILE" in f.one_line()
    with pytest.raises(ValueError):
        Finding(code="NOT-A-CODE", site="s", message="m")
    with pytest.raises(ValueError):
        Finding(code="SPEC-TILE", site="s", message="m", severity="meh")
    with pytest.raises(ValueError):
        Waiver(code="SPEC-TILE", site="s", reason="")


def test_waivers_partition_and_unused_detection():
    f1 = Finding(code="CONC-GLOBAL", site="a.py::f", message="m")
    f2 = Finding(code="CONC-GLOBAL", site="b.py::g", message="m")
    w_used = Waiver(code="CONC-GLOBAL", site="a.py::f", reason="ok")
    w_unused = Waiver(code="CONC-UNLOCKED", site="zz.py::h", reason="ok")
    unwaived, waived, unused = apply_waivers([f1, f2], [w_used, w_unused])
    assert [f.site for f in unwaived] == ["b.py::g"]
    assert [f.site for f in waived] == ["a.py::f"]
    assert unused == [w_unused]


def test_checked_in_waivers_load_and_all_match():
    waivers = load_waivers()
    assert waivers, "waivers.toml should ship at least one waiver"
    unwaived, _, unused = apply_waivers(run_repo_lint(), waivers)
    assert unwaived == [], [f.one_line() for f in unwaived]
    assert unused == [], [f"{w.code} @ {w.site}" for w in unused]


# ----------------------------------------------------------------------
# Concurrency linter: broken fixtures
# ----------------------------------------------------------------------

_BROKEN_COUNTER = textwrap.dedent("""\
    import threading

    class Ring:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []
            self._count = 0

        def locked_add(self, x):
            with self._lock:
                self._items.append(x)
                self._count += 1

        def racy_add(self, x):
            self._items.append(x)
            self._count += 1
""")


def test_concurrency_catches_unlocked_mutation_and_allows_locked():
    fs = lint_source(_BROKEN_COUNTER, "fix/ring.py", ModulePolicy())
    codes = {(f.code, f.site) for f in fs}
    assert ("CONC-UNLOCKED", "fix/ring.py::Ring.racy_add") in codes
    assert all("locked_add" not in f.site for f in fs)


def test_concurrency_catches_global_contextvar_threadlocal():
    src = textwrap.dedent("""\
        import threading
        from contextvars import ContextVar

        CURRENT = ContextVar("current")
        TOTAL = 0

        def bump():
            global TOTAL
            TOTAL += 1

        def set_and_leak(v):
            CURRENT.set(v)

        def set_and_reset(v):
            tok = CURRENT.set(v)
            CURRENT.reset(tok)

        def per_call_local():
            tls = threading.local()
            return tls
    """)
    fs = lint_source(src, "fix/ctx.py", ModulePolicy())
    codes = {(f.code, f.site) for f in fs}
    assert ("CONC-GLOBAL", "fix/ctx.py::bump") in codes
    assert ("CONC-CONTEXTVAR", "fix/ctx.py::set_and_leak") in codes
    assert ("CONC-THREADLOCAL", "fix/ctx.py::per_call_local") in codes
    assert all("set_and_reset" not in f.site for f in fs)


def test_concurrency_policy_exempts_unshared_classes():
    policy = ModulePolicy(unshared={"Ring": "externally locked"})
    assert lint_source(_BROKEN_COUNTER, "fix/ring.py", policy) == []


def test_concurrency_registry_covers_threaded_modules():
    for rel in ("serve/coalescer.py", "obs/metrics.py",
                "mapspace/cache.py", "obs/flightrec.py"):
        assert rel in concurrency.THREADED


# ----------------------------------------------------------------------
# Spec/dataflow linter: broken fixtures + clean corpus
# ----------------------------------------------------------------------

def test_speclint_non_divisor_tile_is_caught():
    df = Dataflow("bad-tile", (TemporalMap(5, 5, "K"), SpatialMap(1, 1, "C")))
    fs = speclint.lint_dataflow(df, CONV)
    assert [f.code for f in fs] == ["SPEC-TILE"]
    assert "does not divide" in fs[0].message


def test_speclint_sliding_window_is_not_a_tile_violation():
    # YX-P style: offset < size on X is a sliding window, never SPEC-TILE
    df = Dataflow("win", (TemporalMap(10, 8, "X"), SpatialMap(1, 1, "K")))
    assert speclint.lint_dataflow(df, CONV) == []


def test_speclint_cluster_and_spatial_fixtures():
    empty = Dataflow("c-empty", (SpatialMap(1, 1, "K"), Cluster(8)))
    assert [f.code for f in speclint.lint_dataflow(empty, CONV)] \
        == ["SPEC-CLUSTER"]
    big = Dataflow("c-big", (SpatialMap(1, 1, "K"), Cluster(64),
                             SpatialMap(1, 1, "C")))
    assert [f.code for f in
            speclint.lint_dataflow(big, CONV, num_pes=16)] \
        == ["SPEC-CLUSTER"]
    ragged = Dataflow("sp", (SpatialMap(2, 2, "Y"), SpatialMap(3, 3, "R")))
    assert [f.code for f in speclint.lint_dataflow(ragged, CONV)] \
        == ["SPEC-SPATIAL"]


def test_speclint_oversize_span_warns_illegal():
    df = Dataflow("over", (TemporalMap(100, 100, "K"),))
    fs = speclint.lint_dataflow(df, CONV)
    assert {(f.code, f.severity) for f in fs} \
        == {("SPEC-ILLEGAL", "warn")}


def test_speclint_parse_error_is_a_finding_not_a_crash():
    fs = speclint.lint_text("TemporalMap(2,2) K\nTemporalMap(3,3) K", CONV)
    assert [f.code for f in fs] == ["SPEC-PARSE"]
    ok = speclint.lint_text("SpatialMap(1,1) K\nTemporalMap(2,2) C", CONV)
    assert ok == []


def test_speclint_shipped_corpus_is_clean():
    assert speclint.lint_corpus() == []


def _query(**search):
    from repro.api import Query
    return Query.from_json({
        "workload": {"op": {"type": "conv2d", "name": "an-q", "k": 64,
                            "c": 64, "y": 28, "x": 28, "r": 3, "s": 3}},
        "hardware": {"num_pes": 48},
        "search": {"objective": "edp", **search}})


def test_speclint_query_bad_dims_and_budget():
    errs = speclint.errors_only(speclint.lint_query(
        _query(dims=["K", "Z"])))
    assert [f.code for f in errs] == ["SPEC-DIMS"]
    errs = speclint.errors_only(speclint.lint_query(
        _query(l1_prune_kb=0.001)))
    assert [f.code for f in errs] == ["SPEC-BUDGET"]
    assert speclint.errors_only(speclint.lint_query(_query())) == []


def test_query_lint_raises_specerror_with_findings():
    from repro.resilience.errors import SpecError
    with pytest.raises(SpecError) as ei:
        _query(dims=["K", "Z"]).lint()
    assert ei.value.details["findings"][0]["code"] == "SPEC-DIMS"
    _query().lint()          # legal query: no raise


# ----------------------------------------------------------------------
# Jaxpr audit: broken fixtures
# ----------------------------------------------------------------------

def _case(fn, ops, **kw):
    from repro.analysis.jaxpr_audit import FamilyCase
    return FamilyCase(name="fix:L1/x", family="fix:L1", fn=fn, ops=ops,
                      kind=kw.pop("kind", "plain"), **kw)


def test_jaxpr_audit_catches_f64_upcast():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.jaxpr_audit import audit_case
    ops = {"x": np.ones((4,), np.float32)}
    with jax.experimental.enable_x64():
        fs, _ = audit_case(_case(
            lambda o: jnp.asarray(o["x"], jnp.float64) * 2.0, ops))
    assert "JAX-F64" in {f.code for f in fs}
    assert "JAX-WIDEN" in {f.code for f in fs}


def test_jaxpr_audit_catches_host_callback():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.jaxpr_audit import audit_case

    def with_cb(o):
        return jax.pure_callback(
            lambda x: x, jax.ShapeDtypeStruct((4,), jnp.float32), o["x"])

    fs, _ = audit_case(_case(with_cb, {"x": np.ones((4,), np.float32)}))
    assert "JAX-CALLBACK" in {f.code for f in fs}


def test_jaxpr_audit_catches_ignored_operand():
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.jaxpr_audit import audit_case
    ops = {"used": np.ones((4,), np.float32),
           "ignored": np.ones((4,), np.float32)}
    fn = lambda o: jnp.sum(o["used"])          # noqa: E731
    fs, _ = audit_case(_case(fn, ops, unwrapped=fn, unwrapped_ops=ops))
    bad = [f for f in fs if f.code == "JAX-CONSTFOLD"]
    assert len(bad) == 1 and "'ignored'" in bad[0].message


def test_jaxpr_audit_catches_non_shrinking_reduce():
    import numpy as np
    from repro.analysis.jaxpr_audit import audit_case
    ops = {"x": np.ones((64,), np.float32)}
    fs, _ = audit_case(_case(lambda o: o["x"] * 2.0, ops, kind="reduced"))
    assert "JAX-DONATION" in {f.code for f in fs}


def test_jaxpr_audit_primitive_budget_trips():
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis import jaxpr_audit

    def big(o):
        x = o["x"]
        for _ in range(40):
            x = jnp.sin(x) + 1.0
        return x

    old = dict(jaxpr_audit.PRIMITIVE_BUDGET)
    jaxpr_audit.PRIMITIVE_BUDGET["fix:L1"] = 10
    try:
        fs, n = jaxpr_audit.audit_case(
            _case(big, {"x": np.ones((4,), np.float32)}))
    finally:
        jaxpr_audit.PRIMITIVE_BUDGET.clear()
        jaxpr_audit.PRIMITIVE_BUDGET.update(old)
    assert n > 10
    assert "JAX-PRIMBUDGET" in {f.code for f in fs}


def test_jaxpr_audit_trace_error_is_a_finding():
    import numpy as np
    from repro.analysis.jaxpr_audit import audit_case
    fs, n = audit_case(_case(
        lambda o: o["missing-key"], {"x": np.ones((4,), np.float32)}))
    assert n == 0 and [f.code for f in fs] == ["JAX-TRACE"]


# ----------------------------------------------------------------------
# Jaxpr audit: the shipped families are clean (1 and N devices)
# ----------------------------------------------------------------------

def test_jaxpr_audit_shipped_families_clean_all_devices():
    import jax
    from repro.analysis.jaxpr_audit import PRIMITIVE_BUDGET, audit
    nd = jax.local_device_count()
    counts = (1,) if nd <= 1 else (1, nd)
    findings, report = audit(counts)
    assert findings == [], [f.one_line() for f in findings]
    # every (op, level-count) family variant traced, budget recorded
    fams = {name.split("/")[0] for name in report["primitive_counts"]}
    assert fams == set(PRIMITIVE_BUDGET)
    assert report["device_counts"] == list(counts)
    for name, n in report["primitive_counts"].items():
        assert 0 < n, name


# ----------------------------------------------------------------------
# Found-by-linter regressions
# ----------------------------------------------------------------------

def test_cache_gauges_consistent_under_concurrent_writers(tmp_path):
    """PR-9 bug: gauges were published from an unsynchronized scan.  Now
    every directory transition (store commit, corrupt quarantine) and
    its gauge delta share one lock — so after any storm of concurrent
    writers, gauges == directory truth, with no rescan needed."""
    from repro import obs
    from repro.mapspace import cache

    d = str(tmp_path / "rc")
    cache.cache_stats(d)           # baseline the gauges for this dir
    errs = []

    def writer(w):
        try:
            for i in range(20):
                cache.store(d, f"w{w}-{i}", {"payload": list(range(8))})
                if i % 5 == 0:
                    cache.cache_stats(d)
        except Exception as e:    # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []

    m = obs.metrics()
    names = [n for n in os.listdir(d)
             if n.startswith("mapsearch-") and n.endswith(".json")]
    truth_bytes = sum(os.path.getsize(os.path.join(d, n)) for n in names)
    # incremental accounting alone (no trailing rescan) matches the dir
    assert m.gauge_value("result_cache.entries") == len(names) == 160
    assert m.gauge_value("result_cache.bytes") == truth_bytes
    # and the locked rescan agrees
    assert cache.cache_stats(d) == (len(names), truth_bytes)


def test_cache_quarantine_adjusts_gauges(tmp_path):
    from repro import obs
    from repro.mapspace import cache

    d = str(tmp_path / "rc")
    cache.cache_stats(d)
    cache.store(d, "good", {"v": 1})
    # plant a corrupt entry by hand, rescan to count it…
    bad = os.path.join(d, "mapsearch-bad.json")
    with open(bad, "w") as f:
        f.write("{truncated")
    e0, _ = cache.cache_stats(d)
    assert e0 == 2
    # …then the quarantining miss must subtract it from the gauges
    assert cache.load(d, "bad") is None
    assert os.path.exists(bad + ".corrupt")
    m = obs.metrics()
    assert m.gauge_value("result_cache.entries") == 1
    assert cache.cache_stats(d)[0] == 1


def test_maybe_dump_single_claim_under_race(tmp_path):
    """The found-by-linter flightrec fix: of N threads racing past the
    rate-limit interval, exactly one dumps."""
    from repro.obs.flightrec import FlightRecorder

    rec = FlightRecorder(capacity=16)
    rec.record("event", "warmup")
    results, barrier = [], threading.Barrier(8)

    def racer():
        barrier.wait()
        results.append(rec.maybe_dump(str(tmp_path), "storm",
                                      min_interval_s=60.0))

    threads = [threading.Thread(target=racer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    paths = [r for r in results if r is not None]
    assert len(paths) == 1
    with open(paths[0]) as f:
        assert json.load(f)["reason"] == "storm"
    # a second storm inside the interval stays suppressed
    assert rec.maybe_dump(str(tmp_path), "storm",
                          min_interval_s=60.0) is None


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def test_lint_cli_json_report_is_bench_schema(tmp_path):
    from repro.launch import lint as lint_cli

    out = str(tmp_path / "lint.json")
    rc = lint_cli.main(["--no-jaxpr", "--json", "--out", out, "-q"])
    assert rc == 0
    with open(out) as f:
        doc = json.load(f)
    assert (doc["kind"], doc["name"]) == ("bench", "lint")
    assert doc["n_unwaived"] == 0 and doc["unused_waivers"] == []
    assert "environment" in doc            # provenance block rides along
    from repro.api import Report
    rep = Report.from_json(doc)            # round-trips like any bench
    assert rep.name == "lint"


def test_lint_cli_fails_on_unused_waiver(tmp_path):
    from repro.launch import lint as lint_cli

    wpath = str(tmp_path / "waivers.toml")
    with open("src/repro/analysis/waivers.toml") as f:
        base = f.read()
    with open(wpath, "w") as f:
        f.write(base + '\n[[waiver]]\ncode = "CONC-UNLOCKED"\n'
                       'site = "zz/nowhere.py::gone"\n'
                       'reason = "stale"\n')
    rc = lint_cli.main(["--no-jaxpr", "--waivers", wpath, "-q"])
    assert rc == 1
