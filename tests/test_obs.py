"""Tests for the observability spine (``repro.obs``).

Load-bearing properties:

  * the disabled-tracer fast path allocates NOTHING — ``obs.span()``
    returns the one shared :data:`NULL_SPAN` singleton, so the hot
    paths can call it unconditionally;
  * the tracer is thread-safe and its output is a valid Chrome
    ``trace_event`` document (loads in chrome://tracing / Perfetto);
  * the metrics snapshot JSON round-trips exactly;
  * compile accounting has ONE writer: ``universal.compile_count()``,
    the per-family counters, and the engine's run-local ``n_compiles``
    all agree — and a cold coalesced ``Session.run_many`` batch records
    exactly ``n_families`` compile spans;
  * every ``Report.bench`` artifact carries the environment provenance
    block (schema_version 2).
"""
import json
import threading

import pytest

from repro import obs
from repro.api import Hardware, Query, Report, SearchSpec, Session, \
    Workload
from repro.core import tensor_analysis as ta
from repro.mapspace.universal import compile_count
from repro.obs.metrics import Metrics
from repro.obs.trace import NULL_SPAN, Tracer


@pytest.fixture
def tracer():
    """A fresh process tracer, always uninstalled on exit."""
    obs.disable_tracing()
    t = obs.enable_tracing()
    yield t
    obs.disable_tracing()


# ----------------------------------------------------------------------
# Disabled mode: the zero-allocation fast path
# ----------------------------------------------------------------------

def test_disabled_span_is_the_shared_singleton():
    obs.disable_tracing()
    assert not obs.tracing_enabled()
    a = obs.span("compile", family="x:L1")
    b = obs.span("device-pass", rows=4096)
    assert a is b is NULL_SPAN          # zero allocation per call
    with a as s:
        s.set(discovered="late")        # no-op, must not raise
    assert obs.save_trace("/nonexistent/never-written.json") is None
    obs.instant("marker")               # no-op, must not raise


# ----------------------------------------------------------------------
# Enabled mode: spans, nesting, threads, instants
# ----------------------------------------------------------------------

def test_span_nesting_records_complete_events(tracer):
    with obs.span("outer", kind="t"):
        with obs.span("inner", family="conv:L1") as s:
            s.set(rows=128)
    evs = tracer.spans()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert inner["ph"] == outer["ph"] == "X"
    assert inner["args"] == {"family": "conv:L1", "rows": 128}
    # the inner span lies inside the outer one on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert inner["pid"] == outer["pid"]


def test_tracer_thread_safety(tracer):
    n_threads, n_spans = 8, 50
    # hold every thread at the line until all exist: finished threads'
    # idents get recycled, which would collapse the tid count
    gate = threading.Barrier(n_threads)

    def work():
        gate.wait()
        for i in range(n_spans):
            with obs.span("work", i=i):
                pass

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tracer.spans("work")
    assert len(evs) == n_threads * n_spans       # nothing lost or torn
    assert len({e["tid"] for e in evs}) == n_threads  # own timeline rows


def test_instant_event(tracer):
    obs.instant("query", kind="layer", id="deadbeef")
    evs = [e for e in tracer.events() if e["ph"] == "i"]
    assert len(evs) == 1
    assert evs[0]["name"] == "query"
    assert evs[0]["args"]["id"] == "deadbeef"


def test_trace_file_is_valid_chrome_trace_event_json(tmp_path, tracer):
    with obs.span("compile", family="gemm:L1"):
        pass
    obs.instant("marker")
    path = obs.save_trace(str(tmp_path / "sub" / "trace.json"))
    doc = json.load(open(path))
    # the Chrome trace_event container format
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["name"], str)
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # environment provenance rides in otherData
    assert doc["otherData"]["backend"]
    assert doc["otherData"]["jax"]


# ----------------------------------------------------------------------
# Metrics registry
# ----------------------------------------------------------------------

def test_metrics_snapshot_json_round_trip():
    m = Metrics()                       # isolated, NOT the process one
    m.inc("universal.compiles")
    m.inc("universal.compiles_by_family", family="conv1:L2")
    m.inc("gene.rows_evaluated", 4096)
    m.inc("universal.compile_s", 0.25)
    m.gauge("devices", 4)
    m.observe("gene.chunk_occupancy", 1.0)
    m.observe("gene.chunk_occupancy", 0.5)
    snap = m.snapshot()
    assert snap == json.loads(json.dumps(snap))      # JSON round trip
    c = snap["counters"]
    # integral totals serialize as ints, fractional ones as floats
    assert c["universal.compiles"] == 1
    assert isinstance(c["universal.compiles"], int)
    assert c["universal.compiles_by_family[family=conv1:L2]"] == 1
    assert c["gene.rows_evaluated"] == 4096
    assert c["universal.compile_s"] == 0.25
    assert snap["gauges"]["devices"] == 4
    h = snap["histograms"]["gene.chunk_occupancy"]
    assert h["count"] == 2 and h["min"] == 0.5 and h["max"] == 1.0
    assert h["mean"] == pytest.approx(0.75)
    assert snap["schema_version"] == obs.SNAPSHOT_SCHEMA_VERSION


def test_metrics_label_keys_sorted_and_queryable():
    m = Metrics()
    m.inc("d.t", 2.0, b="y", a="x")
    assert "d.t[a=x,b=y]" in m.counters()
    assert m.value("d.t", a="x", b="y") == 2.0
    assert m.value("d.t") == 0.0                 # unlabeled is distinct
    assert m.counters("d.") == {"d.t[a=x,b=y]": 2.0}


def test_metrics_inc_thread_safety():
    m = Metrics()

    def work():
        for _ in range(200):
            m.inc("n")

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.value("n") == 8 * 200


# ----------------------------------------------------------------------
# Environment provenance + Report.bench schema v2
# ----------------------------------------------------------------------

def test_environment_block():
    env = obs.environment()
    for k in ("hostname", "python", "jax", "jaxlib", "backend",
              "device_kind", "device_count"):
        assert k in env, k
    assert env["device_count"] >= 1
    env["backend"] = "tampered"
    assert obs.environment()["backend"] != "tampered"   # returns a copy


def test_report_bench_carries_provenance():
    doc = Report.bench("t", {"x": 1, "n_evaluated": 7}).to_json()
    assert doc["schema_version"] == 2
    assert doc["x"] == 1 and doc["n_evaluated"] == 7
    assert doc["environment"]["backend"]
    # an explicit environment key wins (payload overrides the default)
    doc2 = Report.bench("t", {"environment": {"backend": "pinned"}})
    assert doc2.to_json()["environment"] == {"backend": "pinned"}


def test_profile_to_smoke(tmp_path):
    import jax.numpy as jnp
    with obs.profile_to(str(tmp_path / "prof")):
        jnp.arange(8).sum().block_until_ready()
    # best-effort: must never raise, whether or not the profiler wrote


# ----------------------------------------------------------------------
# The hot path: compile accounting parity + span regression
# ----------------------------------------------------------------------

def _cold_queries():
    """Layer shapes unique to this test (and a block size used nowhere
    else) so the family executables are guaranteed cold even when the
    whole suite runs in one process."""
    ops = [
        ta.conv2d("obs-conv1", k=10, c=6, y=14, x=14, r=3, s=3),
        ta.conv2d("obs-conv2", k=6, c=10, y=11, x=11, r=3, s=3),
        ta.gemm("obs-gemm1", m=12, n=40, k=24),
    ]
    return [Query(Workload.of_layer(op),
                  Hardware(num_pes=56, noc_bw=14.0),
                  SearchSpec(objective="edp", budget=48,
                             strategy="random", block=96, top_k=3))
            for op in ops]


def test_run_many_records_exactly_n_families_compile_spans(tracer):
    session = Session()
    c0 = compile_count()
    reports = session.run_many(_cold_queries())
    assert len(reports) == 3
    batch = session.last_batch
    n_fam = batch["n_families"]
    assert n_fam >= 2                     # conv + gemm classes at least

    # the regression: one compile span per family, no more, no less
    spans = tracer.spans("compile")
    assert len(spans) == n_fam, \
        (len(spans), n_fam, [s.get("args") for s in spans])
    fams = [s["args"]["family"] for s in spans]
    assert len(set(fams)) == n_fam        # one per DISTINCT family

    # and the three accountings agree: trace, batch stats, obs counter
    assert batch["n_compiles"] == n_fam
    assert compile_count() - c0 == n_fam
    for fam in fams:
        assert obs.metrics().value("universal.compiles_by_family",
                                   family=fam) >= 1
    # the timeline carries the whole batch story
    assert len(tracer.spans("run_many")) == 1
    assert tracer.spans("coalesce")
    assert tracer.spans("device-pass")
    assert any(e["name"] == "query" for e in tracer.events())


def test_compile_count_parity_with_family_counters():
    # process-lifetime invariant, checked after real work has run: the
    # single-writer design makes the total equal the per-family sum
    met = obs.metrics()
    total = met.value("universal.compiles")
    by_family = met.counters("universal.compiles_by_family[")
    assert int(total) == compile_count()
    assert int(total) == int(sum(by_family.values()))


def test_session_metrics_accessor():
    session = Session()
    q = Query(Workload.of_layer(
        ta.conv2d("obs-conv3", k=8, c=6, y=10, x=10, r=3, s=3)),
        Hardware(num_pes=56, noc_bw=14.0),
        SearchSpec(objective="edp", budget=32, strategy="random",
                   block=96))
    session.run(q)
    snap = session.metrics()
    assert snap["schema_version"] == obs.SNAPSHOT_SCHEMA_VERSION
    assert snap["counters"]["session.queries"] >= 1
    assert snap["session"]["n_queries"] == 1
    assert snap == json.loads(json.dumps(snap))      # serializable
