"""Reuse analysis vs the paper: Table 1, Fig. 5 playground, Fig. 6
row-stationary pattern."""
import pytest

from repro.core import dataflows as dfl
from repro.core import tensor_analysis as ta
from repro.core.cluster_analysis import py_backend, unit_counts
from repro.core.directives import complete, extended_dims
from repro.core.model import _build_level
from repro.core.reuse_analysis import (HALO, MULTICAST, NONE, PARTIAL,
                                       REDUCTION, STATIONARY, UNIQUE,
                                       classify_level,
                                       reuse_opportunity_table,
                                       spatial_reduction_active)

XP = py_backend()


def conv():
    return ta.conv2d("c", k=8, c=8, y=12, x=12, r=3, s=3)


def build_level0(df, op, pes=16):
    cdf = complete(df, op.dims)
    counts = unit_counts(XP, pes, cdf.cluster_sizes)
    dims = extended_dims(df, op.dims)
    return _build_level(XP, cdf.levels[0], dims, counts[0], 0,
                        len(cdf.levels) == 1, op)


# ----------------------------------------------------------------------
# Table 1: spatially mapped dim -> reuse opportunities
# ----------------------------------------------------------------------

def test_table1_spatial_K():
    t = reuse_opportunity_table(conv())
    e = t[("K", "C")]
    assert e["spatial"]["I"] == MULTICAST          # I decoupled from K
    assert e["spatial"]["F"] == "-"
    assert e["temporal"]["O"] == REDUCTION         # C innermost -> reduction


def test_table1_spatial_C():
    t = reuse_opportunity_table(conv())
    e = t[("C", "K")]
    assert e["spatial"]["O"] == REDUCTION          # C is a reduction dim
    assert e["temporal"]["I"] == MULTICAST         # K innermost: I unchanged


def test_table1_spatial_RS():
    t = reuse_opportunity_table(conv())
    e = t[("R", "X")]
    assert e["spatial"]["I"] == MULTICAST          # input-centric: I vs R
    assert e["temporal"]["F"] == MULTICAST         # X innermost: F unchanged


def test_table1_spatial_XY():
    t = reuse_opportunity_table(conv())
    e = t[("X", "C")]
    assert e["spatial"]["F"] == MULTICAST          # F decoupled from X
    assert e["temporal"]["O"] == REDUCTION


# ----------------------------------------------------------------------
# Fig. 5 playground (1-D conv, output-centric dims X' and S)
# ----------------------------------------------------------------------

def conv1d_os():
    return ta.conv1d_outputs("f5", x_out=6, s=3)


def test_fig5_A_output_stationary():
    lvl = build_level0(dfl.FIG5_A, conv1d_os(), pes=6)
    cl = classify_level(conv1d_os(), lvl)
    assert cl["O"].temporal == STATIONARY          # psums stay in place
    assert cl["F"].spatial == MULTICAST            # weights broadcast
    assert cl["F"].temporal == NONE or cl["F"].temporal == PARTIAL


def test_fig5_B_weight_stationary():
    op = conv1d_os()
    # 3 PEs over X'=6 -> the X' map folds; weights stay put across folds
    lvl = build_level0(dfl.FIG5_B, op, pes=3)
    cl = classify_level(op, lvl)
    assert cl["F"].temporal == STATIONARY          # weight-stationary
    assert cl["O"].spatial != REDUCTION            # X' spatial: no psum mix


def test_fig5_C_weight_spatial():
    op = conv1d_os()
    lvl = build_level0(dfl.FIG5_C, op, pes=3)
    cl = classify_level(op, lvl)
    # S spatially mapped: PEs hold different taps of the same window ->
    # partial sums for the same outputs = spatial reduction
    assert cl["O"].spatial == REDUCTION
    assert spatial_reduction_active(op, lvl)


def test_fig5_input_halo():
    op = conv1d_os()
    lvl = build_level0(dfl.FIG5_A, op, pes=6)
    cl = classify_level(op, lvl)
    # consecutive PEs read overlapping input windows (skewed iteration)
    assert cl["I"].spatial in (HALO, UNIQUE)


# ----------------------------------------------------------------------
# Fig. 6 row-stationary on the 2-cluster × 3-PE accelerator
# ----------------------------------------------------------------------

def rs_op():
    return ta.conv2d("rs", k=1, c=1, y=5, x=6, r=3, s=3)


def test_row_stationary_pattern():
    op = rs_op()
    df = dfl.ROW_STATIONARY_6PE
    cdf = complete(df, op.dims)
    counts = unit_counts(XP, 6, cdf.cluster_sizes)
    assert counts == [2, 3]                        # 2 clusters × 3 PEs
    dims = extended_dims(df, op.dims)
    lvl0 = _build_level(XP, cdf.levels[0], dims, counts[0], 0, False, op)
    cl0 = classify_level(op, lvl0)
    # inputs replicated across clusters in a skewed manner -> halo reuse
    assert cl0["I"].spatial == HALO
    # weights identical across clusters within a step -> spatial multicast,
    # and stationary across X steps (the paper's horizontal filter reuse)
    assert cl0["F"].spatial == MULTICAST
    assert cl0["F"].temporal == STATIONARY

    inner_dims = lvl0.steady_tile()
    lvl1 = _build_level(XP, cdf.levels[1], inner_dims, counts[1], 1, True,
                        op)
    # aligned Y/R diagonal: every PE of a cluster computes psums for the
    # same output row -> vertical spatial reduction (paper Fig. 6)
    assert spatial_reduction_active(op, lvl1)
    cl1 = classify_level(op, lvl1)
    assert cl1["O"].spatial == REDUCTION


def test_row_stationary_output_extent_is_one_row():
    from repro.core.reuse_analysis import level_tile_sizes, tensor_volume
    op = rs_op()
    df = dfl.ROW_STATIONARY_6PE
    cdf = complete(df, op.dims)
    counts = unit_counts(XP, 6, cdf.cluster_sizes)
    dims = extended_dims(df, op.dims)
    lvl0 = _build_level(XP, cdf.levels[0], dims, counts[0], 0, False, op)
    lvl1 = _build_level(XP, cdf.levels[1], lvl0.steady_tile(), counts[1],
                        1, True, op)
    tiles = level_tile_sizes(lvl1, XP)
    # 3 PEs with aligned (Y, R) cover one output row of X'-S+1 columns
    oy = (tiles["Y"] - tiles["R"]) + 1
    assert oy == 1
