"""Tests for the whole-network, fusion-aware schedule search
(``repro.netspace``).

Load-bearing properties:

  * the shape-as-operand evaluator reproduces the per-op universal
    evaluator's values for every layer of a network, at ≤ 2 XLA compiles
    per (op-class, level-count), deterministically at any device count;
  * the DP composer is exact: it matches brute-force enumeration over
    (per-layer choice × segmentation) on a toy chain, and the genetic
    fallback converges to the same optimum;
  * fused stacks respect the resident-tile L2 budget;
  * with reconfiguration cost disabled and fusion off, the composed
    schedule's per-layer choices coincide with independent per-layer
    ``search()`` runs on fixed seeds (shared candidate generation).
"""
import itertools

import numpy as np
import pytest
import jax

from repro.core import dnn_models as zoo
from repro.core import tensor_analysis as ta
from repro.core.dse import DSEConfig
from repro.core.performance import HWConfig
from repro.core.vectorized import FEATURES
from repro.mapspace import search
from repro.mapspace.space import points_from_genes, sample_genes
from repro.mapspace.universal import evaluate_points_universal
from repro.netspace import (NetCostModel, build_netspace,
                            co_search_network, compose_genetic,
                            evaluate_candidates, evaluate_schedule,
                            search_network, uniform_baseline)
from repro.netspace.search import _out_vols, best_uniform

PES, BW = 48, 12.0
BLOCK = 64


@pytest.fixture(scope="module")
def chain():
    return [ta.conv2d("net-c1", k=8, c=4, y=12, x=12, r=3, s=3),
            ta.conv2d("net-c2", k=12, c=8, y=14, x=14, r=3, s=3),
            ta.fc("net-f1", k=16, c=32)]


@pytest.fixture(scope="module")
def ns(chain):
    return build_netspace(chain)


@pytest.fixture(scope="module")
def searched(chain, ns):
    """One fusion-aware search shared by the composer tests."""
    hw = HWConfig(num_pes=PES, noc_bw=BW, noc_latency=2.0,
                  reconfig_latency=100.0)
    return search_network(chain, objective="edp", budget=150,
                          num_pes=PES, noc_bw=BW, seed=0, frontier_k=3,
                          fuse=True, reconfig=True, l2_budget_kb=60.0,
                          hw=hw, block=BLOCK, netspace=ns)


# ----------------------------------------------------------------------
# Shape dedup + shared gene layout
# ----------------------------------------------------------------------

def test_unique_layers_dedup():
    layers = zoo.vgg16()
    unique, index = zoo.unique_layers(layers)
    assert len(unique) < len(layers)
    assert len(index) == len(layers)
    for i, u in enumerate(index):
        assert zoo.layer_shape_key(layers[i]) == \
            zoo.layer_shape_key(unique[u])
    # repeated conv shapes (conv6/conv7, conv11..13) collapse
    names = [l.name for l in layers]
    assert index[names.index("vgg16-conv6")] == \
        index[names.index("vgg16-conv7")]
    assert zoo.summarize("vgg16").n_unique_shapes == len(unique)


def test_netspace_shared_gene_layout(chain, ns):
    assert ns.n_layers == 3
    assert len(ns.classes) == 2          # conv class + fc class
    for cls in ns.classes:
        ranges = {ns.spaces[u].gene_ranges() for u in cls.members}
        assert len(ranges) == 1          # one gene layout per class
        assert cls.spec1.ext_operand
    # per-layer tile candidates stay layer-legal after padding
    for u, sp in enumerate(ns.spaces):
        op = ns.unique[u]
        for ax in sp.axes:
            ext = op.dims[ax.dim]
            for size, off in zip(ax.sizes, ax.offsets):
                assert size <= ext and off >= 1


# ----------------------------------------------------------------------
# Shape-as-operand evaluator vs the per-op universal evaluator
# ----------------------------------------------------------------------

def test_evaluator_matches_per_op_universal(chain, ns):
    cand = [sample_genes(sp, np.random.default_rng(u), 60)
            for u, sp in enumerate(ns.spaces)]
    ev = evaluate_candidates(ns, cand, objective="edp", num_pes=PES,
                             noc_bw=BW, block=BLOCK, dedupe=False)
    cols_i = [FEATURES.index(c)
              for c in ("runtime", "energy_pj", "l1_kb", "l2_kb")]
    for u, op in enumerate(ns.unique):
        feats, _ = evaluate_points_universal(
            op, ns.spaces[u], points_from_genes(cand[u]),
            num_pes=PES, noc_bw=BW, block=BLOCK)
        ref = feats[:, FEATURES.index("edp")].astype(np.float64)
        np.testing.assert_allclose(ev.vals[u], ref, rtol=1e-5)
        np.testing.assert_allclose(ev.cols[u],
                                   feats[:, cols_i].astype(np.float64),
                                   rtol=1e-5)


def test_evaluator_dedupe_matches_full(chain, ns):
    cand = [sample_genes(sp, np.random.default_rng(7 + u), 40)
            for u, sp in enumerate(ns.spaces)]
    a = evaluate_candidates(ns, cand, objective="edp", num_pes=PES,
                            noc_bw=BW, block=BLOCK, dedupe=True)
    b = evaluate_candidates(ns, cand, objective="edp", num_pes=PES,
                            noc_bw=BW, block=BLOCK, dedupe=False)
    for u in range(len(ns.unique)):
        np.testing.assert_allclose(a.vals[u], b.vals[u], rtol=1e-6)


def test_evaluator_device_determinism(chain, ns):
    """With XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
    smoke job) this compares a real 4-device pmap against the 1-device
    jit path."""
    cand = [sample_genes(sp, np.random.default_rng(3 + u), 50)
            for u, sp in enumerate(ns.spaces)]
    kw = dict(objective="edp", num_pes=PES, noc_bw=BW, block=32)
    one = evaluate_candidates(ns, cand, n_devices=1, **kw)
    many = evaluate_candidates(ns, cand,
                               n_devices=jax.local_device_count(), **kw)
    assert many.run.n_devices == jax.local_device_count()
    for u in range(len(ns.unique)):
        np.testing.assert_array_equal(one.vals[u], many.vals[u])
        np.testing.assert_array_equal(one.cols[u], many.cols[u])


def test_compile_budget_per_op_class():
    """≤ 2 compiles per (op-class, level-count) no matter how many layers
    or structure groups; warm on repeat."""
    layers = [ta.conv2d("nb-c1", k=8, c=4, y=10, x=10, r=3, s=3),
              ta.conv2d("nb-c2", k=6, c=8, y=12, x=12, r=3, s=3),
              ta.conv2d("nb-c3", k=4, c=4, y=8, x=8, r=3, s=3)]
    ns2 = build_netspace(layers)
    assert len(ns2.classes) == 1
    cand = [sample_genes(sp, np.random.default_rng(u), 48)
            for u, sp in enumerate(ns2.spaces)]
    ev = evaluate_candidates(ns2, cand, objective="edp", num_pes=32,
                             noc_bw=8.0, block=32)
    assert ev.run.n_compiles <= 2
    ev2 = evaluate_candidates(ns2, cand, objective="edp", num_pes=32,
                              noc_bw=8.0, block=32)
    assert ev2.run.n_compiles == 0


# ----------------------------------------------------------------------
# Composer: DP exactness, footprint bounds, genetic fallback
# ----------------------------------------------------------------------

def _brute_force(frontiers, out_vols, fusible, model):
    best = (np.inf, None, None)
    n_b = len(frontiers) - 1
    for choice in itertools.product(*[range(len(f)) for f in frontiers]):
        for fuse in itertools.product((False, True), repeat=n_b):
            c, _, _ = evaluate_schedule(frontiers, choice, fuse,
                                        out_vols, fusible, model)
            if c < best[0]:
                best = (c, choice, fuse)
    return best


def test_dp_matches_bruteforce(chain, ns, searched):
    r = searched
    frontiers = [r.frontiers[ns.index[i]] for i in range(ns.n_layers)]
    cost, choice, fuse = _brute_force(frontiers, _out_vols(chain),
                                      ns.fusible, r.model)
    assert np.isfinite(cost)
    assert r.schedule.cost == pytest.approx(cost, rel=1e-9)
    assert tuple(r.schedule.choice) == choice
    assert tuple(r.schedule.fuse) == fuse


def test_genetic_composer_matches_dp(chain, ns, searched):
    r = searched
    frontiers = [r.frontiers[ns.index[i]] for i in range(ns.n_layers)]
    macs = float(sum(op.total_macs for op in chain))
    sched, _ = compose_genetic(frontiers, _out_vols(chain), ns.fusible,
                               r.model, [l.name for l in chain], macs,
                               seed=1)
    assert sched.cost == pytest.approx(r.schedule.cost, rel=1e-9)


def test_fused_footprint_respected(chain, ns):
    budget = 40.0
    r = search_network(chain, objective="edp", budget=150, num_pes=PES,
                       noc_bw=BW, seed=0, frontier_k=3, fuse=True,
                       l2_budget_kb=budget, block=BLOCK, netspace=ns)
    s = r.schedule
    for a, b in s.segments:
        if b > a:
            stack = sum(s.per_layer[i]["l2_kb"] for i in range(a, b + 1))
            assert stack <= budget + 1e-9
    # an infeasible-budget run degrades to singleton stacks, not a crash
    tiny = search_network(chain, objective="edp", budget=150,
                          num_pes=PES, noc_bw=BW, seed=0, frontier_k=3,
                          fuse=True, l2_budget_kb=1e-3, block=BLOCK,
                          netspace=ns)
    assert all(not f for f in tiny.schedule.fuse)


def test_fusible_mask_blocks_fusion(chain, ns):
    ns2 = build_netspace(chain, fusible=[False, True])
    r = search_network(chain, objective="edp", budget=150, num_pes=PES,
                       noc_bw=BW, seed=0, frontier_k=3, fuse=True,
                       block=BLOCK, netspace=ns2)
    assert r.schedule.fuse[0] is False
    macs = float(sum(op.total_macs for op in chain))
    frontiers = [r.frontiers[ns2.index[i]] for i in range(ns2.n_layers)]
    sched, _ = compose_genetic(frontiers, _out_vols(chain), ns2.fusible,
                               r.model, [l.name for l in chain], macs,
                               seed=0)
    assert sched.fuse[0] is False


# ----------------------------------------------------------------------
# Reconfig-0 / fusion-off parity with independent per-layer search()
# ----------------------------------------------------------------------

def test_reconfig_zero_matches_independent_search(chain, ns):
    r = search_network(chain, objective="edp", budget=150, num_pes=PES,
                       noc_bw=BW, seed=0, strategy="random",
                       fuse=False, reconfig=False, block=BLOCK,
                       netspace=ns)
    assert all(not f for f in r.schedule.fuse)
    total_e = total_r = 0.0
    for i, op in enumerate(chain):
        s = search(op, objective="edp", budget=150,
                   space=ns.space_for(i), num_pes=PES, noc_bw=BW,
                   strategy="random", seed=0, block=BLOCK)
        assert r.schedule.genes[i] == tuple(s.best_point)
        assert r.schedule.per_layer[i]["value"] == \
            pytest.approx(s.best_value, rel=1e-5)
        total_e += s.best_stats["energy_pj"]
        total_r += s.best_stats["runtime"]
    # network totals = sums of the independent per-layer results
    assert r.schedule.energy_pj == pytest.approx(total_e, rel=1e-5)
    assert r.schedule.runtime == pytest.approx(total_r, rel=1e-5)


# ----------------------------------------------------------------------
# Baselines + network co-DSE
# ----------------------------------------------------------------------

def test_uniform_baseline_shape(chain):
    model = NetCostModel(hw=HWConfig(num_pes=PES, noc_bw=BW,
                                     noc_latency=2.0))
    base = uniform_baseline(chain, model)
    assert set(base) == {"C-P", "X-P", "YX-P", "YR-P", "KC-P"}
    for v in base.values():
        assert np.isfinite(v["edp"]) and v["edp"] > 0
    flow, b = best_uniform(base)
    assert b["edp"] == min(v["edp"] for v in base.values())


def test_co_search_network(chain, ns):
    cfg = DSEConfig(pe_range=(16, 32, 64), bw_range=(4.0, 8.0, 16.0))
    co = co_search_network(chain, cfg, objective="edp", budget=100,
                           num_pes=32, noc_bw=8.0, seed=0,
                           frontier_k=3, block=BLOCK, netspace=ns)
    assert co.n_hw == 9
    assert co.n_valid > 0
    assert co.pareto, "empty network frontier"
    # frontier is strictly improving in both axes
    es = [p["energy_pj"] for p in co.pareto]
    ts = [p["throughput"] for p in co.pareto]
    assert es == sorted(es) and ts == sorted(ts)
    assert co.best["edp"] is not None
    assert co.top and "segments" in co.top[0]
    # hardware rides existing executables: no compiles beyond the
    # reference search's own (per-class, per-level) budget
    assert co.n_compiles <= 2 * 2 * len(ns.classes)
