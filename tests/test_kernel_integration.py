"""Model-level kernel integration: the Pallas flash-attention path
(forced via REPRO_USE_PALLAS=interpret) must match the pure-jnp model."""
import os
import subprocess
import sys
import textwrap


def test_model_with_pallas_attention_matches():
    prog = textwrap.dedent("""
        import os
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY
        from repro.models import registry as R
        from repro.models.param import init_params
        cfg = REGISTRY['olmo-1b'].reduced().replace(chunk_size=128)
        params = init_params(R.specs(cfg), jax.random.PRNGKey(0))
        B, S = 1, 128
        batch = {'tokens': jnp.ones((B, S), jnp.int32),
                 'labels': jnp.ones((B, S), jnp.int32)}
        base = float(R.loss_fn(params, batch, cfg))
        os.environ['REPRO_USE_PALLAS'] = 'interpret'
        pallas = float(R.loss_fn(params, batch, cfg))
        rel = abs(base - pallas) / abs(base)
        assert rel < 5e-3, (base, pallas)
        print('OK', base, pallas)
    """)
    r = subprocess.run([sys.executable, "-c", prog],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
