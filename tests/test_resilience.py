"""Tests for repro.resilience: fault-tolerant, resumable sweep execution.

Load-bearing properties:

  * deterministic fault injection (``kind@site:index``) makes every
    recovery path exercisable without flakes;
  * transparent retry: a crashed device chunk re-dispatches and the
    result is bit-identical to an undisturbed run; exhausting the retry
    budget surfaces a structured ``DeviceError``;
  * kill-and-resume: a sweep killed at chunk k and re-launched with a
    ``SweepCheckpoint`` resumes from the last saved chunk and returns
    bit-identical results — at 1 device and at every available device
    count (CI re-runs this file under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
  * OOM chunk-splitting converges and matches the unsplit run;
  * corrupt/stale checkpoints and corrupt result-cache entries are
    quarantined misses, never crashes;
  * the Session degrades a persistently-failing gene-pipeline query to
    the legacy engine (and a poisoned coalesced batch to sequential
    queries) and still produces correct Reports;
  * spec validation raises ``SpecError`` naming the offending field, and
    the launch CLIs turn any ``ReproError`` into a one-line exit 2.
"""
import json
import os

import numpy as np
import pytest
import jax

from repro import obs
from repro.api import Hardware, Query, SearchSpec, Session, Workload
from repro.api.spec import (VALID_BUDGET_POLICIES, VALID_OBJECTIVES,
                            VALID_PIPELINES, VALID_STRATEGIES)
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import tensor_analysis as ta
from repro.core.dse import DSEConfig
from repro.ft.coordinator import FaultTolerantLoop
from repro.mapspace import (build_space, evaluate_genes, joint_sweep,
                            sample_genes)
from repro.mapspace import cache as mcache
from repro.mapspace.search import (OBJECTIVES, PIPELINES, STRATEGIES,
                                   search_impl)
from repro.resilience import (DeviceError, ReproError, ResilienceConfig,
                              RetryPolicy, SpecError, StragglerWatchdog,
                              SweepCheckpoint, SweepKilled, faultinject,
                              set_default_policy)
from repro.resilience.faultinject import parse

PES, BW = 48, 12.0
NDEV = jax.local_device_count()

# small backoffs + min_rows below the test block size so the OOM split
# path is actually reachable
FAST = RetryPolicy(max_attempts=2, backoff_s=0.001, min_rows=16)


@pytest.fixture(autouse=True)
def _clean_process_state():
    yield
    faultinject.clear()
    set_default_policy(None)


@pytest.fixture(scope="module")
def conv_op():
    return ta.conv2d("res-conv", k=8, c=6, y=12, x=12, r=3, s=3)


@pytest.fixture(scope="module")
def conv_space(conv_op):
    return build_space(conv_op, dims=("K", "C", "Y"), cluster_sizes=(8,),
                       perm_mode="all")


@pytest.fixture(scope="module")
def genes(conv_space):
    return sample_genes(conv_space, np.random.default_rng(0), 256)


def ev_sig(ev):
    """The bit-identity signature of a GeneEval."""
    return ([(t["row"], t["value"], t["feats"].tobytes()) for t in ev.top],
            [(p["row"], p["energy_pj"], p["throughput"])
             for p in ev.pareto],
            None if ev.vals is None else ev.vals.tobytes())


def run_eval(conv_op, conv_space, genes, **kw):
    kw.setdefault("num_pes", PES)
    kw.setdefault("noc_bw", BW)
    kw.setdefault("block", 32)
    kw.setdefault("n_devices", 1)
    return evaluate_genes(conv_op, conv_space, genes, **kw)


def counter(name):
    return obs.metrics().value(name)


# ----------------------------------------------------------------------
# Fault-spec grammar
# ----------------------------------------------------------------------

def test_fault_spec_parse():
    ds = parse("crash@chunk:3, oom@chunk:2, slow@chunk:1:0.25,"
               "kill@design-chunk:5x2")
    assert [(d.kind, d.site, d.index, d.arg, d.times) for d in ds] == [
        ("crash", "chunk", 3, 0.0, 1), ("oom", "chunk", 2, 0.0, 1),
        ("slow", "chunk", 1, 0.25, 1), ("kill", "design-chunk", 5, 0.0, 2)]
    assert [d.spec() for d in ds] == ["crash@chunk:3", "oom@chunk:2",
                                     "slow@chunk:1:0.25",
                                     "kill@design-chunk:5x2"]
    for bad in ("explode@chunk:1", "crash@chunk", "crash@", "oom"):
        with pytest.raises(ValueError):
            parse(bad)


# ----------------------------------------------------------------------
# Retry: transparent recovery and budget exhaustion
# ----------------------------------------------------------------------

def test_retry_is_transparent_and_bit_identical(conv_op, conv_space,
                                                genes):
    ref = run_eval(conv_op, conv_space, genes)
    r0 = counter("resilience.retries")
    with faultinject.scoped("crash@chunk:1"):
        ev = run_eval(conv_op, conv_space, genes, retry=FAST)
    assert counter("resilience.retries") == r0 + 1
    assert ev_sig(ev) == ev_sig(ref)


def test_retry_exhaustion_surfaces_device_error(conv_op, conv_space,
                                                genes):
    with faultinject.scoped("crash@chunk:1x99"):
        with pytest.raises(DeviceError) as ei:
            run_eval(conv_op, conv_space, genes, retry=FAST)
    assert ei.value.details["attempts"] == FAST.max_attempts
    assert isinstance(ei.value, RuntimeError)          # taxonomy contract
    assert "failed after" in ei.value.one_line()


def test_oom_splits_chunk_and_matches(conv_op, conv_space, genes):
    ref = run_eval(conv_op, conv_space, genes)
    s0 = counter("resilience.chunk_splits")
    with faultinject.scoped("oom@chunk:2"):
        ev = run_eval(conv_op, conv_space, genes, retry=FAST)
    assert counter("resilience.chunk_splits") >= s0 + 1
    assert ev_sig(ev) == ev_sig(ref)


# ----------------------------------------------------------------------
# Kill + checkpoint resume (the headline bit-identity contract)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("ndev", sorted({1, NDEV}))
def test_kill_resume_bit_identical(conv_op, conv_space, genes, tmp_path,
                                   ndev):
    ref = run_eval(conv_op, conv_space, genes, n_devices=ndev)
    ck = SweepCheckpoint(str(tmp_path), f"kr{ndev}", every_chunks=1)
    with faultinject.scoped("kill@chunk:1"):
        with pytest.raises(SweepKilled):
            run_eval(conv_op, conv_space, genes, n_devices=ndev, ckpt=ck)
    assert os.path.exists(ck.path)
    r0 = counter("resilience.checkpoint_resumes")
    ev = run_eval(conv_op, conv_space, genes, n_devices=ndev, ckpt=ck)
    assert counter("resilience.checkpoint_resumes") == r0 + 1
    assert ev_sig(ev) == ev_sig(ref)
    assert not os.path.exists(ck.path)       # cleared on completion


def test_truncated_checkpoint_quarantined_and_rerun(conv_op, conv_space,
                                                    genes, tmp_path):
    ref = run_eval(conv_op, conv_space, genes)
    ck = SweepCheckpoint(str(tmp_path), "tr", every_chunks=1)
    # every save is truncated post-commit, then the sweep dies at chunk 4
    with faultinject.scoped("truncate@checkpoint:0x999,kill@chunk:4"):
        with pytest.raises(SweepKilled):
            run_eval(conv_op, conv_space, genes, ckpt=ck)
    c0 = counter("resilience.checkpoint_corrupt")
    ev = run_eval(conv_op, conv_space, genes, ckpt=ck)
    assert counter("resilience.checkpoint_corrupt") == c0 + 1
    assert os.path.exists(ck.path + ".corrupt")
    assert ev_sig(ev) == ev_sig(ref)          # full restart, same answer


def test_stale_checkpoint_discarded(conv_op, conv_space, genes, tmp_path):
    ck = SweepCheckpoint(str(tmp_path), "st", every_chunks=1)
    with faultinject.scoped("kill@chunk:2"):
        with pytest.raises(SweepKilled):
            run_eval(conv_op, conv_space, genes, ckpt=ck)
    other = sample_genes(conv_space, np.random.default_rng(9), 256)
    ref = run_eval(conv_op, conv_space, other)
    s0 = counter("resilience.checkpoint_stale")
    ev = run_eval(conv_op, conv_space, other, ckpt=ck)
    assert counter("resilience.checkpoint_stale") == s0 + 1
    assert ev_sig(ev) == ev_sig(ref)


def test_search_ckpt_dir_resume(conv_op, conv_space, tmp_path):
    kw = dict(budget=96, block=32, strategy="random", seed=3,
              num_pes=PES, noc_bw=BW, space=conv_space, devices=1,
              pipeline="gene")
    ref = search_impl(conv_op, **kw)
    with faultinject.scoped("kill@chunk:1"):
        with pytest.raises(SweepKilled):
            search_impl(conv_op, ckpt_dir=str(tmp_path), **kw)
    assert any(f.startswith("sweep-") for f in os.listdir(tmp_path))
    res = search_impl(conv_op, ckpt_dir=str(tmp_path), **kw)
    assert res.best_value == ref.best_value
    assert res.best_point == ref.best_point
    assert [e["value"] for e in res.top_k] == \
        [e["value"] for e in ref.top_k]


def test_joint_sweep_kill_resume(conv_op, conv_space, tmp_path):
    genes = sample_genes(conv_space, np.random.default_rng(0), 48)
    cfg = DSEConfig(pe_range=(32, 64, 96, 128), bw_range=(8.0, 16.0),
                    batch=1024)

    def sig(r):
        return ([(t["value"], t["point"], t["num_pes"], t["noc_bw"])
                 for t in r.top],
                [(p["point"], p["energy_pj"], p["throughput"])
                 for p in r.pareto], r.n_valid)

    ref = joint_sweep(conv_op, conv_space, genes, cfg, chunk_designs=64)
    ck = SweepCheckpoint(str(tmp_path), "joint")
    with faultinject.scoped("kill@design-chunk:2"):
        with pytest.raises(SweepKilled):
            joint_sweep(conv_op, conv_space, genes, cfg,
                        chunk_designs=64, ckpt=ck)
    assert os.path.exists(ck.path)
    res = joint_sweep(conv_op, conv_space, genes, cfg, chunk_designs=64,
                      ckpt=ck)
    assert sig(res) == sig(ref)
    assert not os.path.exists(ck.path)


# ----------------------------------------------------------------------
# Session: error boundary, degradation, batch isolation
# ----------------------------------------------------------------------

def _query(name="res-q", budget=96, seed=3):
    op = ta.conv2d(name, k=8, c=6, y=12, x=12, r=3, s=3)
    return Query(Workload.of_layer(op), Hardware(num_pes=PES, noc_bw=BW),
                 SearchSpec(budget=budget, block=32, strategy="random",
                            seed=seed))


def _session(**kw):
    return Session(resilience=ResilienceConfig(retry=FAST, **kw))


def test_session_degrades_to_legacy(conv_op):
    q = _query()
    d0 = counter("resilience.degraded_queries")
    with faultinject.scoped("crash@chunk:0x9999"):
        rep = _session().run(q)
    assert rep.kind == "layer"
    assert rep.extras["pipeline"] == "legacy"
    dg = rep.extras["degraded"]
    assert dg["from"] == "gene" and dg["to"] == "legacy"
    assert "DeviceError" in dg["error"]
    assert counter("resilience.degraded_queries") == d0 + 1
    # the degraded report is still a real answer
    assert np.isfinite(rep.best["value"]) and rep.n_evaluated > 0


def test_session_degrade_off_raises_classified():
    with faultinject.scoped("crash@chunk:0x9999"):
        with pytest.raises(DeviceError):
            _session(degrade=False).run(_query())


def test_run_many_isolates_poisoned_batch():
    qs = [_query(), _query("res-q2", budget=64, seed=1)]
    b0 = counter("resilience.batch_degraded")
    with faultinject.scoped("crash@chunk:0x9999"):
        reps = _session().run_many(qs)
    assert counter("resilience.batch_degraded") == b0 + 1
    assert [r.kind for r in reps] == ["layer", "layer"]
    assert all(r.extras.get("degraded") for r in reps)


def test_run_many_kill_resume_bit_identical(tmp_path):
    qs = [_query(), _query("res-q2", budget=64, seed=1)]
    clean = _session().run_many(qs)
    sig = [r.results_json() for r in clean]
    with faultinject.scoped("kill@chunk:1"):
        with pytest.raises(SweepKilled):
            _session(ckpt_dir=str(tmp_path)).run_many(qs)
    assert any(f.startswith("sweep-batch-") for f in os.listdir(tmp_path))
    r0 = counter("resilience.checkpoint_resumes")
    resumed = _session(ckpt_dir=str(tmp_path)).run_many(qs)
    assert counter("resilience.checkpoint_resumes") == r0 + 1
    assert [r.results_json() for r in resumed] == sig
    assert not os.listdir(tmp_path)           # cleared on completion


# ----------------------------------------------------------------------
# Spec validation
# ----------------------------------------------------------------------

def test_spec_errors_name_the_field():
    cases = [
        (lambda: SearchSpec(objective="speed"), "objective"),
        (lambda: SearchSpec(strategy="warp"), "strategy"),
        (lambda: SearchSpec(pipeline="quantum"), "pipeline"),
        (lambda: SearchSpec(budget=0), "budget"),
        (lambda: SearchSpec(block=-4), "block"),
        (lambda: SearchSpec(budget_policy="greedy"), "budget_policy"),
        (lambda: Hardware(num_pes=0), "num_pes"),
        (lambda: Hardware(noc_bw=0.0), "noc_bw"),
        (lambda: Hardware(pe_range=()), "pe_range"),
        (lambda: Hardware(bw_range=(8.0, -1.0)), "bw_range"),
        (lambda: Workload(), "ops"),
        (lambda: Workload(model="nosuch-net"), "model"),
        (lambda: DSEConfig(pe_range=()), "pe_range"),
        (lambda: DSEConfig(batch=0), "batch"),
    ]
    for build, field in cases:
        with pytest.raises(SpecError) as ei:
            build()
        assert ei.value.field == field, (field, ei.value)
        assert isinstance(ei.value, ValueError)   # old callers still work


def test_spec_unknown_json_fields():
    with pytest.raises(SpecError) as ei:
        SearchSpec.from_json({"objective": "edp", "budgett": 9})
    assert ei.value.field == "budgett"
    with pytest.raises(SpecError) as ei:
        Hardware.from_json({"num_pess": 4})
    assert ei.value.field == "num_pess"


def test_spec_literals_agree_with_engine():
    assert set(VALID_OBJECTIVES) == set(OBJECTIVES)
    assert set(VALID_STRATEGIES) == {"auto", *STRATEGIES}
    assert tuple(VALID_PIPELINES) == PIPELINES
    assert set(VALID_BUDGET_POLICIES) == {"adaptive", "uniform"}


def test_cli_prints_one_line_error_and_exits_2(tmp_path, capsys):
    from repro.launch import query as qcli
    bad = tmp_path / "queries.json"
    bad.write_text(json.dumps(
        [{"workload": {"model": "vgg16"}, "search": {"strategy": "warp"}}]))
    with pytest.raises(SystemExit) as ei:
        qcli.main(["--file", str(bad), "--cache-dir", "",
                   "--jax-cache-dir", ""])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert err.strip().splitlines()[-1].startswith("error: SpecError")


# ----------------------------------------------------------------------
# Result-cache hardening
# ----------------------------------------------------------------------

def test_cache_corruption_is_quarantined_miss(tmp_path):
    cdir = str(tmp_path)
    mcache.store(cdir, "deadbeef", {"x": 1})
    assert mcache.load(cdir, "deadbeef")["x"] == 1
    path = mcache._path(cdir, "deadbeef")
    with open(path, "w") as f:
        f.write("{not json")
    c0 = counter("result_cache.corrupt")
    assert mcache.load(cdir, "deadbeef") is None
    assert counter("result_cache.corrupt") == c0 + 1
    assert os.path.exists(path + ".corrupt")
    assert mcache.load(cdir, "deadbeef") is None   # now a plain miss
    # the slot is writable again after quarantine
    mcache.store(cdir, "deadbeef", {"x": 2})
    assert mcache.load(cdir, "deadbeef")["x"] == 2


def test_cache_concurrent_writers_never_tear(tmp_path):
    """Server workers share a cache dir: many threads storing the same
    key concurrently must never produce a torn entry — every load
    observes some writer's complete payload."""
    import threading

    cdir = str(tmp_path)
    n_writers, n_rounds = 8, 20
    start = threading.Barrier(n_writers)
    errors = []

    def writer(wid):
        try:
            start.wait()
            for r in range(n_rounds):
                mcache.store(cdir, "shared",
                             {"writer": wid, "round": r,
                              "pad": "x" * 4096})
                got = mcache.load(cdir, "shared")
                assert got is not None, "store then load missed"
                assert len(got["pad"]) == 4096
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    final = mcache.load(cdir, "shared")
    assert final["round"] == n_rounds - 1
    # no leftover temp files: every writer's commit completed
    leftovers = [f for f in os.listdir(cdir) if ".tmp-" in f]
    assert not leftovers, leftovers


def test_checkpointer_skips_unreadable_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"w": np.arange(4)})
    ck.save(2, {"w": np.arange(4) * 2})
    with open(tmp_path / "step_000000002" / "manifest.json", "w") as f:
        f.write("{oops")
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1
    state, manifest = ck.restore({"w": np.zeros(4, np.int64)})
    assert manifest["step"] == 1
    assert np.array_equal(state["w"], np.arange(4))


# ----------------------------------------------------------------------
# Straggler watchdog (ported from ft.coordinator)
# ----------------------------------------------------------------------

def test_watchdog_flags_stragglers_without_poisoning_ewma():
    wd = StragglerWatchdog(threshold=3.0, alpha=0.2)
    assert wd.observe(1.0) is False           # first sample seeds EWMA
    assert wd.observe(1.0) is False
    assert wd.observe(10.0) is True           # 10 > 3 x 1.0
    assert wd.ewma == pytest.approx(1.0)      # straggler didn't update it
    assert wd.slow_count == 1
    assert wd.observe(1.2) is False           # baseline keeps adapting
    assert wd.ewma == pytest.approx(1.04)


def test_ft_loop_delegates_to_shared_watchdog(tmp_path):
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(lambda s, b: (s, {}), ck)
    assert isinstance(loop._watchdog, StragglerWatchdog)
    for i, w in enumerate([1.0, 1.0, 10.0, 1.0]):
        loop._observe(i, w)
    assert loop.straggler_steps == [2]
