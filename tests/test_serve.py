"""Tests for repro.serve: the hardened DSE-as-a-service tier.

Load-bearing properties:

  * every well-formed request reaches a terminal status — a report
    (including ``timeout``/``error`` kinds), an explicit 429/503 shed,
    or a 400 reject — and the counter invariant
    ``serve.shed + serve.completed == serve.admitted`` holds;
  * admission backpressure: a full queue sheds with 429 + Retry-After
    derived from the EWMA flush time; an over-cost query sheds with the
    estimated cost in the body;
  * deadlines are enforced cooperatively in the engine chunk loops AND
    backstopped in the handler — an expired request answers a terminal
    timeout report, never a hang, even when the flush worker is stuck;
  * chaos drills: ``crash@serve-worker`` answers error reports and the
    server keeps serving; ``kill@serve-drain`` leaves the persisted
    pending queue behind and a restart recovers it bit-identically to
    the offline oracle;
  * the coalescing server and the offline ``--file`` batch path share
    one execution function, so a single-flush batch answers bit-equal
    to the offline run of the same query set.
"""
import asyncio
import glob
import json
import os
import time

import pytest

from repro import obs
from repro.api import Query, Report, Session
from repro.resilience import ResilienceConfig, faultinject
from repro.serve import (DSEServer, ServeConfig, execute_batch, http_json,
                         http_text, run_loadgen)
from repro.serve.drain import pending_path, recovered_path


@pytest.fixture(autouse=True)
def _clean_process_state():
    yield
    faultinject.clear()
    obs.disable_tracing()
    obs.enable_flight_spans(False)


def counter(name):
    return obs.metrics().value(name)


def wire_conv(tag, name, *, k=8, c=6, y=10, x=10, objective="edp",
              budget=32, deadline_s=None):
    """A small coalescible conv query in the wire (queries.json)
    format."""
    search = {"objective": objective, "budget": budget, "block": 64,
              "top_k": 4}
    if deadline_s is not None:
        search["deadline_s"] = deadline_s
    return {"tag": tag,
            "workload": {"op": {"type": "conv2d", "name": name,
                                "k": k, "c": c, "y": y, "x": x,
                                "r": 3, "s": 3}},
            "hardware": {"num_pes": 48, "noc_bw": 12.0},
            "search": search}


QUERIES = [wire_conv("a", "sv-a"),
           wire_conv("b", "sv-b", k=12, objective="runtime"),
           wire_conv("c", "sv-c", c=8, objective="energy")]

_SLICE = ("kind", "name", "objective", "strategy", "best", "top_k",
          "pareto", "n_evaluated")


def results_slice(body):
    """The deterministic Report slice out of a wire response body."""
    return {k: body.get(k) for k in _SLICE}


def serve_test(coro_fn, *, config=None, session=None, faults=None,
               stop=True):
    """Run ``coro_fn(server)`` against a fresh in-process server on an
    ephemeral port."""
    async def main():
        if faults:
            faultinject.install(faults)
        sess = session or Session()
        srv = DSEServer(sess, config
                        or ServeConfig(port=0, exit_on_kill=False))
        await srv.start()
        try:
            return await srv_coro(srv)
        finally:
            if stop:
                await srv.stop()
    srv_coro = coro_fn
    return asyncio.run(main())


async def post(srv, query, timeout=60.0):
    return await http_json("127.0.0.1", srv.port, "POST", "/query",
                           query, timeout=timeout)


# ----------------------------------------------------------------------
# Basic serving + endpoints + counter invariant
# ----------------------------------------------------------------------

def test_query_roundtrip_and_endpoints():
    async def drill(srv):
        st, body = await post(srv, QUERIES[0])
        assert st == 200
        assert body["kind"] == "layer"
        # the wire body IS Report.to_json — it must round-trip
        rep = Report.from_json(body)
        assert rep.kind == "layer" and rep.best is not None

        st, health = await http_json("127.0.0.1", srv.port, "GET",
                                     "/healthz")
        assert (st, health["ok"]) == (200, True)
        st, ready = await http_json("127.0.0.1", srv.port, "GET",
                                    "/readyz")
        assert (st, ready["ready"]) == (200, True)
        # the worker clears its in-flight list just AFTER resolving the
        # answer, so poll the snapshot until the queue reads empty
        for _ in range(100):
            st, snap = await http_json("127.0.0.1", srv.port, "GET",
                                       "/metricsz")
            assert st == 200
            if snap["serve"]["queue_depth"] == 0:
                break
            await asyncio.sleep(0.05)
        c = snap["counters"]
        for name in ("serve.requests", "serve.admitted",
                     "serve.completed", "serve.flushes"):
            assert c.get(name, 0) >= 1, name
        assert snap["serve"]["ready"] is True
        assert snap["serve"]["queue_depth"] == 0
        assert (c.get("serve.shed", 0) + c["serve.completed"]
                == c["serve.admitted"])
    serve_test(drill)


def test_malformed_query_is_400_outside_invariant():
    async def drill(srv):
        admitted0 = counter("serve.admitted")
        st, body = await post(srv, {"workload": {"op": {"type": "nope"}}})
        assert st == 400
        assert "error" in body
        assert counter("serve.bad_requests") >= 1
        assert counter("serve.admitted") == admitted0
    serve_test(drill)


def test_statically_illegal_query_rejected_before_admission():
    """A parseable query that can never produce a result (searched dim
    the layer lacks) is rejected by the pre-admission speclint — 400
    with the structured findings, no flush slot burned, and the
    shed/completed/admitted ledger untouched."""
    bad = wire_conv("zdim", "sv-zdim")
    bad["search"]["dims"] = ["K", "Z"]

    async def drill(srv):
        admitted0 = counter("serve.admitted")
        rejected0 = counter("serve.speclint_rejected")
        st, body = await post(srv, bad)
        assert st == 400
        assert body["error"]["type"] == "SpecError"
        codes = [f["code"] for f in body["error"]["findings"]]
        assert "SPEC-DIMS" in codes
        assert counter("serve.speclint_rejected") == rejected0 + 1
        assert counter("serve.admitted") == admitted0
        # a legal query still flows normally afterwards
        st, ok = await post(srv, QUERIES[0])
        assert st == 200 and ok["kind"] == "layer"
    serve_test(drill)


# ----------------------------------------------------------------------
# Admission control: queue bound and cost bound
# ----------------------------------------------------------------------

def test_full_queue_sheds_429_with_retry_after():
    cfg = ServeConfig(port=0, exit_on_kill=False, max_queue=1,
                      max_batch=64, flush_interval_s=30.0,
                      default_deadline_s=1.0, grace_s=0.2)

    async def drill(srv):
        shed0 = counter("serve.shed")
        # park one request (the flush trigger is far away), then every
        # further arrival sees a full queue and sheds deterministically
        parked = asyncio.create_task(post(srv, QUERIES[0]))
        await asyncio.sleep(0.2)
        for q in (QUERIES[1], QUERIES[2]):
            st, body = await post(srv, q)
            assert st == 429
            assert body["error"]["type"] == "overloaded"
            assert body["error"]["reason"] == "queue"
            assert body["error"]["retry_after_s"] >= 1
        # the Retry-After header itself, via one raw exchange
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       srv.port)
        payload = json.dumps(QUERIES[1]).encode()
        writer.write(b"POST /query HTTP/1.1\r\nHost: x\r\n"
                     b"Content-Length: %d\r\n"
                     b"Connection: close\r\n\r\n" % len(payload)
                     + payload)
        await writer.drain()
        raw = await reader.read()
        writer.close()
        assert b"429" in raw.split(b"\r\n", 1)[0]
        assert b"Retry-After:" in raw
        # the parked request still terminates (deadline backstop)
        st, body = await parked
        assert st == 200 and body["kind"] == "timeout"
        assert counter("serve.shed") - shed0 == 3
    serve_test(drill, config=cfg)


def test_over_cost_query_sheds_with_estimate():
    cfg = ServeConfig(port=0, exit_on_kill=False, max_cost=10.0)

    async def drill(srv):
        st, body = await post(srv, QUERIES[0])   # cost = budget × 1 = 32
        assert st == 429
        err = body["error"]
        assert err["reason"] == "cost"
        assert err["estimated_cost"] > err["max_cost"] == 10.0
    serve_test(drill, config=cfg)


# ----------------------------------------------------------------------
# Deadlines: cooperative cancellation + handler backstop, never a hang
# ----------------------------------------------------------------------

def test_deadline_expiry_returns_timeout_report_not_hang():
    cfg = ServeConfig(port=0, exit_on_kill=False,
                      default_deadline_s=0.5, grace_s=0.3)

    async def drill(srv):
        t0 = time.monotonic()
        st, body = await post(srv, QUERIES[0], timeout=10.0)
        waited = time.monotonic() - t0
        assert st == 200
        assert body["kind"] == "timeout"
        assert body["timeout"]["deadline_s"] == 0.5
        assert body["timeout"]["where"] in ("queued", "flush", "run",
                                            "in-flight")
        # bounded by deadline + grace + scheduling slack — NOT by the
        # injected 5 s flush stall
        assert waited < 4.0
        assert counter("serve.timeouts") >= 1
    # the stall sits in the flush path, past the deadline
    serve_test(drill, config=cfg, faults="slow@serve-flush:0:5.0")


def test_query_carried_deadline_beats_server_default():
    async def drill(srv):
        st, body = await post(srv, wire_conv("tiny", "sv-tiny",
                                             deadline_s=1e-6))
        assert st == 200 and body["kind"] == "timeout"
        assert body["timeout"]["deadline_s"] == 1e-6
    serve_test(drill)


# ----------------------------------------------------------------------
# Chaos drills
# ----------------------------------------------------------------------

def test_crash_at_worker_answers_errors_and_survives():
    async def drill(srv):
        st, body = await post(srv, QUERIES[0])
        assert st == 200 and body["kind"] == "error"
        assert body["error"]["type"] == "InjectedFault"
        # the worker thread survived: the next request serves normally
        st, body = await post(srv, QUERIES[1])
        assert st == 200 and body["kind"] == "layer"
        assert counter("serve.flush_errors") >= 1
    serve_test(drill, faults="crash@serve-worker:0")


def test_clean_drain_flushes_and_clears_pending(tmp_path):
    ck = str(tmp_path / "ckpt")
    sess = Session(resilience=ResilienceConfig(ckpt_dir=ck))
    cfg = ServeConfig(port=0, exit_on_kill=False, max_batch=64,
                      flush_interval_s=30.0)

    async def drill(srv):
        posts = [asyncio.create_task(post(srv, q))
                 for q in QUERIES[:2]]
        await asyncio.sleep(0.3)          # park them in the buffer
        assert srv.coalescer.depth() == 2
        await srv.drain()
        for t in posts:                   # the final flush answered them
            st, body = await t
            assert st == 200 and body["kind"] == "layer"
        import os
        assert not os.path.exists(pending_path(ck))
        assert counter("serve.drains") >= 1
    serve_test(drill, config=cfg, session=sess, stop=False)


def test_kill_mid_drain_then_recovery_matches_oracle(tmp_path):
    ck = str(tmp_path / "ckpt")
    import os
    sess = Session(resilience=ResilienceConfig(
        ckpt_dir=ck, faults="kill@serve-drain:0"))
    cfg = ServeConfig(port=0, exit_on_kill=False, max_batch=64,
                      flush_interval_s=30.0, default_deadline_s=3.0,
                      grace_s=0.2)

    async def killed_drill(srv):
        posts = [asyncio.create_task(post(srv, q, timeout=30.0))
                 for q in QUERIES[:2]]
        await asyncio.sleep(0.3)
        await srv.drain()
        # simulated process death: the pending queue is persisted, the
        # parked requests are NOT answered with real reports — the
        # handler backstop gives them terminal timeouts
        assert os.path.exists(pending_path(ck))
        for t in posts:
            st, body = await t
            assert st == 200 and body["kind"] == "timeout"
    serve_test(killed_drill, config=cfg, session=sess, stop=False)
    faultinject.clear()

    recovered0 = counter("serve.recovered")
    sess2 = Session(resilience=ResilienceConfig(ckpt_dir=ck))

    async def restarted_drill(srv):
        # recovery ran synchronously inside start()
        assert not os.path.exists(pending_path(ck))
        assert counter("serve.recovered") - recovered0 == 2
        st, ready = await http_json("127.0.0.1", srv.port, "GET",
                                    "/readyz")
        assert (st, ready["ready"]) == (200, True)
    serve_test(restarted_drill, session=sess2)

    rec = json.load(open(recovered_path(ck)))["reports"]
    oracle = [r.results_json() for r in
              execute_batch(Session(),
                            [Query.from_json(q) for q in QUERIES[:2]])]
    assert json.loads(json.dumps(oracle)) == rec


# ----------------------------------------------------------------------
# Coalesced server == offline --file oracle (single-flush batch)
# ----------------------------------------------------------------------

def test_single_flush_batch_bit_equal_to_offline_oracle():
    # family spaces pad over the distinct shapes of a batch, so the
    # unit of bit-equality is the FLUSH: hold the trigger open long
    # enough that all three concurrent posts land in one flush
    cfg = ServeConfig(port=0, exit_on_kill=False, max_batch=8,
                      flush_interval_s=0.5)

    async def drill(srv):
        results = await asyncio.gather(*(post(srv, q) for q in QUERIES))
        assert {body["kind"] for _, body in results} == {"layer"}
        assert counter("serve.flushes") >= 1
        return {body["name"]: results_slice(body)
                for _, body in results}
    flushes0 = counter("serve.flushes")
    served = serve_test(drill, config=cfg)
    assert counter("serve.flushes") - flushes0 == 1, \
        "batch split across flushes — widen the flush window"

    oracle = execute_batch(Session(),
                           [Query.from_json(q) for q in QUERIES])
    for rep in oracle:
        assert json.loads(json.dumps(rep.results_json())) \
            == served[rep.name]


# ----------------------------------------------------------------------
# Request ids, timing breakdowns, Prometheus exposition, flight recorder
# ----------------------------------------------------------------------

async def raw_post(srv, query, headers=None):
    """One raw exchange returning (status, response headers, body)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", srv.port)
    try:
        payload = json.dumps(query).encode()
        head = [f"POST /query HTTP/1.1", "Host: x",
                f"Content-Length: {len(payload)}", "Connection: close"]
        head += [f"{k}: {v}" for k, v in (headers or {}).items()]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
        await writer.drain()
        raw = await reader.read()
    finally:
        writer.close()
    head_blk, _, body = raw.partition(b"\r\n\r\n")
    lines = head_blk.decode().split("\r\n")
    status = int(lines[0].split(" ")[1])
    hdrs = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        hdrs[k.strip().lower()] = v.strip()
    return status, hdrs, json.loads(body) if body.strip() else None


def test_request_id_honored_and_minted():
    async def drill(srv):
        st, hdrs, body = await raw_post(srv, QUERIES[0],
                                        headers={"X-Request-Id": "my-rid"})
        assert st == 200
        assert hdrs["x-request-id"] == "my-rid"
        assert body["timing"]["request_id"] == "my-rid"
        # no inbound id: the server mints one and echoes it
        st, hdrs, body = await raw_post(srv, QUERIES[1])
        assert st == 200
        minted = hdrs["x-request-id"]
        assert minted and body["timing"]["request_id"] == minted
    serve_test(drill)


def test_report_timing_phases_sum_to_wall():
    async def drill(srv):
        t0 = time.monotonic()
        st, hdrs, body = await raw_post(srv, QUERIES[0],
                                        headers={"X-Request-Id": "tm-1"})
        client_wall = time.monotonic() - t0
        assert st == 200 and body["kind"] == "layer"
        timing = body["timing"]
        phases = timing["phases"]
        assert "queue_wait" in phases and "other" in phases
        # phases sum to the server-measured wall by construction
        assert sum(phases.values()) == pytest.approx(timing["wall_s"],
                                                     abs=1e-4)
        # and the server wall is within the client-observed wall
        assert 0.0 < timing["wall_s"] <= client_wall + 0.05
        for p in phases:
            assert p in obs.PHASE_NAMES
    serve_test(drill)


def test_metricsz_content_negotiation():
    async def drill(srv):
        st, snap = await http_json("127.0.0.1", srv.port, "GET",
                                   "/metricsz")
        assert st == 200 and isinstance(snap, dict)    # JSON default
        assert "counters" in snap
        st, text = await http_text("127.0.0.1", srv.port, "GET",
                                   "/metricsz?format=prometheus")
        assert st == 200
        assert "# TYPE serve_requests counter" in text
        st, text2 = await http_text(
            "127.0.0.1", srv.port, "GET", "/metricsz",
            headers={"Accept": "text/plain"})
        assert st == 200 and "# TYPE" in text2
        # the Prometheus counters agree with the JSON snapshot
        want = snap["counters"].get("serve.requests", 0)
        got = [ln for ln in text.split("\n")
               if ln.startswith("serve_requests ")]
        assert got and float(got[0].split(" ")[1]) >= want
    async def outer(srv):
        await post(srv, QUERIES[0])
        await drill(srv)
    serve_test(outer)


def test_slo_histograms_with_exemplar_request_ids():
    async def drill(srv):
        st, hdrs, body = await raw_post(srv, QUERIES[0],
                                        headers={"X-Request-Id": "ex-1"})
        assert st == 200
        st, text = await http_text("127.0.0.1", srv.port, "GET",
                                   "/metricsz?format=prometheus")
        assert "# TYPE serve_latency_s histogram" in text
        assert 'le="+Inf"' in text
        assert 'request_id="ex-1"' in text
        # per-phase histograms ride too
        assert "serve_phase_s_bucket" in text
    serve_test(drill)


def test_crash_drill_dumps_flight_recorder_with_request_spans(tmp_path):
    fdir = str(tmp_path / "flight")
    cfg = ServeConfig(port=0, exit_on_kill=False, flight_dir=fdir)

    async def drill(srv):
        st, hdrs, body = await raw_post(srv, QUERIES[0],
                                        headers={"X-Request-Id": "cr-1"})
        assert st == 200 and body["kind"] == "error"
        dumps = glob.glob(os.path.join(fdir, "flight-*.json"))
        assert dumps, "crash@serve-worker produced no flight dump"
        doc = json.load(open(dumps[0]))
        assert doc["reason"] == "flush-error"
        assert "cr-1" in doc["request_ids"]
        errors = [e for e in doc["entries"]
                  if e["name"] == "serve-flush-error"]
        assert errors and errors[0]["error"] == "InjectedFault"
        # the failing request's id is attributable in the ring
        assert any("cr-1" in (e.get("rid") or "")
                   for e in doc["entries"])
    serve_test(drill, config=cfg, faults="crash@serve-worker:0")


def test_sigterm_drain_saves_trace_and_metrics(tmp_path):
    ck = str(tmp_path / "ckpt")
    sess = Session(resilience=ResilienceConfig(ckpt_dir=ck))
    obs.enable_tracing()

    async def drill(srv):
        st, _ = await post(srv, QUERIES[0])
        assert st == 200
        await srv.drain()
        # the previously-lost-on-SIGTERM observability state is flushed
        trace = json.load(open(os.path.join(ck, "serve-trace.json")))
        names = {e["name"] for e in trace["traceEvents"]}
        assert {"request", "flush", "queue-wait"} <= names
        snap = json.load(open(os.path.join(ck, "serve-metrics.json")))
        assert snap["counters"]["serve.completed"] >= 1
    serve_test(drill, session=sess, stop=False)
    obs.disable_tracing()


def test_trace_threads_one_request_through_server_and_engine():
    tracer = obs.enable_tracing()

    async def drill(srv):
        st, hdrs, body = await raw_post(srv, QUERIES[0],
                                        headers={"X-Request-Id": "tr-1"})
        assert st == 200 and body["kind"] == "layer"
    serve_test(drill)
    obs.disable_tracing()

    def rids(ev):
        r = (ev.get("args") or {}).get("rid")
        return r if isinstance(r, list) else [r]
    evs = tracer.events()
    by_name = {}
    for e in evs:
        if "tr-1" in rids(e):
            by_name.setdefault(e["name"], []).append(e)
    # one rid threads the server span, the queue-wait + flush spans,
    # and the engine leaf spans of its device pass
    assert "request" in by_name
    assert "queue-wait" in by_name
    assert "flush" in by_name
    assert by_name.keys() & {"compile", "dispatch", "device-pass",
                             "encode"}


# ----------------------------------------------------------------------
# Load: N concurrent clients, every request terminal
# ----------------------------------------------------------------------

def test_loadgen_all_requests_terminal():
    cfg = ServeConfig(port=0, exit_on_kill=False, max_batch=8,
                      flush_interval_s=0.1, default_deadline_s=60.0)

    async def drill(srv):
        res = await run_loadgen("127.0.0.1", srv.port, QUERIES,
                                clients=10, requests_per_client=2,
                                timeout=120.0)
        snap = srv.metrics()
        return res, snap
    res, snap = serve_test(drill, config=cfg)
    assert res.n_requests == 20
    assert res.transport_errors == 0
    assert res.n_terminal == 20               # zero unexplained drops
    assert set(res.statuses) <= {200, 429, 503}
    s = res.summary()
    assert s["p99_s"] > 0 and s["queries_per_s"] > 0
    c = snap["counters"]
    assert (c.get("serve.shed", 0) + c["serve.completed"]
            == c["serve.admitted"])
