"""Tests for the mapping-space search engine (repro.mapspace)."""
import itertools

import numpy as np
import pytest

from repro.core import tensor_analysis as ta
from repro.core.dataflows import TABLE3, table3_for_layer
from repro.core.directives import (FULL, Cluster, Dataflow, SpatialMap, Sz,
                                   TemporalMap, divisors, extended_dims,
                                   is_legal, tile_candidates)
from repro.core.dse import tile_variants
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.core.vectorized import FEATURES
from repro.mapspace import (build_space, enumerate_points, evaluate_points,
                            point_dataflow, sample_points, search)

HW = HWConfig(num_pes=64, noc_bw=16.0, noc_latency=2.0)


@pytest.fixture(scope="module")
def tiny_conv():
    return ta.conv2d("tiny", k=8, c=4, y=10, x=10, r=3, s=3)


@pytest.fixture(scope="module")
def tiny_space(tiny_conv):
    return build_space(tiny_conv, dims=("K", "C"), cluster_sizes=(4,))


# ----------------------------------------------------------------------
# Divisor / legality helpers
# ----------------------------------------------------------------------

def test_divisors():
    assert divisors(12) == (1, 2, 3, 4, 6, 12)
    assert divisors(1) == (1,)
    assert divisors(7) == (1, 7)
    with pytest.raises(ValueError):
        divisors(0)


def test_tile_candidates_thinning():
    full = tile_candidates(360)
    assert full == divisors(360)
    thin = tile_candidates(360, 5)
    assert len(thin) == 5
    assert thin[0] == 1 and thin[-1] == 360
    assert set(thin) <= set(full)


def test_is_legal():
    dims = {"K": 8, "C": 4}
    ok = Dataflow("ok", (SpatialMap(2, 2, "K"), TemporalMap(4, 4, "C")))
    assert is_legal(ok, dims)
    too_big = Dataflow("big", (SpatialMap(16, 16, "K"),))
    assert not is_legal(too_big, dims)
    # symbolic sizes are legal (resolve clamps them)
    sym = Dataflow("sym", (TemporalMap(Sz("R"), 1, "Y"),))
    assert is_legal(sym, {"Y": 10, "R": 3})


# ----------------------------------------------------------------------
# Space definition
# ----------------------------------------------------------------------

def test_space_size_matches_bruteforce(tiny_space):
    pts = list(enumerate_points(tiny_space))
    assert len(pts) == tiny_space.size
    assert len(set(pts)) == tiny_space.size
    # brute-force recomputation of the count from the gene ranges
    n = 1
    for r in tiny_space.gene_ranges():
        n *= r
    assert tiny_space.size == n


def test_every_point_is_legal(tiny_conv, tiny_space):
    for pt in enumerate_points(tiny_space):
        df = point_dataflow(tiny_space, pt)
        ext = extended_dims(df, tiny_conv.dims)
        assert is_legal(df, tiny_conv.dims), str(df)
        for d in df.directives:
            if isinstance(d, Cluster):
                continue
            if isinstance(d.size, int) and d.size != FULL:
                assert 0 < d.size <= ext[d.dim]


def test_window_dims_pinned_symbolic(tiny_conv, tiny_space):
    assert set(tiny_space.pinned) == {"R", "S"}
    df = point_dataflow(tiny_space, next(enumerate_points(tiny_space)))
    pinned = [d for d in df.directives
              if not isinstance(d, Cluster) and d.dim in ("R", "S")]
    assert len(pinned) == 2
    assert all(isinstance(d.size, Sz) for d in pinned)


def test_window_outer_tiles_cover_outputs():
    """Y/X tile candidates carry the input halo: every tile yields whole
    output rows and the offsets tile the output extent exactly."""
    op = ta.conv2d("s2", k=4, c=4, y=11, x=11, r=3, s=3, stride=2)
    space = build_space(op, dims=("K", "Y"), cluster=False)
    (y_axis,) = [ax for ax in space.axes if ax.dim == "Y"]
    out_extent = (11 - 3) // 2 + 1  # 5 output rows
    for size, off in zip(y_axis.sizes, y_axis.offsets):
        assert out_extent % off == 0
        assert size == (off - 1) * 2 + 3
        assert size <= 11


def test_sampling_deterministic_and_distinct(tiny_space):
    a = sample_points(tiny_space, np.random.default_rng(7), 20)
    b = sample_points(tiny_space, np.random.default_rng(7), 20)
    assert a == b
    assert len(set(a)) == len(a)


# ----------------------------------------------------------------------
# Batched evaluator vs faithful analyze()
# ----------------------------------------------------------------------

def test_batched_agrees_with_faithful(tiny_conv, tiny_space):
    rng = np.random.default_rng(0)
    pts = sample_points(tiny_space, rng, 5)
    assert len(pts) >= 3
    feats, _ = evaluate_points(tiny_conv, tiny_space, pts,
                               num_pes=HW.num_pes, noc_bw=HW.noc_bw,
                               block=8)
    for i, pt in enumerate(pts):
        df = point_dataflow(tiny_space, pt)
        s = analyze(tiny_conv, df, HW)
        ref = {"runtime": float(s.runtime), "energy_pj": float(s.energy_pj),
               "macs": float(s.total_macs), "l1_kb": float(s.l1_req_kb),
               "l2_kb": float(s.l2_req_kb), "util": float(s.utilization),
               "edp": float(s.edp)}
        got = dict(zip(FEATURES, feats[i]))
        for k, v in ref.items():
            assert got[k] == pytest.approx(v, rel=1e-3), (pt, k)


# ----------------------------------------------------------------------
# Search
# ----------------------------------------------------------------------

def test_search_exhaustive_finds_global_best(tiny_conv, tiny_space):
    r = search(tiny_conv, objective="edp", budget=10_000, space=tiny_space,
               num_pes=HW.num_pes, noc_bw=HW.noc_bw, seed=0, block=64)
    assert r.strategy == "exhaustive"
    assert r.n_evaluated == tiny_space.size
    # global best: no enumerated point does better
    vals = [e["value"] for e in r.top_k]
    assert vals == sorted(vals)
    assert r.best_value == vals[0]


def test_search_deterministic_under_seed(tiny_conv, tiny_space):
    kw = dict(objective="edp", budget=60, space=tiny_space,
              num_pes=HW.num_pes, noc_bw=HW.noc_bw, strategy="greedy",
              block=64)
    a = search(tiny_conv, seed=3, **kw)
    b = search(tiny_conv, seed=3, **kw)
    assert a.best_point == b.best_point
    assert a.best_value == b.best_value
    assert [e["point"] for e in a.top_k] == [e["point"] for e in b.top_k]


def test_search_beats_table3(tiny_conv):
    """Acceptance: the found mapping's EDP <= the best Table-3 dataflow's
    on the same layer and hardware."""
    space = build_space(tiny_conv, dims=("K", "Y"), spatial_dims=("Y",),
                        cluster_inner_dims=("X",), cluster_sizes=(8,),
                        perm_mode="all")
    r = search(tiny_conv, objective="edp", budget=400, space=space,
               num_pes=HW.num_pes, noc_bw=HW.noc_bw, seed=0, block=64)
    best_t3 = min(float(analyze(tiny_conv, table3_for_layer(f, tiny_conv),
                                HW).edp) for f in TABLE3)
    assert r.best_value <= best_t3 * (1 + 1e-6)


def test_search_cache_roundtrip(tiny_conv, tiny_space, tmp_path):
    kw = dict(objective="edp", budget=40, space=tiny_space,
              num_pes=HW.num_pes, noc_bw=HW.noc_bw, seed=1,
              strategy="random", block=64, cache_dir=str(tmp_path))
    a = search(tiny_conv, **kw)
    assert not a.cached
    b = search(tiny_conv, **kw)
    assert b.cached
    assert b.best_point == a.best_point
    assert b.best_value == a.best_value
    assert b.n_evaluated == a.n_evaluated
    # different search parameters must not hit the same cache entry
    c = search(tiny_conv, **{**kw, "max_groups": 2})
    assert not c.cached
    d = search(tiny_conv, **{**kw, "top_k": 3})
    assert not d.cached


# ----------------------------------------------------------------------
# Satellite regression: tile_variants symbolic handling
# ----------------------------------------------------------------------

def test_tile_variants_preserve_symbolic():
    df = Dataflow("sym", (
        TemporalMap(Sz("R"), Sz("R"), "C"),   # symbolic: must not scale
        TemporalMap(FULL, FULL, "K"),         # FULL sentinel: must not scale
        SpatialMap(1, 1, "X"),
    ))
    variants = tile_variants(df, scales=(1, 2, 4))
    # nothing scalable -> only the base variant, no misleading tags
    assert [tag for tag, _ in variants] == ["base"]
    for _, v in variants:
        assert v.directives == df.directives


def test_tile_variants_tag_names_scaled_dims():
    df = Dataflow("mix", (
        TemporalMap(4, 4, "C"),
        TemporalMap(Sz("S"), Sz("S"), "K"),
        SpatialMap(1, 1, "X"),
    ))
    variants = dict(tile_variants(df, scales=(1, 2)))
    assert set(variants) == {"base", "x2[C]"}
    base, x2 = variants["base"], variants["x2[C]"]
    assert base.directives == df.directives
    (c_map,) = [d for d in x2.directives
                if not isinstance(d, Cluster) and d.dim == "C"]
    assert (c_map.size, c_map.offset) == (8, 8)
    (k_map,) = [d for d in x2.directives
                if not isinstance(d, Cluster) and d.dim == "K"]
    assert isinstance(k_map.size, Sz)  # symbolic preserved untouched
