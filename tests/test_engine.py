"""Performance/cost engine: conservation laws, monotonicity, hardware
ablations (Table 5), and faithful-vs-vectorized equivalence."""
import numpy as np
import pytest

from repro.core import dataflows as dfl
from repro.core import tensor_analysis as ta
from repro.core.model import analyze
from repro.core.performance import HWConfig

HW = HWConfig(num_pes=64, noc_bw=16.0, noc_latency=2.0)

OPS = [
    ta.conv2d("late", k=32, c=32, y=10, x=10, r=3, s=3),
    ta.conv2d("strided", k=16, c=3, y=30, x=30, r=5, s=5, stride=2),
    ta.dwconv2d("dw", c=24, y=12, x=12, r=3, s=3),
    ta.fc("fc", k=64, c=96),
    ta.pointwise_conv("pw", k=16, c=8, y=14, x=14),
]
FLOWS = ["C-P", "X-P", "YX-P", "YR-P", "KC-P"]


@pytest.mark.parametrize("op", OPS, ids=lambda o: o.name)
@pytest.mark.parametrize("flow", FLOWS)
def test_mac_conservation(op, flow):
    """Every MAC executes exactly once regardless of dataflow."""
    df = dfl.table3_for_layer(flow, op)
    s = analyze(op, df, HW)
    assert s.total_macs == op.total_macs


@pytest.mark.parametrize("op", OPS[:2], ids=lambda o: o.name)
@pytest.mark.parametrize("flow", FLOWS)
def test_runtime_lower_bound(op, flow):
    """Runtime >= compute-bound bound MACs/PEs (utilization <= 1)."""
    df = dfl.table3_for_layer(flow, op)
    s = analyze(op, df, HW)
    assert s.runtime >= op.total_macs / HW.num_pes
    assert 0.0 < s.utilization <= 1.0 + 1e-9


@pytest.mark.parametrize("flow", FLOWS)
def test_more_bandwidth_never_slower(flow):
    op = OPS[0]
    df = dfl.table3_for_layer(flow, op)
    prev = None
    for bw in (2.0, 8.0, 32.0, 128.0):
        s = analyze(op, df, HW.replace(noc_bw=bw))
        if prev is not None:
            assert s.runtime <= prev + 1e-9
        prev = s.runtime


def test_more_pes_never_more_cycles():
    op = ta.conv2d("c", k=64, c=64, y=18, x=18, r=3, s=3)
    df = dfl.table3_for_layer("KC-P", op)
    prev = None
    for p in (16, 64, 256, 1024):
        s = analyze(op, df, HW.replace(num_pes=p, noc_bw=1e9))
        if prev is not None:
            assert s.runtime <= prev + 1e-9
        prev = s.runtime


def test_multicast_ablation_increases_energy():
    """Table 5: removing spatial multicast support costs energy.  Needs
    >1 top-level cluster so the K-spatial map actually multicasts inputs."""
    op = OPS[0]
    df = dfl.table3_for_layer("KC-P", op)
    hw = HW.replace(num_pes=256)
    e_with = analyze(op, df, hw).energy_pj
    e_without = analyze(op, df, hw.replace(multicast=False)).energy_pj
    assert e_without > e_with


def test_reduction_ablation_increases_energy():
    op = OPS[0]
    df = dfl.table3_for_layer("KC-P", op)  # 64-wide C reduction
    hw = HW.replace(num_pes=256)
    e_with = analyze(op, df, hw).energy_pj
    e_without = analyze(op, df,
                        hw.replace(spatial_reduction=False)).energy_pj
    assert e_without > e_with


def test_bandwidth_ablation_hits_throughput_not_energy():
    """Table 5 row 2: smaller bw -> lower throughput, ~same energy."""
    op = OPS[0]
    df = dfl.table3_for_layer("KC-P", op)
    a = analyze(op, df, HW.replace(noc_bw=64.0))
    b = analyze(op, df, HW.replace(noc_bw=2.0))
    assert b.throughput < a.throughput
    assert abs(b.energy_pj - a.energy_pj) / a.energy_pj < 0.05


def test_reuse_factor_leq_algorithmic_max():
    """Fig. 11: achieved reuse can never beat the algorithmic max 'A'."""
    from repro.core.tensor_analysis import algorithmic_max_reuse
    for op in OPS:
        amax = algorithmic_max_reuse(op)
        for flow in FLOWS:
            s = analyze(op, dfl.table3_for_layer(flow, op), HW)
            for t in ("F", "I"):
                assert s.reuse_factor[t] <= amax[t] * (1 + 1e-6), \
                    (op.name, flow, t)


def test_buffer_requirements_positive():
    for flow in FLOWS:
        s = analyze(OPS[0], dfl.table3_for_layer(flow, OPS[0]), HW)
        assert s.l1_req_kb > 0
        assert s.l2_req_kb >= s.l1_req_kb * 0  # defined


def test_energy_breakdown_sums():
    s = analyze(OPS[0], dfl.table3_for_layer("KC-P", OPS[0]), HW)
    total = sum(s.energy_breakdown.values())
    assert np.isclose(total, s.energy_pj, rtol=1e-6)


# ----------------------------------------------------------------------
# faithful == vectorized
# ----------------------------------------------------------------------

@pytest.mark.parametrize("flow", FLOWS)
def test_vectorized_matches_faithful(flow):
    import jax.numpy as jnp
    from repro.core.vectorized import evaluate_grid
    op = ta.conv2d("v", k=48, c=40, y=14, x=14, r=3, s=3)
    df = dfl.table3_for_layer(flow, op)
    pes = np.array([8, 60, 256, 500], np.int64)
    bw = np.array([4.0, 16.0, 32.0, 64.0], np.float32)
    bs = evaluate_grid(op, df, pes, bw)
    for i in range(len(pes)):
        s = analyze(op, df, HWConfig(num_pes=int(pes[i]),
                                     noc_bw=float(bw[i]),
                                     noc_latency=2.0))
        assert np.isclose(float(bs.runtime[i]), s.runtime, rtol=1e-5), flow
        assert np.isclose(float(bs.macs[i]), s.total_macs, rtol=1e-6)
        assert np.isclose(float(bs.energy_pj[i]), s.energy_pj, rtol=1e-4)
        assert np.isclose(float(bs.util[i]), s.utilization, rtol=1e-5)
