"""End-to-end behaviour tests for the paper's system: the full
analyze→DSE path and the qualitative case-study claims (§5)."""
import numpy as np
import pytest

from repro.core import dnn_models as zoo
from repro.core import tensor_analysis as ta
from repro.core.dataflows import table3_for_layer
from repro.core.model import analyze
from repro.core.performance import HWConfig

HW = HWConfig(num_pes=256, noc_bw=32.0, noc_latency=2.0)
FLOWS = ["C-P", "X-P", "YX-P", "YR-P", "KC-P"]


def _totals(layers, flow):
    rt = en = 0
    for l in layers:
        s = analyze(l, table3_for_layer(flow, l), HW)
        rt += s.runtime
        en += s.energy_pj
    return rt, en


def test_cp_underutilized_on_shallow_channels():
    """§1: channel-parallel dataflows waste PEs on early layers."""
    early = ta.conv2d("e", k=64, c=3, y=230, x=230, r=7, s=7, stride=2)
    s = analyze(early, table3_for_layer("C-P", early), HW)
    assert s.utilization < 0.05


def test_yxp_fast_on_wide_activations():
    """§5.1: YX-P (ShiDianNao) excels on wide/shallow (UNet-style) layers."""
    wide = ta.conv2d("w", k=64, c=3, y=224, x=224, r=3, s=3)
    rts = {f: analyze(wide, table3_for_layer(f, wide), HW).runtime
           for f in FLOWS}
    assert rts["YX-P"] == min(rts.values())


def test_kcp_strong_on_late_layers():
    """§5.1: KC-P (NVDLA) leads on channel-rich late layers."""
    late = ta.conv2d("l", k=512, c=512, y=16, x=16, r=3, s=3)
    rts = {f: analyze(late, table3_for_layer(f, late), HW).runtime
           for f in FLOWS}
    best = min(rts.values())
    assert rts["KC-P"] <= 2.0 * best
    assert rts["KC-P"] < rts["X-P"]


def test_yrp_kcp_late_layer_energy_close():
    """§5.1: 'in late layers, the reuse factors of YR-P and KC-P are
    almost similar' -> similar energy (paper: <11% reuse difference)."""
    late = ta.conv2d("l", k=512, c=512, y=16, x=16, r=3, s=3)
    e_yr = analyze(late, table3_for_layer("YR-P", late), HW).energy_pj
    e_kc = analyze(late, table3_for_layer("KC-P", late), HW).energy_pj
    assert abs(e_yr - e_kc) / min(e_yr, e_kc) < 0.35


def test_yrp_higher_reuse_early_layers():
    """§5.1/Fig 11: YR-P has much higher act+filter reuse in early
    layers than KC-P (paper: 5.8x / 15.17x)."""
    early = zoo.fig11_operators()["early"]
    yr = analyze(early, table3_for_layer("YR-P", early), HW).reuse_factor
    kc = analyze(early, table3_for_layer("KC-P", early), HW).reuse_factor
    assert yr["I"] > 1.5 * kc["I"]
    # filter-reuse magnitudes depend on the L1-tier accounting; the
    # activation direction is the robust claim (EXPERIMENTS.md deviations)
    assert yr["F"] > 0


def test_pointwise_conv_needs_bandwidth():
    """Table 4/Fig 11c: 1x1 convs lose convolutional reuse -> higher NoC
    bandwidth requirement for activation-parallel dataflows."""
    pw = zoo.fig11_operators()["pointwise"]
    late = zoo.fig11_operators()["late"]
    bw_pw = analyze(pw, table3_for_layer("X-P", pw), HW).peak_bw[0]
    bw_late = analyze(late, table3_for_layer("X-P", late), HW).peak_bw[0]
    assert bw_pw > bw_late


def test_adaptive_dataflow_beats_best_fixed():
    """Fig. 10f: per-operator dataflow choice reduces runtime & energy."""
    layers = zoo.mobilenet_v2()[::6] + zoo.vgg16()[::6]
    fixed = {f: _totals(layers, f) for f in FLOWS}
    best_rt = min(v[0] for v in fixed.values())
    best_en = min(v[1] for v in fixed.values())
    ada_rt = sum(min(analyze(l, table3_for_layer(f, l), HW).runtime
                     for f in FLOWS) for l in layers)
    ada_en = sum(min(analyze(l, table3_for_layer(f, l), HW).energy_pj
                     for f in FLOWS) for l in layers)
    assert ada_rt <= best_rt
    assert ada_en <= best_en


def test_dse_finds_distinct_optima():
    """§5.2: throughput- and energy-optimized designs differ."""
    from repro.core.dse import DSEConfig, merge_results, run_dse_full
    op = ta.conv2d("c2", k=64, c=64, y=114, x=114, r=3, s=3)
    cfg = DSEConfig(pe_range=tuple(range(16, 513, 32)),
                    bw_range=(4.0, 8.0, 16.0, 32.0, 64.0))
    agg = merge_results(run_dse_full(op, "KC-P", cfg, scales=(1, 2)))
    assert agg["n_valid"] > 0
    tb, eb = agg["best"]["throughput"], agg["best"]["energy"]
    assert tb["throughput"] >= eb["throughput"]
    assert eb["energy_pj"] <= tb["energy_pj"]
    assert tb["power_mw"] <= 450.0 and tb["area_mm2"] <= 16.0
