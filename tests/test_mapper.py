"""The MAESTRO↔TPU bridge: Table-1 predictions vs actual XLA collectives.

These tests lower tiny sharded GEMMs on a multi-device host mesh and check
that the collectives the SPMD partitioner inserts are exactly the ones the
directive-level reuse analysis predicts (spatial multicast -> all-gather,
spatial reduction -> all-reduce/reduce-scatter)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import tensor_analysis as ta
from repro.core.dataflows import table3_for_layer
from repro.core.mapper import expected_collectives, gemm_op

# Collective checks need >1 device; run them in a subprocess with a forced
# 8-device host platform (XLA device count locks at first jax init).
_SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((8,), ("model",))

    def lower_gemm(spec_l, spec_r, spec_o):
        def f(a, b):
            return jax.lax.with_sharding_constraint(
                a @ b, NamedSharding(mesh, spec_o))
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, spec_l),
                                     NamedSharding(mesh, spec_r)))
        return c.lower(a, b).compile().as_text()

    # K-partitioned (tp): weights sharded on out dim, activations full.
    hlo = lower_gemm(P(), P(None, "model"), P(None, "model"))
    assert "all-gather" not in hlo and "all-reduce" not in hlo, "tp-K"

    # C-partitioned: contraction sharded -> spatial reduction (all-reduce
    # or reduce-scatter) must appear.
    hlo = lower_gemm(P(None, "model"), P("model", None), P())
    assert ("all-reduce" in hlo or "reduce-scatter" in hlo), "tp-C"

    # DP/FSDP: batch sharded, weights sharded on contraction dim ->
    # weight all-gather (spatial multicast of the decoupled tensor).
    hlo = lower_gemm(P("model", None), P("model", None), P("model", None))
    assert "all-gather" in hlo or "all-reduce" in hlo, "fsdp"
    print("OK")
""")


def test_spmd_collectives_match_taxonomy():
    r = subprocess.run([sys.executable, "-c", _SUB],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_expected_collectives_table1():
    from repro.core.mapper import contraction_tp, fsdp_dp, megatron_tp
    op = gemm_op("g", m=32, n=64, k=128)
    # K-partitioned: inputs (I) decoupled from K -> multicast; no psums
    exp = expected_collectives(megatron_tp(None), op)
    assert exp.get("I") == "all-gather"
    assert "O" not in exp
    # C-partitioned: contraction sharded -> output reduction
    exp = expected_collectives(contraction_tp(None), op)
    assert exp.get("O") == "all-reduce"
    # DP: weights decoupled from batch -> weight multicast (FSDP gather)
    exp = expected_collectives(fsdp_dp(None), op)
    assert exp.get("F") == "all-gather"


def test_dataflow_to_pspec_kc():
    import jax
    from repro.core.mapper import dataflow_to_pspec
    op = ta.conv2d("c", k=64, c=64, y=8, x=8, r=3, s=3)
    df = table3_for_layer("KC-P", op)
    mesh = jax.make_mesh((1, 1), ("x", "y"))
    specs = dataflow_to_pspec(df, mesh, op)
    # K spatial at level 0 -> first mesh axis on the K position of F and O
    assert specs["rhs"][1] == "x"      # F[K dim] sharded on level-0 axis
    assert specs["out"][1] == "x"
    assert specs["lhs"] == () or specs["lhs"][0] is None or \
        specs["lhs"][1] == "y"         # C inner -> second axis on lhs


def test_tpu_mapping_analysis_runs():
    import jax
    from repro.core.mapper import analyze_tpu_mapping, megatron_tp
    op = gemm_op("g", m=4096, n=8192, k=8192)
    mesh = jax.make_mesh((1,), ("model",))
    tm = analyze_tpu_mapping(megatron_tp(mesh), op, mesh)
    assert tm.stats.total_macs == op.total_macs
    assert tm.expected_collectives.get("I") == "all-gather"
