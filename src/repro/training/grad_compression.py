"""Int8 gradient compression with error feedback.

Per-tensor symmetric quantization: q = round(g / s · 127) with
s = max|g|; the quantization residual is carried in an ``error_feedback``
buffer and re-injected next step (EF-SGD), which keeps convergence
unbiased to first order.

Under the SPMD partitioner the quantized tensor is what crosses the ICI
for the data-parallel gradient reduction — in MAESTRO terms this shrinks
the spatial-reduction communication volume by 4× (bf16→int8... fp32→int8),
trading it for one extra elementwise pass (compute term), which is the
right trade whenever the collective term dominates the roofline
(EXPERIMENTS.md §Perf-B).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray, bits: int = 8):
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g)) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, opt_state, bits: int = 8):
    """Apply quantize→dequantize with error feedback.  Returns
    (compressed grads, opt_state with updated error_feedback)."""
    ef = opt_state.get("error_feedback")
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf, bits)
        deq = dequantize(q, s)
        return deq, gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_state = dict(opt_state)
    new_state["error_feedback"] = new_ef
    return new_grads, new_state
