from .train_step import TrainConfig, make_train_step, init_train_state, \
    abstract_train_state
from . import grad_compression

__all__ = ["TrainConfig", "make_train_step", "init_train_state",
           "abstract_train_state", "grad_compression"]
