"""Train-step builder: loss → grads → (optional int8-compressed reduction)
→ AdamW, with microbatch gradient accumulation.

Microbatching doubles as compute/communication overlap: with the batch
split into M microbatches scanned sequentially, XLA schedules microbatch
k+1's forward against microbatch k's gradient reduce-scatter — MAESTRO's
double-buffering rule (max instead of sum of delays) realized at pod
scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import registry
from ..optim import adamw
from .grad_compression import compress_decompress


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress_grads: bool = False
    compress_bits: int = 8
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


def _split_micro(batch: dict, m: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return x.reshape(m, b // m, *x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_of(params, batch):
        return registry.loss_fn(params, batch, cfg)

    grad_fn = jax.value_and_grad(loss_of)

    def train_step(params, opt_state, batch):
        if tc.microbatches > 1:
            micro = _split_micro(batch, tc.microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                loss, grads = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (gzero, 0.0), micro)
            loss = lsum / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
        else:
            loss, grads = grad_fn(params, batch)

        if tc.compress_grads:
            grads, opt_state = compress_decompress(
                grads, opt_state, bits=tc.compress_bits)

        params, opt_state, om = adamw.apply_updates(
            params, grads, opt_state, tc.opt)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tc: TrainConfig, key):
    from ..models.param import init_params
    params = init_params(registry.specs(cfg), key)
    opt_state = adamw.init_state(params)
    if tc.compress_grads:
        opt_state["error_feedback"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt_state


def abstract_train_state(cfg: ModelConfig, tc: TrainConfig):
    """ShapeDtypeStruct trees for the dry-run (no allocation)."""
    from ..models.param import abstract_params
    params = abstract_params(registry.specs(cfg))

    def f32(sds):
        return jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    opt_state = {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if tc.compress_grads:
        opt_state["error_feedback"] = jax.tree.map(f32, params)
    return params, opt_state
