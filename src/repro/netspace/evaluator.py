"""Batched multi-layer evaluation: layer shape as a vmapped operand.

The PR-2/PR-3 universal executable already treats tile sizes, loop order,
spatial choice, cluster option and the hardware point as operands of one
compiled computation.  This module adds the last structural axis — the
LAYER SHAPE — so one XLA compile per (op-class, level-count) produces the
candidate frontiers of every layer of a network in a single device pass
over a ``(n_layers, n_candidates, G)`` gene tensor:

  * ``ext`` (i, D): the dim extents of row i's layer;
  * ``cin_size``/``cin_off`` (i, K): the layer-resolved cluster inner
    maps (the sliding ``SpatialMap(Sz(S), 1)`` inner differs per layer);
  * everything else encodes exactly like the per-layer gene pipeline
    (``universal.encode_genes_base`` — shared code, not a twin).

Evaluation reuses the fused on-device reduction
(``core.vectorized.universal_reduced_evaluator``) with the per-row
objective column plus the (runtime, energy, L1, L2) columns the network
composer needs, chunks striped over local devices with async double
buffering — per-row outputs, so results are bit-identical at any device
count.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .. import obs
from ..core.vectorized import (HWTail, ReduceSpec,
                               universal_reduced_evaluator)
from ..mapspace.search import OBJECTIVES
from ..mapspace.space import dedupe_equivalent_genes, gene_tables
from ..mapspace.universal import (GeneRun, _pad_rows, compile_count,
                                  encode_genes_base, is_warm, warm_once)
from ..resilience import (CHUNK_WATCHDOG, RetryPolicy, SweepCheckpoint,
                          SweepKilled, array_hash, check_cancel,
                          default_policy, fault_point, is_oom,
                          run_attempts)
from .space import NetSpace

# The per-row feature columns the composer consumes.
COLS = ("runtime", "energy_pj", "l1_kb", "l2_kb")


@dataclasses.dataclass
class NetEval:
    """Per-candidate results of one network evaluation pass.

    ``vals[u][i]`` is the canonical-minimize objective of candidate ``i``
    of unique layer ``u``; ``cols[u]`` the matching ``(n, len(COLS))``
    feature columns."""
    vals: list[np.ndarray]
    cols: list[np.ndarray]
    run: GeneRun


def _encode_rows(ns: NetSpace, cls, uid: np.ndarray, genes: np.ndarray,
                 spec, *, pes: np.ndarray, bw: np.ndarray
                 ) -> dict[str, np.ndarray]:
    """Operand arrays for rows of ONE (class, level-count) family; rows
    may mix layers (``uid`` per row)."""
    n = genes.shape[0]
    a = len(cls.dims)
    d = len(spec.dim_names)
    ops = {
        "sizes": np.empty((n, a), np.float32),
        "offsets": np.empty((n, a), np.float32),
        "rank": np.empty((n, a), np.float32),
        "sp": np.zeros((n, a), np.float32),
        "ext": np.empty((n, d), np.float32),
        "pes": np.asarray(pes, np.float32).copy(),
        "bw": np.asarray(bw, np.float32).copy(),
    }
    if spec.cluster:
        k = len(spec.cluster)
        ops["csize"] = np.empty((n,), np.float32)
        ops["csel"] = np.zeros((n, k), np.float32)
        ops["cin_size"] = np.empty((n, k), np.float32)
        ops["cin_off"] = np.empty((n, k), np.float32)
    for u in np.unique(uid):
        m = uid == u
        op, space = ns.unique[u], ns.spaces[u]
        sub = genes[m]
        base = encode_genes_base(op, space, sub, num_pes=pes[m],
                                 noc_bw=bw[m])
        for key in ("sizes", "offsets", "rank", "sp"):
            ops[key][m] = base[key]
        ops["ext"][m] = ns.ext_row(u)[None, :]
        if spec.cluster:
            tb = gene_tables(op, space)
            if tb.cluster_is_none[sub[:, 2]].any():
                raise ValueError("1-level rows passed to a 2-level spec")
            ops["csize"][m] = tb.csize_tab[sub[:, 2]]
            cand = ns.cand_of_option(u)[sub[:, 2]]
            sel = np.zeros((sub.shape[0], len(spec.cluster)), np.float32)
            sel[np.arange(sub.shape[0]), cand] = 1.0
            ops["csel"][m] = sel
            cin_s, cin_o = ns.cin_rows(u)
            ops["cin_size"][m] = cin_s[None, :]
            ops["cin_off"][m] = cin_o[None, :]
    return ops


def _rep_key(cls) -> str:
    rep = cls.rep
    return f"{rep.name}|{sorted(rep.dims.items())}|{rep.op_type}"


def evaluate_rows(ns: NetSpace, uid: np.ndarray, genes: np.ndarray, *,
                  objective: str = "edp", num_pes, noc_bw,
                  block: int = 1024, n_devices: int | None = None,
                  depth: int = 2, multicast: bool = True,
                  spatial_reduction: bool = True,
                  hw_tail: HWTail | None = None, run: GeneRun | None = None,
                  ckpt: SweepCheckpoint | None = None,
                  retry: RetryPolicy | None = None,
                  _splits_left: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Evaluate (layer, candidate) rows of ONE op-class through the
    shape-as-operand executable: ≤ 2 compiles (1-level + 2-level family)
    no matter how many layers/structure groups the rows span.  Returns
    ``(vals, cols)`` aligned with the input rows; ``num_pes``/``noc_bw``
    may be scalars or per-row arrays (network co-DSE).

    Resilience mirrors ``universal.evaluate_genes``: chunks run under
    ``retry`` (transient failures re-dispatch with backoff, OOM halves
    the block recursively, exhaustion raises ``DeviceError``), and with
    ``ckpt`` the (vals, cols, cursor) accumulators persist every few
    chunks so a killed pass resumes bit-identically — the outputs are
    direct-indexed by row, so resume order cannot change them."""
    col, maximize = OBJECTIVES[objective]
    uid = np.asarray(uid, np.int64)
    genes = np.asarray(genes, np.int64)
    n = genes.shape[0]
    cls = ns.classes[ns.class_of[uid[0]]]
    if any(ns.class_of[u] != ns.class_of[uid[0]] for u in np.unique(uid)):
        raise ValueError("evaluate_rows: rows must share one op-class")
    nd = n_devices if n_devices is not None else jax.local_device_count()
    nd = max(1, min(nd, jax.local_device_count()))
    run = run if run is not None else GeneRun()
    run.n_rows += n
    run.n_devices = max(run.n_devices, nd)
    pes = np.broadcast_to(np.asarray(num_pes, np.float32), (n,))
    bw = np.broadcast_to(np.asarray(noc_bw, np.float32), (n,))

    # 2-level membership: option slots are uniform across the class
    tb0 = gene_tables(ns.unique[uid[0]], ns.spaces[uid[0]])
    is2 = ~tb0.cluster_is_none[genes[:, 2]]

    vals = np.empty(n, np.float64)
    cols = np.empty((n, len(COLS)), np.float64)
    t_start = time.perf_counter()

    met = obs.metrics()
    met.inc("netspace.rows_evaluated", n)
    n_compiles_at_entry = run.n_compiles
    nv_entry = run.n_valid      # ``run`` may be shared across calls —
    c0 = compile_count()        # checkpoint state is entry-relative
    retry = retry or default_policy()
    splits_left = retry.max_splits if _splits_left is None else _splits_left

    # -- resilience state: resume cursor + periodic checkpoint ----------
    start_cursor = 0
    chunks_done = 0
    gidx = 0
    ckpt_meta: dict | None = None
    if ckpt is not None:
        ckpt_meta = {"key": ckpt.key, "n": int(n), "block": int(block),
                     "nd": int(nd), "objective": objective,
                     "content": array_hash(uid, genes, pes, bw)}
        st = ckpt.load(ckpt_meta)
        if st is not None:
            start_cursor = chunks_done = int(st["cursor"])
            run.n_valid = nv_entry + int(st["n_valid"])
            vals[:] = st["vals"]
            cols[:] = st["cols"]

    def ckpt_state() -> dict:
        return {"cursor": chunks_done, "n_valid": run.n_valid - nv_entry,
                "vals": vals, "cols": cols}

    def split_eval(sub: np.ndarray) -> None:
        # OOM recovery: same rows, half the block, one device; outputs
        # are direct-indexed by row so the merge is bit-transparent
        rrun = GeneRun()
        v, c = evaluate_rows(
            ns, uid[sub], genes[sub], objective=objective,
            num_pes=pes[sub], noc_bw=bw[sub],
            block=max(retry.min_rows, block // 2), n_devices=1,
            depth=depth, multicast=multicast,
            spatial_reduction=spatial_reduction, hw_tail=hw_tail,
            run=rrun, retry=retry, _splits_left=splits_left - 1)
        vals[sub] = v
        cols[sub] = c
        run.n_valid += rrun.n_valid
        run.n_steady += rrun.n_steady
        run.n_compiles += rrun.n_compiles
        run.compile_s += rrun.compile_s
        run.eval_s += rrun.eval_s
        run.encode_s += rrun.encode_s

    def collect(sub: np.ndarray, m: int, out: dict) -> None:
        # the blocked wait for (and host copy of) this chunk's reduced
        # device results — the host-visible tail of the device pass
        with obs.span("device-pass", op=cls.rep.name, rows=m, devices=nd):
            t0 = time.perf_counter()
            host = {kk: np.asarray(v) for kk, v in out.items()}
            dt = time.perf_counter() - t0
        run.eval_s += dt
        met.observe("netspace.collect_wait_s", dt)
        met.inc("netspace.merge_bytes",
                sum(v.nbytes for v in host.values()))
        chunk_rows = nd * block
        vals[sub] = host["vals"].reshape(chunk_rows)[:m]
        cols[sub] = host["cols"].reshape(chunk_rows, len(COLS))[:m]
        run.n_valid += int(np.sum(host["n_valid"]))

    for spec, fam in ((cls.spec1, np.where(~is2)[0]),
                      (cls.spec2, np.where(is2)[0])):
        if fam.size == 0:
            continue
        assert spec is not None
        fam_label = f"{cls.rep.name}:L{2 if spec.cluster else 1}"
        chunk_rows = nd * block
        reduce = ReduceSpec(objective=col, maximize=maximize,
                            k=1, return_vals=True, pareto=False,
                            hw=hw_tail, cols=COLS)
        f = universal_reduced_evaluator(
            cls.rep, spec, reduce, multicast=multicast,
            spatial_reduction=spatial_reduction, n_devices=nd)
        wk = ("netspace", _rep_key(cls), spec, reduce, multicast,
              spatial_reduction, nd, chunk_rows)
        pending: collections.deque = collections.deque()

        def make_chunk(sub, m, in_flight):
            with obs.span("encode", family=fam_label, rows=m):
                t0 = time.perf_counter()
                batch = _encode_rows(ns, cls, uid[sub], genes[sub], spec,
                                     pes=pes[sub], bw=bw[sub])
                pad = chunk_rows - m
                live = np.zeros(chunk_rows, np.float32)
                live[:m] = 1.0
                batch = {kk: _pad_rows(v, pad) for kk, v in batch.items()}
                batch["live"] = live
                if nd > 1:
                    batch = {kk: v.reshape((nd, block) + v.shape[1:])
                             for kk, v in batch.items()}
                jbatch = {kk: jnp.asarray(v) for kk, v in batch.items()}
                t_enc = time.perf_counter() - t0
                run.encode_s += t_enc
            if in_flight:
                # double-buffer overlap, measured not guessed: host
                # encode time spent while >= 1 chunk was in flight
                met.inc("netspace.overlap_encode_s", t_enc)
            met.observe("netspace.chunk_occupancy", m / chunk_rows)
            return jbatch

        def dispatch(jbatch, m):
            check_cancel("chunk")
            fault_point("chunk")
            if not is_warm(wk):
                with obs.span("compile", family=fam_label,
                              rows=chunk_rows, devices=nd):
                    t0 = time.perf_counter()
                    out = f(jbatch)
                    jax.block_until_ready(out)
                    dt = time.perf_counter() - t0
                if warm_once(wk, family=fam_label, seconds=dt):
                    run.compile_s += dt
                    run.n_compiles += 1
            else:
                met.inc("universal.warm_hits", family=fam_label)
                with obs.span("dispatch", family=fam_label, rows=m,
                              devices=nd):
                    t0 = time.perf_counter()
                    out = f(jbatch)    # async dispatch
                    met.observe("netspace.dispatch_s",
                                time.perf_counter() - t0)
                run.n_steady += m
            return out

        def recover(sub, m, exc):
            if isinstance(exc, SweepKilled):
                raise exc            # simulated process death: no retry
            if is_oom(exc) and splits_left > 0 and block > retry.min_rows:
                met.inc("resilience.chunk_splits")
                obs.instant("chunk-split", family=fam_label, rows=int(m),
                            block=block,
                            to=max(retry.min_rows, block // 2))
                split_eval(sub)
                return

            def once():
                collect(sub, m, dispatch(make_chunk(sub, m, False), m))
            run_attempts(once, policy=retry,
                         label=f"{fam_label} chunk", first_exc=exc)

        def finish(sub, m, out, t_disp):
            nonlocal chunks_done
            try:
                collect(sub, m, out)
            except Exception as exc:  # noqa: BLE001 — recover classifies
                recover(sub, m, exc)
            wall = time.perf_counter() - t_disp
            CHUNK_WATCHDOG.observe(wall, family=fam_label, rows=int(m))
            retry.check_deadline(wall, family=fam_label, rows=int(m))
            chunks_done += 1
            if ckpt is not None:
                ckpt.maybe_save(ckpt_state, ckpt_meta,
                                chunks_done=chunks_done)

        for lo in range(0, fam.size, chunk_rows):
            if gidx < start_cursor:
                gidx += 1        # merged by the resumed checkpoint
                continue
            gidx += 1
            sub = fam[lo:lo + chunk_rows]
            m = sub.size
            try:
                out = dispatch(make_chunk(sub, m, bool(pending)), m)
            except Exception as exc:  # noqa: BLE001 — recover classifies
                # drain in dispatch order first so the chunk cursor stays
                # contiguous, then recover this chunk synchronously
                while pending:
                    finish(*pending.popleft())
                recover(sub, m, exc)
                chunks_done += 1
                if ckpt is not None:
                    ckpt.maybe_save(ckpt_state, ckpt_meta,
                                    chunks_done=chunks_done)
                continue
            pending.append((sub, m, out, time.perf_counter()))
            while len(pending) > depth:
                finish(*pending.popleft())
        while pending:
            finish(*pending.popleft())

    # run-local vs process compile accounting cannot drift: both increment
    # on the same warm_once() event (recursive split merges move both)
    assert compile_count() - c0 == run.n_compiles - n_compiles_at_entry
    if ckpt is not None:
        ckpt.clear()               # completed: the checkpoint is spent
    run.e2e_s += time.perf_counter() - t_start
    return vals, cols


def evaluate_candidates(ns: NetSpace, cand: Sequence[np.ndarray], *,
                        objective: str = "edp", num_pes, noc_bw,
                        block: int = 1024, n_devices: int | None = None,
                        multicast: bool = True,
                        spatial_reduction: bool = True,
                        dedupe: bool = True) -> NetEval:
    """Evaluate per-unique-layer candidate gene matrices for the whole
    network: one device pass per (op-class, level-count), analysis-
    equivalent candidates collapsed per layer (``dedupe=True``; disable
    when ``num_pes``/``noc_bw`` are per-row arrays, where equal genes may
    carry different hardware points).

    ``cand[u]`` is the ``(n_u, G)`` candidate matrix of unique layer
    ``u``; ``num_pes``/``noc_bw`` are scalars or per-unique-layer arrays
    aligned with ``cand``."""
    run = GeneRun()
    vals: list[np.ndarray] = [np.empty(0, np.float64)] * len(ns.unique)
    cols: list[np.ndarray] = [np.empty((0, len(COLS)),
                                       np.float64)] * len(ns.unique)
    per_row_hw = isinstance(num_pes, (list, tuple))
    for cls in ns.classes:
        jobs = []  # (uid, rep rows, back map, per-row pes, per-row bw)
        for u in cls.members:
            g = np.asarray(cand[u], np.int64)
            if not g.shape[0]:
                continue
            if dedupe:
                reps, back = dedupe_equivalent_genes(
                    ns.unique[u], ns.spaces[u], g)
            else:
                reps = back = np.arange(g.shape[0])
            p = b = None
            if per_row_hw:
                p = np.broadcast_to(np.asarray(num_pes[u], np.float32),
                                    (g.shape[0],))[reps]
                b = np.broadcast_to(np.asarray(noc_bw[u], np.float32),
                                    (g.shape[0],))[reps]
            jobs.append((u, g[reps], back, p, b))
        if not jobs:
            continue
        uid = np.concatenate([np.full(g.shape[0], u, np.int64)
                              for u, g, *_ in jobs])
        genes = np.concatenate([g for _, g, *_ in jobs])
        v, c = evaluate_rows(
            ns, uid, genes, objective=objective,
            num_pes=np.concatenate([p for *_, p, _ in jobs])
            if per_row_hw else num_pes,
            noc_bw=np.concatenate([b for *_, b in jobs])
            if per_row_hw else noc_bw,
            block=block, n_devices=n_devices, multicast=multicast,
            spatial_reduction=spatial_reduction, run=run)
        at = 0
        for u, g, back, *_ in jobs:
            vals[u] = v[at:at + g.shape[0]][back]
            cols[u] = c[at:at + g.shape[0]][back]
            at += g.shape[0]
    return NetEval(vals=vals, cols=cols, run=run)
