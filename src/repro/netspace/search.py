"""Whole-network schedule search (``netspace.search_network``) and the
network-level joint mapping × hardware co-DSE
(``netspace.co_search_network``).

Pipeline: build the shared-gene-layout :class:`NetSpace`, generate
per-layer candidates with the SAME draws as per-layer ``search()``
(``mapspace.search.static_candidates`` — the parity guarantee), evaluate
every (unique layer, candidate) row in one device pass per (op-class,
level-count) through the shape-as-operand executable, reduce each layer
to a top-``frontier_k`` frontier, and hand the frontiers to the DP (or
genetic) composer for per-layer mapping selection + fused-stack
segmentation under the reconfiguration/off-chip cost model.

The co-DSE crosses the per-layer frontiers with the full (PEs × NoC bw)
grid — hardware as per-row operands of the SAME executables, zero extra
compiles — then applies ``core.dse.run_dse``-style network accounting
(SRAM placed for the worst layer, area/power budgets, leakage on total
runtime) and merges an (energy, throughput) frontier via the co-DSE's
``pareto_front``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np

from .. import obs
from ..core import dnn_models as zoo
from ..core.dataflows import TABLE3, table3_for_layer
from ..core.dse import DSEConfig
from ..core.model import analyze
from ..core.performance import HWConfig
from ..core.tensor_analysis import LayerOp
from ..mapspace.codse import hw_grid
from ..mapspace.search import OBJECTIVES, static_candidates
from ..mapspace.space import (enumerate_genes, flat_index, point_dataflow,
                              prune_genes_by_budget, sample_genes)
from ..mapspace.universal import pareto_front
from .composer import (CandStat, NetCostModel, NetworkSchedule,
                       compose_dp, compose_genetic, evaluate_schedule)
from .evaluator import evaluate_candidates
from .space import NetSpace, build_netspace, halo_fractions

COMPOSERS = ("dp", "genetic", "auto")
BUDGET_POLICIES = ("uniform", "adaptive")


@dataclasses.dataclass
class NetSearchResult:
    objective: str
    strategy: str
    composer: str
    schedule: NetworkSchedule
    netspace: NetSpace
    frontiers: list[list[CandStat]]    # per unique layer
    model: NetCostModel
    n_evaluated: int                   # (unique layer, candidate) rows
    n_layers: int
    n_unique: int
    n_classes: int
    n_compiles: int
    compile_s: float
    eval_s: float
    encode_s: float
    compose_s: float
    n_transitions: int                 # composer-explored extensions
    elapsed_s: float
    n_devices: int
    budget_policy: str = "uniform"
    refined: tuple[int, ...] = ()      # unique ids the adaptive policy
    #                                    spent extra budget on

    @property
    def network_edp(self) -> float:
        return self.schedule.network_edp

    @property
    def schedules_per_s(self) -> float:
        """Composer throughput: partial-schedule extensions per second
        (each DP transition extends one resident-tile state by one
        layer)."""
        return self.n_transitions / max(self.compose_s, 1e-9)

    def best_dataflow(self, layer_idx: int):
        return point_dataflow(self.netspace.space_for(layer_idx),
                              self.schedule.genes[layer_idx])


def _layers_of(model) -> list[LayerOp]:
    if isinstance(model, str):
        return zoo.MODELS[model]()
    return list(model)


def _eval_objective(objective: str) -> str:
    """Network throughput = total MACs / total runtime with MACs fixed,
    so maximizing it is exactly minimizing total runtime — the additive
    form the composer needs."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {sorted(OBJECTIVES)}")
    return "runtime" if objective == "throughput" else objective


def _frontier(ns: NetSpace, uid: int, genes: np.ndarray,
              vals: np.ndarray, cols: np.ndarray, k: int
              ) -> list[CandStat]:
    order = np.lexsort((np.arange(len(vals)), vals))[:k]
    halo = halo_fractions(ns.unique[uid], ns.spaces[uid], genes[order])
    cls_id = ns.class_of[uid]
    out = []
    for j, i in enumerate(order):
        g = tuple(int(x) for x in genes[i])
        out.append(CandStat(
            gene=g, val=float(vals[i]), runtime=float(cols[i, 0]),
            energy=float(cols[i, 1]), l1_kb=float(cols[i, 2]),
            l2_kb=float(cols[i, 3]), halo=float(halo[j]),
            struct=(cls_id,) + g[:3]))
    return out


def _out_vols(layers: Sequence[LayerOp]) -> list[float]:
    return [float(op.output.volume(op.dims)) for op in layers]


def search_network(model, objective: str = "edp", budget: int = 512,
                   **kwargs) -> NetSearchResult:
    """Whole-network schedule search — the legacy entry point, now a
    thin wrapper over the declarative session path (``repro.api``);
    forwards verbatim to :func:`search_network_impl` (bit-equal by
    construction, see ``tests/test_api.py``)."""
    from ..api.session import default_session
    return default_session().run_search_network(
        model, objective=objective, budget=budget, **kwargs)


def _adaptive_refine(ns: NetSpace, cand, vals, cols, strats_u, *,
                     budget: int, cheap: int, seed: int,
                     l1_prune_kb, l2_prune_kb, adapt_cover: float
                     ) -> tuple[list[np.ndarray], list[int]]:
    """Pick the unique layers that dominate the cheap first pass's
    network cost and draw their remaining candidate budget.  Returns
    ``(extra_candidate_matrices, refined_unique_ids)`` — extras are
    empty for non-refined layers."""
    reps_n = np.bincount(np.asarray(ns.index), minlength=len(ns.unique))
    contrib = np.empty(len(ns.unique))
    for u in range(len(ns.unique)):
        best = float(np.min(vals[u])) if len(vals[u]) else np.inf
        contrib[u] = reps_n[u] * best
    inf_mask = ~np.isfinite(contrib)
    fin = np.where(inf_mask, 0.0, contrib)
    total = float(fin.sum())
    # infeasible-so-far layers always refine; finite ones by descending
    # network-cost contribution until `adapt_cover` of the total is in
    key = np.where(inf_mask, np.finfo(np.float64).max, fin)
    refined: list[int] = []
    cum = 0.0
    for u in np.argsort(-key, kind="stable"):
        if refined and not inf_mask[u] and total > 0 \
                and cum >= adapt_cover * total:
            break
        refined.append(int(u))
        cum += fin[u]
    extra = [np.empty((0, len(ns.spaces[u].gene_ranges())), np.int64)
             for u in range(len(ns.unique))]
    for u in refined:
        space = ns.spaces[u]
        if strats_u[u].startswith("exhaustive"):
            g = enumerate_genes(space, cheap, min(space.size, budget))
        else:
            g = sample_genes(space, np.random.default_rng([seed, u + 1]),
                             budget - cheap,
                             exclude_flat=flat_index(space, cand[u]))
        if g.shape[0]:
            g = prune_genes_by_budget(ns.unique[u], space, g,
                                      l1_kb=l1_prune_kb,
                                      l2_kb=l2_prune_kb)
        extra[u] = g
    return extra, refined


def search_network_impl(model, objective: str = "edp", budget: int = 512,
                        *, num_pes: int = 256, noc_bw: float = 32.0,
                        seed: int = 0, strategy: str = "auto",
                        frontier_k: int = 8, fuse: bool = True,
                        reconfig: bool = True,
                        l2_budget_kb: float | None = None,
                        l1_prune_kb: float | None = None,
                        l2_prune_kb: float | None = None,
                        hw: HWConfig | None = None,
                        composer: str = "auto",
                        devices: int | None = None, block: int = 1024,
                        multicast: bool = True,
                        spatial_reduction: bool = True,
                        netspace: NetSpace | None = None,
                        max_states: int = 4096,
                        budget_policy: str = "uniform",
                        adapt_cover: float = 0.7,
                        build_kwargs: dict[str, Any] | None = None
                        ) -> NetSearchResult:
    """Search a whole-network schedule: per-layer mapping selection plus
    DeFiNES-style fused-stack segmentation.

    ``model`` is a zoo name (``"vgg16"``) or a list of layers; ``budget``
    caps evaluated mappings PER UNIQUE LAYER SHAPE (repeated shapes are
    deduplicated and broadcast).  ``strategy`` is ``auto`` /
    ``exhaustive`` / ``random`` — non-adaptive by design so every
    layer's frontier comes out of one device pass; for an explicit
    ``exhaustive``/``random`` strategy the candidate draws are identical
    to per-layer ``search()`` under the same seed (``auto`` differs:
    ``search()`` escalates oversized spaces to adaptive ``greedy``,
    netspace to ``random``).  With ``reconfig=False`` and ``fuse=False``
    the composed schedule's per-layer choices then provably coincide
    with independent per-layer searches at the same strategy/seed.  A caller-supplied ``hw`` is the reference design outright:
    its ``num_pes``/``noc_bw`` take precedence over the keyword defaults,
    and the reconfiguration/DRAM cost-model fields live on it.

    ``budget_policy="adaptive"`` spends a cheap uniform first pass
    (``budget // 4`` per unique shape), then steers the remaining budget
    toward the layers that dominate network cost: unique shapes are
    refined, by descending (multiplicity × best-value) contribution,
    until ``adapt_cover`` of the first-pass total is covered.  The
    refinement pass rides the already-warm family executables — zero
    extra compiles."""
    t0 = time.perf_counter()
    eval_obj = _eval_objective(objective)
    if composer not in COMPOSERS:
        raise ValueError(f"composer must be one of {COMPOSERS}")
    if budget_policy not in BUDGET_POLICIES:
        raise ValueError(f"budget_policy must be one of "
                         f"{BUDGET_POLICIES}")
    layers = _layers_of(model)
    ns = netspace or build_netspace(layers, **(build_kwargs or {}))
    if hw is None:
        hw = HWConfig(num_pes=num_pes, noc_bw=noc_bw, noc_latency=2.0)
    # a caller-supplied HWConfig IS the reference design: its hardware
    # point wins over the num_pes/noc_bw keyword defaults
    num_pes, noc_bw = int(hw.num_pes), float(hw.noc_bw)

    cheap = budget if budget_policy == "uniform" \
        else max(16, budget // 4)
    cand: list[np.ndarray] = []
    strats_u: list[str] = []
    for u, op in enumerate(ns.unique):
        g, s = static_candidates(ns.spaces[u], strategy, cheap, seed)
        strats_u.append(s)               # auto may resolve per layer
        g = prune_genes_by_budget(op, ns.spaces[u], g,
                                  l1_kb=l1_prune_kb, l2_kb=l2_prune_kb)
        if not g.shape[0]:
            raise RuntimeError(f"{op.name}: budget pruning dropped every "
                               f"candidate")
        cand.append(g)
    strat = "+".join(dict.fromkeys(strats_u))

    ev_kw = dict(objective=eval_obj, num_pes=num_pes, noc_bw=noc_bw,
                 block=block, n_devices=devices, multicast=multicast,
                 spatial_reduction=spatial_reduction)
    ev = evaluate_candidates(ns, cand, **ev_kw)
    vals = list(ev.vals)
    cols = list(ev.cols)

    refined: list[int] = []
    if budget_policy == "adaptive" and cheap < budget:
        extra, refined = _adaptive_refine(
            ns, cand, vals, cols, strats_u, budget=budget, cheap=cheap,
            seed=seed, l1_prune_kb=l1_prune_kb, l2_prune_kb=l2_prune_kb,
            adapt_cover=adapt_cover)
        if any(g.shape[0] for g in extra):
            ev2 = evaluate_candidates(ns, extra, **ev_kw)
            ev.run.merge(ev2.run)
            for u in refined:
                if extra[u].shape[0]:
                    cand[u] = np.concatenate([cand[u], extra[u]])
                    vals[u] = np.concatenate([vals[u], ev2.vals[u]])
                    cols[u] = np.concatenate([cols[u], ev2.cols[u]])

    fronts_u = [_frontier(ns, u, cand[u], vals[u], cols[u],
                          frontier_k) for u in range(len(ns.unique))]
    frontiers = [fronts_u[ns.index[i]] for i in range(ns.n_layers)]

    cost_model = NetCostModel(hw=hw, objective=eval_obj, fuse=fuse,
                              reconfig=reconfig,
                              l2_budget_kb=l2_budget_kb)
    names = [op.name for op in layers]
    macs = float(sum(op.total_macs for op in layers))
    t_c = time.perf_counter()
    with obs.span("compose", composer=composer, layers=ns.n_layers):
        if composer == "genetic":
            schedule, n_trans = compose_genetic(
                frontiers, _out_vols(layers), ns.fusible, cost_model,
                names, macs, seed=seed)
            used = "genetic"
        else:
            schedule, n_trans = compose_dp(
                frontiers, _out_vols(layers), ns.fusible, cost_model,
                names, macs, max_states=max_states)
            used = "dp"
    compose_s = time.perf_counter() - t_c
    obs.metrics().observe("netspace.compose_s", compose_s)
    obs.metrics().inc("netspace.transitions", n_trans)

    return NetSearchResult(
        objective=objective, strategy=strat, composer=used,
        schedule=schedule, netspace=ns, frontiers=fronts_u,
        model=cost_model,
        n_evaluated=int(sum(len(c) for c in cand)),
        n_layers=ns.n_layers, n_unique=len(ns.unique),
        n_classes=len(ns.classes), n_compiles=ev.run.n_compiles,
        compile_s=ev.run.compile_s, eval_s=ev.run.eval_s,
        encode_s=ev.run.encode_s, compose_s=compose_s,
        n_transitions=n_trans, elapsed_s=time.perf_counter() - t0,
        n_devices=ev.run.n_devices, budget_policy=budget_policy,
        refined=tuple(refined))


# ----------------------------------------------------------------------
# Uniform Table-3 baseline: the number the schedule must beat
# ----------------------------------------------------------------------

def uniform_baseline(layers: Sequence[LayerOp], model: NetCostModel,
                     flows: Sequence[str] = tuple(TABLE3)
                     ) -> dict[str, dict[str, float]]:
    """Each Table-3 dataflow applied network-wide (no fusion, and no
    reconfiguration by construction — one fixed mapping), accounted
    through the SAME cost model as searched schedules (off-chip boundary
    terms included when fusion modeling is on) so the comparison is
    apples to apples.  Shape-deduplicated: each distinct layer analyzed
    once."""
    unique, index = zoo.unique_layers(list(layers))
    out_vols = _out_vols(layers)
    out: dict[str, dict[str, float]] = {}
    for flow in flows:
        per_u = []
        for op in unique:
            s = analyze(op, table3_for_layer(flow, op), model.hw)
            per_u.append((float(s.runtime), float(s.energy_pj)))
        fr = []
        for i in range(len(layers)):
            r, e = per_u[index[i]]
            val = {"edp": e * r, "energy": e, "runtime": r}[
                model.objective]
            fr.append([CandStat(gene=(), val=val, runtime=r, energy=e,
                                l1_kb=0.0, l2_kb=0.0, halo=0.0,
                                struct=("t3", flow))])
        cost, energy, runtime = evaluate_schedule(
            fr, [0] * len(layers), [False] * (len(layers) - 1),
            out_vols, [False] * (len(layers) - 1), model)
        out[flow] = {"cost": cost, "energy_pj": energy,
                     "runtime": runtime, "edp": energy * runtime}
    return out


def best_uniform(baselines: dict[str, dict[str, float]],
                 key: str = "edp") -> tuple[str, dict[str, float]]:
    flow = min(baselines, key=lambda f: baselines[f][key])
    return flow, baselines[flow]


# ----------------------------------------------------------------------
# Network-level joint mapping x hardware co-DSE
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CoNetResult:
    search: NetSearchResult
    pareto: list[dict[str, Any]]       # (energy, throughput) frontier
    best: dict[str, dict[str, Any] | None]
    top: list[dict[str, Any]]          # composer-refined best designs
    n_designs: int
    n_hw: int
    n_valid: int
    n_compiles: int
    elapsed_s: float


def co_search_network(model, cfg: DSEConfig | None = None,
                      objective: str = "edp", budget: int = 512,
                      **kwargs) -> CoNetResult:
    """Network-level joint co-DSE — the legacy entry point, now a thin
    wrapper over the declarative session path (``repro.api``); forwards
    verbatim to :func:`co_search_network_impl` (bit-equal by
    construction, see ``tests/test_api.py``)."""
    from ..api.session import default_session
    return default_session().run_co_search_network(
        model, cfg=cfg, objective=objective, budget=budget, **kwargs)


def co_search_network_impl(model, cfg: DSEConfig | None = None,
                           objective: str = "edp", budget: int = 512, *,
                           num_pes: int = 256, noc_bw: float = 32.0,
                           seed: int = 0, frontier_k: int = 4,
                           refine_k: int = 4,
                           **search_kwargs) -> CoNetResult:
    """Network-level joint mapping × hardware sweep: the reference
    ``search_network`` frontiers crossed with the full (PEs × bw) grid —
    hardware as per-row operands of the already-compiled shape-as-operand
    executables (zero extra compiles at matching block shapes) — under
    ``run_dse``-style accounting: SRAM provisioned for the worst layer,
    area/power budgets, leakage energy on the network runtime.

    Grid points use vectorized per-layer frontier selection; the
    ``refine_k`` best points are re-composed with the full fusion/
    reconfiguration DP before reporting."""
    t0 = time.perf_counter()
    cfg = cfg or DSEConfig()
    eval_obj = _eval_objective(objective)
    ref = search_network_impl(model, objective=objective, budget=budget,
                              num_pes=num_pes, noc_bw=noc_bw, seed=seed,
                              frontier_k=frontier_k, **search_kwargs)
    ns = ref.netspace
    pes, bws = hw_grid(cfg)
    h = len(pes)
    macs = float(sum(op.total_macs for op in ns.layers))

    # frontier genes x hardware grid, per unique layer
    cand = []
    pes_rows, bw_rows = [], []
    f_sizes = []
    for u in range(len(ns.unique)):
        genes = np.asarray([c.gene for c in ref.frontiers[u]], np.int64)
        f_sizes.append(genes.shape[0])
        cand.append(np.repeat(genes, h, axis=0))
        pes_rows.append(np.tile(pes.astype(np.float32), genes.shape[0]))
        bw_rows.append(np.tile(bws, genes.shape[0]))
    ev = evaluate_candidates(
        ns, cand, objective=eval_obj, num_pes=pes_rows, noc_bw=bw_rows,
        dedupe=False, block=search_kwargs.get("block", 1024),
        n_devices=search_kwargs.get("devices"),
        multicast=search_kwargs.get("multicast", True),
        spatial_reduction=search_kwargs.get("spatial_reduction", True))
    n_designs = int(sum(len(c) for c in cand))

    # vectorized per-layer selection per hardware point
    e_sum = np.zeros(h)
    r_sum = np.zeros(h)
    l1_max = np.zeros(h)
    l2_max = np.zeros(h)
    sel_per_u = []
    for u in range(len(ns.unique)):
        f = f_sizes[u]
        vals = ev.vals[u].reshape(f, h)
        cols = ev.cols[u].reshape(f, h, -1)
        sel = np.argmin(vals, axis=0)                   # (h,)
        sel_per_u.append(sel)
        picked = cols[sel, np.arange(h)]                # (h, 4)
        reps = sum(1 for i in ns.index if i == u)
        e_sum += reps * picked[:, 1]
        r_sum += reps * picked[:, 0]
        l1_max = np.maximum(l1_max, picked[:, 2])
        l2_max = np.maximum(l2_max, picked[:, 3])

    ap = cfg.area_power
    sram_kb = l1_max * pes + l2_max
    area = ap.area(pes, sram_kb, bws)
    power = ap.power(pes, sram_kb, bws)
    valid = (area <= cfg.area_budget_mm2) & (power <= cfg.power_budget_mw)
    energy = e_sum + ap.static_energy_pj(area, r_sum)
    thr = macs / np.maximum(r_sum, 1.0)
    edp = energy * r_sum
    obj_col = {"edp": edp, "energy": energy, "runtime": r_sum,
               "throughput": -thr}[objective]
    obj_col = np.where(valid, obj_col, np.inf)

    def design(i: int) -> dict[str, Any]:
        return {"num_pes": int(pes[i]), "noc_bw": float(bws[i]),
                "energy_pj": float(energy[i]), "runtime": float(r_sum[i]),
                "throughput": float(thr[i]), "edp": float(edp[i]),
                "area_mm2": float(area[i]), "power_mw": float(power[i])}

    # composer-refined top designs: re-run the fusion/reconfig DP at the
    # best grid points (per-layer selection is fusion-oblivious)
    top = []
    for i in np.argsort(obj_col, kind="stable")[:refine_k]:
        if not np.isfinite(obj_col[i]):
            break
        hw_i = ref.model.hw.replace(num_pes=int(pes[i]),
                                    noc_bw=float(bws[i]))
        fronts_u = []
        for u in range(len(ns.unique)):
            f = f_sizes[u]
            vals = ev.vals[u].reshape(f, h)[:, i]
            cols = ev.cols[u].reshape(f, h, -1)[:, i]
            genes = np.asarray([c.gene for c in ref.frontiers[u]],
                               np.int64)
            fronts_u.append(_frontier(ns, u, genes, vals, cols, f))
        frontiers = [fronts_u[ns.index[j]] for j in range(ns.n_layers)]
        model_i = dataclasses.replace(ref.model, hw=hw_i)
        with obs.span("compose", composer="dp-refine",
                      layers=ns.n_layers):
            sched, _ = compose_dp(frontiers, _out_vols(ns.layers),
                                  ns.fusible, model_i,
                                  [op.name for op in ns.layers], macs)
        d = design(int(i))
        d.update({"schedule_cost": sched.cost,
                  "schedule_energy_pj": sched.energy_pj
                  + float(ap.static_energy_pj(area[i], sched.runtime)),
                  "schedule_runtime": sched.runtime,
                  "n_reconfigs": sched.n_reconfigs,
                  "segments": sched.segments})
        top.append(d)

    front = pareto_front([design(i) for i in np.where(valid)[0]],
                         x="energy_pj", y="throughput")
    best: dict[str, dict[str, Any] | None] = {}
    for obj in ("throughput", "energy", "edp"):
        col = {"throughput": -thr, "energy": energy, "edp": edp}[obj]
        col = np.where(valid, col, np.inf)
        i = int(np.argmin(col))
        best[obj] = design(i) if np.isfinite(col[i]) else None

    return CoNetResult(
        search=ref, pareto=front, best=best, top=top,
        n_designs=n_designs + ref.n_evaluated, n_hw=h,
        n_valid=int(valid.sum()),
        n_compiles=ref.n_compiles + ev.run.n_compiles,
        elapsed_s=time.perf_counter() - t0)
