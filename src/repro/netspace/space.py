"""Network-level mapping space: ONE gene layout per op-class.

``repro.mapspace`` defines the per-layer space; a network search needs the
same *kind* of space for every layer while each layer keeps its own legal
tile candidates.  This module groups a network's (shape-deduplicated)
layers into **op-classes** — layers sharing dim universe, window/pinned
structure and conv strides — and builds, per class:

  * per-layer :class:`~repro.mapspace.space.MapSpace` instances with
    IDENTICAL ``gene_ranges()``: the same searched axes, permutations,
    spatial choices and cluster-option slots, with tile axes padded to a
    common candidate count (``pad_tile_axes``) so one ``(n, G)`` gene
    matrix layout covers every layer of the class;
  * a pair of :class:`UniversalSpec` executables with
    ``ext_operand=True`` — layer shape is a vmapped operand, so ONE XLA
    compile per (op-class, level-count) evaluates candidate frontiers for
    every layer of VGG16/ResNet50/MobileNetV2 in a single device pass.

Cluster options are planned at class level (uniform slot count; per-layer
sizes clamp to the layer's useful extent exactly like ``build_space``) so
the cluster gene means the same thing for every member layer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.directives import Sz
from ..core.dnn_models import unique_layers
from ..core.tensor_analysis import ConvExpr, LayerOp
from ..core.vectorized import UniversalSpec
from ..mapspace.space import (ClusterOption, MapSpace, build_space,
                              pad_tile_axes, _resolve_sz)


@dataclasses.dataclass
class NetClass:
    """One op-class: layers evaluable by a single shape-as-operand
    executable pair."""
    key: tuple
    rep: LayerOp                      # representative (registers the jit)
    dims: tuple[str, ...]             # searched axis dims (shared)
    spec1: UniversalSpec
    spec2: UniversalSpec | None
    cluster_dims: tuple[str, ...]     # spec2 one-hot candidate inner dims
    members: list[int]                # unique-layer ids in this class


@dataclasses.dataclass
class NetSpace:
    """The whole-network search space: per-unique-layer padded spaces plus
    the op-class partition that drives compilation."""
    layers: list[LayerOp]             # full network, schedule order
    index: list[int]                  # layer position -> unique id
    unique: list[LayerOp]             # shape-deduplicated layers
    spaces: list[MapSpace]            # per unique id (padded, shared ranges)
    class_of: list[int]               # unique id -> class id
    classes: list[NetClass]
    fusible: list[bool]               # per boundary (i, i+1): output of i
    #                                   consumed only by i+1 (chain edges)

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def space_for(self, layer_idx: int) -> MapSpace:
        return self.spaces[self.index[layer_idx]]

    def op_for(self, layer_idx: int) -> LayerOp:
        return self.layers[layer_idx]

    def ext_row(self, uid: int) -> np.ndarray:
        """The layer-shape operand row: dim extents in spec dim order."""
        op = self.unique[uid]
        cls = self.classes[self.class_of[uid]]
        return np.asarray([op.dims[d] for d in cls.spec1.dim_names],
                          np.float32)

    def cin_rows(self, uid: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-layer resolved cluster inner (size, offset) operand rows,
        one entry per spec2 candidate dim."""
        op = self.unique[uid]
        space = self.spaces[uid]
        cls = self.classes[self.class_of[uid]]
        size = np.ones(len(cls.cluster_dims), np.float32)
        off = np.ones(len(cls.cluster_dims), np.float32)
        for copt in space.cluster_options:
            if copt is None:
                continue
            k = cls.cluster_dims.index(copt.inner_dim)
            ext = op.dims[copt.inner_dim]
            size[k] = min(_resolve_sz(copt.inner_size, op), ext)
            off[k] = min(_resolve_sz(copt.inner_offset, op), ext)
        return size, off

    def cand_of_option(self, uid: int) -> np.ndarray:
        """cluster-option gene value -> spec2 candidate index (-1 = None)."""
        space = self.spaces[uid]
        cls = self.classes[self.class_of[uid]]
        out = np.full(len(space.cluster_options), -1, np.int64)
        for ci, copt in enumerate(space.cluster_options):
            if copt is not None:
                out[ci] = cls.cluster_dims.index(copt.inner_dim)
        return out


def _class_key(op: LayerOp) -> tuple:
    """Layers with equal keys share directive structure (not extents):
    op type, dim universe, window couplings + strides, weightlessness."""
    entries = []
    for t in op.tensors():
        entries.append((t.name, t.has_data,
                        tuple(sorted(map(str, t.entries)))))
    return (op.op_type, tuple(op.dims),
            tuple(op.stride_of(d) for d in op.dims), tuple(entries))


def _window_outers(op: LayerOp) -> dict[str, tuple[str, int]]:
    return {e.outer: (e.window, e.stride) for e in op.output.entries
            if isinstance(e, ConvExpr)}


def _pinned(op: LayerOp) -> tuple[str, ...]:
    pinned = []
    for t in (op.output, op.input):
        for e in t.entries:
            w = getattr(e, "window", None)
            if w and w in op.dims and w not in pinned:
                pinned.append(w)
    return tuple(pinned)


def build_netspace(layers: Sequence[LayerOp], *,
                   max_tiles_per_dim: int = 6,
                   perm_mode: str = "auto",
                   cluster: bool = True,
                   cluster_sizes: Sequence[int] = (64,),
                   fusible: Sequence[bool] | None = None) -> NetSpace:
    """Build the shared-gene-layout network space for ``layers``.

    ``fusible[i]`` marks the boundary between schedule positions ``i`` and
    ``i+1`` as a legal fusion point (layer ``i``'s output consumed ONLY by
    ``i+1``); default: every boundary (a chain).  Pass an explicit mask for
    graphs with skip edges (ResNet) — the composer never fuses across a
    masked boundary, and the genetic composer handles the rest.
    """
    layers = list(layers)
    unique, index = unique_layers(layers)

    by_class: dict[tuple, list[int]] = {}
    for uid, op in enumerate(unique):
        by_class.setdefault(_class_key(op), []).append(uid)

    classes: list[NetClass] = []
    class_of = [0] * len(unique)
    spaces: list[MapSpace | None] = [None] * len(unique)
    for key, members in by_class.items():
        rep = unique[members[0]]
        pinned = _pinned(rep)
        # searched dims: any member exceeds extent 1 (members at extent 1
        # get the single trivial candidate and ride along)
        dims = tuple(
            d for d in rep.dims
            if d not in pinned and d != "N"
            and any(unique[u].dims[d] > 1 for u in members))
        if not dims:
            dims = tuple(d for d in rep.dims
                         if d not in pinned and d != "N")[:1]
        mode = perm_mode
        if mode == "auto":
            mode = "all" if len(dims) <= 3 else "rotations"

        # class-level cluster plan: same option slots for every member,
        # mirroring build_space's defaults (one searched reduction dim +
        # one sliding-window inner), sizes clamped per layer
        windows = _window_outers(rep)
        inner_dims: list[str] = []
        if cluster:
            red = rep.reduction_dims()
            inner_dims = [d for d in dims if d in red][:1]
            win = [d for d in windows if d in dims]
            inner_dims += [d for d in win[-1:] if d not in inner_dims]
        plan = [(d, int(c)) for d in inner_dims
                for c in dict.fromkeys(int(c) for c in cluster_sizes)]

        member_spaces = []
        for u in members:
            op = unique[u]
            base = build_space(op, dims=dims, perm_mode=mode,
                               max_tiles_per_dim=max_tiles_per_dim,
                               cluster=False)
            options: list[ClusterOption | None] = [None]
            for d, c in plan:
                if d in windows:
                    w, stride = windows[d]
                    useful = (op.dims[d] - op.dims[w]) // stride + 1
                    inner: tuple = (Sz(w), 1)
                else:
                    useful = op.dims[d]
                    inner = (1, 1)
                options.append(ClusterOption(max(min(c, useful), 1), d,
                                             *inner))
            member_spaces.append(dataclasses.replace(
                base, cluster_options=tuple(options)))
        counts = [max(sp.axes[ai].n for sp in member_spaces)
                  for ai in range(len(dims))]
        ranges = None
        for u, sp in zip(members, member_spaces):
            sp = pad_tile_axes(sp, counts)
            spaces[u] = sp
            class_of[u] = len(classes)
            if ranges is None:
                ranges = sp.gene_ranges()
            elif sp.gene_ranges() != ranges:
                raise ValueError(
                    f"class {key}: member gene ranges diverge "
                    f"({sp.gene_ranges()} vs {ranges})")

        cluster_dims = tuple(dict.fromkeys(d for d, _ in plan))
        spec1 = UniversalSpec(dim_names=tuple(rep.dims), axis_dims=dims,
                              pinned=pinned, single_edge=True,
                              ext_operand=True)
        spec2 = UniversalSpec(dim_names=tuple(rep.dims), axis_dims=dims,
                              pinned=pinned,
                              cluster=tuple((d, 0, 0)
                                            for d in cluster_dims),
                              single_edge=True, ext_operand=True) \
            if cluster_dims else None
        classes.append(NetClass(key=key, rep=rep, dims=dims, spec1=spec1,
                                spec2=spec2, cluster_dims=cluster_dims,
                                members=list(members)))

    if fusible is None:
        fusible = [True] * (len(layers) - 1)
    fusible = list(fusible)
    if len(fusible) != max(len(layers) - 1, 0):
        raise ValueError(f"fusible mask needs {len(layers) - 1} entries, "
                         f"got {len(fusible)}")

    return NetSpace(layers=layers, index=index, unique=unique,
                    spaces=[s for s in spaces], class_of=class_of,
                    classes=classes, fusible=fusible)


def halo_fractions(op: LayerOp, space: MapSpace, genes: np.ndarray
                   ) -> np.ndarray:
    """Per-candidate fused-stack recompute fraction, analytically from the
    sliding-window overlap structure the reuse analysis models (RA halo
    class): when this layer is the CONSUMER of a fused boundary, depth-
    first tiling re-produces the window overlap ``(R - stride)`` input
    rows/cols at every interior tile boundary of each tiled window-outer
    axis.  Fraction of the producer's work recomputed =
    ``sum_axes (n_tiles - 1) * overlap / extent``, capped at 1."""
    genes = np.asarray(genes, np.int64)
    windows = _window_outers(op)
    frac = np.zeros(genes.shape[0], np.float64)
    for ai, ax in enumerate(space.axes):
        if ax.dim not in windows:
            continue
        w, stride = windows[ax.dim]
        overlap = op.dims[w] - stride
        if overlap <= 0:
            continue
        ext = op.dims[ax.dim]
        out_ext = (ext - op.dims[w]) // stride + 1
        offs = np.asarray(ax.offsets, np.float64)[genes[:, 3 + ai]]
        n_tiles = np.ceil(out_ext / offs)
        frac += (n_tiles - 1) * overlap / ext
    return np.minimum(frac, 1.0)
