"""Whole-network, fusion-aware schedule search on the gene pipeline.

MAESTRO's headline DSE (paper §VII) optimizes one layer at a time, but the
paper's own Fig. 11 shows the optimal dataflow flips across layer shapes
within one network.  ``repro.netspace`` searches schedules for the ENTIRE
network:

  * :func:`build_netspace` — op-class grouping with a SHARED gene layout
    per class (padded per-layer spaces, identical ``gene_ranges()``);
  * the batched evaluator — layer shape is an additional vmapped operand
    of the universal executable, so one XLA compile per (op-class,
    level-count) produces every layer's candidate frontier in a single
    device pass over a ``(n_layers, n_candidates, G)`` gene tensor;
  * the DP composer — per-layer mapping selection + DeFiNES-style fused
    layer stacks (intermediate activations resident in L2, analytic
    halo/recompute overhead) under an explicit reconfiguration-cost model
    (L1/L2 drain/refill between differing mappings, new ``HWConfig``
    fields), with a genetic fallback for non-chain fusion masks;
  * :func:`search_network` / :func:`co_search_network` — the end-to-end
    APIs, the latter crossing network frontiers with the hardware grid
    under ``run_dse``-style area/power/leakage accounting.

Quick start::

    from repro.netspace import search_network

    r = search_network("vgg16", objective="edp", budget=512)
    print(r.schedule.segments, r.schedule.network_edp)

See ``repro.launch.netsearch`` for the CLI.
"""
from .composer import (CandStat, NetCostModel, NetworkSchedule,
                       compose_dp, compose_genetic, edge_terms,
                       evaluate_schedule, node_cost)
from .evaluator import COLS, NetEval, evaluate_candidates, evaluate_rows
from .search import (BUDGET_POLICIES, CoNetResult, NetSearchResult,
                     best_uniform, co_search_network,
                     co_search_network_impl, search_network,
                     search_network_impl, uniform_baseline)
from .space import (NetClass, NetSpace, build_netspace, halo_fractions)

__all__ = [
    "BUDGET_POLICIES", "COLS", "CandStat", "CoNetResult", "NetClass",
    "NetCostModel", "NetEval", "NetSearchResult", "NetworkSchedule",
    "best_uniform", "build_netspace", "co_search_network",
    "co_search_network_impl", "compose_dp", "compose_genetic",
    "edge_terms", "evaluate_candidates", "evaluate_rows",
    "evaluate_schedule", "halo_fractions", "node_cost", "search_network",
    "search_network_impl", "uniform_baseline",
]
