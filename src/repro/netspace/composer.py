"""Schedule composition: per-layer frontiers -> one network schedule.

The composer receives, per schedule position, a small frontier of
candidate mappings (value + runtime/energy/L1/L2 + fused-halo fraction)
and chooses (a) one candidate per layer and (b) a segmentation of the
layer chain into **fused stacks** (DeFiNES-style depth-first execution:
intermediate activations stay in L2 and never cross the off-chip
boundary).

Cost model (all terms additive over layers/boundaries, which is what
makes the DP exact):

  * node: the layer's objective value (EDP/energy/runtime as produced by
    the evaluator), adjusted by its incoming boundary's (Δe, Δr);
  * reconfiguration: when consecutive layers run DIFFERING mapping
    structures, the PE array drains the outgoing L1/L2 working set and
    refills the incoming one over the NoC plus a fixed latency
    (:func:`core.performance.reconfig_cycles`; new ``HWConfig`` fields);
  * un-fused boundary (fusion modeling on): the intermediate activation
    crosses off-chip twice — ``2·|O|`` elements at ``hw.dram_bw`` /
    ``hw.dram_energy_pj``;
  * fused boundary: no off-chip crossing; instead the producer re-runs
    the consumer's window-halo fraction (``space.halo_fractions`` —
    analytic sliding-overlap recompute), and the stack's L2 footprint
    accumulates: ``Σ l2_kb ≤ l2_budget_kb``.

``compose_dp`` runs exact dynamic programming over states
``(layer, candidate, resident-stack footprint)``; ``compose_genetic`` is
the fallback for schedules the chain DP cannot express (non-chain fusion
masks interact with beam limits) and shares the identical
:func:`evaluate_schedule` cost so the two composers are comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from ..core.cluster_analysis import py_backend
from ..core.performance import HWConfig, dram_cycles, reconfig_cycles

_XP = py_backend()


@dataclasses.dataclass(frozen=True)
class CandStat:
    """One frontier candidate of one layer."""
    gene: tuple
    val: float           # canonical-minimize per-layer objective value
    runtime: float
    energy: float
    l1_kb: float
    l2_kb: float
    halo: float          # fused-consumer recompute fraction of producer
    struct: tuple        # reconfig identity: (class id, s, p, c)


@dataclasses.dataclass(frozen=True)
class NetCostModel:
    """Static knobs of the network cost model."""
    hw: HWConfig
    objective: str = "edp"         # edp | energy | runtime
    fuse: bool = True              # model the off-chip boundary + fusion
    reconfig: bool = True          # charge mapping-switch drain/refill
    l2_budget_kb: float | None = None


def edge_terms(prev: CandStat, nxt: CandStat, fused: bool,
               out_vol: float, model: NetCostModel
               ) -> tuple[float, float]:
    """(Δenergy pJ, Δruntime cycles) of the boundary entering ``nxt``."""
    hw = model.hw
    de = dr = 0.0
    if model.reconfig and prev.struct != nxt.struct:
        dr += float(reconfig_cycles(
            _XP, hw, l1_prev_kb=prev.l1_kb, l2_prev_kb=prev.l2_kb,
            l1_next_kb=nxt.l1_kb, l2_next_kb=nxt.l2_kb))
    if model.fuse:
        if fused:
            de += nxt.halo * prev.energy
            dr += nxt.halo * prev.runtime
        else:
            de += 2.0 * out_vol * hw.dram_energy_pj
            dr += float(dram_cycles(_XP, 2.0 * out_vol, hw))
    return de, dr


def node_cost(c: CandStat, de: float, dr: float, objective: str) -> float:
    """The layer's additive cost with its incoming boundary folded in.
    Expanded around the evaluator's own value so a zero boundary
    reproduces it EXACTLY (the reconfig-0/no-fusion parity guarantee)."""
    if objective == "edp":
        return c.val + c.energy * dr + de * c.runtime + de * dr
    if objective == "energy":
        return c.val + de
    return c.val + dr  # runtime (throughput canonicalizes to runtime)


@dataclasses.dataclass
class NetworkSchedule:
    """One whole-network schedule: per-layer mapping choice + fused-stack
    segmentation, with its cost-model accounting."""
    objective: str
    choice: list[int]              # frontier index per layer
    genes: list[tuple]             # chosen gene tuple per layer
    fuse: list[bool]               # per boundary: True = fused
    per_layer: list[dict[str, Any]]
    cost: float                    # additive objective incl. boundaries
    energy_pj: float
    runtime: float
    total_macs: float
    n_reconfigs: int

    @property
    def network_edp(self) -> float:
        return self.energy_pj * self.runtime

    @property
    def throughput(self) -> float:
        return self.total_macs / max(self.runtime, 1.0)

    @property
    def segments(self) -> list[tuple[int, int]]:
        """Fused stacks as inclusive (start, end) layer index ranges."""
        out = []
        start = 0
        for i, f in enumerate(self.fuse):
            if not f:
                out.append((start, i))
                start = i + 1
        out.append((start, len(self.choice) - 1))
        return out


def evaluate_schedule(frontiers: Sequence[Sequence[CandStat]],
                      choice: Sequence[int], fuse: Sequence[bool],
                      out_vols: Sequence[float],
                      fusible: Sequence[bool], model: NetCostModel
                      ) -> tuple[float, float, float]:
    """Cost-model accounting of one concrete schedule: ``(cost, energy,
    runtime)``; infeasible schedules (illegal fusion, fused stack over the
    L2 budget) cost ``inf``.  THE reference the DP and genetic composers
    — and the brute-force parity test — all share."""
    inf = (np.inf, np.inf, np.inf)
    cost = energy = runtime = 0.0
    stack_kb = 0.0
    for i, ci in enumerate(choice):
        c = frontiers[i][ci]
        de = dr = 0.0
        if i > 0:
            fused = bool(fuse[i - 1])
            if fused and not (model.fuse and fusible[i - 1]):
                return inf
            prev = frontiers[i - 1][choice[i - 1]]
            de, dr = edge_terms(prev, c, fused, out_vols[i - 1], model)
            stack_kb = stack_kb + c.l2_kb if fused else c.l2_kb
            if fused and model.l2_budget_kb is not None \
                    and stack_kb > model.l2_budget_kb:
                return inf
        else:
            stack_kb = c.l2_kb
        cost += node_cost(c, de, dr, model.objective)
        energy += c.energy + de
        runtime += c.runtime + dr
    return cost, energy, runtime


def _finalize(frontiers, choice, fuse, out_vols, fusible, model,
              layer_names, macs) -> NetworkSchedule:
    cost, energy, runtime = evaluate_schedule(
        frontiers, choice, fuse, out_vols, fusible, model)
    per_layer = []
    n_reconf = 0
    for i, ci in enumerate(choice):
        c = frontiers[i][ci]
        de = dr = 0.0
        if i > 0:
            prev = frontiers[i - 1][choice[i - 1]]
            de, dr = edge_terms(prev, c, bool(fuse[i - 1]),
                                out_vols[i - 1], model)
            n_reconf += int(prev.struct != c.struct)
        per_layer.append({
            "layer": layer_names[i], "gene": c.gene, "value": c.val,
            "runtime": c.runtime, "energy_pj": c.energy,
            "l1_kb": c.l1_kb, "l2_kb": c.l2_kb,
            "edge_energy_pj": de, "edge_cycles": dr})
    return NetworkSchedule(
        objective=model.objective, choice=list(choice),
        genes=[frontiers[i][ci].gene for i, ci in enumerate(choice)],
        fuse=[bool(f) for f in fuse], per_layer=per_layer, cost=cost,
        energy_pj=energy, runtime=runtime, total_macs=macs,
        n_reconfigs=n_reconf)


def compose_dp(frontiers: Sequence[Sequence[CandStat]],
               out_vols: Sequence[float], fusible: Sequence[bool],
               model: NetCostModel, layer_names: Sequence[str],
               macs: float, max_states: int = 4096
               ) -> tuple[NetworkSchedule, int]:
    """Exact DP over ``(layer, candidate, resident-stack footprint)``
    states (beam-capped at ``max_states`` per layer; exact whenever the
    cap is not hit, which the parity test relies on).  Returns the best
    schedule and the number of explored transitions."""
    L = len(frontiers)
    # state key (candidate, stack footprint) -> (cost, parent key, fused)
    cur: dict[tuple, tuple[float, tuple | None, bool]] = {}
    for ci, c in enumerate(frontiers[0]):
        key = (ci, round(c.l2_kb, 6))
        cost = node_cost(c, 0.0, 0.0, model.objective)
        if key not in cur or cost < cur[key][0]:
            cur[key] = (cost, None, False)
    parents: list[dict] = [dict(cur)]
    n_transitions = 0
    for b in range(L - 1):
        if len(cur) > max_states:
            keep = sorted(cur, key=lambda k: cur[k][0])[:max_states]
            cur = {k: cur[k] for k in keep}
            parents[b] = cur
        nxt: dict[tuple, tuple[float, tuple, bool]] = {}
        for key, (cost, _, _) in cur.items():
            ci, kb = key
            prev = frontiers[b][ci]
            for cj, c2 in enumerate(frontiers[b + 1]):
                for fused in (False, True):
                    if fused and not (model.fuse and fusible[b]):
                        continue
                    nkb = round(kb + c2.l2_kb, 6) if fused \
                        else round(c2.l2_kb, 6)
                    if fused and model.l2_budget_kb is not None \
                            and nkb > model.l2_budget_kb:
                        continue
                    n_transitions += 1
                    de, dr = edge_terms(prev, c2, fused, out_vols[b],
                                        model)
                    cost2 = cost + node_cost(c2, de, dr, model.objective)
                    k2 = (cj, nkb)
                    if k2 not in nxt or cost2 < nxt[k2][0]:
                        nxt[k2] = (cost2, key, fused)
        cur = nxt
        parents.append(cur)
    best_key = min(cur, key=lambda k: cur[k][0])
    choice = [0] * L
    fuse = [False] * max(L - 1, 0)
    key: tuple | None = best_key
    for i in range(L - 1, -1, -1):
        assert key is not None
        cost, parent, fused = parents[i][key]
        choice[i] = key[0]
        if i > 0:
            fuse[i - 1] = fused
        key = parent
    return (_finalize(frontiers, choice, fuse, out_vols, fusible, model,
                      layer_names, macs), n_transitions)


def compose_genetic(frontiers: Sequence[Sequence[CandStat]],
                    out_vols: Sequence[float], fusible: Sequence[bool],
                    model: NetCostModel, layer_names: Sequence[str],
                    macs: float, *, seed: int = 0, population: int = 64,
                    generations: int = 60, mutate_p: float = 0.15,
                    tournament: int = 3) -> tuple[NetworkSchedule, int]:
    """Genetic fallback over (per-layer choice, boundary fuse bits) for
    schedules outside the chain DP's reach (non-chain fusion masks /
    beam-capped state spaces).  Same :func:`evaluate_schedule` cost as
    the DP; deterministic under ``seed``."""
    rng = np.random.default_rng(seed)
    L = len(frontiers)
    nc = np.asarray([len(f) for f in frontiers])
    nb = max(L - 1, 0)

    def fitness(ch, fb) -> float:
        return evaluate_schedule(frontiers, ch, fb, out_vols, fusible,
                                 model)[0]

    pop_c = rng.integers(0, nc[None, :], size=(population, L))
    pop_f = rng.integers(0, 2, size=(population, nb)).astype(bool)
    pop_c[0] = 0                     # seed the per-layer-best schedule
    pop_f[0] = False
    fit = np.asarray([fitness(pop_c[i], pop_f[i])
                      for i in range(population)])
    n_evals = population
    for _ in range(generations):
        order = np.argsort(fit, kind="stable")
        pop_c, pop_f, fit = pop_c[order], pop_f[order], fit[order]
        ia = rng.integers(0, population, (population, tournament)).min(1)
        ib = rng.integers(0, population, (population, tournament)).min(1)
        mc = rng.random((population, L))
        mf = rng.random((population, nb))
        child_c = np.where(mc < mutate_p,
                           rng.integers(0, nc[None, :],
                                        (population, L)),
                           np.where(mc < (1 + mutate_p) / 2,
                                    pop_c[ia], pop_c[ib]))
        child_f = np.where(mf < mutate_p,
                           rng.integers(0, 2, (population, nb)) > 0,
                           np.where(mf < (1 + mutate_p) / 2,
                                    pop_f[ia], pop_f[ib]))
        child_fit = np.asarray([fitness(child_c[i], child_f[i])
                                for i in range(population)])
        n_evals += population
        both_c = np.concatenate([pop_c, child_c])
        both_f = np.concatenate([pop_f, child_f])
        both = np.concatenate([fit, child_fit])
        keep = np.argsort(both, kind="stable")[:population]
        pop_c, pop_f, fit = both_c[keep], both_f[keep], both[keep]
    best = int(np.argmin(fit))
    return (_finalize(frontiers, pop_c[best].tolist(),
                      pop_f[best].tolist(), out_vols, fusible, model,
                      layer_names, macs), n_evals)
