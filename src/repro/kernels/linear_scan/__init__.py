from .linear_scan import linear_scan
from .ops import scan_op
from .ref import linear_scan_ref

__all__ = ["linear_scan", "scan_op", "linear_scan_ref"]
