"""Oracle: the model-layer chunked implementation (models/ssm.py)."""
from repro.models.ssm import chunked_linear_attn


def linear_scan_ref(r, k, v, log_w, u=None, state0=None, *, chunk=64,
                    post_update=False):
    return chunked_linear_attn(r, k, v, log_w, u=u, state0=state0,
                               chunk=chunk, post_update=post_update)
