"""Dispatching wrapper for the linear-scan kernel."""
from __future__ import annotations

import jax

from .linear_scan import linear_scan
from .ref import linear_scan_ref


def scan_op(r, k, v, log_w, u=None, state0=None, *, chunk=64,
            post_update=False, backend="auto"):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return linear_scan(r, k, v, log_w, u, state0, chunk=chunk,
                           post_update=post_update)
    if backend == "interpret":
        return linear_scan(r, k, v, log_w, u, state0, chunk=chunk,
                           post_update=post_update, interpret=True)
    return linear_scan_ref(r, k, v, log_w, u=u, state0=state0, chunk=chunk,
                           post_update=post_update)
