"""Chunked linear-attention (RWKV-6 / Mamba-2 SSD) as a Pallas TPU kernel.

Recurrence: S_t = diag(w_t)·S_{t-1} + k_t v_t^T;  o_t = r_t · S_{t-1 or t}.

MAESTRO view: grid = (B, H spatial) × (chunks temporal); the state tile
S (K×V) is *output-stationary* in VMEM scratch across the chunk dim
(temporal reduction), while r/k/v/decay chunk tiles stream through —
the TPU-native adaptation of the recurrence: within a chunk the
dependency is expressed as a decay-weighted triangular matmul (MXU work),
across chunks as a rank-c state update, instead of the GPU formulation's
per-timestep elementwise recurrence.

The in-chunk cumulative decay is computed with a lower-triangular ones
matmul (MXU-friendly) rather than a cumsum primitive.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _ls_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
               s_scr, *, chunk: int, post_update: bool, use_u: bool):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    c = chunk
    r = r_ref[0, 0].astype(jnp.float32)           # (c, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)           # (c, V)
    lw = lw_ref[0, 0].astype(jnp.float32)         # (c, K)

    # inclusive cumulative decay via lower-triangular ones matmul
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tri_incl = (jj <= ii).astype(jnp.float32)     # j <= i
    P = jax.lax.dot_general(tri_incl, lw, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    Pq = P if post_update else P - lw
    q_eff = r * jnp.exp(Pq)
    k_eff = k * jnp.exp(-P)

    S = s_scr[...]
    inter = jax.lax.dot_general(q_eff, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    A = jax.lax.dot_general(q_eff, k_eff, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = (jj < ii) if not post_update else (jj <= ii)
    A = jnp.where(mask, A, 0.0)
    if use_u:
        u = u_ref[0].astype(jnp.float32)          # (K,)
        diag = jnp.sum(r * u[None, :] * k, axis=1)
        A = A + jnp.where(jj == ii, diag[:, None], 0.0)
    intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = (inter + intra).astype(o_ref.dtype)

    p_last = P[c - 1]                              # (K,)
    k_scaled = k * jnp.exp(p_last[None, :] - P)
    S_new = S * jnp.exp(p_last)[:, None] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(ci == nc - 1)
    def _finish():
        sT_ref[0, 0] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "post_update",
                                             "interpret"))
def linear_scan(r, k, v, log_w, u=None, state0=None, *, chunk: int = 64,
                post_update: bool = False, interpret: bool = False):
    """r/k/log_w: (B, T, H, K); v: (B, T, H, V); u: (H, K) or None;
    state0: (B, H, K, V) or None.  Returns (o (B,T,H,V), state (B,H,K,V)).

    Layout: tensors are transposed to (B, H, T, *) so chunk tiles are
    contiguous (T, K) VMEM blocks."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, T)
    assert T % c == 0
    nc = T // c
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), jnp.float32)
    if u is None:
        use_u = False
        u_in = jnp.zeros((H, K), jnp.float32)
    else:
        use_u = True
        u_in = u.astype(jnp.float32)
    tb = lambda x: jnp.transpose(x, (0, 2, 1, 3))  # (B, H, T, *)
    rt, kt, vt, lwt = tb(r), tb(k), tb(v), tb(log_w)
    lwt = jnp.clip(lwt.astype(jnp.float32), -60.0 / c, 0.0)

    kernel = functools.partial(_ls_kernel, chunk=c,
                               post_update=post_update, use_u=use_u)
    grid = (B, H, nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, K), lambda b, h, i: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[_vmem((K, V))],
        interpret=interpret,
    )(rt, kt, vt, lwt, u_in, state0)
    return jnp.transpose(o, (0, 2, 1, 3)), sT
