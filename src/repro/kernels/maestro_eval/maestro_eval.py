"""MAESTRO DSE inner loop as a Pallas TPU kernel.

Each design point is 2 scalars (num_pes, noc_bw) and ~40 FLOPs of integer/
fp closed-form evaluation over the static tables of ``tables.py`` — pure
VPU work with perfect data parallelism.  Tiling: 1-D blocks of BLK designs
in VMEM, features written as a (BLK, F) tile.  The arithmetic intensity is
~(40 FLOPs / 8 input bytes) ≈ 5 — comfortably compute-bound on the VPU,
which is what makes the 480M-design sweep of the paper a seconds-scale job
on one TPU core (EXPERIMENTS.md §Perf-A).

``closed_form_features`` is shared verbatim by the kernel body and the
pure-jnp oracle (ref.py); the kernel is just its VMEM-tiled wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tables import EvalTables

FEATURES = ("runtime", "macs", "throughput", "util", "bw_req")
BLK = 1024


def _cdiv(a, b):
    return jnp.floor_divide(a + b - 1, b)


def _comm(v, bw, lat):
    d = jnp.floor_divide(v + bw - 1.0, bw) + lat
    return jnp.where(v > 0, d, 0.0)


def closed_form_features(pes, bw, T: EvalTables):
    """pes int32[N], bw f32[N] -> f32[N, 5].  Exactly the faithful engine's
    single-level analysis (model.py) in closed form."""
    n = pes.astype(jnp.int32)
    f32 = jnp.float32
    o, s, D = T.sp_o, T.sp_s, T.sp_D
    adv = n * o
    span = s + (n - 1) * o
    n_folds = 1 + _cdiv(jnp.maximum(D - span, 0), adv)
    rem = jnp.minimum(D - (n_folds - 1) * adv, span)
    used = jnp.minimum(n, _cdiv(rem, o))
    full = jnp.minimum(used, jnp.maximum((rem - s) // o + 1, 0))
    partial_cnt = used - full
    last_partial = jnp.clip(rem - full * o, 0, s)
    partial = jnp.where(partial_cnt > 0, last_partial, 0)
    is_steady = (full == n).astype(jnp.int32)
    steady_folds = n_folds - 1 + is_steady
    edge_folds = 1 - is_steady
    folds = n_folds

    steps_total = (T.temporal_steps * folds).astype(f32)
    span_e = jnp.minimum(span, D)
    ext_span = T.ext_of(span_e).astype(f32)
    ext_partial = T.ext_of(partial).astype(f32)

    delta = T.delta_a + T.delta_b * span_e.astype(f32)
    ing_full = T.ing_full_a + T.ing_full_b * span_e.astype(f32)
    egress = T.egress_a + T.egress_b * ext_span
    if T.o_coupled_spatial:
        egress = egress * folds.astype(f32)
    step_eg = _cdiv(egress, jnp.maximum(steps_total, 1.0))

    lat = T.noc_latency
    ing_sd = _comm(delta, bw, lat)
    egr_sd = _comm(step_eg, bw, lat)
    fwd = jnp.ceil(jnp.log2(jnp.maximum(n, 1).astype(f32))) \
        if T.spatial_reduces else jnp.zeros_like(bw)

    runtime = jnp.zeros_like(bw)
    macs = jnp.zeros_like(bw)
    active_steps = jnp.zeros_like(bw)
    comp_first = None
    nf = n.astype(f32)
    fullf = full.astype(f32)
    sfolds = steady_folds.astype(f32)
    efolds = edge_folds.astype(f32)
    for row in T.cases:
        comp = f32(row.psums_full)
        if comp_first is None:
            comp_first = jnp.full_like(bw, comp)
        delay = jnp.maximum(jnp.maximum(comp + fwd, ing_sd), egr_sd)
        runtime = runtime + row.occ * folds.astype(f32) * delay
        ps_partial = row.psums_per_ext * ext_partial
        macs = macs + row.occ * (
            sfolds * nf * row.psums_full
            + efolds * (fullf * row.psums_full + ps_partial))
        has_p = (partial > 0).astype(f32)
        active_steps = active_steps + row.occ * (
            sfolds * nf + efolds * (fullf + has_p))

    serial = _comm(ing_full, bw, lat) + comp_first + fwd + egr_sd
    overlapped = jnp.maximum(jnp.maximum(comp_first + fwd, ing_sd), egr_sd)
    runtime = jnp.maximum(runtime + serial - overlapped, 1.0)

    total_steps_pe = steps_total * nf
    util = active_steps / jnp.maximum(total_steps_pe, 1.0)
    thr = macs / runtime
    bw_req = (delta + step_eg) / jnp.maximum(comp_first, 1.0)
    return jnp.stack([runtime, macs, thr, util, bw_req], axis=-1)


def _eval_kernel(pes_ref, bw_ref, out_ref, *, tables: EvalTables):
    pes = pes_ref[...]
    bw = bw_ref[...]
    out_ref[...] = closed_form_features(pes, bw, tables)


@functools.partial(jax.jit, static_argnames=("tables", "interpret"))
def maestro_eval(pes, bw, *, tables: EvalTables, interpret: bool = False):
    """pes: int32[N], bw: f32[N] (N multiple of BLK or padded) ->
    features f32[N, 5]."""
    N = pes.shape[0]
    pad = (-N) % BLK
    if pad:
        pes = jnp.pad(pes, (0, pad), constant_values=1)
        bw = jnp.pad(bw, (0, pad), constant_values=1.0)
    Np = pes.shape[0]
    out = pl.pallas_call(
        functools.partial(_eval_kernel, tables=tables),
        grid=(Np // BLK,),
        in_specs=[
            pl.BlockSpec((BLK,), lambda i: (i,)),
            pl.BlockSpec((BLK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLK, len(FEATURES)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, len(FEATURES)), jnp.float32),
        interpret=interpret,
    )(pes.astype(jnp.int32), bw.astype(jnp.float32))
    return out[:N]
