"""Dispatching wrapper for the DSE-evaluation kernel."""
from __future__ import annotations

import jax

from .maestro_eval import FEATURES, maestro_eval
from .ref import maestro_eval_ref
from .tables import build_tables


def dse_eval(pes, bw, *, op=None, dataflow=None, tables=None,
             backend: str = "auto"):
    if tables is None:
        tables = build_tables(op, dataflow)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return maestro_eval(pes, bw, tables=tables)
    if backend == "interpret":
        return maestro_eval(pes, bw, tables=tables, interpret=True)
    return maestro_eval_ref(pes, bw, tables=tables)
