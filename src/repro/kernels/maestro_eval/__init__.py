from .maestro_eval import FEATURES, closed_form_features, maestro_eval
from .ops import dse_eval
from .ref import maestro_eval_ref
from .tables import EvalTables, build_tables

__all__ = ["FEATURES", "closed_form_features", "maestro_eval", "dse_eval",
           "maestro_eval_ref", "EvalTables", "build_tables"]
