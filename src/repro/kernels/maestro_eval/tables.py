"""Compile a (layer × single-level dataflow) into static coefficient
tables for the maestro_eval kernel.

Scope: single-cluster dataflows (no Cluster directive) with one SpatialMap
— the family the paper's DSE sweeps (and the hot path of Fig. 13).  All
temporal trip counts, per-case tile sizes and volume coefficients are
static; only (num_pes, noc_bw) vary per design point, so the kernel is a
closed-form evaluation over those two inputs.

Every volume is linear in the spatial dim's *level extent* e (tensors are
products of per-dim extents), so we extract (A + B·e) coefficients by
probing the trusted engine volumes at e ∈ {1, 2}.
"""
from __future__ import annotations

import dataclasses
import itertools

from ...core.cluster_analysis import py_backend, temporal_phases
from ...core.directives import Cluster, Dataflow, SpatialMap, complete, extended_dims
from ...core.reuse_analysis import psums_volume, tensor_volume
from ...core.tensor_analysis import ConvExpr, DimExpr, LayerOp


@dataclasses.dataclass(frozen=True)
class CaseRow:
    occ: int            # product of temporal phase counts
    psums_full: int     # per-unit MACs at full spatial extent s
    psums_per_ext: float  # MACs per unit of spatial iteration extent
    delta: float        # steady per-step ingress delta (A + B·e applied)
    delta_b: float


@dataclasses.dataclass(frozen=True)
class EvalTables:
    # spatial loop statics
    sp_D: int
    sp_s: int
    sp_o: int
    sp_kind: str        # 'dim' | 'conv'
    sp_window: int      # window taps (conv kind)
    sp_stride: int
    spatial_reduces: bool
    o_coupled_spatial: bool
    # temporal-case table
    cases: tuple[CaseRow, ...]
    # per-step steady ingress delta: A + B·span_ext
    delta_a: float
    delta_b: float
    # init full-tile ingress: A + B·span_ext
    ing_full_a: float
    ing_full_b: float
    # egress totals: (EG_A [+ ×folds if o_coupled_spatial]) ; o_tile coef
    egress_a: float
    egress_b: float     # × span_ext
    temporal_steps: int  # Π temporal trips (per fold)
    noc_latency: float = 2.0

    def ext_of(self, size):
        """Iteration extent contributed by a spatial tile of ``size``."""
        import jax.numpy as jnp
        if self.sp_kind == "dim":
            return size
        valid = size >= self.sp_window
        return jnp.where(valid,
                         (size - self.sp_window) // self.sp_stride + 1, 0)


def build_tables(op: LayerOp, df: Dataflow,
                 noc_latency: float = 2.0) -> EvalTables:
    xp = py_backend()
    dims = extended_dims(df, op.dims)
    cdf = complete(df, op.dims)
    if cdf.cluster_sizes:
        raise ValueError("maestro_eval kernel: single-level dataflows only")
    maps = cdf.levels[0]
    spatials = [d for d in maps if isinstance(d, SpatialMap)]
    if len(spatials) != 1:
        raise ValueError("maestro_eval kernel: exactly one SpatialMap")
    sp = spatials[0]
    sp_stride = op.stride_of(sp.dim)
    temporals = [d for d in maps if not isinstance(d, SpatialMap)]

    # spatial coupling kind w.r.t. the iteration space
    sp_kind, sp_window = "dim", 1
    for e in op.iter_entries:
        if isinstance(e, ConvExpr) and e.outer == sp.dim:
            sp_kind, sp_window = "conv", dims[e.window]

    red = op.reduction_dims()
    spatial_reduces = sp.dim in red
    o_coupled_spatial = op.output.coupled_to(sp.dim)

    # temporal phases (static)
    phase_lists = []
    for d in temporals:
        D = dims[d.dim]
        st, ed = temporal_phases(xp, D, min(d.size, D),
                                 d.offset * op.stride_of(d.dim))
        phase_lists.append((d, (st, ed)))

    sp_s = min(sp.size, dims[sp.dim])

    def span_tile(e: int) -> dict:
        m = dict(dims)
        for d, (st, _) in phase_lists:
            m[d.dim] = st.size
        m[sp.dim] = e
        return m

    # steady advancing loop = innermost temporal with >1 trips
    adv = None
    for d, (st, ed) in reversed(phase_lists):
        if st.count + ed.count > 1:
            adv = d
            break

    def delta_for(e: int) -> float:
        """Engine rule (reuse_analysis.analyze_level_traffic): overlap
        credit only when a tensor's innermost *coupled* loop IS the global
        advancing loop; otherwise the whole steady tile refetches."""
        m = span_tile(e)
        total = 0.0
        for t in op.input_tensors():
            coupled = [d for d in maps if t.coupled_to(d.dim)]
            if not coupled:
                continue
            inner = coupled[-1]
            if adv is not None and inner is adv:
                ov = {adv.dim: min(adv.offset * op.stride_of(adv.dim),
                                   m[adv.dim])}
                total += tensor_volume(t, m, xp, override=ov)
            else:
                total += tensor_volume(t, m, xp)
        return total

    def full_ing(e: int) -> float:
        m = span_tile(e)
        return float(sum(tensor_volume(t, m, xp)
                         for t in op.input_tensors()))

    d1, d2 = delta_for(1), delta_for(2)
    f1, f2 = full_ing(1), full_ing(2)

    # egress: tile_vol(O) × commits(temporal part) × spill; folds factor
    # applied in-kernel when the spatial dim couples O.
    commits = 1
    o_loops = [d for d, (st, ed) in phase_lists
               if op.output.coupled_to(d.dim)]
    spill = 1
    if o_loops:
        inner_o = o_loops[-1]
        seen_inner = False
        for d, (st, ed) in reversed(phase_lists):
            if d is inner_o:
                seen_inner = True
                continue
            if seen_inner and d.dim in red:
                spill *= st.count + ed.count
        for d, (st, ed) in phase_lists:
            if op.output.coupled_to(d.dim):
                commits *= st.count + ed.count
    # probe at iteration extents 1 and 2 (for conv-coupled spatial dims the
    # raw sizes giving those extents are w and w+stride)
    if sp_kind == "dim":
        e_ext1, e_ext2 = 1, 2
    else:
        e_ext1, e_ext2 = sp_window, sp_window + sp_stride
    ov1 = tensor_volume(op.output, span_tile(e_ext1), xp)
    ov2 = tensor_volume(op.output, span_tile(e_ext2), xp)
    eg_b = float((ov2 - ov1) * commits * spill)
    eg_a = float(ov1 * commits * spill - eg_b)

    # temporal case table
    rows = []
    t_steps = 1
    for d, (st, ed) in phase_lists:
        t_steps *= st.count + ed.count
    for choice in itertools.product(*[range(2) for _ in phase_lists]):
        occ = 1
        m = dict(dims)
        for (d, phases), ci in zip(phase_lists, choice):
            ph = phases[ci]
            occ *= ph.count
            m[d.dim] = ph.size
        if occ == 0:
            continue
        m1 = dict(m)
        m1[sp.dim] = sp_s
        ps_full = psums_volume(op, m1, xp)
        m2 = dict(m)
        # per-extent MACs: psums at extent 1 of the spatial iteration dim
        if sp_kind == "dim":
            m2[sp.dim] = 1
        else:
            m2[sp.dim] = sp_window  # one window = extent 1
        ps_unit = psums_volume(op, m2, xp)
        rows.append(CaseRow(occ=occ, psums_full=int(ps_full),
                            psums_per_ext=float(ps_unit),
                            delta=0.0, delta_b=0.0))

    return EvalTables(
        sp_D=dims[sp.dim], sp_s=sp_s, sp_o=sp.offset * sp_stride,
        sp_kind=sp_kind, sp_window=sp_window, sp_stride=sp_stride,
        spatial_reduces=spatial_reduces,
        o_coupled_spatial=o_coupled_spatial,
        cases=tuple(rows),
        delta_a=float(2 * d1 - d2), delta_b=float(d2 - d1),
        ing_full_a=float(2 * f1 - f2), ing_full_b=float(f2 - f1),
        egress_a=eg_a, egress_b=eg_b,
        temporal_steps=int(t_steps),
        noc_latency=noc_latency,
    )
