"""Oracle: the shared closed form evaluated as plain jnp (no Pallas)."""
from __future__ import annotations

import jax.numpy as jnp

from .maestro_eval import closed_form_features
from .tables import EvalTables, build_tables


def maestro_eval_ref(pes, bw, *, tables: EvalTables):
    return closed_form_features(jnp.asarray(pes, jnp.int32),
                                jnp.asarray(bw, jnp.float32), tables)
