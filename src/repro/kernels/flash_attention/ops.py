"""Dispatching wrapper: Pallas kernel on TPU, interpret-mode kernel for
validation, chunked-jnp reference elsewhere."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def attention(q, k, v, *, causal: bool = True, backend: str = "auto",
              blk_q: int = 128, blk_k: int = 128):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k)
    if backend == "interpret":
        return flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=True)
    return attention_ref(q, k, v, causal=causal)
