"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True):
    """Dense softmax attention; q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
