"""Causal GQA flash attention as a Pallas TPU kernel.

MAESTRO view of the tiling (DESIGN.md §2): the grid is a directive program

    TemporalMap(blk_q, blk_q) Q        # grid dim 2 (parallel)
    TemporalMap(blk_k, blk_k) K        # grid dim 3 (arbitrary = reduction)
    SpatialMap(1, 1) B, H              # grid dims 0/1 across cores

with the output tile *temporally reduced* in VMEM scratch across the K
grid dim (online softmax = MAESTRO's temporal reduction with a running
rescale), and Q/O tiles stationary while K/V stream — a weight-stationary
dataflow where "weights" are the query block.

Block shapes keep the working set in VMEM: (blk_q × D) query/output tiles,
(blk_k × D) K/V tiles, all multiples of the 128-lane MXU width.
GQA is handled in the index map (query head h reads KV head h // group) —
no repeated K/V is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  blk_q: int, blk_k: int, seq_k: int, causal: bool,
                  scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)      # (blk_q, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (blk_k, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * blk_q + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 0)
        k_pos = ki * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) with Hq % Hkv == 0.
    Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0
    grid = (B, Hq, Sq // blk_q, Sk // blk_k)

    kernel = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, seq_k=Sk, causal=causal,
        scale=D ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, D),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, D),
                         lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, blk_k, 1, D),
                         lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        scratch_shapes=[
            pl_vmem((blk_q, 1)),
            pl_vmem((blk_q, 1)),
            pl_vmem((blk_q, D)),
        ],
        interpret=interpret,
    )(q, k, v)


def pl_vmem(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
