# Pallas TPU kernels (validated with interpret=True on CPU):
#   flash_attention  causal GQA attention (train/prefill hot spot)
#   linear_scan      chunked RWKV6/Mamba2 recurrence
#   maestro_eval     the paper's DSE inner loop (design points -> features)
