"""Environment provenance: what produced this artifact?

BENCH_* numbers are only comparable across machines when the artifact
records what produced them — jax/jaxlib versions, backend, device
kind/count, host, git SHA.  ``environment()`` gathers that once per
process; ``Report.bench`` and trace files embed it.
"""
from __future__ import annotations

import os
import platform
import socket
import subprocess
from typing import Any

__all__ = ["environment"]

_ENV: dict[str, Any] | None = None


def _git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def environment() -> dict[str, Any]:
    """Provenance block for artifacts (computed once per process).

    Returns a fresh copy each call so callers can't corrupt the cache."""
    global _ENV
    if _ENV is None:
        env: dict[str, Any] = {
            "hostname": socket.gethostname(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "git_sha": _git_sha(),
        }
        try:
            import jax
            import jaxlib
            env["jax"] = jax.__version__
            env["jaxlib"] = jaxlib.__version__
            env["backend"] = jax.default_backend()
            devs = jax.devices()
            env["device_kind"] = devs[0].device_kind if devs else None
            env["device_count"] = jax.local_device_count()
        except Exception:  # pragma: no cover - jax is a hard dep in-repo
            env["jax"] = None
        _ENV = env
    return dict(_ENV)
