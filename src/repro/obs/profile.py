"""Opt-in ``jax.profiler`` hook.

The span tracer times host-side phases; when the question is *inside*
the device pass (fusion, layout, HLO-level time), wrap the region in
``obs.profile_to(log_dir)`` and open the resulting TensorBoard/Perfetto
dump.  Best-effort: profiling failures (unsupported backend, nested
trace) never break the run.
"""
from __future__ import annotations

import contextlib
import logging

__all__ = ["profile_to"]

LOG = logging.getLogger("repro.obs")


@contextlib.contextmanager
def profile_to(log_dir: str):
    """Record a ``jax.profiler`` trace of the wrapped region into
    ``log_dir`` (viewable in TensorBoard or Perfetto)."""
    import jax

    started = False
    try:
        jax.profiler.start_trace(str(log_dir))
        started = True
    except Exception as e:  # pragma: no cover - backend-dependent
        LOG.warning("jax profiler unavailable: %s", e)
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
                LOG.info("wrote jax profile to %s", log_dir)
            except Exception as e:  # pragma: no cover
                LOG.warning("jax profiler stop failed: %s", e)
