"""Typed counters, gauges, and histograms for the search stack.

One process-wide :class:`Metrics` registry (``metrics()``) collects the
quantities the engine already *computes* but never *kept*: compiles per
(op-class, level-count) family, warm-executable and result-cache
hit/miss, genes evaluated, chunk occupancy, per-device dispatch time,
bytes shipped across the top-k merge.  Everything is thread-safe and
cheap (a dict update under a lock, at chunk — not row — granularity).

``snapshot()`` returns a plain JSON-serializable dict with its own
schema version; ``Report.bench`` and the query CLI embed it in BENCH_*
artifacts and ``--out`` payloads so CI asserts budgets from ONE
structured snapshot instead of grepping stdout.

Label convention: a metric instance is keyed ``name[k=v,...]`` with
labels sorted, e.g. ``universal.compiles_by_family[family=conv1:L2]``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any

__all__ = ["LATENCY_BUCKETS_S", "Metrics", "SNAPSHOT_SCHEMA_VERSION",
           "metrics"]

# Version of the dict layout returned by ``Metrics.snapshot``.  Still 1:
# the bucketed-histogram block is additive (new top-level key), every
# existing reader keeps working.
SNAPSHOT_SCHEMA_VERSION = 1

# Default fixed buckets (seconds) for SLO latency histograms: log-spaced
# from sub-ms warm phases to multi-minute cold compiles.  Fixed across
# the fleet so histograms aggregate by simple vector addition.
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0)


def _key(name: str, labels: dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}[{inner}]"


class _Hist:
    """Streaming summary of one histogram: count/total/min/max."""
    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def summary(self) -> dict[str, float]:
        return {"count": self.count, "total": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "mean": (self.total / self.count) if self.count else 0.0}


class _BucketHist:
    """Fixed-bucket cumulative histogram (Prometheus ``le`` semantics:
    a value lands in the first bucket whose upper bound is >= it) with
    one exemplar — the last ``(request_id, value)`` — per bucket."""
    __slots__ = ("buckets", "counts", "count", "total", "exemplars")

    def __init__(self, buckets: tuple[float, ...]) -> None:
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self.count = 0
        self.total = 0.0
        self.exemplars: dict[int, dict[str, Any]] = {}

    def observe(self, v: float, exemplar: str | None = None) -> None:
        i = bisect.bisect_left(self.buckets, v)
        self.counts[i] += 1
        self.count += 1
        self.total += v
        if exemplar is not None:
            self.exemplars[i] = {"request_id": str(exemplar),
                                 "value": v}

    def summary(self) -> dict[str, Any]:
        bounds = [*self.buckets, "+Inf"]
        cum, rows = 0, []
        for le, n in zip(bounds, self.counts):
            cum += n
            rows.append([le, cum])
        ex = {str(bounds[i]): e
              for i, e in sorted(self.exemplars.items())}
        return {"count": self.count, "total": self.total,
                "buckets": rows, "exemplars": ex}


class Metrics:
    """Thread-safe registry of counters (monotonic), gauges (last value),
    streaming histograms (count/total/min/max/mean), and fixed-bucket
    SLO histograms with per-bucket exemplars."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _Hist] = {}
        self._bucket_hists: dict[str, _BucketHist] = {}

    # -- counters ------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels: Any) -> float:
        """Add ``value`` to a counter; returns the new total."""
        k = _key(name, labels)
        with self._lock:
            v = self._counters.get(k, 0.0) + value
            self._counters[k] = v
        return v

    def value(self, name: str, **labels: Any) -> float:
        """Current counter total (0.0 when never incremented)."""
        with self._lock:
            return self._counters.get(_key(name, labels), 0.0)

    def counters(self, prefix: str = "") -> dict[str, float]:
        """Counters whose key starts with ``prefix`` (all by default)."""
        with self._lock:
            return {k: v for k, v in self._counters.items()
                    if k.startswith(prefix)}

    # -- gauges --------------------------------------------------------

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        with self._lock:
            self._gauges[_key(name, labels)] = value

    def gauge_value(self, name: str, default: float = 0.0,
                    **labels: Any) -> float:
        """Current gauge value (``default`` when never set) — the read
        half of read-modify-write gauge maintenance (callers supply
        their own outer lock for atomicity, e.g. mapspace.cache's
        occupancy accounting)."""
        with self._lock:
            return self._gauges.get(_key(name, labels), default)

    # -- histograms ----------------------------------------------------

    def observe(self, name: str, value: float, **labels: Any) -> None:
        k = _key(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = _Hist()
            h.observe(float(value))

    def observe_bucketed(self, name: str, value: float, *,
                         buckets: tuple[float, ...] = LATENCY_BUCKETS_S,
                         exemplar: str | None = None,
                         **labels: Any) -> None:
        """Record into a fixed-bucket SLO histogram.  ``exemplar`` (a
        request id) is kept as the bucket's last exemplar and rides into
        the Prometheus exposition."""
        k = _key(name, labels)
        with self._lock:
            h = self._bucket_hists.get(k)
            if h is None:
                h = self._bucket_hists[k] = _BucketHist(buckets)
            h.observe(float(value), exemplar)

    # -- snapshot ------------------------------------------------------

    def snapshot(self) -> dict[str, Any]:
        """JSON-serializable view of every metric.  Counters that hold
        integral totals serialize as ints so ``==`` asserts in CI read
        naturally."""
        with self._lock:
            counters = {k: (int(v) if float(v).is_integer() else v)
                        for k, v in sorted(self._counters.items())}
            gauges = dict(sorted(self._gauges.items()))
            hists = {k: h.summary()
                     for k, h in sorted(self._hists.items())}
            bucket_hists = {k: h.summary()
                            for k, h in sorted(self._bucket_hists.items())}
        return {"schema_version": SNAPSHOT_SCHEMA_VERSION,
                "counters": counters, "gauges": gauges,
                "histograms": hists, "bucket_histograms": bucket_hists}

    def reset(self) -> None:
        """Drop every metric.  Test-only: the process registry backs
        ``universal.compile_count()``, whose parity with the warmed-key
        set must hold for the life of the process — never reset the
        global registry outside an isolated test ``Metrics()``."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._bucket_hists.clear()


# Process-wide registry.  Always on: recording a counter is a dict update
# under a lock, at chunk granularity — there is no "disabled" mode to
# keep semantics (e.g. compile_count parity) unconditional.
_METRICS = Metrics()


def metrics() -> Metrics:
    """The process-wide metrics registry."""
    return _METRICS
