"""repro.obs — tracing, metrics, and profiling spine of the search stack.

Stdlib-only primitives (jax is only touched lazily, for provenance and
the profiler hook — safe to import from any layer):

  * :func:`span` / :func:`enable_tracing` / :func:`save_trace` — a
    thread-safe span tracer emitting Chrome/Perfetto ``trace_event``
    JSON; a no-op singleton when every sink is off (`trace.py`);
  * :func:`request_scope` / :func:`phase_scope` — contextvar-carried
    request ids and per-phase timing accumulation, threading one
    request's identity from the serve handler through the coalescer
    into the engine chunk loops (`context.py`);
  * :func:`metrics` — the process-wide typed counter/gauge/histogram
    registry with a JSON ``snapshot()`` schema plus fixed-bucket SLO
    histograms with request-id exemplars (`metrics.py`), renderable in
    Prometheus text format via :func:`prometheus_text` (`prom.py`);
  * :func:`flight_record` / :func:`dump_flight` — the always-on crash
    flight recorder: a bounded lock-free ring of recent spans/events/
    errors dumped to ``flight-<ts>.json`` on crashes (`flightrec.py`);
  * :func:`environment` / :func:`profile_to` — artifact provenance and
    the opt-in ``jax.profiler`` hook (`env.py`, `profile.py`).

Quick start::

    from repro import obs
    obs.enable_tracing()
    ... session.run_many(queries) ...
    obs.save_trace("trace.json")          # open in ui.perfetto.dev
    print(obs.metrics().snapshot())
"""
from .context import (PHASE_NAMES, PHASE_OF_SPAN, PhaseBreakdown,
                      current_phases, current_request_ids,
                      new_request_id, phase_scope, request_scope,
                      timing_breakdown)
from .env import environment
from .flightrec import (FlightRecorder, default_flight_dir, dump_flight,
                        enable_flight_spans, flight_record,
                        flight_recorder, flight_spans_enabled)
from .metrics import (LATENCY_BUCKETS_S, SNAPSHOT_SCHEMA_VERSION,
                      Metrics, metrics)
from .profile import profile_to
from .prom import prometheus_text
from .trace import (NULL_SPAN, Tracer, current_tracer, disable_tracing,
                    enable_tracing, instant, save_trace, span,
                    tracing_enabled)

__all__ = [
    "FlightRecorder", "LATENCY_BUCKETS_S", "Metrics", "NULL_SPAN",
    "PHASE_NAMES", "PHASE_OF_SPAN", "PhaseBreakdown",
    "SNAPSHOT_SCHEMA_VERSION", "Tracer", "current_phases",
    "current_request_ids", "current_tracer", "default_flight_dir",
    "disable_tracing", "dump_flight", "enable_flight_spans",
    "enable_tracing", "environment", "flight_record", "flight_recorder",
    "flight_spans_enabled", "instant", "metrics", "new_request_id",
    "phase_scope", "profile_to", "prometheus_text", "request_scope",
    "save_trace", "span", "timing_breakdown", "tracing_enabled",
]
