"""repro.obs — tracing, metrics, and profiling spine of the search stack.

Three independent, stdlib-only primitives (jax is only touched lazily,
for provenance and the profiler hook — safe to import from any layer):

  * :func:`span` / :func:`enable_tracing` / :func:`save_trace` — a
    thread-safe span tracer emitting Chrome/Perfetto ``trace_event``
    JSON; a no-op singleton when disabled (`trace.py`);
  * :func:`metrics` — the process-wide typed counter/gauge/histogram
    registry with a JSON ``snapshot()`` schema (`metrics.py`);
  * :func:`environment` / :func:`profile_to` — artifact provenance and
    the opt-in ``jax.profiler`` hook (`env.py`, `profile.py`).

Quick start::

    from repro import obs
    obs.enable_tracing()
    ... session.run_many(queries) ...
    obs.save_trace("trace.json")          # open in ui.perfetto.dev
    print(obs.metrics().snapshot())
"""
from .env import environment
from .metrics import SNAPSHOT_SCHEMA_VERSION, Metrics, metrics
from .profile import profile_to
from .trace import (NULL_SPAN, Tracer, current_tracer, disable_tracing,
                    enable_tracing, instant, save_trace, span,
                    tracing_enabled)

__all__ = [
    "NULL_SPAN", "Metrics", "SNAPSHOT_SCHEMA_VERSION", "Tracer",
    "current_tracer", "disable_tracing", "enable_tracing", "environment",
    "instant", "metrics", "profile_to", "save_trace", "span",
    "tracing_enabled",
]
