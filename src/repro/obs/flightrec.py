"""Always-on crash flight recorder: a bounded lock-free ring of recent
spans/events/errors that dumps to ``flight-<ts>.json`` on unhandled
handler errors, serve fault drills, SIGQUIT, and timeout reports.

The RING is lock-free by construction: ``itertools.count().__next__``
hands out monotonically increasing sequence numbers (a single C-level
call — atomic under the GIL), and each writer stores its finished entry
dict at ``seq % capacity`` with one list item assignment (also atomic).
Readers snapshot the ring without coordination; a concurrently
overwritten slot yields either the old or the new complete entry, never
a torn one.  (The recording fast path is waived from the concurrency
linter's lock rule — see ``analysis/waivers.toml``.)  Dump bookkeeping
is COLD path and takes a real lock: ``maybe_dump``'s rate-limit
check-then-stamp must be atomic or concurrent timeout storms
double-dump.

Recording is cheap enough to stay on unconditionally for events and
errors.  *Span* capture (every ``obs.span`` exit feeding the ring) is
opt-in via :func:`enable_flight_spans` — the server turns it on at
start so postmortem dumps carry the failing request's engine spans,
while offline CLI hot paths keep the zero-allocation ``NULL_SPAN``
fast path.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any

from . import context as _context

__all__ = [
    "DEFAULT_CAPACITY",
    "FlightRecorder",
    "default_flight_dir",
    "dump_flight",
    "enable_flight_spans",
    "flight_record",
    "flight_recorder",
    "flight_spans_enabled",
]

DEFAULT_CAPACITY = 2048

# Span capture into the ring: module global read on the span fast path.
_SPANS_ON = False


class FlightRecorder:
    """Bounded ring of recent observability entries + crash dumper."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self.capacity = max(int(capacity), 8)
        self._slots: list[dict | None] = [None] * self.capacity
        self._next = itertools.count().__next__   # atomic in CPython
        self._dump_count = itertools.count(1).__next__
        # dump bookkeeping is COLD path and lock-guarded: the rate-limit
        # check-then-stamp in maybe_dump() must be atomic or concurrent
        # timeout storms double-dump past min_interval_s
        self._dump_lock = threading.Lock()
        self._last_dump_t = 0.0

    # -- recording (hot path, lock-free) -------------------------------

    def record(self, kind: str, name: str, /, **fields: Any) -> None:
        """Append one entry.  ``kind`` is ``span``/``event``/``error``/
        ``cancel``; the current request ids attach automatically.
        Positional-only so span args may themselves carry ``kind``/
        ``name`` keys (the structural keys win on collision)."""
        entry: dict[str, Any] = {
            "seq": 0,                      # patched below, keep key first
            "t": time.time(),
            "kind": kind,
            "name": name,
            "thread": threading.current_thread().name,
        }
        rids = _context.current_request_ids()
        if rids:
            entry["rid"] = list(rids) if len(rids) > 1 else rids[0]
        for k, v in fields.items():
            entry.setdefault(k, v)
        seq = self._next()
        entry["seq"] = seq
        self._slots[seq % self.capacity] = entry

    # -- reading / dumping ---------------------------------------------

    def entries(self) -> list[dict]:
        """Snapshot of surviving entries, oldest first."""
        out = [e for e in list(self._slots) if e is not None]
        out.sort(key=lambda e: e["seq"])
        return out

    def dump(self, out_dir: str, reason: str, **info: Any) -> str:
        """Write the ring to ``flight-<ts>-<pid>-<n>.json``; returns the
        path.  Never raises into the caller's crash path by design —
        callers wrap it — but the write itself is straightforward."""
        from .env import environment
        from .metrics import metrics
        os.makedirs(out_dir, exist_ok=True)
        stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
        path = os.path.join(
            out_dir,
            f"flight-{stamp}-{os.getpid()}-{self._dump_count()}.json")
        payload = {
            "reason": reason,
            "dumped_at": time.time(),
            **info,
            "environment": environment(),
            "entries": self.entries(),
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        with self._dump_lock:
            self._last_dump_t = time.monotonic()
        metrics().inc("flight.dumps", reason=reason)
        return path

    def maybe_dump(self, out_dir: str, reason: str,
                   min_interval_s: float = 5.0, **info: Any) -> str | None:
        """Rate-limited dump for recurring triggers (timeout storms).
        The check-then-stamp is atomic: of N threads racing past the
        interval, exactly one dumps (the stamp is claimed up front and
        rolled back only if the dump itself fails)."""
        with self._dump_lock:
            now = time.monotonic()
            if now - self._last_dump_t < min_interval_s:
                return None
            prev, self._last_dump_t = self._last_dump_t, now
        try:
            return self.dump(out_dir, reason, **info)
        except BaseException:
            with self._dump_lock:
                self._last_dump_t = prev   # failed claim: allow a retry
            raise


_RECORDER = FlightRecorder()


def flight_recorder() -> FlightRecorder:
    """The process-wide flight recorder (always on)."""
    return _RECORDER


def flight_record(kind: str, name: str, /, **fields: Any) -> None:
    _RECORDER.record(kind, name, **fields)


def dump_flight(out_dir: str, reason: str, **info: Any) -> str:
    return _RECORDER.dump(out_dir, reason, **info)


def enable_flight_spans(on: bool = True) -> None:
    """Feed every ``obs.span`` exit (and instant) into the ring.  The
    server enables this at start; offline CLIs keep the null fast path."""
    global _SPANS_ON
    _SPANS_ON = bool(on)


def flight_spans_enabled() -> bool:
    return _SPANS_ON


def default_flight_dir() -> str:
    """Dump directory when none is configured: ``$REPRO_FLIGHT_DIR`` or
    the system temp dir."""
    import tempfile
    return os.environ.get("REPRO_FLIGHT_DIR") or tempfile.gettempdir()
