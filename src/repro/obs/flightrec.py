"""Always-on crash flight recorder: a bounded lock-free ring of recent
spans/events/errors that dumps to ``flight-<ts>.json`` on unhandled
handler errors, serve fault drills, SIGQUIT, and timeout reports.

Lock-free by construction: ``itertools.count().__next__`` hands out
monotonically increasing sequence numbers (a single C-level call —
atomic under the GIL), and each writer stores its finished entry dict at
``seq % capacity`` with one list item assignment (also atomic).  Readers
snapshot the ring without coordination; a concurrently overwritten slot
yields either the old or the new complete entry, never a torn one.

Recording is cheap enough to stay on unconditionally for events and
errors.  *Span* capture (every ``obs.span`` exit feeding the ring) is
opt-in via :func:`enable_flight_spans` — the server turns it on at
start so postmortem dumps carry the failing request's engine spans,
while offline CLI hot paths keep the zero-allocation ``NULL_SPAN``
fast path.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any

from . import context as _context

__all__ = [
    "DEFAULT_CAPACITY",
    "FlightRecorder",
    "default_flight_dir",
    "dump_flight",
    "enable_flight_spans",
    "flight_record",
    "flight_recorder",
    "flight_spans_enabled",
]

DEFAULT_CAPACITY = 2048

# Span capture into the ring: module global read on the span fast path.
_SPANS_ON = False


class FlightRecorder:
    """Bounded ring of recent observability entries + crash dumper."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self.capacity = max(int(capacity), 8)
        self._slots: list[dict | None] = [None] * self.capacity
        self._next = itertools.count().__next__   # atomic in CPython
        self._dump_count = itertools.count(1).__next__
        self._last_dump_t = 0.0

    # -- recording (hot path, lock-free) -------------------------------

    def record(self, kind: str, name: str, /, **fields: Any) -> None:
        """Append one entry.  ``kind`` is ``span``/``event``/``error``/
        ``cancel``; the current request ids attach automatically.
        Positional-only so span args may themselves carry ``kind``/
        ``name`` keys (the structural keys win on collision)."""
        entry: dict[str, Any] = {
            "seq": 0,                      # patched below, keep key first
            "t": time.time(),
            "kind": kind,
            "name": name,
            "thread": threading.current_thread().name,
        }
        rids = _context.current_request_ids()
        if rids:
            entry["rid"] = list(rids) if len(rids) > 1 else rids[0]
        for k, v in fields.items():
            entry.setdefault(k, v)
        seq = self._next()
        entry["seq"] = seq
        self._slots[seq % self.capacity] = entry

    # -- reading / dumping ---------------------------------------------

    def entries(self) -> list[dict]:
        """Snapshot of surviving entries, oldest first."""
        out = [e for e in list(self._slots) if e is not None]
        out.sort(key=lambda e: e["seq"])
        return out

    def dump(self, out_dir: str, reason: str, **info: Any) -> str:
        """Write the ring to ``flight-<ts>-<pid>-<n>.json``; returns the
        path.  Never raises into the caller's crash path by design —
        callers wrap it — but the write itself is straightforward."""
        from .env import environment
        from .metrics import metrics
        os.makedirs(out_dir, exist_ok=True)
        stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
        path = os.path.join(
            out_dir,
            f"flight-{stamp}-{os.getpid()}-{self._dump_count()}.json")
        payload = {
            "reason": reason,
            "dumped_at": time.time(),
            **info,
            "environment": environment(),
            "entries": self.entries(),
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        self._last_dump_t = time.monotonic()
        metrics().inc("flight.dumps", reason=reason)
        return path

    def maybe_dump(self, out_dir: str, reason: str,
                   min_interval_s: float = 5.0, **info: Any) -> str | None:
        """Rate-limited dump for recurring triggers (timeout storms)."""
        if time.monotonic() - self._last_dump_t < min_interval_s:
            return None
        return self.dump(out_dir, reason, **info)


_RECORDER = FlightRecorder()


def flight_recorder() -> FlightRecorder:
    """The process-wide flight recorder (always on)."""
    return _RECORDER


def flight_record(kind: str, name: str, /, **fields: Any) -> None:
    _RECORDER.record(kind, name, **fields)


def dump_flight(out_dir: str, reason: str, **info: Any) -> str:
    return _RECORDER.dump(out_dir, reason, **info)


def enable_flight_spans(on: bool = True) -> None:
    """Feed every ``obs.span`` exit (and instant) into the ring.  The
    server enables this at start; offline CLIs keep the null fast path."""
    global _SPANS_ON
    _SPANS_ON = bool(on)


def flight_spans_enabled() -> bool:
    return _SPANS_ON


def default_flight_dir() -> str:
    """Dump directory when none is configured: ``$REPRO_FLIGHT_DIR`` or
    the system temp dir."""
    import tempfile
    return os.environ.get("REPRO_FLIGHT_DIR") or tempfile.gettempdir()
