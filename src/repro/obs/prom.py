"""Prometheus text exposition of a ``Metrics.snapshot()``.

Renders the snapshot dict (the same one ``/metricsz`` serves as JSON)
in the Prometheus text format, version 0.0.4, with OpenMetrics-style
exemplars on bucketed-histogram lines:

    serve_latency_s_bucket{kind="layer",le="0.25"} 17 # {request_id="ab12"} 0.093

Mapping:

  * counters  -> ``# TYPE <name> counter``  (dots become underscores;
    the ``name[k=v,...]`` label key encoding round-trips into real
    ``{k="v"}`` label sets)
  * gauges    -> ``# TYPE <name> gauge``
  * streaming histograms (count/total/min/max) -> ``# TYPE <name>
    summary`` with ``_sum``/``_count``
  * bucketed histograms -> ``# TYPE <name> histogram`` with cumulative
    ``_bucket{le="..."}`` rows, an explicit ``le="+Inf"``, and
    ``_sum``/``_count``

Pure function over the snapshot — no locks, no registry access — so it
renders identically for a live server and a saved snapshot file.
"""
from __future__ import annotations

import re
from typing import Any

__all__ = ["CONTENT_TYPE", "prometheus_text"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _san(name: str) -> str:
    n = _NAME_BAD.sub("_", name)
    return ("_" + n) if n[:1].isdigit() else (n or "_")


def _parse_key(key: str) -> tuple[str, dict[str, str]]:
    """Invert ``metrics._key``: ``'a.b[k=v,k2=v2]'`` -> name + labels."""
    if key.endswith("]") and "[" in key:
        name, _, inner = key[:-1].partition("[")
        labels = {}
        for part in inner.split(","):
            k, _, v = part.partition("=")
            labels[k] = v
        return name, labels
    return key, {}


def _esc(v: Any) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labels(labels: dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_san(str(k))}="{_esc(v)}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


def _num(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class _Writer:
    def __init__(self) -> None:
        self.lines: list[str] = []
        self._typed: set[str] = set()

    def type_line(self, family: str, kind: str) -> None:
        if family not in self._typed:
            self._typed.add(family)
            self.lines.append(f"# TYPE {family} {kind}")

    def sample(self, name: str, labels: dict[str, Any], value: float,
               exemplar: dict[str, Any] | None = None) -> None:
        line = f"{name}{_labels(labels)} {_num(value)}"
        if exemplar:
            line += (f' # {{request_id="{_esc(exemplar["request_id"])}"}}'
                     f' {_num(exemplar["value"])}')
        self.lines.append(line)


def prometheus_text(snapshot: dict[str, Any]) -> str:
    """Render a ``Metrics.snapshot()`` (or a ``Session.metrics()`` dict,
    whose extra non-metric blocks are ignored) as Prometheus text."""
    w = _Writer()
    for key, value in snapshot.get("counters", {}).items():
        name, labels = _parse_key(key)
        fam = _san(name)
        w.type_line(fam, "counter")
        w.sample(fam, labels, value)
    for key, value in snapshot.get("gauges", {}).items():
        name, labels = _parse_key(key)
        fam = _san(name)
        w.type_line(fam, "gauge")
        w.sample(fam, labels, value)
    for key, h in snapshot.get("histograms", {}).items():
        name, labels = _parse_key(key)
        fam = _san(name)
        w.type_line(fam, "summary")
        w.sample(fam + "_sum", labels, h["total"])
        w.sample(fam + "_count", labels, h["count"])
    for key, h in snapshot.get("bucket_histograms", {}).items():
        name, labels = _parse_key(key)
        fam = _san(name)
        w.type_line(fam, "histogram")
        exemplars = h.get("exemplars", {})
        for le, cum in h["buckets"]:
            le_s = le if isinstance(le, str) else _num(le)
            w.sample(fam + "_bucket", {**labels, "le": le_s}, cum,
                     exemplars.get(str(le)))
        w.sample(fam + "_sum", labels, h["total"])
        w.sample(fam + "_count", labels, h["count"])
    return "\n".join(w.lines) + "\n"
