"""Lightweight span tracer emitting Chrome/Perfetto ``trace_event`` JSON.

One process-wide :class:`Tracer` (enabled on demand) collects *complete*
events (``"ph": "X"``) so a whole ``Session.run_many`` batch renders as a
timeline in ``chrome://tracing`` / https://ui.perfetto.dev: coalesce →
encode → device-pass chunks per device → top-k merge → DP compose.

Design constraints, in order:

  * **near-zero overhead when disabled** — the hot paths call
    :func:`span` unconditionally; with no tracer active it returns ONE
    shared no-op context manager (:data:`NULL_SPAN`), so the fast path
    allocates nothing and does no clock reads;
  * **thread-safe** — events append under a lock and carry the emitting
    thread id, so spans from worker threads land on their own timeline
    rows;
  * **self-contained output** — ``save()`` writes a valid Chrome
    ``trace_event`` file (``{"traceEvents": [...]}``) with the
    environment provenance in ``otherData``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

from . import context as _context
from . import flightrec as _flightrec

__all__ = ["NULL_SPAN", "Tracer", "current_tracer", "disable_tracing",
           "enable_tracing", "instant", "save_trace", "span",
           "tracing_enabled"]

_PID = os.getpid()


class _NullSpan:
    """The disabled-tracer fast path: one shared, stateless context
    manager.  ``span()`` returns this exact singleton whenever tracing is
    off — zero allocation, zero clock reads (regression-tested in
    ``tests/test_obs.py``)."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        """No-op counterpart of :meth:`_Span.set`."""


NULL_SPAN = _NullSpan()


class _Span:
    """One live span feeding up to three sinks on exit: the tracer (a
    complete ``"X"`` event, stamped with the current request ids), the
    ambient :class:`~repro.obs.context.PhaseBreakdown` (mapped span
    names accumulate into timing phases), and the flight-recorder ring
    (when span capture is enabled)."""
    __slots__ = ("_tracer", "name", "cat", "args", "_t0_pc")

    def __init__(self, tracer: "Tracer | None", name: str, cat: str,
                 args: dict[str, Any] | None):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0_pc = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        dur_s = t1 - self._t0_pc
        t = self._tracer
        if t is not None:
            args = self.args
            rids = _context.current_request_ids()
            if rids:
                args = dict(args) if args else {}
                args["rid"] = list(rids) if len(rids) > 1 else rids[0]
            t.emit(self.name, self.cat, (self._t0_pc - t._t0) * 1e6,
                   dur_s * 1e6, args)
        acc = _context.current_phases()
        if acc is not None:
            phase = _context.PHASE_OF_SPAN.get(self.name)
            if phase is not None:
                acc.add(phase, dur_s)
        if _flightrec._SPANS_ON:
            _flightrec.flight_record("span", self.name,
                                     dur_s=round(dur_s, 6),
                                     **(self.args or {}))
        return False

    def set(self, **args) -> None:
        """Attach/override args discovered while the span is open."""
        if self.args is None:
            self.args = {}
        self.args.update(args)


class Tracer:
    """Thread-safe in-memory collector of Chrome ``trace_event`` events.

    Timestamps are microseconds since the tracer was created
    (``perf_counter`` based), which is what the Chrome/Perfetto viewers
    expect of ``ts``/``dur``."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list[dict[str, Any]] = []
        self._t0 = time.perf_counter()

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def emit(self, name: str, cat: str, ts_us: float, dur_us: float,
             args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {
            "name": name, "cat": cat, "ph": "X", "pid": _PID,
            "tid": threading.get_ident(), "ts": round(ts_us, 3),
            "dur": round(max(dur_us, 0.0), 3)}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, cat: str = "repro",
             args: dict[str, Any] | None = None) -> _Span:
        return _Span(self, name, cat, args)

    def emit_between(self, name: str, cat: str, t0_pc: float,
                     t1_pc: float,
                     args: dict[str, Any] | None = None) -> None:
        """Emit a complete event for a past ``perf_counter`` interval —
        retroactive spans like per-request queue wait, emitted at flush
        time from the enqueue timestamp."""
        self.emit(name, cat, (t0_pc - self._t0) * 1e6,
                  (t1_pc - t0_pc) * 1e6, args)

    def instant(self, name: str, cat: str = "repro",
                args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {
            "name": name, "cat": cat, "ph": "i", "s": "t", "pid": _PID,
            "tid": threading.get_ident(), "ts": round(self.now_us(), 3)}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------------
    # Introspection / output
    # ------------------------------------------------------------------

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def spans(self, name: str | None = None) -> list[dict[str, Any]]:
        """Complete (``"X"``) events, optionally filtered by name."""
        return [e for e in self.events()
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def to_json(self) -> dict[str, Any]:
        """A complete Chrome ``trace_event`` document — load the saved
        file directly in ``chrome://tracing`` or Perfetto."""
        from .env import environment
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "otherData": environment()}

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path


# ----------------------------------------------------------------------
# Process-wide tracer (None = disabled; the common case)
# ----------------------------------------------------------------------

_TRACER: Tracer | None = None


def tracing_enabled() -> bool:
    return _TRACER is not None


def current_tracer() -> Tracer | None:
    return _TRACER


def enable_tracing() -> Tracer:
    """Install (or return the already-active) process tracer."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer()
    return _TRACER


def disable_tracing() -> Tracer | None:
    """Uninstall and return the active tracer (``None`` if none was)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def span(name: str, cat: str = "repro", **args: Any):
    """Context manager timing one region.  THE instrumentation entry
    point: ``with span("compile", family=...):``.  Returns the shared
    no-op singleton when every sink is inactive — no tracer, no ambient
    phase accumulator, no flight span capture — so cold hot-path calls
    stay zero-allocation."""
    t = _TRACER
    if t is None and not _flightrec._SPANS_ON \
            and _context.current_phases() is None:
        return NULL_SPAN
    return _Span(t, name, cat, args or None)


def instant(name: str, cat: str = "repro", **args: Any) -> None:
    """Zero-duration marker event (no-op when every sink is off).  Also
    lands in the flight-recorder ring when span capture is enabled."""
    t = _TRACER
    if t is not None:
        targs = args or None
        rids = _context.current_request_ids()
        if rids:
            targs = dict(args)
            targs["rid"] = list(rids) if len(rids) > 1 else rids[0]
        t.instant(name, cat, targs)
    if _flightrec._SPANS_ON:
        _flightrec.flight_record("event", name, **args)


def save_trace(path: str) -> str | None:
    """Write the active tracer's events as a Chrome trace file; returns
    the path, or ``None`` when tracing is disabled."""
    t = _TRACER
    if t is None:
        return None
    return t.save(path)
