"""Request-scoped observability context.

Two :mod:`contextvars` carry per-request state from the serve handler
through the coalescer's flush thread into the engine chunk loops:

  * the **request-id scope** — the set of request ids whose work is
    currently executing.  The server mints one per ``POST /query``
    (honoring an inbound ``X-Request-Id``); a coalesced flush opens one
    scope holding *all* member ids, so every engine span/flight entry
    recorded inside is attributable to the exact requests that rode
    that device pass.
  * the **phase accumulator** — a thread-safe per-phase seconds sink.
    ``Session.run`` / ``run_many`` open a fresh one per query (or per
    coalesced family batch); span exits add their duration to the
    mapped timing phase, and the snapshot becomes the ``timing``
    breakdown stamped on every ``Report``.

Both are contextvars, NOT thread-locals: the coalescer's single flush
worker opens the scopes *inside* the worker thread, and everything the
engines do on that thread inherits them.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
import uuid

__all__ = [
    "PHASE_NAMES",
    "PHASE_OF_SPAN",
    "PhaseBreakdown",
    "current_phases",
    "current_request_ids",
    "new_request_id",
    "phase_scope",
    "request_scope",
    "timing_breakdown",
]

_REQUEST_IDS: contextvars.ContextVar[tuple[str, ...]] = \
    contextvars.ContextVar("repro_request_ids", default=())
_PHASES: contextvars.ContextVar["PhaseBreakdown | None"] = \
    contextvars.ContextVar("repro_phase_acc", default=None)

# Span name -> timing phase.  Only LEAF spans are mapped (the phases
# must be disjoint wall-time intervals so they can sum to wall latency);
# container spans (``query``, ``run_many``, ``flush``, ``design-chunk``)
# stay unmapped or they would double-count their children.
PHASE_OF_SPAN = {
    "coalesce": "coalesce_wait",
    "encode": "encode",
    "compile": "compile",
    "dispatch": "device_pass",
    "device-pass": "device_pass",
    "warmup": "compile",
    "topk-merge": "merge",
    "compose": "merge",
}

# Canonical phase order for the ``timing`` breakdown.  ``queue_wait`` is
# server-side (enqueue -> flush start); ``other`` is the residual that
# makes the phases sum to measured wall latency by construction.
PHASE_NAMES = ("queue_wait", "coalesce_wait", "encode", "compile",
               "device_pass", "merge", "other")


def new_request_id() -> str:
    """A fresh 16-hex-char request id."""
    return uuid.uuid4().hex[:16]


def current_request_ids() -> tuple[str, ...]:
    """Request ids whose work is executing in this context (may be
    several: a coalesced flush carries all member ids)."""
    return _REQUEST_IDS.get()


@contextlib.contextmanager
def request_scope(*rids: str):
    """Attribute everything inside to ``rids`` (spans, flight entries)."""
    token = _REQUEST_IDS.set(tuple(rids))
    try:
        yield
    finally:
        _REQUEST_IDS.reset(token)


class PhaseBreakdown:
    """Thread-safe accumulator of per-phase seconds for one unit of
    engine work (one ``Session.run`` or one coalesced family batch)."""

    __slots__ = ("_lock", "_phases")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._phases: dict[str, float] = {}

    def add(self, phase: str, seconds: float) -> None:
        with self._lock:
            self._phases[phase] = self._phases.get(phase, 0.0) + seconds

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            return dict(self._phases)


def current_phases() -> PhaseBreakdown | None:
    return _PHASES.get()


@contextlib.contextmanager
def phase_scope(acc: PhaseBreakdown | None = None):
    """Route mapped span durations into ``acc`` (fresh one if None)."""
    acc = acc if acc is not None else PhaseBreakdown()
    token = _PHASES.set(acc)
    try:
        yield acc
    finally:
        _PHASES.reset(token)


def timing_breakdown(wall_s: float, phases: dict[str, float],
                     request_id: str | None = None) -> dict:
    """The ``Report.extras['timing']`` payload.

    ``other`` is the residual ``wall - sum(mapped phases)``, so the
    phases sum to the measured wall latency exactly (up to rounding).
    Engine phases can never exceed wall: they are disjoint sub-intervals
    of the same measurement window.
    """
    wall = round(max(0.0, wall_s), 6)
    out = {p: round(v, 6) for p, v in sorted(phases.items())
           if p != "other" and v > 0.0}
    out["other"] = round(max(0.0, wall - sum(out.values())), 6)
    doc: dict = {"wall_s": wall, "phases": out}
    if request_id is not None:
        doc["request_id"] = request_id
    return doc
