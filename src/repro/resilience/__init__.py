"""repro.resilience — fault-tolerant, resumable sweep execution.

The layer between the gene-pipeline chunk loops and the hardware's bad
days: checkpointed resumable sweeps (bit-identical to uninterrupted
runs), bounded retry with OOM chunk-splitting, graceful degradation to
the legacy engine, a structured error taxonomy at the Query boundary,
and deterministic fault injection so every one of those paths is
exercised in tests and CI.  All recovery events are counted in the
``repro.obs`` metrics registry under ``resilience.*`` and visible as
trace spans/instants.
"""
from __future__ import annotations

import dataclasses

from .errors import (BudgetExceeded, CacheError, DeviceError, ReproError,
                     SpecError, classify, is_oom)
from .faultinject import (FaultInjector, InjectedFault, InjectedOOM,
                          SweepKilled, fault_point)
from . import faultinject
from .policy import (DEFAULT_POLICY, RetryPolicy, cancel_scope,
                     check_cancel, default_policy, run_attempts,
                     set_default_policy)
from .sweepckpt import SweepCheckpoint, array_hash, pack_top, unpack_top
from .watchdog import CHUNK_WATCHDOG, StragglerWatchdog


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Session-level resilience knobs.

    ``ckpt_dir``   directory for sweep checkpoints (None = no
                   checkpointing); killed sweeps resume bit-identically.
    ``retry``      the RetryPolicy wrapped around every device pass.
    ``degrade``    on persistent gene-pipeline failure, fall back to the
                   legacy grouped engine (warning + ``degraded`` extras)
                   instead of failing the query.
    ``faults``     fault-injection spec (see ``resilience.faultinject``);
                   installed process-wide when the Session is built.
    """
    ckpt_dir: str | None = None
    retry: RetryPolicy = DEFAULT_POLICY
    degrade: bool = True
    faults: str | None = None

    def install_faults(self) -> None:
        if self.faults is not None:
            faultinject.install(self.faults)

    def install(self) -> None:
        """Make this config the process default: fault spec (if any) and
        the retry policy the chunk loops fall back to."""
        self.install_faults()
        set_default_policy(self.retry)


__all__ = [
    "BudgetExceeded", "CacheError", "DeviceError", "ReproError",
    "SpecError", "classify", "is_oom",
    "FaultInjector", "InjectedFault", "InjectedOOM", "SweepKilled",
    "fault_point", "faultinject",
    "DEFAULT_POLICY", "RetryPolicy", "cancel_scope", "check_cancel",
    "default_policy", "run_attempts", "set_default_policy",
    "SweepCheckpoint", "array_hash", "pack_top", "unpack_top",
    "CHUNK_WATCHDOG", "StragglerWatchdog", "ResilienceConfig",
]
