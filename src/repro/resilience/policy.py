"""Retry policy for device passes.

A :class:`RetryPolicy` bounds how hard the chunk loops fight a failing
device pass before surfacing a :class:`~.errors.DeviceError`:

  * up to ``max_attempts`` total attempts per chunk, with exponential
    backoff and *deterministic* jitter (seeded from the label+attempt,
    so test runs are reproducible);
  * on OOM, up to ``max_splits`` recursive halvings of the chunk's block
    size (down to ``min_rows``) before falling back to plain retry;
  * ``chunk_deadline_s`` is an advisory per-chunk SLO: an XLA dispatch
    cannot be preempted, so a chunk that finishes over deadline is
    *flagged* (``resilience.deadline_exceeded``) rather than discarded —
    re-running a completed chunk would only add latency.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
import zlib

from .. import obs
from .errors import BudgetExceeded, DeviceError, ReproError, is_oom
from .faultinject import SweepKilled


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    jitter_frac: float = 0.25
    chunk_deadline_s: float | None = None
    max_splits: int = 4        # OOM block halvings before giving up
    min_rows: int = 64         # never split below this block size

    def backoff(self, attempt: int, salt: str = "") -> float:
        """Sleep before retry ``attempt`` (1-based); exponential with
        deterministic jitter."""
        base = self.backoff_s * self.backoff_mult ** (attempt - 1)
        rng = random.Random(zlib.crc32(f"{salt}:{attempt}".encode()))
        return base * (1.0 + self.jitter_frac * rng.random())

    def check_deadline(self, wall_s: float, **labels) -> bool:
        """Flag (never fail) a chunk that exceeded the per-chunk
        deadline; returns True when it did."""
        if self.chunk_deadline_s is None or wall_s <= self.chunk_deadline_s:
            return False
        obs.metrics().inc("resilience.deadline_exceeded")
        obs.instant("deadline-exceeded", wall_s=round(wall_s, 4),
                    deadline_s=self.chunk_deadline_s, **labels)
        return True


DEFAULT_POLICY = RetryPolicy()

# The process-wide policy the chunk loops fall back to when the caller
# does not pass one explicitly — Session(resilience=...) installs its
# RetryPolicy here so it reaches every device pass without threading a
# parameter through four layers of call sites.
_INSTALLED: RetryPolicy = DEFAULT_POLICY


def set_default_policy(policy: RetryPolicy | None) -> None:
    """Install ``policy`` as the process-wide default (None restores
    :data:`DEFAULT_POLICY`)."""
    global _INSTALLED
    _INSTALLED = policy or DEFAULT_POLICY


def default_policy() -> RetryPolicy:
    """The currently installed process-wide retry policy."""
    return _INSTALLED


# -- cooperative cancellation -------------------------------------------
#
# An XLA dispatch cannot be preempted, so deadlines are enforced *between*
# chunks: the serving tier opens a cancel scope around a device pass and
# the chunk loops poll check_cancel() at each chunk boundary (next to the
# existing fault_point sites).  The scope is thread-local — one server
# worker's deadline never leaks into another thread's sweep.

_CANCEL = threading.local()


@contextlib.contextmanager
def cancel_scope(deadline_t: float | None):
    """Bound all chunk work inside the ``with`` body by an absolute
    ``time.monotonic()`` deadline (None = no bound).  Scopes nest; the
    innermost-effective deadline is the minimum of the stack."""
    prev = getattr(_CANCEL, "deadline_t", None)
    if deadline_t is not None and prev is not None:
        deadline_t = min(deadline_t, prev)
    _CANCEL.deadline_t = deadline_t
    try:
        yield
    finally:
        _CANCEL.deadline_t = prev


def check_cancel(label: str = "chunk") -> None:
    """Raise :class:`BudgetExceeded` when the enclosing
    :func:`cancel_scope` deadline has passed.  Cheap enough to call at
    every chunk boundary; a no-op outside any scope."""
    deadline_t = getattr(_CANCEL, "deadline_t", None)
    if deadline_t is None:
        return
    over = time.monotonic() - deadline_t
    if over >= 0.0:
        obs.metrics().inc("resilience.cancelled_chunks")
        obs.instant("cancel", label=label, over_s=round(over, 4))
        # the flight recorder keeps the cancellation even when no tracer
        # is live — a postmortem dump shows WHERE the budget expired
        obs.flight_record("cancel", label, over_s=round(over, 4))
        raise BudgetExceeded(
            f"deadline expired {over:.3f}s ago at {label} boundary",
            budget="deadline_s")


def run_attempts(fn, *, policy: RetryPolicy, label: str,
                 first_exc: BaseException | None = None):
    """Run ``fn()`` under the retry budget.  ``first_exc`` counts a
    failure that already happened (the caller's in-line first attempt).
    :class:`SweepKilled` and already-classified :class:`ReproError`\\ s
    propagate immediately — a recursive recovery call has its own budget,
    and re-retrying its final error would multiply attempts."""
    met = obs.metrics()
    attempts = 1 if first_exc is not None else 0
    exc = first_exc
    while True:
        if exc is not None:
            if isinstance(exc, (SweepKilled, ReproError)):
                raise exc
            if attempts >= policy.max_attempts:
                raise DeviceError(
                    f"{label}: failed after {attempts} attempts "
                    f"({type(exc).__name__}: "
                    f"{str(exc).strip().splitlines()[0] if str(exc) else ''})",
                    attempts=attempts, oom=is_oom(exc)) from exc
            met.inc("resilience.retries")
            obs.instant("retry", label=label, attempt=attempts)
            time.sleep(policy.backoff(attempts, salt=label))
        attempts += 1
        try:
            return fn()
        except Exception as e:    # noqa: BLE001 — classified above
            exc = e
