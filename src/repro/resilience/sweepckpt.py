"""Compact, atomic sweep checkpoints for resumable chunk loops.

One checkpoint = one ``.npz`` file holding the sweep's running
accumulator state (chunk cursor, top-k entries, Pareto candidates,
partial value columns) plus a JSON ``meta`` guard (query fingerprint /
cache key, row count, chunking parameters).  The commit protocol is the
dormant ``checkpoint.Checkpointer``'s, adapted from a per-step directory
tree down to a single file: write to a temp path, ``os.replace`` to
commit — a crash mid-save never corrupts the previous checkpoint.

Robustness contract (mirrors ``mapspace.cache``): a truncated or
otherwise unreadable checkpoint is a *miss*, never a crash — the file is
quarantined to ``<path>.corrupt``, ``resilience.checkpoint_corrupt`` is
bumped, and the sweep restarts from chunk 0.  A readable checkpoint
whose ``meta`` guard doesn't match the current call (different genes,
block size, or device count — chunk boundaries would differ) is silently
discarded the same way, minus the quarantine.

Resume is bit-exact by construction: the chunk loops collect results in
deterministic dispatch order, the saved accumulators are restored
verbatim (float64/float32 round-trip exactly through ``.npz``), and the
final top-k sort / Pareto refinement are order-insensitive merges.
"""
from __future__ import annotations

import json
import logging
import os
import re
import time
import zipfile

import numpy as np

from .. import obs
from .faultinject import fault_point

LOG = logging.getLogger("repro.resilience")

_META_KEY = "__meta_json__"


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", key)[:120]


def array_hash(*arrays) -> str:
    """Order-sensitive content hash of input arrays — the genes/hardware
    part of a checkpoint's meta guard."""
    import hashlib
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:24]


class SweepCheckpoint:
    """Periodic saver/loader for one sweep's accumulator state."""

    def __init__(self, directory: str, key: str, *,
                 every_chunks: int = 4, every_s: float = 2.0,
                 max_overhead: float = 0.02):
        self.directory = directory
        self.key = key
        self.path = os.path.join(directory, f"sweep-{_sanitize(key)}.npz")
        self.every_chunks = max(1, int(every_chunks))
        self.every_s = float(every_s)
        self.max_overhead = float(max_overhead)
        self._n_saves = 0
        self._last_save_dt = 0.0
        self._last_save_chunks = 0
        self._last_save_t = time.perf_counter()

    # -- write ---------------------------------------------------------
    def save(self, state: dict, meta: dict) -> None:
        """Atomically persist ``state`` (numpy arrays / scalars) guarded
        by ``meta`` (JSON-serializable dict, matched exactly on load)."""
        t0 = time.perf_counter()
        os.makedirs(self.directory, exist_ok=True)
        blob = {k: np.asarray(v) for k, v in state.items()
                if v is not None}
        blob[_META_KEY] = np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8)
        tmp = self.path + f".tmp-{os.getpid()}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **blob)
        os.replace(tmp, self.path)           # atomic commit
        dt = time.perf_counter() - t0
        self._n_saves += 1
        self._last_save_dt = dt
        m = obs.metrics()
        m.inc("resilience.checkpoint_saves")
        m.inc("resilience.checkpoint_save_s", dt)
        obs.instant("checkpoint-save", key=self.key,
                    bytes=os.path.getsize(self.path), s=round(dt, 5))
        # fault point AFTER the commit so truncate@checkpoint:k corrupts
        # the file a later load must survive
        fault_point("checkpoint", path=self.path)

    def maybe_save(self, state_fn, meta: dict, *, chunks_done: int) -> bool:
        """Save when the cadence (every N chunks or T seconds) is due;
        ``state_fn`` is called lazily only when actually saving.

        The first completed chunk ALWAYS commits — a kill after chunk 0
        must be resumable — and later commits are additionally
        cost-gated: a save only fires once enough sweep wall has passed
        that time-spent-saving stays under ``max_overhead`` of the run,
        so sub-millisecond chunks can't turn an every-chunk cadence into
        double-digit checkpoint overhead."""
        if chunks_done == self._last_save_chunks:
            return False
        if self._n_saves:
            gap = time.perf_counter() - self._last_save_t
            due = (chunks_done - self._last_save_chunks
                   >= self.every_chunks or gap >= self.every_s)
            if not due or gap < self._last_save_dt / self.max_overhead:
                return False
        self.save(state_fn(), meta)
        self._last_save_chunks = chunks_done
        self._last_save_t = time.perf_counter()
        return True

    # -- read ----------------------------------------------------------
    def load(self, meta: dict) -> dict | None:
        """The persisted state, or None (missing / corrupt / stale).
        Corrupt files are quarantined; a successful load bumps
        ``resilience.checkpoint_resumes``."""
        if not os.path.exists(self.path):
            return None
        m = obs.metrics()
        try:
            with np.load(self.path, allow_pickle=False) as z:
                blob = {k: z[k] for k in z.files}
            saved = json.loads(bytes(blob.pop(_META_KEY)).decode())
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile, json.JSONDecodeError) as e:
            self._quarantine(e)
            return None
        if saved != json.loads(json.dumps(meta, sort_keys=True)):
            # different run parameters — chunk boundaries would not line
            # up; discard rather than resume wrongly
            m.inc("resilience.checkpoint_stale")
            self.clear()
            return None
        m.inc("resilience.checkpoint_resumes")
        obs.instant("checkpoint-resume", key=self.key,
                    cursor=int(blob.get("cursor", -1)))
        return blob

    def _quarantine(self, exc: Exception) -> None:
        from .errors import CacheError
        err = CacheError(f"corrupt sweep checkpoint {self.path}: "
                         f"{type(exc).__name__}: {exc}", path=self.path)
        LOG.warning("%s — quarantined, restarting sweep from chunk 0",
                    err.one_line())
        obs.metrics().inc("resilience.checkpoint_corrupt")
        try:
            os.replace(self.path, self.path + ".corrupt")
        except OSError:
            pass

    def clear(self) -> None:
        """Remove the checkpoint (called after a sweep completes)."""
        try:
            os.remove(self.path)
        except OSError:
            pass


# -- top-k entry (value, global row, feature row) packing ---------------

def pack_top(entries: list[tuple]) -> dict:
    """Pack evaluate_genes-style top entries into checkpointable arrays
    (float64 values and int64 rows round-trip bit-exactly)."""
    if not entries:
        return {"top_v": np.zeros(0, np.float64),
                "top_r": np.zeros(0, np.int64),
                "top_f": np.zeros((0, 0), np.float32)}
    return {"top_v": np.array([e[0] for e in entries], np.float64),
            "top_r": np.array([e[1] for e in entries], np.int64),
            "top_f": np.stack([np.asarray(e[2], np.float32)
                               for e in entries])}


def unpack_top(st: dict) -> list[tuple]:
    return [(float(v), int(r), f) for v, r, f in
            zip(st["top_v"], st["top_r"], st["top_f"])]
