"""Deterministic fault injection for the sweep execution layer.

A :class:`FaultInjector` is configured from a spec string (the
``REPRO_FAULTS`` env var, the ``--faults`` CLI flag, or
``Session(resilience=ResilienceConfig(faults=...))``) and fired from
instrumented *fault points* inside the chunk loops.  Because the points
are indexed by a deterministic per-site counter, "crash on chunk 3"
means the same chunk on every run — every recovery path is exercisable
in tests and CI without flakes.

Spec grammar (comma-separated directives)::

    kind@site:index[:arg][xN]

    crash@chunk:3        raise InjectedFault at the 4th chunk fault point
    oom@chunk:2          raise InjectedOOM (message matches is_oom)
    kill@chunk:5         raise SweepKilled — NOT retried; simulates
                         process death for checkpoint/resume tests
    slow@chunk:1:0.25    sleep 0.25 s at chunk 1 (straggler injection)
    truncate@checkpoint:0  truncate the checkpoint file written by save 0
    crash@chunk:3x2      fire twice (chunks 3 and 4), i.e. also defeats
                         one retry

Sites in the tree: ``chunk`` (universal.evaluate_genes and
netspace.evaluate_rows device chunks), ``design-chunk``
(codse.joint_sweep outer chunks), ``checkpoint`` (SweepCheckpoint.save),
``legacy-batch`` (the grouped fallback engine), and the serving tier's
``serve-flush`` (head of every batch execution — ``slow@serve-flush``
stretches a flush past its members' deadlines), ``serve-worker`` (the
flush worker loop — ``crash@serve-worker`` exercises the
answer-with-error-reports isolation path), and ``serve-drain``
(between pending-queue persist and the final drain flush —
``kill@serve-drain`` is the mid-drain process death the restart
recovery drill replays).  Every firing increments
``resilience.faults_injected``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time

from .. import obs


class InjectedFault(RuntimeError):
    """A deliberately injected failure (retryable)."""


class InjectedOOM(InjectedFault):
    """Injected device-memory exhaustion; the message carries the XLA
    RESOURCE_EXHAUSTED marker so ``errors.is_oom`` routes it to the
    chunk-split path exactly like a real OOM."""

    def __init__(self, site: str, index: int):
        super().__init__(
            f"RESOURCE_EXHAUSTED: injected OOM at {site}:{index}")


class SweepKilled(InjectedFault):
    """Injected process death.  Never retried or degraded — it must
    propagate so checkpoint/resume tests observe a genuine mid-sweep
    kill."""


@dataclasses.dataclass
class _Directive:
    kind: str            # crash | oom | kill | slow | truncate
    site: str
    index: int
    arg: float = 0.0
    times: int = 1

    def spec(self) -> str:
        s = f"{self.kind}@{self.site}:{self.index}"
        if self.arg:
            s += f":{self.arg:g}"
        if self.times != 1:
            s += f"x{self.times}"
        return s


_KINDS = ("crash", "oom", "kill", "slow", "truncate")


def parse(spec: str) -> list[_Directive]:
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition("@")
        if kind not in _KINDS or not rest:
            raise ValueError(f"bad fault directive {part!r} "
                             f"(want kind@site:index, kind in {_KINDS})")
        times = 1
        if "x" in rest.rsplit(":", 1)[-1]:
            rest, _, t = rest.rpartition("x")
            times = int(t)
        bits = rest.split(":")
        if len(bits) < 2:
            raise ValueError(f"bad fault directive {part!r}: missing index")
        site, index = bits[0], int(bits[1])
        arg = float(bits[2]) if len(bits) > 2 else 0.0
        out.append(_Directive(kind, site, index, arg, times))
    return out


class FaultInjector:
    """Holds parsed directives plus a per-site call counter; thread-safe
    (the async chunk loops collect from one thread, but netspace +
    Session may share the process-wide injector)."""

    def __init__(self, spec: str = ""):
        self._lock = threading.Lock()
        self.directives = parse(spec) if spec else []
        self._counts: dict[str, int] = {}
        self.fired = 0

    def active(self) -> bool:
        return any(d.times > 0 for d in self.directives)

    def fire(self, site: str, index: int | None = None,
             path: str | None = None) -> None:
        """Evaluate the fault point ``site`` (indexed by an internal
        per-site counter unless ``index`` is given).  Raises / sleeps /
        truncates ``path`` when a directive matches; no-op otherwise."""
        with self._lock:
            if index is None:
                index = self._counts.get(site, 0)
                self._counts[site] = index + 1
            hit = None
            for d in self.directives:
                if d.site == site and d.times > 0 and d.index <= index \
                        < d.index + d.times:
                    hit = d
                    break
            if hit is None:
                return
        obs.metrics().inc("resilience.faults_injected",
                          kind=hit.kind, site=site)
        obs.instant("fault-injected", kind=hit.kind, site=site, index=index)
        if hit.kind == "slow":
            time.sleep(hit.arg)
        elif hit.kind == "truncate":
            if path and os.path.exists(path):
                keep = max(1, os.path.getsize(path) // 2)
                with open(path, "r+b") as f:
                    f.truncate(keep)
        elif hit.kind == "oom":
            raise InjectedOOM(site, index)
        elif hit.kind == "kill":
            raise SweepKilled(f"injected kill at {site}:{index}")
        else:
            raise InjectedFault(f"injected crash at {site}:{index}")


_NULL = FaultInjector()
_CURRENT: FaultInjector = _NULL
_ENV_READ = False


def install(spec: str | None) -> FaultInjector:
    """Install a process-wide injector from a spec string (or clear with
    None/empty).  Returns the installed injector."""
    global _CURRENT, _ENV_READ
    _ENV_READ = True         # explicit install overrides the env knob
    _CURRENT = FaultInjector(spec) if spec else _NULL
    return _CURRENT


def clear() -> None:
    install(None)


def current() -> FaultInjector:
    """The active injector; reads ``REPRO_FAULTS`` once on first use."""
    global _CURRENT, _ENV_READ
    if not _ENV_READ:
        _ENV_READ = True
        env = os.environ.get("REPRO_FAULTS", "")
        if env:
            _CURRENT = FaultInjector(env)
    return _CURRENT


def fault_point(site: str, index: int | None = None,
                path: str | None = None) -> None:
    """The hook the chunk loops call; free when no injector is active."""
    inj = current()
    if inj.directives:
        inj.fire(site, index, path)


class scoped:
    """``with faultinject.scoped("kill@chunk:1"):`` — test helper that
    installs a fresh injector and restores the previous one on exit."""

    def __init__(self, spec: str):
        self.spec = spec

    def __enter__(self) -> FaultInjector:
        global _CURRENT
        self._prev = _CURRENT
        return install(self.spec)

    def __exit__(self, *exc) -> None:
        global _CURRENT
        _CURRENT = self._prev
