"""Slow-chunk (straggler) detection.

The EWMA logic is ported from the seed's ``ft.coordinator``
(``FaultTolerantLoop._observe``) where it watched training steps; here it
watches device-chunk wall times in the sweep loops.  A chunk is *slow*
when its wall exceeds ``threshold ×`` the running EWMA; slow chunks are
flagged (``resilience.slow_chunks`` + a trace instant) and deliberately
do NOT update the EWMA, so one straggler cannot poison the baseline.
``ft.coordinator`` now delegates to this class, so the tree has exactly
one straggler detector.
"""
from __future__ import annotations

import threading

from .. import obs


class StragglerWatchdog:
    def __init__(self, threshold: float = 3.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.slow_count = 0
        self._lock = threading.Lock()

    def observe(self, wall_s: float, **labels) -> bool:
        """Record one chunk/step wall time; returns True when it was a
        straggler (> threshold × EWMA of non-straggler walls)."""
        with self._lock:
            if self.ewma is None:
                self.ewma = wall_s
                return False
            slow = wall_s > self.threshold * self.ewma
            if slow:
                self.slow_count += 1
            else:
                self.ewma = (1 - self.alpha) * self.ewma \
                    + self.alpha * wall_s
        if slow:
            obs.metrics().inc("resilience.slow_chunks")
            obs.instant("slow-chunk", wall_s=round(wall_s, 4),
                        ewma_s=round(self.ewma, 4), **labels)
        return slow


# Process-wide watchdog for the sweep chunk loops: chunk walls within one
# (op, block) regime are comparable, and a shared baseline is what makes
# a straggler stand out across many small evaluate calls.
CHUNK_WATCHDOG = StragglerWatchdog()
