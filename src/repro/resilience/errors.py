"""Structured error taxonomy for the search stack.

Every failure a ``Query`` can surface is one of a handful of typed,
one-line errors instead of a deep XLA traceback:

  ReproError                 base (carries a ``details`` dict)
    SpecError                invalid Query/Workload/Hardware/SearchSpec
                             field (also a ValueError, so existing
                             ``pytest.raises(ValueError)`` call sites and
                             try/except blocks keep working)
    DeviceError              a device pass failed after the retry budget
                             (also a RuntimeError)
    CacheError               corrupt/unreadable result cache or sweep
                             checkpoint (always recoverable: the file is
                             quarantined and treated as a miss)
    BudgetExceeded           a wall-time / deadline budget was exhausted

``classify`` wraps an arbitrary exception into this taxonomy at the
``Session.run`` boundary; ``is_oom`` is the single place that decides
whether an exception means "out of device memory" (and therefore that
halving the chunk is worth trying before giving up).

Stdlib-only on purpose: importable from ``api.spec`` / ``mapspace.cache``
without cycles.
"""
from __future__ import annotations


class ReproError(Exception):
    """Base of the structured error taxonomy; ``details`` holds
    machine-readable context (offending field, attempts, chunk index)."""

    def __init__(self, message: str, **details):
        super().__init__(message)
        self.details = details

    def one_line(self) -> str:
        d = ", ".join(f"{k}={v}" for k, v in self.details.items())
        return f"{type(self).__name__}: {self} ({d})" if d else \
            f"{type(self).__name__}: {self}"


class SpecError(ReproError, ValueError):
    """A Query/Workload/Hardware/SearchSpec field is invalid; raised at
    construction so bad specs never reach gene encoding."""

    def __init__(self, message: str, *, field: str, **details):
        super().__init__(message, field=field, **details)
        self.field = field


class DeviceError(ReproError, RuntimeError):
    """A device pass kept failing after retries/splits were exhausted."""


class CacheError(ReproError):
    """A persisted artifact (result cache entry, sweep checkpoint) was
    corrupt.  Never fatal: callers quarantine the file and recompute."""


class BudgetExceeded(ReproError, RuntimeError):
    """A wall-time or per-chunk deadline budget was exhausted."""


_OOM_MARKERS = ("resource_exhausted", "out of memory", "oom",
                "failed to allocate")


def is_oom(exc: BaseException) -> bool:
    """Whether ``exc`` looks like device memory exhaustion — the one
    failure where shrinking the chunk (rather than plain retry) helps."""
    msg = f"{type(exc).__name__}: {exc}".lower()
    return any(m in msg for m in _OOM_MARKERS)


def classify(exc: BaseException, *, context: str = "") -> ReproError:
    """Wrap an arbitrary exception as a :class:`ReproError` for the Query
    boundary.  Already-classified errors pass through unchanged."""
    if isinstance(exc, ReproError):
        return exc
    kind = type(exc).__name__
    # first line only: XLA errors carry multi-KB tracebacks in str()
    msg = str(exc).strip().splitlines()[0] if str(exc).strip() else kind
    prefix = f"{context}: " if context else ""
    if is_oom(exc):
        return DeviceError(f"{prefix}device out of memory ({msg})",
                           cause=kind)
    if isinstance(exc, (ValueError, TypeError, KeyError)):
        return SpecError(f"{prefix}{msg}", field="unknown", cause=kind)
    return DeviceError(f"{prefix}{msg}", cause=kind)
