import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*abstract_args)
        compiled = lowered.compile()
        memory_analysis() / cost_analysis() / collective bytes from HLO

and writes one JSON record per cell into experiments/dryrun/.  The
single-pod 16×16 mesh feeds the roofline table; the 2×16×16 multi-pod
mesh proves the 'pod' axis shards.  No device buffers are ever allocated
(ShapeDtypeStruct arguments only).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from ..configs import REGISTRY, get_config
from ..core.hlo_analysis import analyze_collectives, while_trip_counts
from ..core.roofline import model_flops
from ..launch.mesh import make_production_mesh, mesh_name
from ..launch.shapes import SHAPES, build_cell, cell_runs
from ..training.train_step import TrainConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _depth_variants(cfg):
    """Two reduced-depth, fully-unrolled configs + the full unit count.

    XLA's cost_analysis counts while-loop bodies once, so exact totals come
    from unrolled compiles at depths k=1,2 extrapolated linearly (the model
    is exactly linear in layer count).  The 'unit' is a layer (dense/moe/
    ssm), an encoder+decoder layer pair (encdec), or a shared-attention
    group (hybrid)."""
    if cfg.family == "hybrid":
        e = cfg.attn_every
        tail = cfg.n_layers % e
        mk = lambda g: cfg.replace(n_layers=g * e + tail, scan_unroll=True)
        return mk(1), mk(2), cfg.n_layers // e
    if cfg.is_encdec:
        mk = lambda k: cfg.replace(n_layers=k, n_dec_layers=k,
                                   scan_unroll=True)
        return mk(1), mk(2), cfg.n_layers
    mk = lambda k: cfg.replace(n_layers=k, scan_unroll=True)
    return mk(1), mk(2), cfg.n_layers


def default_microbatches(cfg, shape) -> int:
    """Gradient-accumulation depth for train cells, sized so remat
    residuals (n_layers × B_loc × S × d_model × 2B) plus fp32 logits fit
    16 GB HBM (every production 70B-class recipe microbatches)."""
    if shape.kind != "train":
        return 1
    layers = cfg.n_layers + cfg.n_dec_layers
    b_loc = shape.global_batch / 16          # data-axis shards
    resid = layers * b_loc * shape.seq * cfg.d_model * 2
    logits = b_loc * shape.seq * max(cfg.padded_vocab / 16, 1) * 4
    budget = 3.5e9                           # headroom for fwd/bwd temps
    mb_cap = max(1, shape.global_batch // 16)  # keep batch data-shardable
    mb = 1
    while (resid + logits) / mb > budget and mb < mb_cap:
        mb *= 2
    return mb


def _cost_compile(cfg, shape, mesh, train_cfg, param_rules=None) -> dict:
    # cost compiles always use microbatches=1: total FLOPs/bytes match and
    # the extrapolation stays linear in depth (the accumulation scan body
    # would otherwise be costed once)
    if train_cfg is not None and train_cfg.microbatches != 1:
        train_cfg = TrainConfig(microbatches=1,
                                compress_grads=train_cfg.compress_grads)
    spec = build_cell(cfg, shape, mesh, train_cfg,
                      param_rules=param_rules)
    with mesh:
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         donate_argnums=spec.donate)
        compiled = jitted.lower(*spec.args).compile()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = analyze_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.total_bytes),
    }


def extrapolated_costs(arch_cfg, shape, mesh, train_cfg,
                       param_rules=None) -> dict:
    """Exact (flops, bytes, collective bytes) per device via depth-linear
    extrapolation of two unrolled compiles."""
    c1, c2, units = _depth_variants(arch_cfg)
    f1 = _cost_compile(c1, shape, mesh, train_cfg, param_rules)
    f2 = _cost_compile(c2, shape, mesh, train_cfg, param_rules)
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = f2[k] - f1[k]
        out[k] = f1[k] + slope * (units - 1)
    out["per_unit"] = {k: f2[k] - f1[k] for k in ("flops", "bytes", "coll")}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             train_cfg: TrainConfig | None = None,
             tag: str = "", out_dir: str = OUT_DIR,
             param_rules: dict | None = None,
             cfg_override=None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mname,
        "chips": int(mesh.devices.size), "tag": tag or "base",
    }
    runs, reason = cell_runs(cfg, shape)
    if not runs:
        record["status"] = "skipped"
        record["reason"] = reason
        _write(record, out_dir)
        return record

    if train_cfg is None or train_cfg.microbatches == 1:
        mb = default_microbatches(cfg, shape)
        train_cfg = TrainConfig(
            microbatches=mb,
            compress_grads=bool(train_cfg and train_cfg.compress_grads))
    record["microbatches"] = train_cfg.microbatches

    t0 = time.time()
    try:
        spec = build_cell(cfg, shape, mesh, train_cfg,
                          param_rules=param_rules)
        with mesh:
            jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                             donate_argnums=spec.donate)
            lowered = jitted.lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()

        coll = analyze_collectives(hlo)
        # exact per-device cost totals via depth-linear extrapolation of
        # two unrolled reduced-depth compiles (scan bodies are costed once
        # by XLA; see _depth_variants).  The roofline table reads
        # single-pod records only, so multi-pod cells skip the costly
        # extrapolation compiles (they prove pod-axis shardability).
        if multi_pod:
            ex = {"flops": float(cost.get("flops", 0.0)),
                  "bytes": float(cost.get("bytes accessed", 0.0)),
                  "coll": float(coll.total_bytes),
                  "per_unit": {}}
        else:
            ex = extrapolated_costs(cfg, shape, mesh, train_cfg,
                                    param_rules)
        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": ex["flops"],
            "bytes_accessed": ex["bytes"],
            "collective_bytes": ex["coll"],
            "per_unit_costs": ex["per_unit"],
            "flops_scan_raw": float(cost.get("flops", 0.0)),
            "bytes_scan_raw": float(cost.get("bytes accessed", 0.0)),
            "collective_scan_raw": int(coll.total_bytes),
            "collective_breakdown": coll.bytes_by_kind,
            "collective_counts": coll.count_by_kind,
            "while_trip_counts": while_trip_counts(hlo)[:8],
            "tokens": spec.tokens,
            "kind": spec.kind,
            "model_flops": model_flops(
                cfg, spec.kind, spec.tokens),
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes",
                                           0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        })
        # peak per-device estimate: arguments + temps (+ outputs aliased)
        record["per_device_bytes"] = (
            record["argument_size_bytes"] + record["temp_size_bytes"])
        record["fits_16gb"] = record["per_device_bytes"] < 16e9
        # Refined HBM-traffic estimate: CPU-backend cost_analysis counts
        # fusion-internal intermediates (TPU would not), so also record a
        # buffer-level bound: every argument/output read or written once,
        # every temp written + read once.
        record["bytes_hbm_est"] = (
            record["argument_size_bytes"] + record["output_size_bytes"]
            + 2 * record["temp_size_bytes"])
    except Exception as e:  # noqa: BLE001 — a failed cell IS the finding
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(record, out_dir)
    return record


def _write(record: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            f"__{record.get('tag', 'base')}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None,
                    choices=("none", "full", "dots"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--decode-rules", choices=("default", "tp"),
                    default="default")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    tc = TrainConfig(microbatches=args.microbatches)
    from .shapes import decode_tp_rules
    param_rules = decode_tp_rules() if args.decode_rules == "tp" else None
    archs = sorted(REGISTRY) if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        if args.remat:
            # config override plumbed through the registry copy
            cfg = REGISTRY[arch]
            REGISTRY[arch] = cfg.replace(remat=args.remat)
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, tc, tag=args.tag,
                             out_dir=args.out, param_rules=param_rules)
                status = r["status"]
                msg = r.get("error", "")[:120]
                print(f"[dryrun] {arch:24s} {shape:12s} "
                      f"{'multi' if mp else 'single':6s} -> {status} "
                      f"{msg}", flush=True)
                failures += status == "error"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
