"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single-pod; 2×16×16 (pod, data, model) for the
    two-pod run.  Uses the first prod(shape) available devices so the same
    512-device host platform serves both."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh():
    """Whatever this host actually has (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
