"""CLI for whole-network schedule search — a thin shim over the
declarative query backend (``repro.launch.query`` / ``repro.api``), kept
for compatibility.  Prefer ``python -m repro.launch.query --model <net>``.

Examples::

    # best-EDP VGG16 schedule (per-layer mappings + fused stacks) at the
    # Fig. 10 reference design
    PYTHONPATH=src python -m repro.launch.netsearch --model vgg16

    # ablations: no fusion / no reconfiguration cost
    PYTHONPATH=src python -m repro.launch.netsearch --model vgg16 \
        --no-fuse --no-reconfig

    # network-level joint mapping x hardware co-DSE
    PYTHONPATH=src python -m repro.launch.netsearch --model resnet50 \
        --co-dse --budget 256
"""
from __future__ import annotations

import argparse

from repro.api import Hardware, Query, SearchSpec, Workload
from repro.core import dnn_models as zoo
from repro.launch.query import (DEFAULT_JAX_CACHE, _fmt, add_obs_args,
                                cli_errors, obs_scope,
                                print_network_codse_report,
                                print_network_report, session_from_args)
from repro.netspace import best_uniform, uniform_baseline


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16", choices=sorted(zoo.MODELS))
    ap.add_argument("--objective", default="edp",
                    choices=["edp", "energy", "runtime", "throughput"])
    ap.add_argument("--budget", type=int, default=512,
                    help="evaluated mappings per unique layer shape")
    ap.add_argument("--frontier-k", type=int, default=8,
                    help="per-layer frontier width the composer sees")
    ap.add_argument("--pes", type=int, default=256)
    ap.add_argument("--bw", type=float, default=32.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "exhaustive", "random"])
    ap.add_argument("--composer", default="auto",
                    choices=["auto", "dp", "genetic"])
    ap.add_argument("--budget-policy", default="uniform",
                    choices=["uniform", "adaptive"],
                    help="adaptive: cheap first pass, then refine the "
                         "top network-cost contributors (the new-API "
                         "default; uniform kept as the legacy default "
                         "here)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable fused-stack/off-chip boundary modeling")
    ap.add_argument("--no-reconfig", action="store_true",
                    help="disable the mapping-switch reconfiguration cost")
    ap.add_argument("--l2-budget-kb", type=float, default=None,
                    help="fused-stack resident-tile L2 budget")
    ap.add_argument("--reconfig-latency", type=float, default=0.0,
                    help="fixed cycles per dataflow switch (HWConfig)")
    ap.add_argument("--dram-bw", type=float, default=16.0,
                    help="off-chip elements/cycle (HWConfig)")
    ap.add_argument("--dram-energy-pj", type=float, default=100.0,
                    help="pJ per off-chip element (HWConfig)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--block", type=int, default=1024)
    ap.add_argument("--co-dse", action="store_true",
                    help="cross the network frontiers with the hardware "
                         "DSE grid")
    ap.add_argument("--quick", action="store_true",
                    help="tiny budget/frontier (smoke test)")
    ap.add_argument("--cache-dir", default="",
                    help="on-disk result cache ('' disables)")
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE,
                    help="persistent XLA compilation cache ('' disables)")
    add_obs_args(ap)
    args = ap.parse_args(argv)

    with cli_errors(), obs_scope(args):
        session = session_from_args(args)
        budget = min(args.budget, 128) if args.quick else args.budget
        frontier_k = min(args.frontier_k, 4) if args.quick \
            else args.frontier_k

        hw = Hardware(num_pes=args.pes, noc_bw=args.bw,
                      dram_bw=args.dram_bw,
                      dram_energy_pj=args.dram_energy_pj,
                      reconfig_latency=args.reconfig_latency)
        spec = SearchSpec(objective=args.objective, budget=budget,
                          strategy=args.strategy, seed=args.seed,
                          frontier_k=frontier_k, fuse=not args.no_fuse,
                          reconfig=not args.no_reconfig,
                          l2_budget_kb=args.l2_budget_kb,
                          composer=args.composer,
                          budget_policy=args.budget_policy,
                          block=args.block, codse_top_k=4)
        rep = session.run(Query(Workload.of_network(args.model), hw,
                                spec))
        print_network_report(rep)

        r = rep.raw
        base = uniform_baseline(r.netspace.layers, r.model)
        flow, b = best_uniform(base, "edp")
        print(f"\n# uniform Table-3 baselines (network EDP, same cost "
              f"model):")
        for f, v in base.items():
            mark = " <- best uniform" if f == flow else ""
            print(f"  {f:5s} EDP={_fmt(v['edp'])}{mark}")
        print(f"# schedule vs best uniform ({flow}): "
              f"{b['edp'] / r.schedule.network_edp:.2f}x better EDP")

        if args.co_dse:
            if args.quick:
                grid = Hardware(num_pes=args.pes, noc_bw=args.bw,
                                dram_bw=args.dram_bw,
                                dram_energy_pj=args.dram_energy_pj,
                                reconfig_latency=args.reconfig_latency,
                                pe_range=(64, 128, 256),
                                bw_range=(8.0, 16.0, 32.0))
            else:
                grid = Hardware(
                    num_pes=args.pes, noc_bw=args.bw,
                    dram_bw=args.dram_bw,
                    dram_energy_pj=args.dram_energy_pj,
                    reconfig_latency=args.reconfig_latency,
                    pe_range=tuple(range(32, 513, 32)),
                    bw_range=tuple(float(b) for b in range(4, 65, 4)))
            co_spec = SearchSpec(
                objective=args.objective, budget=budget,
                strategy=args.strategy, seed=args.seed,
                frontier_k=min(frontier_k, 4), fuse=not args.no_fuse,
                reconfig=not args.no_reconfig,
                l2_budget_kb=args.l2_budget_kb, composer=args.composer,
                budget_policy=args.budget_policy, block=args.block)
            co = session.run(Query(Workload.of_network(args.model), grid,
                                   co_spec))
            print()
            print_network_codse_report(co)


if __name__ == "__main__":
    main()
