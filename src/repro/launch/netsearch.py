"""CLI for whole-network, fusion-aware schedule search (``repro.netspace``).

Examples::

    # best-EDP VGG16 schedule (per-layer mappings + fused stacks) at the
    # Fig. 10 reference design
    PYTHONPATH=src python -m repro.launch.netsearch --model vgg16

    # ablations: no fusion / no reconfiguration cost
    PYTHONPATH=src python -m repro.launch.netsearch --model vgg16 \
        --no-fuse --no-reconfig

    # network-level joint mapping x hardware co-DSE
    PYTHONPATH=src python -m repro.launch.netsearch --model resnet50 \
        --co-dse --budget 256
"""
from __future__ import annotations

import argparse

from repro.core import dnn_models as zoo
from repro.core.dse import DSEConfig
from repro.core.performance import HWConfig
from repro.mapspace import enable_compilation_cache
from repro.netspace import (best_uniform, co_search_network,
                            search_network, uniform_baseline)
from repro.launch.mapsearch import DEFAULT_JAX_CACHE


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16", choices=sorted(zoo.MODELS))
    ap.add_argument("--objective", default="edp",
                    choices=["edp", "energy", "runtime", "throughput"])
    ap.add_argument("--budget", type=int, default=512,
                    help="evaluated mappings per unique layer shape")
    ap.add_argument("--frontier-k", type=int, default=8,
                    help="per-layer frontier width the composer sees")
    ap.add_argument("--pes", type=int, default=256)
    ap.add_argument("--bw", type=float, default=32.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "exhaustive", "random"])
    ap.add_argument("--composer", default="auto",
                    choices=["auto", "dp", "genetic"])
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable fused-stack/off-chip boundary modeling")
    ap.add_argument("--no-reconfig", action="store_true",
                    help="disable the mapping-switch reconfiguration cost")
    ap.add_argument("--l2-budget-kb", type=float, default=None,
                    help="fused-stack resident-tile L2 budget")
    ap.add_argument("--reconfig-latency", type=float, default=0.0,
                    help="fixed cycles per dataflow switch (HWConfig)")
    ap.add_argument("--dram-bw", type=float, default=16.0,
                    help="off-chip elements/cycle (HWConfig)")
    ap.add_argument("--dram-energy-pj", type=float, default=100.0,
                    help="pJ per off-chip element (HWConfig)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--block", type=int, default=1024)
    ap.add_argument("--co-dse", action="store_true",
                    help="cross the network frontiers with the hardware "
                         "DSE grid")
    ap.add_argument("--quick", action="store_true",
                    help="tiny budget/frontier (smoke test)")
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE,
                    help="persistent XLA compilation cache ('' disables)")
    args = ap.parse_args(argv)

    if args.jax_cache_dir:
        enable_compilation_cache(args.jax_cache_dir)
    budget = min(args.budget, 128) if args.quick else args.budget
    frontier_k = min(args.frontier_k, 4) if args.quick else args.frontier_k

    hw = HWConfig(num_pes=args.pes, noc_bw=args.bw, noc_latency=2.0,
                  dram_bw=args.dram_bw,
                  dram_energy_pj=args.dram_energy_pj,
                  reconfig_latency=args.reconfig_latency)
    r = search_network(args.model, objective=args.objective,
                       budget=budget, num_pes=args.pes, noc_bw=args.bw,
                       seed=args.seed, strategy=args.strategy,
                       frontier_k=frontier_k, fuse=not args.no_fuse,
                       reconfig=not args.no_reconfig,
                       l2_budget_kb=args.l2_budget_kb, hw=hw,
                       composer=args.composer, devices=args.devices,
                       block=args.block)
    s = r.schedule
    print(f"# {args.model}: {r.n_layers} layers ({r.n_unique} unique "
          f"shapes, {r.n_classes} op-classes) strategy={r.strategy} "
          f"composer={r.composer}")
    print(f"# evaluated={r.n_evaluated} mappings, compiles="
          f"{r.n_compiles} ({r.compile_s:.1f}s), eval={r.eval_s:.2f}s, "
          f"compose={r.compose_s:.2f}s "
          f"({r.schedules_per_s / 1e3:.1f}k sched-exts/s), "
          f"wall={r.elapsed_s:.1f}s, devices={r.n_devices}")
    seg_of = {}
    for si, (a, b) in enumerate(s.segments):
        for i in range(a, b + 1):
            seg_of[i] = si
    print(f"\n{'layer':28s} {'seg':>4s} {'runtime':>12s} "
          f"{'energy':>12s} {'l2KB':>8s}  mapping")
    for i, pl in enumerate(s.per_layer):
        gene = "-".join(str(g) for g in pl["gene"])
        print(f"{pl['layer']:28s} {seg_of[i]:>4d} "
              f"{_fmt(pl['runtime']):>12s} {_fmt(pl['energy_pj']):>12s} "
              f"{pl['l2_kb']:>8.1f}  {gene}")
    print(f"\n# schedule: {len(s.segments)} fused stacks, "
          f"{s.n_reconfigs} reconfigurations")
    print(f"# totals: runtime={_fmt(s.runtime)}cy "
          f"energy={_fmt(s.energy_pj)}pJ EDP={_fmt(s.network_edp)} "
          f"throughput={s.throughput:.2f} MACs/cy")

    base = uniform_baseline(r.netspace.layers, r.model)
    flow, b = best_uniform(base, "edp")
    print(f"\n# uniform Table-3 baselines (network EDP, same cost model):")
    for f, v in base.items():
        mark = " <- best uniform" if f == flow else ""
        print(f"  {f:5s} EDP={_fmt(v['edp'])}{mark}")
    print(f"# schedule vs best uniform ({flow}): "
          f"{b['edp'] / s.network_edp:.2f}x better EDP")

    if args.co_dse:
        cfg = DSEConfig(pe_range=tuple(range(32, 513, 32)),
                        bw_range=tuple(float(b) for b in range(4, 65, 4)))
        if args.quick:
            cfg = DSEConfig(pe_range=(64, 128, 256),
                            bw_range=(8.0, 16.0, 32.0))
        co = co_search_network(
            args.model, cfg, objective=args.objective, budget=budget,
            num_pes=args.pes, noc_bw=args.bw, seed=args.seed,
            frontier_k=min(frontier_k, 4), fuse=not args.no_fuse,
            reconfig=not args.no_reconfig,
            l2_budget_kb=args.l2_budget_kb, hw=hw, devices=args.devices,
            block=args.block)
        print(f"\n# co-DSE: {co.n_designs} designs over {co.n_hw} hw "
              f"points in {co.elapsed_s:.1f}s; {co.n_valid} valid, "
              f"{len(co.pareto)} frontier points, compiles="
              f"{co.n_compiles}")
        for p in co.pareto[:12]:
            print(f"  pes={p['num_pes']:4d} bw={p['noc_bw']:5.1f} "
                  f"energy={_fmt(p['energy_pj'])} "
                  f"thr={_fmt(p['throughput'])}")
        for obj, p in co.best.items():
            if p:
                print(f"  best {obj:10s}: pes={p['num_pes']} "
                      f"bw={p['noc_bw']} EDP={_fmt(p['edp'])}")


if __name__ == "__main__":
    main()
