"""The assigned input-shape cells and their abstract input specs.

Every (arch × shape) cell resolves to:
  * a step function (train_step / prefill / serve_step),
  * ShapeDtypeStruct arguments (zero allocation),
  * in/out shardings derived from the logical-axis rules.

``long_500k`` lowers ``serve_step`` (one token against a 512k-token
context) and only exists for sub-quadratic archs (ssm / hybrid) — the
skip list is part of the roofline table.  ``decode_*`` KV caches shard
KV-heads over 'model' when divisible, otherwise the cache *sequence* axis
takes 'model' (GQA kv < TP width — e.g. qwen2's kv=8 on a 16-way model
axis); long-context additionally shards sequence over 'data'.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import (DEFAULT_RULES, replicated, resolve_spec,
                                    shardings_for_params, tree_shardings)
from ..models import registry
from ..models.param import abstract_params
from ..training.train_step import (TrainConfig, abstract_train_state,
                                   make_train_step)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int
    needs_subquadratic: bool = False


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1,
                           needs_subquadratic=True),
}


def cell_runs(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.needs_subquadratic and not cfg.subquadratic:
        return False, ("full-attention arch: long_500k requires "
                       "sub-quadratic context (DESIGN.md §Arch skips)")
    return True, ""


# ----------------------------------------------------------------------
# Batch specs
# ----------------------------------------------------------------------

def _batch_specs(cfg: ModelConfig, shape: ShapeCell):
    B = shape.global_batch
    tok = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": tok((B, shape.seq), jnp.int32),
                 "labels": tok((B, shape.seq), jnp.int32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    elif shape.kind == "prefill":
        specs = {"tokens": tok((B, shape.seq), jnp.int32)}
        axes = {"tokens": ("batch", "seq")}
    else:
        specs = {"tokens": tok((B, 1), jnp.int32)}
        axes = {"tokens": ("batch", None)}
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["frontend"] = tok((B, cfg.frontend_len, cfg.frontend_dim),
                                jnp.float32)
        axes["frontend"] = ("batch", None, None)
    if cfg.is_encdec and shape.kind != "decode":
        specs["frontend"] = tok((B, shape.seq, cfg.frontend_dim),
                                jnp.float32)
        axes["frontend"] = ("batch", "seq", None)
    return specs, axes


# ----------------------------------------------------------------------
# Cache specs (abstract) + axes
# ----------------------------------------------------------------------

def _seq_rule(cfg: ModelConfig, mesh: Mesh, long: bool):
    """Decide KV-cache sharding: kv-heads on 'model' when divisible, else
    the sequence axis takes 'model'; long-context adds 'data' on seq."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    kv_on_model = cfg.n_kv_heads % model == 0 and cfg.n_kv_heads >= model
    seq_axes: tuple[str, ...] = ()
    if not kv_on_model:
        seq_axes += ("model",)
    if long:
        seq_axes = ("data",) + seq_axes
    rules = dict(DEFAULT_RULES)
    rules["kv_seq"] = seq_axes
    if not kv_on_model:
        rules["kv_heads"] = ()
    return rules


def _max_len(cfg: ModelConfig, shape: ShapeCell) -> int:
    """KV capacity: the sequence plus any frontend prefix (VLM patches)."""
    extra = cfg.frontend_len if cfg.frontend == "vision" else 0
    return shape.seq + extra


def _abstract_cache(cfg: ModelConfig, shape: ShapeCell):
    B = shape.global_batch
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.is_encdec:
        def build():
            from ..models import encdec
            # self cache + cross kv for a seq-length encoder context
            from ..models.layers import make_kv_cache
            self_c = make_kv_cache(cfg, B, _max_len(cfg, shape),
                                   n_layers=cfg.n_dec_layers,
                                   dtype=cfg.dtype)
            hd = cfg.head_dim_
            ck = jnp.zeros((cfg.n_dec_layers, B, shape.seq,
                            cfg.n_kv_heads, hd), cfg.dtype)
            return {"self": self_c, "cross": (ck, ck)}
        return jax.eval_shape(build)

    def build():
        from ..models.transformer import empty_cache
        return empty_cache(None, batch, cfg, train=False,
                           max_len=_max_len(cfg, shape))
    return jax.eval_shape(build)


def _cache_axes(cfg: ModelConfig, shape: ShapeCell):
    kv = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
    dense_axes = {"k": kv, "v": kv, "length": ("layers",)}
    if cfg.is_encdec:
        cross = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
        return {"self": dense_axes, "cross": (cross, cross)}
    if cfg.family in ("dense", "moe"):
        return dense_axes
    if cfg.family == "ssm":
        st = ("layers", "batch", "heads", None, None)
        carry = ("layers", "batch", None, None)
        return ((st, carry, carry), ())
    # hybrid
    st = ("layers", "batch", "heads_flat", None, None)
    conv = ("layers", "batch", None, "mlp")
    return ((st, conv), dense_axes)


# ----------------------------------------------------------------------
# Lowerable cell: fn + abstract args + shardings
# ----------------------------------------------------------------------

@dataclasses.dataclass
class LoweredSpec:
    fn: Callable
    args: tuple
    in_shardings: tuple
    donate: tuple[int, ...]
    tokens: int
    kind: str


def decode_tp_rules() -> dict:
    """Weight-stationary decode sharding (beyond-paper, §Perf-B): weights
    shard over BOTH mesh axes on their output-feature dims — MAESTRO's
    K-partitioned row, which Table 1 predicts needs only *activation*
    multicast — so no per-step weight all-gathers.  The FSDP 'embed' axis
    is dropped: contraction dims stay unsharded."""
    rules = dict(DEFAULT_RULES)
    rules.update({
        "embed": (),
        "mlp": ("data", "model"),
        "vocab": ("data", "model"),
        "heads": ("model",),
        "heads_flat": ("data", "model"),
        "experts": ("model",),
        "embed_out": ("data", "model"),
    })
    return rules


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
               train_cfg: TrainConfig | None = None,
               param_rules: dict | None = None) -> LoweredSpec:
    from ..distributed.autosharding import wrap_with_context
    tc = train_cfg or TrainConfig()
    specs = registry.specs(cfg)
    params = abstract_params(specs)
    p_shard = shardings_for_params(specs, mesh, param_rules)
    batch_specs, batch_axes = _batch_specs(cfg, shape)
    b_shard = tree_shardings(batch_specs, batch_axes, mesh)

    if shape.kind == "train":
        params_a, opt_a = abstract_train_state(cfg, tc)
        o_shard = {
            "mu": p_shard, "nu": p_shard,
            "count": replicated(mesh),
        }
        if tc.compress_grads:
            o_shard["error_feedback"] = p_shard
        step = wrap_with_context(make_train_step(cfg, tc), mesh)
        return LoweredSpec(
            fn=step, args=(params_a, opt_a, batch_specs),
            in_shardings=(p_shard, o_shard, b_shard),
            donate=(0, 1),
            tokens=shape.global_batch * shape.seq, kind="train")

    if shape.kind == "prefill":
        def fn(params, batch):
            return registry.prefill(params, batch, cfg,
                                    _max_len(cfg, shape))
        return LoweredSpec(
            fn=wrap_with_context(fn, mesh), args=(params, batch_specs),
            in_shardings=(p_shard, b_shard), donate=(),
            tokens=shape.global_batch * shape.seq, kind="prefill")

    # decode
    long = shape.name == "long_500k"
    rules = _seq_rule(cfg, mesh, long)
    if param_rules:
        rules.update({k: v for k, v in param_rules.items()
                      if k not in ("kv_seq", "kv_heads")})
    cache_specs = _abstract_cache(cfg, shape)
    cache_shard = tree_shardings(cache_specs, _cache_axes(cfg, shape),
                                 mesh, rules)

    def fn(params, batch, cache):
        return registry.decode_step(params, batch, cache, cfg)

    return LoweredSpec(
        fn=wrap_with_context(fn, mesh, rules),
        args=(params, batch_specs, cache_specs),
        in_shardings=(p_shard, b_shard, cache_shard), donate=(2,),
        tokens=shape.global_batch, kind="decode")


def input_specs(arch: str, shape_name: str = "train_4k"):
    """Public API (per the dry-run spec): ShapeDtypeStruct stand-ins for
    every model input of an (arch × shape) cell — weak-type-correct,
    shardable, no device allocation.

    For training that's {tokens, labels} (+frontend embeddings for
    vlm/audio); for decode it also includes the KV-cache/recurrent-state
    tree."""
    from ..configs import get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs, _ = _batch_specs(cfg, shape)
    if shape.kind == "decode":
        specs = dict(specs)
        specs["cache"] = _abstract_cache(cfg, shape)
    return specs
