"""Roofline report (deliverable g): read dry-run records, derive the
three terms, pick hillclimb candidates, emit the EXPERIMENTS.md table.

    PYTHONPATH=src python -m repro.launch.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from ..core.roofline import RooflineTerms, format_table

DRY = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "dryrun")


def load_records(mesh: str = "16x16", tag: str = "base") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh and r.get("tag", "base") == tag:
            out.append(r)
    return out


def to_terms(r: dict) -> RooflineTerms:
    return RooflineTerms(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"], chips=r["chips"],
        hlo_flops=r.get("flops", 0.0),
        hlo_bytes=r.get("bytes_accessed", 0.0),
        collective_bytes=r.get("collective_bytes", 0.0),
        model_flops=r.get("model_flops", 0.0),
        tokens=r.get("tokens", 0))


def rows_for(mesh: str, tag: str = "base"):
    rows, skips, errors = [], [], []
    for r in load_records(mesh, tag):
        if r["status"] == "ok":
            rows.append(to_terms(r))
        elif r["status"] == "skipped":
            skips.append((r["arch"], r["shape"], r.get("reason", "")))
        else:
            errors.append((r["arch"], r["shape"],
                           r.get("error", "")[:120]))
    return rows, skips, errors


def pick_hillclimb(rows: list[RooflineTerms]) -> dict[str, RooflineTerms]:
    """Worst roofline fraction (train cells), most collective-bound, and
    the most paper-representative (the biggest DSE-relevant GEMM stack =
    largest-model train cell)."""
    train = [r for r in rows if r.shape == "train_4k"]
    worst_mfu = min(train, key=lambda r: r.mfu) if train else None
    coll = max(rows, key=lambda r: (r.collective_s /
                                    max(r.step_s, 1e-12)))
    rep = max(train, key=lambda r: r.model_flops) if train else None
    return {"worst_mfu": worst_mfu, "most_collective": coll,
            "representative": rep}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="base")
    args = ap.parse_args(argv)
    rows, skips, errors = rows_for(args.mesh, args.tag)
    rows.sort(key=lambda r: (r.arch, r.shape))
    print(format_table(rows))
    print(f"\nskipped cells ({len(skips)}):")
    for a, s, why in skips:
        print(f"  {a:24s} {s:12s} {why}")
    if errors:
        print(f"\nERROR cells ({len(errors)}):")
        for a, s, e in errors:
            print(f"  {a:24s} {s:12s} {e}")
    hc = pick_hillclimb(rows)
    print("\nhillclimb candidates:")
    for k, r in hc.items():
        if r:
            print(f"  {k:16s} {r.arch} {r.shape} "
                  f"(bottleneck={r.bottleneck}, MFU={r.mfu:.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
