# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must only be imported by the dry-run entry point itself.
from .mesh import make_host_mesh, make_production_mesh, mesh_name

__all__ = ["make_host_mesh", "make_production_mesh", "mesh_name"]
