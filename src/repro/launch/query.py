"""The declarative query CLI — one front door for every search engine.

Single queries come from flags; batches come from ``--file queries.json``
(a JSON list of query dicts) and are answered through
``Session.run_many`` — heterogeneous single-layer queries that share an
(op-class, level-count) family coalesce into one padded device pass.

Examples::

    # best-EDP mapping for one layer at the Fig. 10 reference design
    PYTHONPATH=src python -m repro.launch.query --model vgg16 --layer 12

    # whole-network schedule search (the netsearch path)
    PYTHONPATH=src python -m repro.launch.query --model vgg16

    # joint mapping x hardware co-DSE over the default grid
    PYTHONPATH=src python -m repro.launch.query --model vgg16 --layer 12 \
        --co-dse

    # serving-style batch: mixed layer/network/grid queries, coalesced
    PYTHONPATH=src python -m repro.launch.query --file queries.json \
        --out reports.json

``repro.launch.mapsearch`` and ``repro.launch.netsearch`` are kept as
thin shims over this backend.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import logging
import os
import sys
from typing import Any, Sequence

from repro import obs
from repro.api import (Hardware, Query, Report, SearchSpec, Session,
                       Workload, queries_from_file)
from repro.core import dnn_models as zoo
from repro.resilience import ReproError, ResilienceConfig

DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache",
                             "repro-mapspace")
DEFAULT_JAX_CACHE = os.path.join(DEFAULT_CACHE, "xla")

# THE launch-CLI logger: every diagnostic/progress line across the query
# CLI and its shims routes through here (results still print to stdout);
# ``-v``/``-q`` pick the level in :func:`obs_scope`.
LOG = logging.getLogger("repro.launch")


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def _write_json(path: str, payload: Any) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    LOG.info("wrote %s", path)


def configure_logging(args) -> None:
    """One logging config for every launch CLI: ``-v`` -> DEBUG,
    default INFO, ``-q`` -> WARNING (diagnostics go to stderr; result
    tables stay on stdout)."""
    level = logging.INFO
    if getattr(args, "quiet", 0):
        level = logging.WARNING
    if getattr(args, "verbose", 0):
        level = logging.DEBUG
    logging.basicConfig(level=level, stream=sys.stderr,
                        format="# %(message)s")
    logging.getLogger("repro").setLevel(level)


@contextlib.contextmanager
def cli_errors():
    """CLI-facing slice of the resilience error taxonomy: a
    :class:`ReproError` escaping a launch entry point prints as ONE line
    on stderr and exits 2 — never a multi-screen XLA traceback."""
    try:
        yield
    except ReproError as e:
        print(f"error: {e.one_line()}", file=sys.stderr)
        raise SystemExit(2) from e


@contextlib.contextmanager
def obs_scope(args):
    """Observability bracket around one CLI run (shared by the query CLI
    and the mapsearch/netsearch shims): configures logging, turns on the
    span tracer for ``--trace``, wraps the run in ``jax.profiler`` for
    ``--profile-dir``, and on exit writes the trace file and prints the
    metrics snapshot for ``--metrics``."""
    configure_logging(args)
    if getattr(args, "trace", None):
        obs.enable_tracing()
    try:
        if getattr(args, "profile_dir", None):
            with obs.profile_to(args.profile_dir):
                yield
        else:
            yield
    except (ReproError, SystemExit, KeyboardInterrupt):
        raise                          # expected exits: no postmortem
    except BaseException:
        # unhandled crash: dump the flight-recorder ring next to the
        # user before the traceback (best effort, never masks it)
        flight_dir = getattr(args, "flight_dir", None)
        if flight_dir:
            try:
                path = obs.dump_flight(flight_dir, "cli-crash")
                LOG.warning("wrote flight recorder dump %s", path)
            except Exception:  # noqa: BLE001 — crash path
                pass
        raise
    finally:
        if getattr(args, "trace", None):
            obs.save_trace(args.trace)
            LOG.info("wrote trace %s", args.trace)
        if getattr(args, "metrics", False):
            print(json.dumps(obs.metrics().snapshot(), indent=2))


# ----------------------------------------------------------------------
# Report printers (shared by this CLI and the mapsearch/netsearch shims)
# ----------------------------------------------------------------------

def print_layer_report(rep: Report) -> None:
    r = rep.raw
    tag = ""
    if r is not None and getattr(r, "cached", False):
        tag = " (cached)"
    via = "coalesced family pass" if rep.coalesced else \
        f"strategy={rep.strategy}"
    print(f"# {rep.name}: {via}{tag} evaluated={rep.n_evaluated} "
          f"compiles={rep.n_compiles} ({rep.compile_s:.1f}s) "
          f"devices={rep.n_devices}")
    if rep.rates.get("end_to_end_mappings_per_s"):
        print(f"# rate={rep.rates['mappings_per_s'] / 1e6:.2f}M "
              f"mappings/s "
              f"e2e={rep.rates['end_to_end_mappings_per_s'] / 1e6:.2f}M "
              f"mappings/s")
    print(f"best {rep.objective} = {_fmt(rep.best['value'])}  "
          f"gene={'-'.join(str(g) for g in rep.best['point'])}")
    if r is not None and hasattr(r, "best_dataflow"):
        print(r.best_dataflow)
    s = rep.best["stats"]
    print(f"runtime={_fmt(s['runtime'])}cy "
          f"energy={_fmt(s['energy_pj'])}pJ "
          f"l1={_fmt(s['l1_kb'])}KB l2={_fmt(s['l2_kb'])}KB")


def print_network_report(rep: Report) -> None:
    b = rep.best
    print(f"# {rep.name}: {rep.extras['n_layers']} layers "
          f"({rep.extras['n_unique']} unique shapes, "
          f"{rep.extras['n_classes']} op-classes) "
          f"strategy={rep.strategy} composer={rep.extras['composer']} "
          f"budget_policy={rep.extras['budget_policy']}")
    print(f"# evaluated={rep.n_evaluated} mappings, "
          f"compiles={rep.n_compiles} ({rep.compile_s:.1f}s), "
          f"eval={rep.eval_s:.2f}s, wall={rep.elapsed_s:.1f}s, "
          f"devices={rep.n_devices}")
    seg_of = {}
    for si, (a, bnd) in enumerate(b["segments"]):
        for i in range(a, bnd + 1):
            seg_of[i] = si
    print(f"\n{'layer':28s} {'seg':>4s} {'runtime':>12s} "
          f"{'energy':>12s} {'l2KB':>8s}  mapping")
    for i, pl in enumerate(b["per_layer"]):
        gene = "-".join(str(g) for g in pl["gene"])
        print(f"{pl['layer']:28s} {seg_of[i]:>4d} "
              f"{_fmt(pl['runtime']):>12s} "
              f"{_fmt(pl['energy_pj']):>12s} "
              f"{pl['l2_kb']:>8.1f}  {gene}")
    print(f"\n# schedule: {len(b['segments'])} fused stacks, "
          f"{b['n_reconfigs']} reconfigurations")
    print(f"# totals: runtime={_fmt(b['runtime'])}cy "
          f"energy={_fmt(b['energy_pj'])}pJ EDP={_fmt(b['edp'])} "
          f"throughput={b['throughput']:.2f} MACs/cy")


def _print_pareto(rep: Report, limit: int = 12) -> None:
    print(f"# frontier ({len(rep.pareto)} points, energy vs throughput):")
    for p in rep.pareto[:limit]:
        extra = f" {p['mapping']:24s}" if "mapping" in p else ""
        print(f"  pes={p['num_pes']:4d} bw={p['noc_bw']:5.1f} "
              f"energy={_fmt(p['energy_pj'])} "
              f"thr={_fmt(p['throughput'])}{extra}")
    for obj, p in rep.best["per_objective"].items():
        if p:
            print(f"  best {obj:10s}: pes={p['num_pes']} "
                  f"bw={p['noc_bw']}")


def print_layer_codse_report(rep: Report) -> None:
    print(f"# {rep.name}: co-DSE, {rep.n_evaluated} designs in "
          f"{rep.elapsed_s:.1f}s, compiles={rep.n_compiles}")
    if "joint" in rep.extras:
        j = rep.extras["joint"]
        print(f"# joint sweep: {j['n_designs']} designs "
              f"({j['n_valid']} valid) at "
              f"{j['designs_per_s'] / 1e6:.2f}M designs/s")
    _print_pareto(rep)


def print_network_codse_report(rep: Report) -> None:
    print(f"# {rep.name}: network co-DSE over "
          f"{rep.extras['n_hw']} hw points, {rep.n_evaluated} designs "
          f"in {rep.elapsed_s:.1f}s; {rep.extras['n_valid']} valid, "
          f"compiles={rep.n_compiles}")
    _print_pareto(rep)


def print_error_report(rep: Report) -> None:
    e = rep.extras["error"]
    print(f"# {rep.name or '(query)'}: FAILED — "
          f"{e['type']}: {e['message']}")


def print_timeout_report(rep: Report) -> None:
    t = rep.extras["timeout"]
    budget = "server default" if t["deadline_s"] is None else \
        f"{t['deadline_s']}s"
    print(f"# {rep.name or '(query)'}: TIMEOUT — deadline {budget} "
          f"expired after {t['waited_s']}s ({t['where']}); "
          f"partial answer only")


PRINTERS = {
    "layer": print_layer_report,
    "layer_codse": print_layer_codse_report,
    "network": print_network_report,
    "network_codse": print_network_codse_report,
    "error": print_error_report,
    "timeout": print_timeout_report,
}


def print_report(rep: Report) -> None:
    PRINTERS[rep.kind](rep)


def print_layer_table(reps: Sequence[Report], objective: str) -> None:
    """Per-layer best-mapping table (``mapsearch --layer all``)."""
    print(f"{'layer':28s} {'eval':>6s} {'best ' + objective:>14s}  "
          f"mapping")
    for rep in reps:
        gene = "-".join(str(g) for g in rep.best["point"])
        print(f"{rep.name:28s} {rep.n_evaluated:>6d} "
              f"{_fmt(rep.best['value']):>14s}  {gene}")


def print_batch_summary(session: Session) -> None:
    b = session.last_batch
    if not b:
        return
    print(f"\n# batch: {b['n_queries']} queries "
          f"({b['n_coalesced']} coalesced into {b['n_families']} "
          f"family passes), compiles={b['n_compiles']}"
          f"/{b['compile_budget']} budget ({b['compile_s']:.1f}s), "
          f"wall={b['elapsed_s']:.1f}s, devices={b['n_devices']}")


# ----------------------------------------------------------------------
# Query construction from flags
# ----------------------------------------------------------------------

def session_from_args(args) -> Session:
    res = None
    ckpt_dir = getattr(args, "checkpoint_dir", None)
    faults = getattr(args, "faults", None)
    if ckpt_dir or faults:
        res = ResilienceConfig(ckpt_dir=ckpt_dir or None,
                               faults=faults or None)
    return Session(cache_dir=(args.cache_dir or None),
                   jax_cache_dir=(args.jax_cache_dir or None),
                   devices=args.devices, resilience=res)


def hardware_from_args(args) -> Hardware:
    kw: dict[str, Any] = dict(num_pes=args.pes, noc_bw=args.bw)
    for name in ("reconfig_latency", "dram_bw", "dram_energy_pj"):
        if getattr(args, name, None) is not None:
            kw[name] = getattr(args, name)
    if getattr(args, "co_dse", False):
        if args.quick:
            kw["pe_range"] = (64, 128, 256)
            kw["bw_range"] = (8.0, 16.0, 32.0)
        else:
            kw["pe_range"] = tuple(range(32, 513, 32))
            kw["bw_range"] = tuple(float(b) for b in range(4, 65, 4))
    return Hardware(**kw)


def searchspec_from_args(args, *, dims=None, cluster=True) -> SearchSpec:
    budget = args.budget
    frontier_k = getattr(args, "frontier_k", 8)
    if args.quick:
        budget = min(budget, 128)
        frontier_k = min(frontier_k, 4)
    return SearchSpec(
        objective=args.objective, budget=budget,
        strategy=args.strategy, seed=args.seed, top_k=args.top_k,
        frontier_k=frontier_k,
        fuse=not getattr(args, "no_fuse", False),
        reconfig=not getattr(args, "no_reconfig", False),
        composer=getattr(args, "composer", "auto"),
        l2_budget_kb=getattr(args, "l2_budget_kb", None),
        budget_policy=getattr(args, "budget_policy", "adaptive"),
        cluster=cluster, dims=dims,
        l1_prune_kb=getattr(args, "l1_budget_kb", None),
        l2_prune_kb=getattr(args, "l2_prune_kb", None),
        population=getattr(args, "population", None),
        block=args.block,
        pipeline=getattr(args, "pipeline", "gene"),
        codse_top_k=min(args.top_k, 4),
        joint_genes=getattr(args, "joint_genes", 0))


def add_common_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--objective", default="edp",
                    choices=["edp", "energy", "runtime", "throughput"])
    ap.add_argument("--budget", type=int, default=512,
                    help="evaluated mappings (per unique layer shape for "
                         "network queries)")
    ap.add_argument("--pes", type=int, default=256)
    ap.add_argument("--bw", type=float, default=32.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--block", type=int, default=1024)
    ap.add_argument("--devices", type=int, default=None,
                    help="local devices to stripe evaluation over "
                         "(default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny budgets (smoke test)")
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE,
                    help="on-disk result cache ('' disables)")
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE,
                    help="persistent XLA compilation cache "
                         "('' disables)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="sweep checkpoint directory: a killed run "
                         "re-launched with the same flags resumes "
                         "bit-identically from the last chunk")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. "
                         "'kill@chunk:3' (see repro.resilience."
                         "faultinject; also via REPRO_FAULTS)")
    add_obs_args(ap)


def add_obs_args(ap: argparse.ArgumentParser) -> None:
    """The shared observability flags (also used by the mapsearch/
    netsearch shims): logging verbosity, span tracing, metrics snapshot,
    jax profiler."""
    ap.add_argument("-v", "--verbose", action="count", default=0,
                    help="debug logging")
    ap.add_argument("-q", "--quiet", action="count", default=0,
                    help="warnings only")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record a Chrome/Perfetto trace_event timeline "
                         "of the run (open in ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the obs metrics snapshot (JSON) at exit")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="wrap the run in jax.profiler (TensorBoard/"
                         "Perfetto device-level dump)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="flight-recorder dump directory: an unhandled "
                         "crash writes flight-<ts>.json (recent spans/"
                         "events/errors) there before the traceback")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--file", default=None,
                    help="JSON batch of queries (list of query dicts or "
                         "{'queries': [...]}); answered via "
                         "Session.run_many with family coalescing")
    ap.add_argument("--out", default=None,
                    help="write reports (+ batch stats) as JSON")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="batch mode: run each query separately through "
                         "the same family spaces (determinism oracle)")
    ap.add_argument("--model", default=None, choices=sorted(zoo.MODELS))
    ap.add_argument("--layer", default=None,
                    help="layer selector (index/substring/'all'/comma "
                         "list); omit for a whole-network query")
    ap.add_argument("--list-layers", action="store_true")
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "exhaustive", "random", "greedy",
                             "genetic"])
    ap.add_argument("--frontier-k", type=int, default=8)
    ap.add_argument("--budget-policy", default="adaptive",
                    choices=["adaptive", "uniform"],
                    help="network queries: adaptive refines the top "
                         "network-cost contributors")
    ap.add_argument("--composer", default="auto",
                    choices=["auto", "dp", "genetic"])
    ap.add_argument("--no-fuse", action="store_true")
    ap.add_argument("--no-reconfig", action="store_true")
    ap.add_argument("--l2-budget-kb", type=float, default=None)
    ap.add_argument("--co-dse", action="store_true",
                    help="sweep the hardware grid (joint co-DSE)")
    ap.add_argument("--joint-genes", type=int, default=0)
    add_common_args(ap)
    args = ap.parse_args(argv)

    with cli_errors(), obs_scope(args):
        session = session_from_args(args)

        if args.file:
            # the SAME execution path the server's flush worker uses
            # (serve.coalescer.execute_batch): --file batches are the
            # offline oracle the coalesced server must answer bit-equal
            # to
            from repro.serve import execute_batch
            queries = queries_from_file(args.file)
            reports = execute_batch(session, queries,
                                    coalesce=not args.no_coalesce)
            for i, rep in enumerate(reports):
                tag = f" [{rep.tag}]" if rep.tag else ""
                print(f"\n=== query {i}{tag}: {rep.kind} {rep.name} ===")
                print_report(rep)
            print_batch_summary(session)
            if args.out:
                payload = {"reports": [r.to_json() for r in reports],
                           "batch": session.last_batch,
                           "metrics": session.metrics(),
                           "environment": obs.environment()}
                _write_json(args.out, payload)
            return

        if not args.model:
            ap.error("give --model (single query) or --file (batch)")
        layers = zoo.MODELS[args.model]()
        if args.list_layers:
            for i, l in enumerate(layers):
                print(f"{i:3d} {l.op_type:10s} {l.name} {l.dims}")
            return

        from repro.api import select_layers
        hw = hardware_from_args(args)
        spec = searchspec_from_args(args)
        if args.layer is None:
            rep = session.run(Query(Workload.of_network(args.model), hw,
                                    spec))
            print_report(rep)
            out_payload: Any = rep.to_json()
        elif len(select_layers(layers, args.layer)) == 1:
            rep = session.run(Query(
                Workload(model=args.model, layer=args.layer), hw, spec))
            print_report(rep)
            out_payload = rep.to_json()
        else:
            if args.co_dse:
                LOG.warning("--co-dse applies to single-layer selections "
                            "only; running the per-layer batch instead")
                hw = Hardware(num_pes=args.pes, noc_bw=args.bw)
            qs = [Query(Workload.of_layer(op), hw, spec)
                  for op in select_layers(layers, args.layer)]
            reps = session.run_many(qs)
            print_layer_table(reps, args.objective)
            print_batch_summary(session)
            out_payload = {"reports": [r.to_json() for r in reps],
                           "batch": session.last_batch,
                           "metrics": session.metrics()}
        if args.out:
            _write_json(args.out, out_payload)


if __name__ == "__main__":
    main()
