"""CLI for per-layer mapping search — a thin shim over the declarative
query backend (``repro.launch.query`` / ``repro.api``), kept for
compatibility.  Prefer ``python -m repro.launch.query``.

Examples::

    # best EDP mapping for VGG16 conv1_2 at the Fig. 10 reference design
    PYTHONPATH=src python -m repro.launch.mapsearch --model vgg16 --layer 1

    # joint mapping x hardware co-DSE
    PYTHONPATH=src python -m repro.launch.mapsearch --model resnet50 \
        --layer conv2 --objective edp --co-dse --budget 1500

    # list a model's layers
    PYTHONPATH=src python -m repro.launch.mapsearch --model vgg16 \
        --list-layers
"""
from __future__ import annotations

import argparse

from repro.api import Hardware, Query, SearchSpec, Workload, select_layers
from repro.core import dnn_models as zoo
from repro.core.dataflows import TABLE3, table3_for_layer
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.launch.query import (DEFAULT_CACHE, DEFAULT_JAX_CACHE, LOG,
                                _fmt, add_obs_args, cli_errors,
                                obs_scope, print_batch_summary,
                                print_layer_report,
                                print_layer_codse_report,
                                session_from_args)


def _table3_values(op, args) -> tuple[float, dict[str, float]]:
    """(best value, per-flow value) of the Table 3 baselines at the CLI's
    hardware point and objective."""
    hw = HWConfig(num_pes=args.pes, noc_bw=args.bw, noc_latency=2.0)
    per_flow: dict[str, float] = {}
    best = None
    for f in TABLE3:
        st = analyze(op, table3_for_layer(f, op), hw)
        vals = {"edp": float(st.edp), "energy": float(st.energy_pj),
                "runtime": float(st.runtime),
                "throughput": float(st.throughput)}
        v = vals[args.objective]
        per_flow[f] = v
        if best is None or \
                (v > best if args.objective == "throughput" else v < best):
            best = v
    return best, per_flow


def _spec_from_args(args, op) -> SearchSpec:
    if args.quick:
        dims = tuple(args.dims.split(",")) if args.dims else \
            (("K", "C") if "K" in op.dims else None)
        cluster = False
        budget = min(args.budget, 200)
    else:
        dims = tuple(args.dims.split(",")) if args.dims else None
        cluster = not args.no_cluster
        budget = args.budget
    return SearchSpec(
        objective=args.objective, budget=budget, strategy=args.strategy,
        seed=args.seed, top_k=args.top_k, population=args.population,
        cluster=cluster, dims=dims, l1_prune_kb=args.l1_budget_kb,
        l2_prune_kb=args.l2_budget_kb, block=1024,
        pipeline=args.pipeline,
        codse_top_k=min(args.top_k, 4), joint_genes=args.joint_genes)


def _multi_layer(picked, session, args) -> None:
    """Per-layer best-mapping table for --layer all / comma lists — now
    answered as ONE coalesced ``run_many`` batch (shared family
    executables) instead of N independent searches."""
    hw = Hardware(num_pes=args.pes, noc_bw=args.bw)
    qs = [Query(Workload.of_layer(op), hw, _spec_from_args(args, op))
          for op in picked]
    reps = session.run_many(qs)
    print(f"# {len(picked)} layers, objective={args.objective}, "
          f"budget={qs[0].search.budget}/layer")
    print(f"{'layer':28s} {'eval':>6s} "
          f"{'best ' + args.objective:>12s} {'bestT3':>12s} "
          f"{'vs T3':>6s}  mapping")
    for op, r in zip(picked, reps):
        t3, _ = _table3_values(op, args)
        imp = (r.best["value"] / t3 if args.objective == "throughput"
               else t3 / r.best["value"])
        gene = "-".join(str(g) for g in r.best["point"])
        print(f"{op.name:28s} {r.n_evaluated:>6d} "
              f"{_fmt(r.best['value']):>12s} {_fmt(t3):>12s} "
              f"{imp:>5.2f}x  {gene}")
    print_batch_summary(session)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16",
                    choices=sorted(zoo.MODELS))
    ap.add_argument("--layer", default="0",
                    help="layer index, name substring, 'all', or a "
                         "comma-separated list (multi-selection prints a "
                         "per-layer best-mapping table; default: 0)")
    ap.add_argument("--list-layers", action="store_true")
    ap.add_argument("--objective", default="edp",
                    choices=["edp", "energy", "runtime", "throughput"])
    ap.add_argument("--budget", type=int, default=1000,
                    help="max mappings to evaluate")
    ap.add_argument("--pes", type=int, default=256)
    ap.add_argument("--bw", type=float, default=32.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "exhaustive", "random", "greedy",
                             "genetic"])
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--population", type=int, default=None,
                    help="genetic strategy population per generation")
    ap.add_argument("--dims", default=None,
                    help="comma-separated searched dims (default: auto)")
    ap.add_argument("--no-cluster", action="store_true",
                    help="exclude two-level (Cluster) mappings")
    ap.add_argument("--l1-budget-kb", type=float, default=None,
                    help="prune tile sets over this L1 budget")
    ap.add_argument("--l2-budget-kb", type=float, default=None,
                    help="prune tile sets over this L2 budget")
    ap.add_argument("--quick", action="store_true",
                    help="tiny space + budget (smoke test)")
    ap.add_argument("--pipeline", default="gene",
                    choices=["gene", "legacy"],
                    help="gene: device-resident vectorized pipeline "
                         "(default); legacy: tuple-point parity oracle "
                         "(never coalesced)")
    ap.add_argument("--devices", type=int, default=None,
                    help="local devices to stripe evaluation chunks over "
                         "(default: all; CPU multi-device needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--co-dse", action="store_true",
                    help="cross top-k mappings with the hardware DSE grid")
    ap.add_argument("--joint-genes", type=int, default=0,
                    help="with --co-dse: also run the paper-scale joint "
                         "sweep through the fused device pipeline")
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE,
                    help="on-disk result cache ('' disables)")
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE,
                    help="persistent XLA compilation cache ('' disables)")
    add_obs_args(ap)
    args = ap.parse_args(argv)

    with cli_errors(), obs_scope(args):
        session = session_from_args(args)
        layers = zoo.MODELS[args.model]()
        if args.list_layers:
            for i, l in enumerate(layers):
                print(f"{i:3d} {l.op_type:10s} {l.name} {l.dims}")
            return
        try:
            picked = select_layers(layers, args.layer)
        except ValueError as e:
            raise SystemExit(f"{e}; try --list-layers")
        if len(picked) > 1:
            if args.co_dse:
                LOG.warning("--co-dse applies to single-layer selections "
                            "only; running the per-layer table instead "
                            "(pick one layer for the co-DSE)")
            _multi_layer(picked, session, args)
            return
        op = picked[0]
        print(f"# layer {op.name} {op.op_type} {op.dims}")

        spec = _spec_from_args(args, op)
        hw = Hardware(num_pes=args.pes, noc_bw=args.bw)
        rep = session.run(Query(Workload.of_layer(op), hw, spec))
        print_layer_report(rep)

        # Table 3 baselines at the same hardware point
        print("\n# Table 3 baselines (same hardware):")
        best_t3, per_flow = _table3_values(op, args)
        for f, v in per_flow.items():
            print(f"  {f:5s} {args.objective}={_fmt(v)}")
        best_val = rep.best["value"]
        if args.objective == "throughput":
            imp = best_val / best_t3
        else:
            imp = best_t3 / best_val
        print(f"# best-found vs best-Table-3: {imp:.2f}x")

        if args.co_dse:
            grid = Hardware(
                num_pes=args.pes, noc_bw=args.bw,
                pe_range=tuple(range(32, 513, 32)),
                bw_range=tuple(float(b) for b in range(4, 65, 4)))
            co = session.run(Query(Workload.of_layer(op), grid, spec))
            print()
            print_layer_codse_report(co)


if __name__ == "__main__":
    main()
