"""CLI for the mapping-space search engine (``repro.mapspace``).

Examples::

    # best EDP mapping for VGG16 conv1_2 at the Fig. 10 reference design
    PYTHONPATH=src python -m repro.launch.mapsearch --model vgg16 --layer 1

    # joint mapping x hardware co-DSE with Table 3 baselines on the frontier
    PYTHONPATH=src python -m repro.launch.mapsearch --model resnet50 \
        --layer conv2 --objective edp --co-dse --budget 1500

    # list a model's layers
    PYTHONPATH=src python -m repro.launch.mapsearch --model vgg16 \
        --list-layers
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.core import dnn_models as zoo
from repro.core.dataflows import TABLE3, table3_for_layer
from repro.core.dse import DSEConfig
from repro.core.model import analyze
from repro.core.performance import HWConfig
from repro.mapspace import (build_space, co_search,
                            enable_compilation_cache, search)

DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache",
                             "repro-mapspace")
DEFAULT_JAX_CACHE = os.path.join(DEFAULT_CACHE, "xla")


def _pick_layers(layers, which: str):
    """Resolve ``--layer``: an index, a name substring, ``all``, or a
    comma-separated list of those (multi-match substrings select every
    match) — one entry per selected layer, model order, deduplicated."""
    if which == "all":
        return list(layers)
    out = []
    for part in which.split(","):
        part = part.strip()
        if not part:
            continue
        if part.isdigit():
            out.append(layers[int(part)])
            continue
        matches = [l for l in layers if part in l.name]
        if not matches:
            raise SystemExit(f"no layer matching {part!r}; "
                             f"try --list-layers")
        out.extend(matches)
    seen: set[str] = set()
    uniq = [l for l in out
            if not (l.name in seen or seen.add(l.name))]
    if not uniq:
        raise SystemExit(f"no layer matching {which!r}; try --list-layers")
    order = [l.name for l in layers]
    return sorted(uniq, key=lambda l: order.index(l.name))


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def _search_one(op, args, budget=None):
    if args.quick:
        dims = tuple(args.dims.split(",")) if args.dims else \
            (("K", "C") if "K" in op.dims else None)
        space = build_space(op, dims=dims, cluster=False)
        budget = min(budget or args.budget, 200)
    else:
        dims = tuple(args.dims.split(",")) if args.dims else None
        space = build_space(op, dims=dims, cluster=not args.no_cluster)
        budget = budget or args.budget
    r = search(op, objective=args.objective, budget=budget, space=space,
               num_pes=args.pes, noc_bw=args.bw, strategy=args.strategy,
               seed=args.seed, top_k=args.top_k,
               population=args.population,
               l1_budget_kb=args.l1_budget_kb,
               l2_budget_kb=args.l2_budget_kb,
               pipeline=args.pipeline, devices=args.devices,
               cache_dir=args.cache_dir or None)
    return space, budget, r


def _table3_values(op, args) -> tuple[float, dict[str, float]]:
    """(best value, per-flow value) of the Table 3 baselines at the CLI's
    hardware point and objective."""
    hw = HWConfig(num_pes=args.pes, noc_bw=args.bw, noc_latency=2.0)
    per_flow: dict[str, float] = {}
    best = None
    for f in TABLE3:
        st = analyze(op, table3_for_layer(f, op), hw)
        vals = {"edp": float(st.edp), "energy": float(st.energy_pj),
                "runtime": float(st.runtime),
                "throughput": float(st.throughput)}
        v = vals[args.objective]
        per_flow[f] = v
        if best is None or \
                (v > best if args.objective == "throughput" else v < best):
            best = v
    return best, per_flow


def _multi_layer(picked, args) -> None:
    """Per-layer best-mapping table for --layer all / comma lists."""
    print(f"# {len(picked)} layers, objective={args.objective}, "
          f"budget={args.budget}/layer")
    print(f"{'layer':28s} {'space':>10s} {'eval':>6s} "
          f"{'best ' + args.objective:>12s} {'bestT3':>12s} "
          f"{'vs T3':>6s}  mapping")
    for op in picked:
        space, budget, r = _search_one(op, args)
        t3, _ = _table3_values(op, args)
        imp = (r.best_value / t3 if args.objective == "throughput"
               else t3 / r.best_value)
        gene = "-".join(str(g) for g in r.best_point)
        print(f"{op.name:28s} {space.size:>10d} {r.n_evaluated:>6d} "
              f"{_fmt(r.best_value):>12s} {_fmt(t3):>12s} "
              f"{imp:>5.2f}x  {gene}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16",
                    choices=sorted(zoo.MODELS))
    ap.add_argument("--layer", default="0",
                    help="layer index, name substring, 'all', or a "
                         "comma-separated list (multi-selection prints a "
                         "per-layer best-mapping table; default: 0)")
    ap.add_argument("--list-layers", action="store_true")
    ap.add_argument("--objective", default="edp",
                    choices=["edp", "energy", "runtime", "throughput"])
    ap.add_argument("--budget", type=int, default=1000,
                    help="max mappings to evaluate")
    ap.add_argument("--pes", type=int, default=256)
    ap.add_argument("--bw", type=float, default=32.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "exhaustive", "random", "greedy",
                             "genetic"])
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--population", type=int, default=None,
                    help="genetic strategy population per generation")
    ap.add_argument("--dims", default=None,
                    help="comma-separated searched dims (default: auto)")
    ap.add_argument("--no-cluster", action="store_true",
                    help="exclude two-level (Cluster) mappings")
    ap.add_argument("--l1-budget-kb", type=float, default=None,
                    help="prune tile sets over this L1 budget")
    ap.add_argument("--l2-budget-kb", type=float, default=None,
                    help="prune tile sets over this L2 budget")
    ap.add_argument("--quick", action="store_true",
                    help="tiny space + budget (smoke test)")
    ap.add_argument("--pipeline", default="gene",
                    choices=["gene", "legacy"],
                    help="gene: device-resident vectorized pipeline "
                         "(default); legacy: tuple-point parity oracle")
    ap.add_argument("--devices", type=int, default=None,
                    help="local devices to stripe evaluation chunks over "
                         "(default: all; CPU multi-device needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--co-dse", action="store_true",
                    help="cross top-k mappings with the hardware DSE grid")
    ap.add_argument("--joint-genes", type=int, default=0,
                    help="with --co-dse: also run the paper-scale joint "
                         "sweep — this many sampled mappings x the FULL "
                         "hardware grid through the fused device pipeline")
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE,
                    help="on-disk result cache ('' disables)")
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE,
                    help="persistent XLA compilation cache: the universal "
                         "evaluator's one compile also amortizes across "
                         "processes ('' disables)")
    args = ap.parse_args(argv)

    if args.jax_cache_dir:
        if not enable_compilation_cache(args.jax_cache_dir):
            print(f"# warning: could not enable XLA compilation cache at "
                  f"{args.jax_cache_dir!r}; compiles will not persist "
                  f"across processes", file=sys.stderr)

    layers = zoo.MODELS[args.model]()
    if args.list_layers:
        for i, l in enumerate(layers):
            print(f"{i:3d} {l.op_type:10s} {l.name} {l.dims}")
        return
    picked = _pick_layers(layers, args.layer)
    if len(picked) > 1:
        if args.co_dse:
            print("# note: --co-dse applies to single-layer selections "
                  "only; running the per-layer table instead "
                  "(pick one layer for the co-DSE)", file=sys.stderr)
        _multi_layer(picked, args)
        return
    op = picked[0]
    print(f"# layer {op.name} {op.op_type} {op.dims}")

    space, budget, r = _search_one(op, args)
    print(f"# space: {space.size} mappings in {space.n_groups} "
          f"structure groups")
    tag = " (cached)" if r.cached else ""
    print(f"# pipeline={r.pipeline} devices={r.n_devices} "
          f"strategy={r.strategy}{tag} evaluated={r.n_evaluated} "
          f"groups={r.n_groups} encode={r.encode_s:.2f}s "
          f"eval={r.eval_s:.2f}s compiles={r.n_compiles} "
          f"({r.compile_s:.1f}s) "
          f"rate={r.mappings_per_s / 1e6:.2f}M mappings/s "
          f"e2e={r.end_to_end_mappings_per_s / 1e6:.2f}M mappings/s")
    print(f"\nbest {args.objective} = {_fmt(r.best_value)}")
    print(r.best_dataflow)
    s = r.best_stats
    print(f"runtime={_fmt(s['runtime'])}cy energy={_fmt(s['energy_pj'])}pJ "
          f"util={s['util']:.2f} l1={_fmt(s['l1_kb'])}KB "
          f"l2={_fmt(s['l2_kb'])}KB")

    # Table 3 baselines at the same hardware point
    print("\n# Table 3 baselines (same hardware):")
    best_t3, per_flow = _table3_values(op, args)
    for f, v in per_flow.items():
        print(f"  {f:5s} {args.objective}={_fmt(v)}")
    if args.objective == "throughput":
        imp = r.best_value / best_t3
    else:
        imp = best_t3 / r.best_value
    print(f"# best-found vs best-Table-3: {imp:.2f}x")

    if args.co_dse:
        cfg = DSEConfig(pe_range=tuple(range(32, 513, 32)),
                        bw_range=tuple(float(b) for b in range(4, 65, 4)))
        co = co_search(op, objective=args.objective,
                       mapping_budget=budget, top_k=min(args.top_k, 4),
                       cfg=cfg, num_pes=args.pes, noc_bw=args.bw,
                       seed=args.seed, space=space,
                       include_table3=list(TABLE3),
                       joint_genes=args.joint_genes,
                       cache_dir=args.cache_dir or None)
        if co.joint is not None:
            j = co.joint
            print(f"\n# joint sweep: {j.n_designs} designs "
                  f"({j.n_mappings} mappings x {j.n_hw} hw points) in "
                  f"{j.elapsed_s:.1f}s = "
                  f"{j.designs_per_s / 1e6:.2f}M designs/s on "
                  f"{j.n_devices} device(s); {j.n_valid} valid, "
                  f"{len(j.pareto)} frontier points")
        print(f"\n# co-DSE: {co.n_evaluated} designs in "
              f"{co.elapsed_s:.1f}s; merged Pareto frontier "
              f"({len(co.pareto)} points, energy vs throughput):")
        for p in co.pareto[:12]:
            print(f"  {p['mapping']:28s} pes={p['num_pes']:4d} "
                  f"bw={p['noc_bw']:5.1f} energy={_fmt(p['energy_pj'])} "
                  f"thr={_fmt(p['throughput'])}")
        for obj, p in co.best.items():
            if p:
                print(f"  best {obj:10s}: {p['mapping']} "
                      f"pes={p['num_pes']} bw={p['noc_bw']}")


if __name__ == "__main__":
    main()
