"""§Perf-B helper: compare dry-run variants of a cell.

    PYTHONPATH=src python -m repro.launch.perf_compare \
        --arch dbrx-132b --shape decode_32k --tags base tp
"""
from __future__ import annotations

import argparse
import json
import os

from ..core.roofline import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS

DRY = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "dryrun")


def load(arch: str, shape: str, mesh: str, tag: str) -> dict | None:
    p = os.path.join(DRY, f"{arch}__{shape}__{mesh}__{tag}.json")
    return json.load(open(p)) if os.path.exists(p) else None


def terms(r: dict) -> dict:
    c = r.get("flops", 0.0) / V5E_PEAK_FLOPS
    m = r.get("bytes_accessed", 0.0) / V5E_HBM_BW
    m2 = r.get("bytes_hbm_est", 0.0) / V5E_HBM_BW
    x = r.get("collective_bytes", 0.0) / V5E_ICI_BW
    step = max(c, m, x)
    step2 = max(c, m2, x)
    return {"compute_s": c, "memory_s": m, "memory_buf_s": m2,
            "collective_s": x, "step_s": step, "step_buf_s": step2,
            "temp_gb": r.get("temp_size_bytes", 0) / 1e9,
            "fits": r.get("fits_16gb"),
            "mfu": (r.get("model_flops", 0)
                    / max(step2 * r["chips"] * V5E_PEAK_FLOPS, 1e-12))}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tags", nargs="+", default=["base"])
    args = ap.parse_args(argv)
    hdr = (f"{'tag':12s} {'compute_s':>10s} {'mem(cost)':>10s} "
           f"{'mem(buf)':>10s} {'coll_s':>10s} {'step(buf)':>10s} "
           f"{'tempGB':>7s} {'fits':>5s} {'MFU':>6s}")
    print(f"{args.arch} {args.shape} {args.mesh}")
    print(hdr)
    base = None
    for tag in args.tags:
        r = load(args.arch, args.shape, args.mesh, tag)
        if r is None or r.get("status") != "ok":
            print(f"{tag:12s}  -- missing/not-ok --")
            continue
        t = terms(r)
        if base is None:
            base = t
        speedup = base["step_buf_s"] / max(t["step_buf_s"], 1e-12)
        print(f"{tag:12s} {t['compute_s']:10.3e} {t['memory_s']:10.3e} "
              f"{t['memory_buf_s']:10.3e} {t['collective_s']:10.3e} "
              f"{t['step_buf_s']:10.3e} {t['temp_gb']:7.1f} "
              f"{str(t['fits']):>5s} {t['mfu']:6.3f}"
              + (f"   (x{speedup:.2f} vs base)" if tag != args.tags[0]
                 else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
