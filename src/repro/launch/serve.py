"""The DSE-as-a-service server CLI — ``Session`` behind HTTP/JSON.

Wire format: ``POST /query`` takes ONE query dict in the
``examples/queries.json`` schema and answers ``Report.to_json()``;
``GET /healthz`` / ``/readyz`` / ``/metricsz`` serve liveness,
readiness, and the structured metrics snapshot.  SIGTERM drains
gracefully: admission stops, the unanswered queue is persisted, and
in-flight families flush over sweep checkpoints so a killed drain
resumes bit-identically on restart.

Examples::

    # serve on an ephemeral port with checkpointed drains
    PYTHONPATH=src python -m repro.launch.serve --port 8732 \
        --checkpoint-dir /tmp/serve-ckpt

    # chaos drill: die mid-drain, then restart to recover
    PYTHONPATH=src python -m repro.launch.serve --port 8732 \
        --checkpoint-dir /tmp/serve-ckpt --faults kill@serve-drain
"""
from __future__ import annotations

import argparse
import asyncio

from repro.serve import DSEServer, ServeConfig

from .query import (DEFAULT_CACHE, DEFAULT_JAX_CACHE, LOG, add_obs_args,
                    cli_errors, obs_scope, session_from_args)


def add_serve_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (printed at startup)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admitted-but-unanswered bound; beyond it "
                         "requests shed with 429 + Retry-After")
    ap.add_argument("--max-cost", type=float, default=1e6,
                    help="estimated-cost shed gate (0 disables)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="flush when this many requests are buffered")
    ap.add_argument("--flush-interval", type=float, default=0.05,
                    metavar="S",
                    help="... or when the oldest waited this long")
    ap.add_argument("--deadline", type=float, default=30.0, metavar="S",
                    help="default per-request budget for queries that "
                         "carry no search.deadline_s (0 = unbounded)")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="flush each request separately (oracle mode)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE)
    ap.add_argument("--jax-cache-dir", default=DEFAULT_JAX_CACHE)
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="drain persistence + sweep checkpoints: a "
                         "killed drain resumes bit-identically here")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="deterministic fault injection (serve sites: "
                         "slow@serve-flush, crash@serve-worker, "
                         "kill@serve-drain)")
    add_obs_args(ap)
    # --flight-dir comes from add_obs_args; the server also falls back
    # to --checkpoint-dir, then $REPRO_FLIGHT_DIR / tmp


def config_from_args(args) -> ServeConfig:
    return ServeConfig(
        host=args.host, port=args.port,
        max_queue=args.max_queue,
        max_cost=args.max_cost if args.max_cost > 0 else None,
        max_batch=args.max_batch,
        flush_interval_s=args.flush_interval,
        default_deadline_s=args.deadline if args.deadline > 0 else None,
        coalesce=not args.no_coalesce,
        flight_dir=getattr(args, "flight_dir", None))


async def _serve(args) -> None:
    session = session_from_args(args)
    server = DSEServer(session, config_from_args(args))
    await server.start()
    server.install_signal_handlers()
    LOG.warning("ready on http://%s:%d (POST /query; SIGTERM drains)",
                args.host, server.port)
    await server.wait_stopped()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_serve_args(ap)
    args = ap.parse_args(argv)
    with cli_errors(), obs_scope(args):
        asyncio.run(_serve(args))


if __name__ == "__main__":
    main()
