"""Training launcher: real steps on the host mesh (CPU smoke / small runs)
or lower-only against the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 20 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import REGISTRY, get_config
from ..checkpoint import Checkpointer
from ..data import batch_for_step
from ..ft import FaultTolerantLoop, FTConfig
from ..models import registry
from ..models.param import init_params
from ..optim import adamw
from ..training import TrainConfig, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default="olmo-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps))
    params = init_params(registry.specs(cfg), jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), manifest = ckpt.restore((params, opt_state))
        start = manifest["step"]
        print(f"resumed from step {start}")

    def batch_fn(step):
        b = batch_for_step(step, global_batch=args.batch, seq=args.seq,
                           vocab=cfg.vocab)
        if cfg.frontend == "vision":
            b["frontend"] = np.zeros(
                (args.batch, cfg.frontend_len, cfg.frontend_dim),
                np.float32)
        if cfg.is_encdec:
            b["frontend"] = np.random.default_rng(step).normal(
                size=(args.batch, args.seq, cfg.frontend_dim)
            ).astype(np.float32)
        return b

    def wrapped(state, batch):
        p, o = state
        p, o, m = step_fn(p, o, batch)
        return (p, o), m

    loop = FaultTolerantLoop(
        wrapped, ckpt, FTConfig(checkpoint_every=args.ckpt_every))
    t0 = time.time()
    (params, opt_state), step = loop.run((params, opt_state), batch_fn,
                                         start, args.steps)
    dt = time.time() - t0
    # final report
    b = batch_fn(step)
    loss = registry.loss_fn(params, {k: jax.numpy.asarray(v)
                                     for k, v in b.items()}, cfg)
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} it/s), final loss {float(loss):.4f}, "
          f"stragglers={loop.straggler_steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
