"""LLM-inference serving launcher: prefill a batch of requests,
then batched decode.

    PYTHONPATH=src python -m repro.launch.llmserve --arch olmo-1b \
        --requests 4 --prompt-len 64 --gen 32 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REGISTRY, get_config
from ..models import registry
from ..models.param import init_params


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default="olmo-1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(registry.specs(cfg), jax.random.PRNGKey(0))
    B, P = args.requests, args.prompt_len
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.zeros((B, cfg.frontend_len,
                                       cfg.frontend_dim), jnp.float32)
    if cfg.is_encdec:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.frontend_dim)), jnp.float32)

    max_len = P + args.gen
    t0 = time.time()
    logits, cache = registry.prefill(params, batch, cfg, max_len)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, b, c: registry.decode_step(p, b, c, cfg))
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"prefill {B}x{P} in {t_prefill:.2f}s; "
          f"decoded {args.gen - 1} steps in {t_dec:.2f}s "
          f"({B * (args.gen - 1) / max(t_dec, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0, :16]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
