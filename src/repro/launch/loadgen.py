"""Load-generator CLI for the DSE serving tier.

Drives N concurrent clients against a running ``repro.launch.serve``
instance with the queries from a ``queries.json`` batch file, then
prints the terminal-status accounting and latency summary (and writes
it as JSON with ``--out``).  The acceptance bar it measures: every
request gets a terminal status — a report (including ``timeout`` /
``error`` kinds), a 429/503 shed, or a 400 reject — zero hangs, zero
unexplained drops.

Example::

    PYTHONPATH=src python -m repro.launch.loadgen --port 8732 \
        --file examples/queries.json --clients 10 --requests 4
"""
from __future__ import annotations

import argparse
import asyncio
import json

from repro.serve import http_json, http_text, run_loadgen

from .query import _write_json, cli_errors, configure_logging


def _load_queries(path: str) -> list[dict]:
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        payload = payload.get("queries", [])
    return list(payload)


async def _run(args) -> dict:
    queries = _load_queries(args.file)
    result = await run_loadgen(
        args.host, args.port, queries, clients=args.clients,
        requests_per_client=args.requests, timeout=args.timeout)
    summary = result.summary()
    if args.metricsz:
        _, snap = await http_json(args.host, args.port, "GET",
                                  "/metricsz")
        summary["server_metrics"] = snap
    if args.prometheus:
        _, text = await http_text(args.host, args.port, "GET",
                                  "/metricsz?format=prometheus")
        summary["server_prometheus"] = text
    if args.save_reports:
        # full report bodies (with extras.timing + request ids) — what
        # the CI observability smoke reconciles against the histograms
        summary["reports"] = [{"query_index": qi, "report": body}
                              for qi, body in result.reports]
    return summary


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--file", default="examples/queries.json",
                    help="queries.json batch to draw requests from")
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per client (round-robin over the "
                         "file's queries)")
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--metricsz", action="store_true",
                    help="append the server's /metricsz snapshot")
    ap.add_argument("--prometheus", action="store_true",
                    help="append the server's Prometheus text "
                         "exposition (/metricsz?format=prometheus)")
    ap.add_argument("--save-reports", action="store_true",
                    help="embed every 200 report body in the summary "
                         "(per-request timing breakdowns)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    ap.add_argument("-v", "--verbose", action="count", default=0)
    ap.add_argument("-q", "--quiet", action="count", default=0)
    args = ap.parse_args(argv)
    configure_logging(args)
    with cli_errors():
        summary = asyncio.run(_run(args))
        # keep stdout readable: the bulky payloads only go to --out
        printed = {k: v for k, v in summary.items()
                   if k not in ("reports", "server_prometheus")}
        print(json.dumps(printed, indent=2))
        if args.out:
            _write_json(args.out, summary)


if __name__ == "__main__":
    main()
