"""``python -m repro.launch.lint`` — run the static analyzers.

The zero-findings CI gate: exit 0 only when every finding is covered by
a checked-in waiver (``src/repro/analysis/waivers.toml``) AND every
waiver still matches something (an unused waiver means the code was
fixed — delete the waiver).

Examples::

    # everything: repo lint + dataflow corpus + jaxpr audit
    PYTHONPATH=src python -m repro.launch.lint

    # the cheap jax-free pass (pre-commit speed)
    PYTHONPATH=src python -m repro.launch.lint --no-jaxpr

    # machine-readable findings (Report.bench schema, flows through
    # scripts/bench_check.py like any BENCH_* artifact)
    PYTHONPATH=src python -m repro.launch.lint --json --out lint.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

from repro.analysis import (apply_waivers, load_waivers, run_repo_lint,
                            sort_findings)

from .query import LOG, _write_json, cli_errors, configure_logging


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="static analysis: concurrency lint, dataflow-spec "
                    "lint, jaxpr audit of the universal executables")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr audit (no jax import; the "
                         "cheap pre-commit pass)")
    ap.add_argument("--devices", type=int, nargs="*", default=None,
                    help="device counts to audit the pmap executables "
                         "at (default: 1 and jax.local_device_count() "
                         "when more)")
    ap.add_argument("--waivers", default=None, metavar="FILE",
                    help="waiver file (default: the checked-in "
                         "analysis/waivers.toml)")
    ap.add_argument("--json", action="store_true",
                    help="print the findings report as JSON "
                         "(Report.bench schema) instead of lines")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the JSON report to FILE")
    ap.add_argument("-v", "--verbose", action="count", default=0)
    ap.add_argument("-q", "--quiet", action="count", default=0)
    return ap


def _device_counts(args) -> tuple[int, ...]:
    if args.devices:
        return tuple(dict.fromkeys(int(d) for d in args.devices))
    import jax
    nd = jax.local_device_count()
    return (1,) if nd <= 1 else (1, nd)


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    configure_logging(args)
    with cli_errors():
        report: dict = {}
        if args.no_jaxpr:
            findings = run_repo_lint()
        else:
            from repro.analysis import run_full
            findings, report = run_full(_device_counts(args))
        waivers = load_waivers(args.waivers)
        unwaived, waived, unused = apply_waivers(findings, waivers)
        unwaived = sort_findings(unwaived)

        payload = {
            "n_findings": len(findings),
            "n_unwaived": len(unwaived),
            "n_waived": len(waived),
            "unused_waivers": [f"{w.code} @ {w.site}" for w in unused],
            "findings": [f.to_json() for f in unwaived],
            "waived": [f.to_json() for f in waived],
            "jaxpr": report,
        }
        if args.json or args.out:
            from repro.api import Report
            doc = Report.bench("lint", payload).to_json()
            if args.json:
                print(json.dumps(doc, indent=2))
            if args.out:
                _write_json(args.out, doc)
        if not args.json:
            for f in unwaived:
                print(f.one_line())
            LOG.info("lint: %d finding(s), %d unwaived, %d waived, "
                     "%d unused waiver(s)", len(findings), len(unwaived),
                     len(waived), len(unused))
        for w in unused:
            print(f"unused waiver: {w.code} @ {w.site} — the finding "
                  f"is gone, delete the waiver", file=sys.stderr)
        return 1 if unwaived or unused else 0


if __name__ == "__main__":
    raise SystemExit(main())
