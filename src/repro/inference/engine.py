"""Batched serving engine: fixed-slot continuous batching.

A decode batch of ``slots`` sequences advances in lockstep; finished or
empty slots are refilled from the request queue by re-prefilling just
that slot (cache surgery via dynamic updates).  This is the standard
fixed-batch TPU serving pattern (vLLM-style paged KV is a GPU-pointer
idiom — on TPU, dense per-slot caches + slot recycling is the native
adaptation; see DESIGN.md §2 hardware-adaptation notes).

Greedy decoding; EOS or max-tokens terminates a slot.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import registry


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 32
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.cache = None
        self._tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, b, c: registry.decode_step(p, b, c, cfg))

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        uid = len(self.queue) + sum(r is not None for r in self.active)
        self.queue.append(Request(uid=uid, prompt=np.asarray(
            prompt, np.int32), max_new=max_new))
        return uid

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """(Re)build the whole batch cache including this slot.

        Single-host simplification: slot refill re-prefills the batch of
        active prompts+generations; a production pod would do per-slot
        cache insertion (dynamic_update_slice on the batch dim) to avoid
        recomputing neighbors — the cache layout (batch-major) already
        supports it."""
        self.active[slot] = req
        prompts = []
        for r in self.active:
            if r is None:
                prompts.append(np.zeros(1, np.int32))
            else:
                prompts.append(np.concatenate(
                    [r.prompt, np.asarray(r.generated, np.int32)]))
        width = max(len(p) for p in prompts)
        batch = np.zeros((self.slots, width), np.int32)
        for i, p in enumerate(prompts):
            batch[i, width - len(p):] = p      # left-pad
        logits, self.cache = registry.prefill(
            self.params, {"tokens": jnp.asarray(batch)}, self.cfg,
            self.max_len)
        self._tokens = jnp.argmax(logits[:, -1], -1)[:, None].astype(
            jnp.int32)

    # ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """Refill empty slots, decode one token for the batch; returns
        newly finished requests."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self._prefill_slot(i, self.queue.popleft())
        if self.cache is None:
            return []
        logits, self.cache = self._decode(
            self.params, {"tokens": self._tokens}, self.cache)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        self._tokens = nxt[:, None]
        toks = np.asarray(nxt)
        finished = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.generated.append(int(toks[i]))
            if len(r.generated) >= r.max_new or \
                    (self.eos_id is not None and toks[i] == self.eos_id):
                r.done = True
                finished.append(r)
                self.active[i] = None
        return finished

    def run(self, max_steps: int = 1000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                break
        return out
