"""``repro.api`` — the declarative front door to the dataflow cost
model and every search engine behind it.

One query surface replaces the four historical entry points
(``mapspace.search``/``co_search``, ``netspace.search_network``/
``co_search_network`` — all still available as thin parity-tested
wrappers over this path):

    from repro.api import Query, Workload, Hardware, SearchSpec, Session

    s = Session(jax_cache_dir="~/.cache/repro/xla")

    # one layer, fixed hardware
    q = Query(Workload.of_layer(op), Hardware(num_pes=256, noc_bw=32.0),
              SearchSpec(objective="edp", budget=1000))
    report = s.run(q)
    print(report.best["value"], report.to_json())

    # a whole network; grid hardware turns a query into a co-DSE
    s.run(Query(Workload.of_network("vgg16")))

    # the headline: heterogeneous queries coalesced into one padded
    # device pass per (op-class, level-count) family
    reports = s.run_many([q1, q2, q3, q4, q5, q6])

See ``repro.launch.query`` for the CLI (single queries and
``--file queries.json`` batch mode).
"""
from .report import Report
from .session import (PendingReport, Session, default_session, run,
                      run_many)
from .spec import (OP_BUILDERS, SCHEMA_VERSION, Hardware, Query,
                   SearchSpec, Workload, op_from_json, queries_from_file,
                   select_layers)

__all__ = [
    "Hardware", "OP_BUILDERS", "PendingReport", "Query", "Report",
    "SCHEMA_VERSION", "SearchSpec", "Session", "Workload",
    "default_session", "op_from_json", "queries_from_file", "run",
    "run_many", "select_layers",
]
