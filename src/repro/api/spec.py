"""Declarative query specs: ``Workload`` x ``Hardware`` x ``SearchSpec``
composed into a :class:`Query`.

A query is pure data — no engine state, no device handles — so it can be
hashed (cache keys), serialized (``--file queries.json`` batch mode,
served traffic) and routed (:meth:`repro.api.Session.run` picks the
engine from the query's shape):

  * ``Workload`` — ONE layer, an explicit layer list, or a named zoo
    network;
  * ``Hardware`` — a fixed accelerator point, or a (PEs x NoC-bw) grid
    with area/power budgets (which turns the query into a co-DSE);
  * ``SearchSpec`` — objective / budget / strategy / fusion / co-DSE
    knobs, including the adaptive per-layer budget policy.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Sequence

from ..core import dnn_models as zoo
from ..core import tensor_analysis as ta
from ..core.dse import DSEConfig
from ..core.performance import HWConfig
from ..core.tensor_analysis import LayerOp
# One source of truth for the engine/schema version: bumping it
# invalidates disk-cached results (it is baked into every
# ``mapspace.cache.search_key`` AND every query fingerprint).
from ..mapspace.cache import ENGINE_SCHEMA_VERSION as SCHEMA_VERSION
from ..resilience.errors import SpecError

# Valid enum fields, restated as literals so constructing a Query never
# imports the jax-heavy engine modules (mapspace.search asserts it
# agrees — see test_resilience).
VALID_OBJECTIVES = ("edp", "energy", "runtime", "throughput")
VALID_STRATEGIES = ("auto", "exhaustive", "random", "greedy", "genetic")
VALID_PIPELINES = ("gene", "legacy")
VALID_BUDGET_POLICIES = ("adaptive", "uniform")


def _check_enum(value: str, valid: Sequence[str], field: str) -> None:
    if value not in valid:
        raise SpecError(f"{field} must be one of {sorted(valid)}, "
                        f"got {value!r}", field=field)


def _check_min(value, lo, field: str) -> None:
    if value is not None and not value >= lo:
        raise SpecError(f"{field} must be >= {lo}, got {value!r}",
                        field=field)


def _check_range(rng: Sequence | None, lo, field: str) -> None:
    if rng is None:
        return
    if len(rng) == 0:
        raise SpecError(f"{field} must be non-empty", field=field)
    bad = [v for v in rng if not v >= lo]
    if bad:
        raise SpecError(f"{field} entries must be >= {lo}, got {bad}",
                        field=field)

# LayerOp constructors reachable from query JSON ({"type": ..., ...}).
OP_BUILDERS = {
    "conv2d": ta.conv2d,
    "dwconv2d": ta.dwconv2d,
    "pool2d": ta.pool2d,
    "fc": ta.fc,
    "gemm": ta.gemm,
    "pointwise_conv": ta.pointwise_conv,
    "conv1d": ta.conv1d,
    "lstm_cell": ta.lstm_cell,
    "attention_score": ta.attention_score,
}


def op_from_json(d: dict[str, Any]) -> LayerOp:
    """Build a :class:`LayerOp` from a query-JSON op dict:
    ``{"type": "conv2d", "name": ..., "k": ..., ...}``."""
    d = dict(d)
    kind = d.pop("type", None)
    if kind not in OP_BUILDERS:
        raise SpecError(f"unknown op type {kind!r}; "
                        f"one of {sorted(OP_BUILDERS)}", field="type")
    d.setdefault("name", kind)
    name = d.pop("name")
    try:
        return OP_BUILDERS[kind](name, **d)
    except TypeError as e:
        raise SpecError(f"bad {kind!r} op fields: {e}", field=kind) from e


def _op_descriptor(op: LayerOp) -> dict[str, Any]:
    """Identifying (not necessarily reconstructing) JSON for a LayerOp."""
    return {"name": op.name, "op_type": op.op_type, "dims": dict(op.dims)}


def select_layers(layers: Sequence[LayerOp], which: str
                  ) -> list[LayerOp]:
    """Resolve a layer selector: an index, a name substring, ``all``, or
    a comma-separated list of those — model order, deduplicated.  (The
    historical ``mapsearch --layer`` semantics, now shared by every
    front end.)"""
    layers = list(layers)
    if which == "all":
        return layers
    out: list[LayerOp] = []
    for part in str(which).split(","):
        part = part.strip()
        if not part:
            continue
        if part.lstrip("-").isdigit():
            out.append(layers[int(part)])
            continue
        matches = [l for l in layers if part in l.name]
        if not matches:
            raise ValueError(f"no layer matching {part!r}")
        out.extend(matches)
    seen: set[str] = set()
    uniq = [l for l in out if not (l.name in seen or seen.add(l.name))]
    if not uniq:
        raise ValueError(f"no layer matching {which!r}")
    order = [l.name for l in layers]
    return sorted(uniq, key=lambda l: order.index(l.name))


@dataclasses.dataclass(frozen=True)
class Workload:
    """What to search a schedule/mapping for.

    Three shapes, normalized by :meth:`resolve`:

      * ``Workload.layer(op)`` / ``Workload(model=..., layer=...)`` —
        ONE layer (a mapping search);
      * ``Workload.layers([...])`` — an explicit layer list (a network
        schedule search);
      * ``Workload.network("vgg16")`` — a named zoo network.
    """
    model: str | None = None          # zoo model name
    layer: str | None = None          # selector within model (layer query)
    ops: tuple[LayerOp, ...] = ()     # explicit layers

    @staticmethod
    def of_layer(op: LayerOp) -> "Workload":
        return Workload(ops=(op,))

    @staticmethod
    def of_layers(ops: Sequence[LayerOp]) -> "Workload":
        return Workload(ops=tuple(ops))

    @staticmethod
    def of_network(model: str) -> "Workload":
        return Workload(model=model)

    def __post_init__(self) -> None:
        if self.ops and self.model:
            raise SpecError("Workload: give ops OR model, not both",
                            field="model")
        if not self.ops and not self.model:
            raise SpecError("Workload: needs ops or a model name",
                            field="ops")
        if self.layer is not None and not self.model:
            raise SpecError("Workload: layer selector needs a model",
                            field="layer")
        if self.model is not None and self.model not in zoo.MODELS:
            raise SpecError(f"unknown model {self.model!r}; "
                            f"one of {sorted(zoo.MODELS)}", field="model")

    def resolve(self) -> list[LayerOp]:
        if self.ops:
            return list(self.ops)
        layers = zoo.MODELS[self.model]()
        if self.layer is None:
            return layers
        return select_layers(layers, self.layer)

    @property
    def kind(self) -> str:
        """``"layer"`` (single-layer mapping query) or ``"network"``."""
        if self.ops:
            return "layer" if len(self.ops) == 1 else "network"
        if self.layer is None:
            return "network"
        return "layer" if len(self.resolve()) == 1 else "network"

    def describe(self) -> dict[str, Any]:
        if self.model:
            d: dict[str, Any] = {"model": self.model}
            if self.layer is not None:
                d["layer"] = self.layer
            return d
        if len(self.ops) == 1:
            return {"op": _op_descriptor(self.ops[0])}
        return {"layers": [_op_descriptor(o) for o in self.ops]}

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Workload":
        if "op" in d:
            return Workload.of_layer(op_from_json(d["op"]))
        if "layers" in d:
            return Workload.of_layers([op_from_json(o)
                                       for o in d["layers"]])
        if "model" in d:
            layer = d.get("layer")
            return Workload(model=d["model"],
                            layer=None if layer is None else str(layer))
        raise ValueError(f"workload needs 'op', 'layers' or 'model': {d}")


@dataclasses.dataclass(frozen=True)
class Hardware:
    """A fixed accelerator point — or, when ``pe_range``/``bw_range`` are
    set, a hardware grid (the query becomes a joint mapping x hardware
    co-DSE under the area/power budgets)."""
    num_pes: int = 256
    noc_bw: float = 32.0
    noc_latency: float = 2.0
    # network-schedule cost-model fields (repro.netspace)
    dram_bw: float = 16.0
    dram_energy_pj: float = 100.0
    reconfig_latency: float = 0.0
    # grid axes -> co-DSE
    pe_range: tuple[int, ...] | None = None
    bw_range: tuple[float, ...] | None = None
    area_budget_mm2: float | None = None
    power_budget_mw: float | None = None

    def __post_init__(self) -> None:
        _check_min(self.num_pes, 1, "num_pes")
        for f in ("noc_bw", "dram_bw"):
            if not getattr(self, f) > 0:
                raise SpecError(f"{f} must be > 0, "
                                f"got {getattr(self, f)!r}", field=f)
        for f in ("noc_latency", "dram_energy_pj", "reconfig_latency"):
            _check_min(getattr(self, f), 0, f)
        _check_range(self.pe_range, 1, "pe_range")
        _check_range(self.bw_range, 1e-9, "bw_range")
        _check_min(self.area_budget_mm2, 1e-9, "area_budget_mm2")
        _check_min(self.power_budget_mw, 1e-9, "power_budget_mw")

    @property
    def is_grid(self) -> bool:
        return self.pe_range is not None or self.bw_range is not None

    def hwconfig(self) -> HWConfig:
        return HWConfig(num_pes=self.num_pes, noc_bw=self.noc_bw,
                        noc_latency=self.noc_latency,
                        dram_bw=self.dram_bw,
                        dram_energy_pj=self.dram_energy_pj,
                        reconfig_latency=self.reconfig_latency)

    def dse_config(self) -> DSEConfig:
        base = DSEConfig()
        kw: dict[str, Any] = {}
        if self.pe_range is not None:
            kw["pe_range"] = tuple(int(p) for p in self.pe_range)
        if self.bw_range is not None:
            kw["bw_range"] = tuple(float(b) for b in self.bw_range)
        if self.area_budget_mm2 is not None:
            kw["area_budget_mm2"] = float(self.area_budget_mm2)
        if self.power_budget_mw is not None:
            kw["power_budget_mw"] = float(self.power_budget_mw)
        return dataclasses.replace(base, **kw)

    def describe(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Hardware":
        d = dict(d)
        for k in ("pe_range", "bw_range"):
            if d.get(k) is not None:
                d[k] = tuple(d[k])
        known = {f.name for f in dataclasses.fields(Hardware)}
        bad = set(d) - known
        if bad:
            raise SpecError(f"unknown Hardware fields: {sorted(bad)}",
                            field=sorted(bad)[0])
        return Hardware(**d)


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """How to search: objective, budget, strategy and the engine knobs.

    ``budget_policy`` applies to network queries: ``"adaptive"`` (the
    new-API default) spends a cheap uniform first pass, then refines the
    layers that dominate network cost; ``"uniform"`` is the legacy
    equal-budget behaviour.  ``joint_genes``/``codse_top_k`` only matter
    for grid-hardware (co-DSE) queries."""
    objective: str = "edp"
    budget: int = 512
    strategy: str = "auto"
    seed: int = 0
    top_k: int = 8
    # network-schedule knobs
    frontier_k: int = 8
    fuse: bool = True
    reconfig: bool = True
    composer: str = "auto"
    l2_budget_kb: float | None = None
    budget_policy: str = "adaptive"     # adaptive | uniform
    # space/pruning knobs
    cluster: bool = True
    dims: tuple[str, ...] | None = None  # explicit searched dims (layer
    #                                      queries; None = auto)
    l1_prune_kb: float | None = None
    l2_prune_kb: float | None = None
    # engine knobs
    population: int | None = None
    block: int = 1024
    pipeline: str = "gene"              # gene | legacy (layer queries;
    #                                     legacy = tuple-point oracle)
    multicast: bool = True
    spatial_reduction: bool = True
    # co-DSE knobs
    codse_top_k: int = 4
    joint_genes: int = 0
    # serving knobs: wall-clock budget for the whole query.  Enforced
    # cooperatively at chunk boundaries (an XLA dispatch cannot be
    # preempted); an expired query surfaces a timeout Report, never a
    # hang.  None (the default) keeps offline queries unbounded and —
    # because describe() drops None fields — existing fingerprints
    # unchanged.
    deadline_s: float | None = None

    def __post_init__(self) -> None:
        _check_enum(self.objective, VALID_OBJECTIVES, "objective")
        _check_enum(self.strategy, VALID_STRATEGIES, "strategy")
        _check_enum(self.pipeline, VALID_PIPELINES, "pipeline")
        _check_enum(self.budget_policy, VALID_BUDGET_POLICIES,
                    "budget_policy")
        for f in ("budget", "top_k", "frontier_k", "block",
                  "codse_top_k"):
            _check_min(getattr(self, f), 1, f)
        _check_min(self.population, 1, "population")
        _check_min(self.joint_genes, 0, "joint_genes")
        _check_min(self.l1_prune_kb, 1e-9, "l1_prune_kb")
        _check_min(self.l2_prune_kb, 1e-9, "l2_prune_kb")
        _check_min(self.l2_budget_kb, 1e-9, "l2_budget_kb")
        _check_min(self.deadline_s, 1e-9, "deadline_s")

    def describe(self) -> dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    @staticmethod
    def from_json(d: dict[str, Any]) -> "SearchSpec":
        d = dict(d)
        if d.get("dims") is not None:
            d["dims"] = tuple(d["dims"])
        known = {f.name for f in dataclasses.fields(SearchSpec)}
        bad = set(d) - known
        if bad:
            raise SpecError(f"unknown SearchSpec fields: {sorted(bad)}",
                            field=sorted(bad)[0])
        return SearchSpec(**d)


@dataclasses.dataclass(frozen=True)
class Query:
    """One declarative request: workload x hardware x search spec."""
    workload: Workload
    hardware: Hardware = Hardware()
    search: SearchSpec = SearchSpec()
    tag: str | None = None            # caller-visible label (batch files)

    @property
    def kind(self) -> str:
        """Engine route: ``layer`` / ``layer_codse`` / ``network`` /
        ``network_codse``."""
        base = self.workload.kind
        return f"{base}_codse" if self.hardware.is_grid else base

    def describe(self) -> dict[str, Any]:
        d: dict[str, Any] = {
            "workload": self.workload.describe(),
            "hardware": self.hardware.describe(),
            "search": self.search.describe(),
        }
        if self.tag is not None:
            d["tag"] = self.tag
        return d

    def estimated_cost(self) -> float:
        """Admission-control cost estimate: roughly the number of
        candidate evaluations the query can trigger.  A fixed hardware
        point scores ``budget x n_layers``; a co-DSE grid multiplies by
        the hardware-grid size (plus the joint-gene sweep) — exactly the
        "grid bomb" shape overload shedding needs to price *before* any
        engine work runs.  Never raises: an unresolvable workload prices
        as a single layer."""
        try:
            n_layers = len(self.workload.resolve())
        except Exception:  # noqa: BLE001 — sizing only, run() will raise
            n_layers = 1
        n_hw = 1
        if self.hardware.is_grid:
            cfg = self.hardware.dse_config()
            n_hw = len(cfg.pe_range) * len(cfg.bw_range)
        cost = float(self.search.budget) * n_layers * n_hw
        if self.hardware.is_grid and self.search.joint_genes:
            cost += float(self.search.joint_genes) * n_hw
        return cost

    def lint(self) -> None:
        """Static legality lint (``repro.analysis.speclint``): searched
        dims, space constructibility, and the analytic buffer-budget
        feasibility bound — raises a one-line :class:`SpecError` with
        the structured findings attached when the query cannot possibly
        produce a result, all before any compile.  The serving tier
        calls this pre-admission so an illegal query is a 400, not a
        burned flush slot."""
        from ..analysis.speclint import check_query
        check_query(self)

    def fingerprint(self) -> str:
        """Stable content hash of the FULL query plus the engine/schema
        version — the disk-cache key component that keeps stale
        prior-schema results from being replayed."""
        txt = json.dumps({"schema": SCHEMA_VERSION, **self.describe()},
                         sort_keys=True, default=str)
        return hashlib.sha256(txt.encode()).hexdigest()[:24]

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Query":
        if "workload" in d:
            wl = Workload.from_json(d["workload"])
        else:                          # flat form: workload keys top-level
            wl = Workload.from_json(d)
        return Query(
            workload=wl,
            hardware=Hardware.from_json(d.get("hardware", {})),
            search=SearchSpec.from_json(d.get("search", {})),
            tag=d.get("tag"))


def queries_from_file(path: str) -> list[Query]:
    """Load a ``queries.json`` batch: a JSON list of query dicts (or
    ``{"queries": [...]}``)."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        payload = payload.get("queries", [])
    return [Query.from_json(d) for d in payload]
