"""The long-lived query engine behind the declarative front door.

A :class:`Session` owns everything that should outlive one query:

  * the on-disk RESULT cache (``cache_dir`` — ``mapspace.cache``,
    keyed by the full query fingerprint + engine schema version);
  * the persistent XLA COMPILATION cache (``jax_cache_dir``);
  * the in-process family registry: built network spaces and WARM
    universal executables keyed by (op-class, level-count), so repeated
    and concurrent queries never recompile what any earlier query
    already compiled.

``Session.run(query)`` routes one query to the right engine.  The
headline is ``Session.run_many(queries)`` / ``submit()``+``flush()``:
heterogeneous single-layer queries that share an (op-class, level-count)
family are COALESCED into one padded gene-tensor device pass through the
shape-as-operand executables (``netspace``'s ``ext_operand`` machinery)
— N users' layer queries cost the compiles of their unique families, not
N searches.  Hardware points ride as per-row operands, so queries at
different fixed designs still share one executable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import time
from typing import Any, Sequence

import numpy as np

from .. import obs
from ..core import dnn_models as zoo
from ..core.tensor_analysis import LayerOp
from ..resilience import (BudgetExceeded, DeviceError, ReproError,
                          ResilienceConfig, SpecError, SweepCheckpoint,
                          SweepKilled, cancel_scope, classify)
from .report import Report
from .spec import Hardware, Query, SearchSpec, Workload

LOG = logging.getLogger("repro.resilience")

# Objective value from the composer columns (canonical minimize);
# throughput needs the layer's MAC count.
_COL_RUNTIME, _COL_ENERGY = 0, 1


def _objective_from_cols(cols: np.ndarray, objective: str,
                         macs: float) -> np.ndarray:
    r = cols[:, _COL_RUNTIME]
    e = cols[:, _COL_ENERGY]
    if objective == "edp":
        return e * r
    if objective == "energy":
        return e
    if objective == "runtime":
        return r
    if objective == "throughput":
        return -(macs / np.maximum(r, 1e-12))
    raise ValueError(f"unknown objective {objective!r}")


def _stats_from_col(col: np.ndarray, macs: float) -> dict[str, float]:
    r, e = float(col[0]), float(col[1])
    return {"runtime": r, "energy_pj": e, "l1_kb": float(col[2]),
            "l2_kb": float(col[3]), "edp": e * r,
            "throughput": macs / max(r, 1e-12)}


def _deadline_t(query: Query) -> float | None:
    """The query's ``deadline_s`` budget as an absolute monotonic
    deadline for :func:`~repro.resilience.cancel_scope` (None = no
    budget)."""
    dl = query.search.deadline_s
    return None if dl is None else time.monotonic() + dl


def _batch_deadline_t(queries: Sequence[Query]) -> float | None:
    """A coalesced flush shares ONE device pass, so its cancel scope is
    bounded by the most patient member: the max of the members' budgets
    (members with no budget don't cap the flush — their work continues
    past their neighbours' deadlines)."""
    dls = [q.search.deadline_s for q in queries]
    if any(d is None for d in dls) or not dls:
        return None
    return time.monotonic() + max(dls)


class FamilyBest:
    """Decodable handle a coalesced report carries in ``Report.raw``:
    the winning gene row lives in the SHARED family space (padded tile
    axes, class-level cluster plan), which differs from the space
    ``build_space(op)`` would give the same layer — so the report ships
    the space alongside the point."""

    def __init__(self, op: LayerOp, space, point: tuple):
        self.op = op
        self.space = space
        self.point = point

    @property
    def best_dataflow(self):
        from ..mapspace.space import point_dataflow
        return point_dataflow(self.space, self.point)


class PendingReport:
    """Handle returned by :meth:`Session.submit`; resolves when the
    session flushes (explicitly or on first ``result()`` call)."""

    def __init__(self, session: "Session", query: Query):
        self._session = session
        self.query = query
        self._report: Report | None = None

    def done(self) -> bool:
        return self._report is not None

    def result(self) -> Report:
        if self._report is None:
            self._session.flush()
        assert self._report is not None
        return self._report


@dataclasses.dataclass
class _FamilyGroup:
    """Per-settings coalescing bucket: one shared network space over the
    distinct layer shapes of the member queries."""
    ns: Any                            # netspace.space.NetSpace
    uid: list[int]                     # per member query -> unique id


class Session:
    """See module docstring.  ``devices``/``block`` default every query
    that does not override them; ``cache_dir=None`` disables the result
    cache (the in-process executable warmth still amortizes)."""

    def __init__(self, *, cache_dir: str | None = None,
                 jax_cache_dir: str | None = None,
                 devices: int | None = None,
                 resilience: ResilienceConfig | None = None):
        import os
        expand = lambda p: os.path.expanduser(p) if p else p
        self.cache_dir = expand(cache_dir)
        jax_cache_dir = expand(jax_cache_dir)
        self.jax_cache_dir = jax_cache_dir
        self.devices = devices
        self.resilience = resilience or ResilienceConfig()
        if resilience is not None:
            # explicit config: install its fault spec + retry policy
            # process-wide (the chunk loops read the installed policy)
            self.resilience.install()
        if self.resilience.ckpt_dir:
            self.resilience = dataclasses.replace(
                self.resilience, ckpt_dir=expand(self.resilience.ckpt_dir))
        self.n_queries = 0
        self.last_batch: dict[str, Any] | None = None
        self._queue: list[tuple[Query, PendingReport]] = []
        self._netspaces: dict[tuple, Any] = {}
        if jax_cache_dir:
            from ..mapspace.cache import enable_compilation_cache
            enable_compilation_cache(jax_cache_dir)

    # ------------------------------------------------------------------
    # Single-query routing
    # ------------------------------------------------------------------

    def run(self, query: Query) -> Report:
        """Route one query to its engine and answer in the unified
        :class:`Report` schema.

        This is the error boundary of the front door: any engine
        failure surfaces as a one-line :class:`~.resilience.ReproError`
        (``SpecError`` / ``DeviceError`` / ``CacheError``) instead of a
        deep XLA traceback, and — with ``resilience.degrade`` (the
        default) — a layer query whose gene pipeline keeps failing is
        re-answered by the legacy tuple-point engine with a
        ``degraded`` extras block rather than failing."""
        kind = query.kind
        self.n_queries += 1
        met = obs.metrics()
        met.inc("session.queries")
        met.inc("session.queries_by_kind", kind=kind)
        # query fingerprint = the span's trace id (only computed when a
        # tracer is live; span() itself is a no-op singleton otherwise)
        fp = query.fingerprint() if obs.tracing_enabled() else None
        acc = obs.PhaseBreakdown()
        t_q = time.perf_counter()
        with obs.span("query", kind=kind, id=fp), \
                cancel_scope(_deadline_t(query)):
            try:
                with obs.phase_scope(acc):
                    rep = self._route(kind, query)
            except SweepKilled:
                raise              # injected process death: must escape
            except Exception as e:  # noqa: BLE001 — classified here
                err = classify(e, context=f"{kind} query")
                if (self.resilience.degrade and kind == "layer"
                        and query.search.pipeline == "gene"
                        and isinstance(err, DeviceError)):
                    rep = self._degrade_layer(query, err)
                    self._stamp_timing(rep, t_q, acc)
                    return rep
                if err is e:
                    raise
                raise err from e
            self._stamp_timing(rep, t_q, acc)
            return rep

    def _route(self, kind: str, query: Query) -> Report:
        if kind == "layer":
            return self._run_layer(query)
        if kind == "layer_codse":
            return self._run_layer_codse(query)
        if kind == "network":
            return self._run_network(query)
        if kind == "network_codse":
            return self._run_network_codse(query)
        raise SpecError(f"unroutable query kind {kind!r}",
                        field="workload")

    def _degrade_layer(self, query: Query, err: ReproError) -> Report:
        """Persistent gene-pipeline failure: answer through the legacy
        tuple-point engine instead of failing the query; the report says
        so in ``extras['degraded']``."""
        obs.metrics().inc("resilience.degraded_queries")
        obs.instant("degraded", kind="layer", error=type(err).__name__)
        LOG.warning("gene pipeline failed (%s) — degrading query to the "
                    "legacy engine", err.one_line())
        legacy = dataclasses.replace(
            query,
            search=dataclasses.replace(query.search, pipeline="legacy"))
        rep = self._run_layer(legacy)
        rep.extras["degraded"] = {"from": "gene", "to": "legacy",
                                  "error": err.one_line()}
        return rep

    @staticmethod
    def _stamp_timing(rep: Report, t0_pc: float,
                      acc: "obs.PhaseBreakdown") -> None:
        """Attach the measured phase breakdown to a report: engine span
        durations accumulated in ``acc`` plus an ``other`` residual, so
        the phases sum to the measured wall by construction.  First
        stamp wins — an isolated re-run's inner stamp survives the
        family-level one (and the serving tier re-finalizes with
        ``queue_wait`` on top)."""
        if "timing" not in rep.extras:
            rep.extras["timing"] = obs.timing_breakdown(
                time.perf_counter() - t0_pc, acc.snapshot())

    def _result_cache_stats(self) -> dict[str, Any]:
        """On-disk result-cache occupancy + this process's hit ratio.
        Occupancy is measured from the directory (shared across
        processes) by ``mapspace.cache.cache_stats``, which scans and
        publishes the ``result_cache.entries``/``.bytes`` gauges under
        the same lock the writers' store/quarantine transitions take —
        the gauges always equal a real directory state (the PR-10
        found-by-linter fix); hits/misses are this process's
        counters."""
        from ..mapspace import cache as result_cache
        entries, size = result_cache.cache_stats(self.cache_dir)
        met = obs.metrics()
        snap = met.snapshot()["counters"]
        hits = int(snap.get("result_cache.hits", 0))
        misses = int(snap.get("result_cache.misses", 0))
        return {"entries": entries, "bytes": size,
                "hits": hits, "misses": misses,
                "hit_ratio": round(hits / (hits + misses), 4)
                if hits + misses else None}

    def metrics(self) -> dict[str, Any]:
        """The process-wide obs metrics snapshot plus this session's own
        counters — THE structured payload CI budget asserts read (also
        embedded in ``--out`` files and BENCH_* artifacts)."""
        cache = self._result_cache_stats()   # sets gauges pre-snapshot
        snap = obs.metrics().snapshot()
        snap["session"] = {"n_queries": self.n_queries,
                           "last_batch": self.last_batch,
                           "result_cache": cache}
        return snap

    def run_search(self, op: LayerOp, **kwargs) -> "Any":
        """The session path behind the legacy ``mapspace.search()`` entry
        point: forwards verbatim to the engine (bit-equal by
        construction) while the session keeps the query count and owns
        process-level caches."""
        from ..mapspace.search import search_impl
        self.n_queries += 1
        return search_impl(op, **kwargs)

    def run_co_search(self, op: LayerOp, **kwargs) -> "Any":
        """Session path behind legacy ``mapspace.co_search()``."""
        from ..mapspace.codse import co_search_impl
        self.n_queries += 1
        return co_search_impl(op, **kwargs)

    def run_search_network(self, model, **kwargs) -> "Any":
        """Session path behind legacy ``netspace.search_network()``."""
        from ..netspace.search import search_network_impl
        self.n_queries += 1
        return search_network_impl(model, **kwargs)

    def run_co_search_network(self, model, **kwargs) -> "Any":
        """Session path behind legacy ``netspace.co_search_network()``."""
        from ..netspace.search import co_search_network_impl
        self.n_queries += 1
        return co_search_network_impl(model, **kwargs)

    def _layer_search_kwargs(self, query: Query) -> dict[str, Any]:
        sp = query.search
        hw = query.hardware
        return dict(
            objective=sp.objective, budget=sp.budget,
            num_pes=hw.num_pes, noc_bw=hw.noc_bw,
            strategy=sp.strategy, seed=sp.seed, top_k=sp.top_k,
            population=sp.population, block=sp.block,
            pipeline=sp.pipeline, multicast=sp.multicast,
            spatial_reduction=sp.spatial_reduction,
            l1_budget_kb=sp.l1_prune_kb, l2_budget_kb=sp.l2_prune_kb,
            devices=self.devices, ckpt_dir=self.resilience.ckpt_dir)

    def _layer_space(self, query: Query, op: LayerOp):
        sp = query.search
        if sp.cluster and sp.dims is None:
            return None                # engine builds the default space
        from ..mapspace.space import build_space
        return build_space(op, dims=sp.dims, cluster=sp.cluster)

    def _run_layer(self, query: Query) -> Report:
        from ..mapspace.search import search_impl
        (op,) = query.workload.resolve()
        r = search_impl(op, space=self._layer_space(query, op),
                        cache_dir=self.cache_dir,
                        cache_extra=query.fingerprint(),
                        **self._layer_search_kwargs(query))
        rep = Report.from_search(r, query)
        rep.name = op.name
        return rep

    def _run_layer_codse(self, query: Query) -> Report:
        from ..mapspace.codse import co_search_impl
        sp = query.search
        hw = query.hardware
        (op,) = query.workload.resolve()
        kw = self._layer_search_kwargs(query)
        for k in ("objective", "budget", "num_pes", "noc_bw", "seed",
                  "ckpt_dir"):
            kw.pop(k)
        co = co_search_impl(
            op, objective=sp.objective, mapping_budget=sp.budget,
            top_k=sp.codse_top_k, cfg=hw.dse_config(),
            num_pes=hw.num_pes, noc_bw=hw.noc_bw, seed=sp.seed,
            space=self._layer_space(query, op),
            cache_dir=self.cache_dir, joint_genes=sp.joint_genes,
            ckpt_dir=self.resilience.ckpt_dir,
            cache_extra=query.fingerprint(), search_kwargs=kw)
        rep = Report.from_codse(co, query)
        rep.name = op.name
        return rep

    def _network_kwargs(self, query: Query) -> dict[str, Any]:
        sp = query.search
        hw = query.hardware
        if sp.strategy not in ("auto", "exhaustive", "random"):
            raise SpecError(
                f"network queries need a one-pass strategy "
                f"(auto/exhaustive/random), got {sp.strategy!r}",
                field="strategy")
        return dict(
            objective=sp.objective, budget=sp.budget, seed=sp.seed,
            strategy=sp.strategy, frontier_k=sp.frontier_k,
            fuse=sp.fuse, reconfig=sp.reconfig,
            l2_budget_kb=sp.l2_budget_kb, l1_prune_kb=sp.l1_prune_kb,
            l2_prune_kb=sp.l2_prune_kb, hw=hw.hwconfig(),
            composer=sp.composer, devices=self.devices, block=sp.block,
            multicast=sp.multicast,
            spatial_reduction=sp.spatial_reduction,
            budget_policy=sp.budget_policy,
            build_kwargs={"cluster": sp.cluster})

    def _net_name(self, query: Query, layers: Sequence[LayerOp]) -> str:
        return query.workload.model or f"{len(layers)} layers"

    def _run_network(self, query: Query) -> Report:
        from ..netspace.search import search_network_impl
        layers = query.workload.resolve()
        r = search_network_impl(layers, **self._network_kwargs(query))
        rep = Report.from_network(r, query)
        rep.name = self._net_name(query, layers)
        return rep

    def _run_network_codse(self, query: Query) -> Report:
        from ..netspace.search import co_search_network_impl
        sp = query.search
        hw = query.hardware
        layers = query.workload.resolve()
        kw = self._network_kwargs(query)
        for k in ("objective", "budget", "seed", "frontier_k"):
            kw.pop(k)
        co = co_search_network_impl(
            layers, hw.dse_config(), objective=sp.objective,
            budget=sp.budget, num_pes=hw.num_pes, noc_bw=hw.noc_bw,
            seed=sp.seed, frontier_k=sp.frontier_k,
            refine_k=sp.codse_top_k, **kw)
        rep = Report.from_conet(co, query)
        rep.name = self._net_name(query, layers)
        return rep

    # ------------------------------------------------------------------
    # Cross-query batching
    # ------------------------------------------------------------------

    @staticmethod
    def coalescible(query: Query) -> bool:
        """Whether ``run_many`` can fold this query into a shared family
        pass: a single-layer workload at fixed hardware with a one-pass
        candidate strategy.  Everything else falls back to
        :meth:`run`."""
        return (query.kind == "layer"
                and query.search.dims is None
                and query.search.pipeline == "gene"
                and query.search.strategy in ("auto", "exhaustive",
                                              "random"))

    def _netspace_for(self, ops: Sequence[LayerOp], *, cluster: bool):
        """Build (or reuse) the shared-gene-layout family grouping over a
        set of distinct layers — the session's warm-executable registry
        rides on these spaces' op-class specs."""
        from ..netspace.space import build_netspace
        key = (tuple(zoo.layer_shape_key(op) for op in ops), cluster)
        ns = self._netspaces.get(key)
        if ns is None:
            ns = build_netspace(list(ops), cluster=cluster)
            self._netspaces[key] = ns
        return ns

    def _batch_settings(self, query: Query) -> tuple:
        sp = query.search
        return (sp.block, sp.multicast, sp.spatial_reduction, sp.cluster)

    def run_many(self, queries: Sequence[Query], *,
                 coalesce: bool = True) -> list[Report]:
        """Answer a heterogeneous batch.  Coalescible layer queries are
        grouped by engine settings, their layers folded into shared
        family spaces, and ALL their candidates evaluated through one
        shape-as-operand device pass per (op-class, level-count) family —
        at most one XLA compile each, with per-row hardware operands.
        ``coalesce=False`` evaluates each query separately through the
        SAME family spaces (the determinism oracle: results must be
        bit-equal to the coalesced pass).  Non-coalescible queries
        (networks, hardware grids, adaptive strategies, custom dims,
        the legacy pipeline) run via :meth:`run` in order.

        Note the family-space semantics: a coalesced answer searches the
        layer's CLASS space (padded tile axes, class-level cluster plan,
        ``auto`` resolving to exhaustive/random) — like
        ``netspace.search_network`` and unlike single-query
        :meth:`run`, which searches ``build_space(op)`` and escalates
        oversized ``auto`` spaces to greedy refinement.  ``Report.raw``
        carries the family space so winning genes stay decodable
        (``raw.best_dataflow``)."""
        t0 = time.perf_counter()
        queries = list(queries)
        obs.metrics().inc("session.batches")
        reports: list[Report | None] = [None] * len(queries)
        coal: dict[tuple, list[int]] = {}
        budget_rest = 0
        n_compiles = 0
        with obs.span("run_many", queries=len(queries)):
            for i, q in enumerate(queries):
                if self.coalescible(q):
                    coal.setdefault(self._batch_settings(q), []).append(i)
                else:
                    t_q = time.monotonic()
                    try:
                        reports[i] = self.run(q)
                    except BudgetExceeded:
                        # deadline expiry is a per-request terminal
                        # answer, never a batch poison
                        obs.metrics().inc("session.timeouts")
                        rep = Report.timeout(
                            q, deadline_s=q.search.deadline_s,
                            waited_s=time.monotonic() - t_q,
                            where="run")
                        rep.extras["timing"] = obs.timing_breakdown(
                            time.monotonic() - t_q, {})
                        reports[i] = rep
                        continue
                    budget_rest += self._compile_budget_of(reports[i])
                    n_compiles += reports[i].n_compiles
            n_coal = sum(len(v) for v in coal.values())
            n_families = 0
            compile_s = eval_s = encode_s = 0.0
            n_devices = 1
            for settings, idxs in coal.items():
                members = [queries[i] for i in idxs]
                t_fam = time.monotonic()
                # family-level phase breakdown: the device pass is
                # shared, so every member carries the SAME wall/phases
                # (the serving tier re-finalizes with queue_wait)
                acc = obs.PhaseBreakdown()
                t_fam_pc = time.perf_counter()
                try:
                    with cancel_scope(_batch_deadline_t(members)), \
                            obs.phase_scope(acc):
                        out = self._run_family_batch(members, settings,
                                                     coalesce=coalesce)
                except SweepKilled:
                    raise          # injected process death: must escape
                except BudgetExceeded:
                    # the flush outlived its most patient member's
                    # budget: every unanswered member gets a terminal
                    # timeout report (re-running them per-query would
                    # only burn MORE wall past the deadline)
                    out = self._timeout_batch(
                        members, waited_s=time.monotonic() - t_fam)
                except Exception as e:  # noqa: BLE001 — isolated below
                    if not self.resilience.degrade:
                        raise classify(e, context="coalesced batch") \
                            from e
                    out = self._isolate_batch(members, e)
                for i, rep in zip(idxs, out["reports"]):
                    self._stamp_timing(rep, t_fam_pc, acc)
                    reports[i] = rep
                n_compiles += out["n_compiles"]
                n_families += out["n_families"]
                compile_s += out["compile_s"]
                eval_s += out["eval_s"]
                encode_s += out["encode_s"]
                n_devices = max(n_devices, out["n_devices"])
        self.last_batch = {
            "n_queries": len(queries),
            "n_coalesced": n_coal,
            "coalesce": bool(coalesce),
            "n_families": n_families,
            "n_compiles": n_compiles,
            "compile_budget": n_families + budget_rest,
            "compile_s": round(compile_s, 3),
            "eval_s": round(eval_s, 3),
            "encode_s": round(encode_s, 3),
            "n_devices": n_devices,
            "elapsed_s": round(time.perf_counter() - t0, 3),
        }
        assert all(r is not None for r in reports)
        return list(reports)

    @staticmethod
    def _compile_budget_of(rep: Report) -> int:
        """Closed-form executable budget of a non-coalesced query (the
        CI compile-budget assertion sums these with the family count)."""
        if rep.kind == "layer":
            return 2
        if rep.kind == "layer_codse":
            joint = 2 if "joint" in rep.extras else 0
            return 2 + 2 * max(len(rep.raw.dse), 1) + joint
        n_classes = int(rep.extras.get("n_classes", 1))
        if rep.kind == "network":
            return 2 * n_classes
        return 4 * n_classes           # network_codse: ref + grid pass

    def _timeout_batch(self, queries: list[Query], *,
                       waited_s: float) -> dict[str, Any]:
        """A coalesced flush hit its deadline: answer every member with
        a terminal timeout report (partial marker in extras)."""
        met = obs.metrics()
        met.inc("session.batch_timeouts")
        met.inc("session.timeouts", len(queries))
        obs.instant("batch-timeout", queries=len(queries),
                    waited_s=round(waited_s, 3))
        LOG.warning("coalesced flush exceeded its deadline after %.3fs "
                    "— answering %d member(s) with timeout reports",
                    waited_s, len(queries))
        reports = [Report.timeout(q, deadline_s=q.search.deadline_s,
                                  waited_s=waited_s, where="flush")
                   for q in queries]
        return {"reports": reports, "n_compiles": 0, "n_families": 0,
                "compile_s": 0.0, "eval_s": 0.0, "encode_s": 0.0,
                "n_devices": 1}

    def _isolate_batch(self, queries: list[Query],
                       exc: BaseException) -> dict[str, Any]:
        """A coalesced device pass failed: degrade the batch to
        per-query sequential execution so one poisoned query cannot take
        down its neighbours.  Queries that STILL fail answer as
        ``error``-kind reports (the rest get normal single-query
        answers — note those search ``build_space(op)``, not the shared
        family space)."""
        err = classify(exc, context="coalesced batch")
        obs.metrics().inc("resilience.batch_degraded")
        obs.instant("batch-degraded", queries=len(queries),
                    error=type(err).__name__)
        LOG.warning("coalesced batch failed (%s) — degrading to "
                    "per-query sequential execution", err.one_line())
        reports: list[Report] = []
        n_compiles = 0
        n_devices = 1
        for q in queries:
            t_q = time.monotonic()
            try:
                rep = self.run(q)
                n_compiles += rep.n_compiles
                n_devices = max(n_devices, rep.n_devices)
            except SweepKilled:
                raise
            except BudgetExceeded:
                obs.metrics().inc("session.timeouts")
                rep = Report.timeout(q, deadline_s=q.search.deadline_s,
                                     waited_s=time.monotonic() - t_q,
                                     where="isolate")
                reports.append(rep)
                continue
            except Exception as qe:  # noqa: BLE001 — isolated per query
                rep = Report.from_error(q, classify(qe, context="query"))
            reports.append(rep)
        return {"reports": reports, "n_compiles": n_compiles,
                "n_families": 0, "compile_s": 0.0, "eval_s": 0.0,
                "encode_s": 0.0, "n_devices": n_devices}

    def _batch_ckpt(self, queries: list[Query],
                    grp: list[int]) -> SweepCheckpoint | None:
        """Sweep checkpoint for one coalesced family job, keyed by the
        member queries' fingerprints (stable across a re-run of the same
        batch, so a killed flush resumes bit-identically)."""
        if not self.resilience.ckpt_dir:
            return None
        key = hashlib.sha256("|".join(
            queries[qi].fingerprint() for qi in grp).encode()
        ).hexdigest()[:16]
        # save after every chunk: the state is tiny (top-k + frontier
        # candidates), and a killed flush then loses at most one chunk
        return SweepCheckpoint(self.resilience.ckpt_dir, f"batch-{key}",
                               every_chunks=1)

    def _run_family_batch(self, queries: list[Query], settings: tuple,
                          *, coalesce: bool) -> dict[str, Any]:
        from ..mapspace.search import static_candidates
        from ..mapspace.space import prune_genes_by_budget, gene_tables
        from ..mapspace.universal import GeneRun
        from ..netspace.evaluator import evaluate_rows
        block, multicast, spatial_reduction, cluster = settings

        with obs.span("coalesce", queries=len(queries)):
            ops = [q.workload.resolve()[0] for q in queries]
            # fold into distinct shapes (first-appearance order keeps the
            # family registry stable across repeated batches)
            distinct: list[LayerOp] = []
            seen: dict[tuple, int] = {}
            uid_of: list[int] = []
            for op in ops:
                k = zoo.layer_shape_key(op)
                if k not in seen:
                    seen[k] = len(distinct)
                    distinct.append(op)
                uid_of.append(seen[k])
            ns = self._netspace_for(distinct, cluster=cluster)
            # build_netspace dedupes again; map distinct ids through it
            uid_of = [ns.index[u] for u in uid_of]

            # per-query candidate matrices (the SAME draws one-query
            # netspace-style search would make on the shared space)
            cand: list[np.ndarray] = []
            strat: list[str] = []
            for q, op, u in zip(queries, ops, uid_of):
                sp = q.search
                g, s = static_candidates(ns.spaces[u], sp.strategy,
                                         sp.budget, sp.seed)
                g = prune_genes_by_budget(ns.unique[u], ns.spaces[u], g,
                                          l1_kb=sp.l1_prune_kb,
                                          l2_kb=sp.l2_prune_kb)
                if not g.shape[0]:
                    raise RuntimeError(
                        f"{op.name}: budget pruning dropped every "
                        f"candidate")
                cand.append(g)
                strat.append(s)

        run = GeneRun()
        cols_q: list[np.ndarray | None] = [None] * len(queries)
        n_families = 0
        by_class: dict[int, list[int]] = {}
        for qi, u in enumerate(uid_of):
            by_class.setdefault(ns.class_of[u], []).append(qi)
        for cid, members in by_class.items():
            tb = gene_tables(ns.unique[uid_of[members[0]]],
                             ns.spaces[uid_of[members[0]]])
            all_genes = np.concatenate([cand[qi] for qi in members])
            is2 = ~tb.cluster_is_none[all_genes[:, 2]]
            n_families += int((~is2).any()) + int(is2.any())
            jobs = [members] if coalesce else [[qi] for qi in members]
            for grp in jobs:
                uid = np.concatenate(
                    [np.full(cand[qi].shape[0], uid_of[qi], np.int64)
                     for qi in grp])
                genes = np.concatenate([cand[qi] for qi in grp])
                pes = np.concatenate(
                    [np.full(cand[qi].shape[0],
                             queries[qi].hardware.num_pes, np.float32)
                     for qi in grp])
                bw = np.concatenate(
                    [np.full(cand[qi].shape[0],
                             queries[qi].hardware.noc_bw, np.float32)
                     for qi in grp])
                _, cols = evaluate_rows(
                    ns, uid, genes, objective="edp", num_pes=pes,
                    noc_bw=bw, block=block, n_devices=self.devices,
                    multicast=multicast,
                    spatial_reduction=spatial_reduction, run=run,
                    ckpt=self._batch_ckpt(queries, grp))
                at = 0
                for qi in grp:
                    m = cand[qi].shape[0]
                    cols_q[qi] = cols[at:at + m]
                    at += m

        met = obs.metrics()
        reports: list[Report] = []
        for qi, (q, op) in enumerate(zip(queries, ops)):
            met.inc("session.queries")
            met.inc("session.queries_by_kind", kind="layer_coalesced")
            if obs.tracing_enabled():
                obs.instant("query", kind="layer", id=q.fingerprint(),
                            coalesced=True)
            sp = q.search
            cols = cols_q[qi]
            macs = float(op.total_macs)
            v = _objective_from_cols(cols, sp.objective, macs)
            v = np.where(np.isfinite(v), v, np.inf)
            order = np.lexsort((np.arange(len(v)), v))[:sp.top_k]
            maximize = sp.objective == "throughput"

            def actual(x: float) -> float:
                return -x if maximize else x

            top = [{"point": [int(g) for g in cand[qi][i]],
                    "value": actual(float(v[i])),
                    "stats": _stats_from_col(cols[i], macs)}
                   for i in order]
            u = uid_of[qi]
            reports.append(Report(
                kind="layer", name=op.name, objective=sp.objective,
                strategy=strat[qi], query=q.describe(), tag=q.tag,
                best=top[0], top_k=top,
                n_evaluated=int(cand[qi].shape[0]),
                n_devices=run.n_devices, coalesced=bool(coalesce),
                extras={"family_space": True, "uid": int(u),
                        "class_id": int(ns.class_of[u])},
                raw=FamilyBest(ns.unique[u], ns.spaces[u],
                               tuple(top[0]["point"]))))
            self.n_queries += 1
        return {"reports": reports, "n_compiles": run.n_compiles,
                "n_families": n_families, "compile_s": run.compile_s,
                "eval_s": run.eval_s, "encode_s": run.encode_s,
                "n_devices": run.n_devices}

    # ------------------------------------------------------------------
    # Queued submission
    # ------------------------------------------------------------------

    def submit(self, query: Query) -> PendingReport:
        """Queue a query for the next coalesced flush; returns a handle
        whose ``result()`` triggers the flush if still pending."""
        pending = PendingReport(self, query)
        self._queue.append((query, pending))
        return pending

    def flush(self, *, coalesce: bool = True) -> list[Report]:
        """Run every queued query in one :meth:`run_many` batch and
        resolve their handles."""
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        reports = self.run_many([q for q, _ in queue],
                                coalesce=coalesce)
        for (_, pending), rep in zip(queue, reports):
            pending._report = rep
        return reports


_DEFAULT: Session | None = None


def default_session() -> Session:
    """The shared module-level session the legacy entry points route
    through (lazy; one per process)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Session()
    return _DEFAULT


def run(query: Query) -> Report:
    """One-shot convenience: ``repro.api.run(query)`` on the default
    session."""
    return default_session().run(query)


def run_many(queries: Sequence[Query], **kw) -> list[Report]:
    """One-shot convenience: coalesced batch on the default session."""
    return default_session().run_many(queries, **kw)
