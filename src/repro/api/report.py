"""The unified :class:`Report` result schema of the declarative front
door.

Every engine behind :class:`repro.api.Session` — per-layer mapping
search, joint co-DSE, whole-network schedule search, the coalesced
``run_many`` pass — answers in the SAME shape: a best design, a top-k
list, an optional Pareto frontier, and one set of counters/rates.
``to_json()``/``from_json()`` round-trip exactly, and the BENCH_* perf
artifacts are emitted through the same schema (``Report.bench``) so CI
and the perf tracker read one format.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from .spec import SCHEMA_VERSION, Query

# Field names reserved by the flat JSON form (everything else in a
# payload round-trips through ``extras``).
_RESERVED = ("schema_version", "kind", "name", "objective", "strategy",
             "query", "tag", "best", "top_k", "pareto", "n_evaluated",
             "n_compiles", "compile_s", "eval_s", "encode_s",
             "elapsed_s", "n_devices", "coalesced", "rates")


def _jsonable(v: Any) -> Any:
    """numpy scalars/arrays -> Python scalars/lists, tuples -> lists."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and not hasattr(v, "__len__"):
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return v


@dataclasses.dataclass
class Report:
    """One query's answer (or one benchmark's payload) in the unified
    schema.  ``raw`` keeps the engine-native result object for callers
    that need the full dataclass (never serialized)."""
    kind: str                          # layer | layer_codse | network |
    #                                    network_codse | bench | error
    name: str = ""                     # workload / bench label
    objective: str = ""
    strategy: str = ""
    query: dict[str, Any] | None = None
    tag: str | None = None
    best: dict[str, Any] = dataclasses.field(default_factory=dict)
    top_k: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    pareto: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    n_evaluated: int = 0
    n_compiles: int = 0
    compile_s: float = 0.0
    eval_s: float = 0.0
    encode_s: float = 0.0
    elapsed_s: float = 0.0
    n_devices: int = 1
    coalesced: bool = False            # answered by a shared device pass
    rates: dict[str, float] = dataclasses.field(default_factory=dict)
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)
    raw: Any = None

    # ------------------------------------------------------------------
    # JSON round trip
    # ------------------------------------------------------------------

    def to_json(self) -> dict[str, Any]:
        """Flat JSON dict: the reserved schema fields plus ``extras``
        merged at top level (benchmark payload keys stay where CI and
        the perf tracker have always read them)."""
        d: dict[str, Any] = {"schema_version": SCHEMA_VERSION}
        for f in _RESERVED[1:]:
            d[f] = _jsonable(getattr(self, f))
        clash = set(self.extras) & set(_RESERVED)
        if clash:
            raise ValueError(f"extras collide with schema fields: "
                             f"{sorted(clash)}")
        d.update(_jsonable(self.extras))
        return d

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Report":
        """Inverse of :meth:`to_json`, tolerant of *newer* payloads:
        unknown top-level fields ride along in ``extras`` (a v(N) client
        can read a v(N+x) server's report during a rolling upgrade), but
        a ``schema_version`` mismatch — result semantics may differ — is
        a one-line :class:`SpecError` naming both versions."""
        from ..resilience.errors import SpecError
        d = dict(d)
        ver = d.pop("schema_version", SCHEMA_VERSION)
        if ver != SCHEMA_VERSION:
            raise SpecError(
                f"report schema_version {ver} != supported "
                f"{SCHEMA_VERSION}", field="schema_version")
        kw = {f: d.pop(f) for f in _RESERVED[1:] if f in d}
        return Report(**kw, extras=d)

    def results_json(self) -> dict[str, Any]:
        """The DETERMINISTIC slice of the report — what two runs of the
        same query must agree on bit-for-bit (no timings, no rates)."""
        return {k: _jsonable(getattr(self, k))
                for k in ("kind", "name", "objective", "strategy",
                          "best", "top_k", "pareto", "n_evaluated")}

    # ------------------------------------------------------------------
    # Constructors from the engine result dataclasses
    # ------------------------------------------------------------------

    @staticmethod
    def bench(name: str, payload: dict[str, Any]) -> "Report":
        """Wrap a benchmark payload: keys matching schema fields land on
        the report itself, the rest ride in ``extras`` — the flat JSON
        keeps every historical BENCH_* key at top level.

        Every bench artifact carries an ``environment`` provenance block
        (jax/jaxlib version, backend, device kind/count, host, git SHA —
        schema_version 2) so BENCH_* numbers are comparable across
        machines; pass an explicit ``environment`` key to override."""
        from .. import obs
        payload = dict(payload)
        payload.setdefault("environment", obs.environment())
        kw = {f: payload.pop(f) for f in _RESERVED[3:] if f in payload}
        return Report(kind="bench", name=name, **kw, extras=payload)

    @staticmethod
    def from_error(query: Query, err: BaseException) -> "Report":
        """An isolated failure in a batch: ``run_many`` degrades a
        poisoned coalesced pass to per-query execution and answers the
        queries that still fail with an ``error``-kind report instead of
        poisoning the whole batch."""
        msg = str(err).strip().splitlines()[0] if str(err).strip() else ""
        return Report(
            kind="error", objective=query.search.objective,
            query=query.describe(), tag=query.tag,
            extras={"error": {"type": type(err).__name__,
                              "message": msg,
                              "details": _jsonable(
                                  getattr(err, "details", {}))}})

    @staticmethod
    def timeout(query: Query, *, deadline_s: float | None,
                waited_s: float, where: str = "queued") -> "Report":
        """A deadline-expired request's terminal answer.  The serving
        tier returns this instead of hanging: ``extras["timeout"]``
        marks the report as partial (no best/top_k), with the budget
        that expired and where the request was when it did."""
        from .. import obs
        obs.flight_record("event", "timeout-report", where=where,
                          deadline_s=deadline_s,
                          waited_s=round(float(waited_s), 4))
        return Report(
            kind="timeout", objective=query.search.objective,
            query=query.describe(), tag=query.tag,
            elapsed_s=float(waited_s),
            extras={"timeout": {"deadline_s": deadline_s,
                                "waited_s": round(float(waited_s), 4),
                                "where": where}})

    @staticmethod
    def from_search(r, query: Query | None = None) -> "Report":
        """From :class:`repro.mapspace.search.SearchResult`."""
        return Report(
            kind="layer", name=getattr(r.space, "op_name", "") or "",
            objective=r.objective, strategy=r.strategy,
            query=query.describe() if query else None,
            tag=query.tag if query else None,
            best={"point": list(r.best_point), "value": float(r.best_value),
                  "stats": _jsonable(r.best_stats)},
            top_k=[{"point": list(e["point"]), "value": float(e["value"]),
                    "stats": _jsonable(e["stats"])} for e in r.top_k],
            n_evaluated=int(r.n_evaluated), n_compiles=int(r.n_compiles),
            compile_s=float(r.compile_s), eval_s=float(r.eval_s),
            encode_s=float(r.encode_s), elapsed_s=float(r.elapsed_s),
            n_devices=int(r.n_devices),
            rates={"mappings_per_s": float(r.mappings_per_s),
                   "end_to_end_mappings_per_s":
                       float(r.end_to_end_mappings_per_s)},
            extras={"cached": bool(r.cached), "pipeline": r.pipeline,
                    "n_groups": int(r.n_groups)},
            raw=r)

    @staticmethod
    def from_codse(co, query: Query | None = None) -> "Report":
        """From :class:`repro.mapspace.codse.CoDSEResult`."""
        rep = Report.from_search(co.search, query)
        rep.kind = "layer_codse"
        rep.pareto = _jsonable(co.pareto)
        rep.best = {"per_objective": _jsonable(co.best),
                    "mapping": rep.best}
        rep.n_evaluated = int(co.n_evaluated)
        rep.n_compiles = int(co.n_compiles)
        rep.elapsed_s = float(co.elapsed_s)
        if co.joint is not None:
            rep.extras["joint"] = {
                "n_designs": int(co.joint.n_designs),
                "n_hw": int(co.joint.n_hw),
                "n_valid": int(co.joint.n_valid),
                "designs_per_s": float(co.joint.designs_per_s),
                "top": _jsonable(co.joint.top[:4]),
            }
        rep.raw = co
        return rep

    @staticmethod
    def from_network(r, query: Query | None = None) -> "Report":
        """From :class:`repro.netspace.search.NetSearchResult`."""
        s = r.schedule
        return Report(
            kind="network", objective=r.objective, strategy=r.strategy,
            query=query.describe() if query else None,
            tag=query.tag if query else None,
            best={"cost": float(s.cost), "runtime": float(s.runtime),
                  "energy_pj": float(s.energy_pj),
                  "edp": float(s.network_edp),
                  "throughput": float(s.throughput),
                  "segments": _jsonable(s.segments),
                  "n_reconfigs": int(s.n_reconfigs),
                  "per_layer": _jsonable(s.per_layer)},
            n_evaluated=int(r.n_evaluated), n_compiles=int(r.n_compiles),
            compile_s=float(r.compile_s), eval_s=float(r.eval_s),
            encode_s=float(r.encode_s), elapsed_s=float(r.elapsed_s),
            n_devices=int(r.n_devices),
            rates={"schedules_per_s": float(r.schedules_per_s)},
            extras={"composer": r.composer, "n_layers": int(r.n_layers),
                    "n_unique": int(r.n_unique),
                    "n_classes": int(r.n_classes),
                    "budget_policy": getattr(r, "budget_policy",
                                             "uniform"),
                    "refined": _jsonable(getattr(r, "refined", []))},
            raw=r)

    @staticmethod
    def from_conet(co, query: Query | None = None) -> "Report":
        """From :class:`repro.netspace.search.CoNetResult`."""
        rep = Report.from_network(co.search, query)
        rep.kind = "network_codse"
        rep.pareto = _jsonable(co.pareto)
        rep.best = {"per_objective": _jsonable(co.best),
                    "schedule": rep.best}
        rep.top_k = _jsonable(co.top)
        rep.n_evaluated = int(co.n_designs)
        rep.n_compiles = int(co.n_compiles)
        rep.elapsed_s = float(co.elapsed_s)
        rep.extras.update({"n_hw": int(co.n_hw),
                           "n_valid": int(co.n_valid)})
        rep.raw = co
        return rep
