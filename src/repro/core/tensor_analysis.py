"""Tensor analysis (TA) engine: dimension coupling per layer operation.

The paper (§4.4) supports any operation expressible as a loop nest with two
input tensors and one output tensor where every tensor index is an affine
function of at most two loop dims.  We encode that directly:

  * a :class:`DimExpr` couples a tensor axis to one loop dim;
  * a :class:`ConvExpr` couples a tensor axis to a *(outer, window)* dim pair
    — the sliding-window pattern ``index = outer·stride + window`` that makes
    convolutions non-affine for polyhedral tools but trivial here (the
    paper's core argument for the data-centric IR).

Conventions follow the paper: directives are written over *input-centric*
dims ``{N, K, C, Y, X, R, S}`` (Y/X are input rows/cols); output extents are
derived, e.g. a tile with ``m(Y)`` input rows and ``m(R)`` filter rows yields
``(m(Y) - m(R))//stride + 1`` output rows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Union

# Canonical tensor names (paper: Filters, Inputs, Outputs).
FILTER, INPUT, OUTPUT = "F", "I", "O"


@dataclasses.dataclass(frozen=True)
class DimExpr:
    name: str

    def extent(self, m: Mapping[str, int]) -> int:
        return m[self.name]

    @property
    def dims(self) -> frozenset[str]:
        return frozenset({self.name})


@dataclasses.dataclass(frozen=True)
class ConvExpr:
    """Sliding-window coupling: tensor axis spans ``outer`` dim indices,
    produced positions = window placements of ``window`` within ``outer``."""

    outer: str
    window: str
    stride: int = 1

    def extent(self, m: Mapping[str, int]) -> int:
        # number of output positions computable from m[outer] input indices
        # with a window of m[window] taps at the given stride.
        t, w = m[self.outer], m[self.window]
        if t < w:
            return 0
        return (t - w) // self.stride + 1

    @property
    def dims(self) -> frozenset[str]:
        return frozenset({self.outer, self.window})


@dataclasses.dataclass(frozen=True)
class WindowExpr:
    """Output-centric sliding-window coupling: the tensor axis spans the
    *input* indices needed for ``outer`` output positions with a window of
    ``window`` taps: extent = (m(outer) − 1)·stride + m(window).

    This is the paper's Fig. 4/5 convention (directives over X'/Y' and R/S;
    the input dims are derived, 'skewed' iteration space)."""

    outer: str
    window: str
    stride: int = 1

    def extent(self, m: Mapping[str, int]) -> int:
        a, w = m[self.outer], m[self.window]
        if a <= 0 or w <= 0:
            return 0
        return (a - 1) * self.stride + w

    @property
    def dims(self) -> frozenset[str]:
        return frozenset({self.outer, self.window})


Expr = Union[DimExpr, ConvExpr, WindowExpr]


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    name: str
    entries: tuple[Expr, ...]
    has_data: bool = True  # False for weightless ops (pooling "filter")

    def volume(self, m: Mapping[str, int]) -> int:
        if not self.has_data:
            return 0
        v = 1
        for e in self.entries:
            v *= e.extent(m)
        return v

    @property
    def coupled_dims(self) -> frozenset[str]:
        out: frozenset[str] = frozenset()
        for e in self.entries:
            out |= e.dims
        return out

    def coupled_to(self, dim: str) -> bool:
        """Paper's coupling test: does this tensor's data change when ``dim``
        advances?"""
        return dim in self.coupled_dims


@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One DNN layer operation with full dimension sizes and coupling."""

    name: str
    op_type: str
    dims: dict[str, int]
    filter: TensorSpec
    input: TensorSpec
    output: TensorSpec
    # The full iteration space (each MAC = one point).
    iter_entries: tuple[Expr, ...]

    # ------------------------------------------------------------------
    def tensors(self) -> tuple[TensorSpec, TensorSpec, TensorSpec]:
        return (self.filter, self.input, self.output)

    def input_tensors(self) -> tuple[TensorSpec, ...]:
        return (self.filter, self.input)

    def num_psums(self, m: Mapping[str, int]) -> int:
        """MACs (partial sums) inside one tile with mapped sizes ``m``."""
        v = 1
        for e in self.iter_entries:
            v *= e.extent(m)
        return v

    @property
    def total_macs(self) -> int:
        return self.num_psums(self.dims)

    def reduction_dims(self) -> frozenset[str]:
        """Dims coupled to inputs but NOT to the output — advancing them
        accumulates into the same output element (temporal/spatial
        reduction; paper Table 1)."""
        return (self.filter.coupled_dims | self.input.coupled_dims) \
            - self.output.coupled_dims

    def stride_of(self, dim: str) -> int:
        """Index-space advance per unit map offset for ``dim`` (the CLA
        engine's stride handling): a map over an *input* spatial dim of a
        strided conv must advance ``offset × stride`` input indices per
        step so consecutive tiles land on valid windows."""
        for e in self.output.entries:
            if isinstance(e, ConvExpr) and e.outer == dim:
                return e.stride
        return 1

    def validate(self) -> None:
        for d, v in self.dims.items():
            if v <= 0:
                raise ValueError(f"{self.name}: dim {d} = {v} must be > 0")
        for e in self.iter_entries:
            if e.extent(self.dims) <= 0:
                raise ValueError(
                    f"{self.name}: empty iteration extent for {e} "
                    f"with dims {self.dims}")


# ----------------------------------------------------------------------
# Constructors for the op types used in the paper's case studies
# ----------------------------------------------------------------------

def conv2d(name: str, *, n: int = 1, k: int, c: int, y: int, x: int,
           r: int, s: int, stride: int = 1) -> LayerOp:
    """Dense multi-channel 2D convolution (paper Fig. 1). ``y``/``x`` are
    input activation height/width."""
    dims = dict(N=n, K=k, C=c, Y=y, X=x, R=r, S=s)
    oy, ox = ConvExpr("Y", "R", stride), ConvExpr("X", "S", stride)
    op = LayerOp(
        name=name, op_type="CONV2D", dims=dims,
        filter=TensorSpec(FILTER, (DimExpr("K"), DimExpr("C"),
                                   DimExpr("R"), DimExpr("S"))),
        input=TensorSpec(INPUT, (DimExpr("N"), DimExpr("C"),
                                 DimExpr("Y"), DimExpr("X"))),
        output=TensorSpec(OUTPUT, (DimExpr("N"), DimExpr("K"), oy, ox)),
        iter_entries=(DimExpr("N"), DimExpr("K"), DimExpr("C"),
                      DimExpr("R"), DimExpr("S"), oy, ox),
    )
    op.validate()
    return op


def dwconv2d(name: str, *, n: int = 1, c: int, y: int, x: int,
             r: int, s: int, stride: int = 1,
             weightless: bool = False, op_type: str = "DWCONV") -> LayerOp:
    """Depth-wise convolution: output is coupled to C, not K (paper §4.1)."""
    dims = dict(N=n, C=c, Y=y, X=x, R=r, S=s)
    oy, ox = ConvExpr("Y", "R", stride), ConvExpr("X", "S", stride)
    op = LayerOp(
        name=name, op_type=op_type, dims=dims,
        filter=TensorSpec(FILTER, (DimExpr("C"), DimExpr("R"), DimExpr("S")),
                          has_data=not weightless),
        input=TensorSpec(INPUT, (DimExpr("N"), DimExpr("C"),
                                 DimExpr("Y"), DimExpr("X"))),
        output=TensorSpec(OUTPUT, (DimExpr("N"), DimExpr("C"), oy, ox)),
        iter_entries=(DimExpr("N"), DimExpr("C"),
                      DimExpr("R"), DimExpr("S"), oy, ox),
    )
    op.validate()
    return op


def pool2d(name: str, *, n: int = 1, c: int, y: int, x: int,
           r: int, s: int, stride: int) -> LayerOp:
    """Pooling = weightless depth-wise op (one compare/acc per window tap)."""
    return dwconv2d(name, n=n, c=c, y=y, x=x, r=r, s=s, stride=stride,
                    weightless=True, op_type="POOL")


def fc(name: str, *, n: int = 1, k: int, c: int) -> LayerOp:
    """Fully-connected layer: O[N,K] += F[K,C] · I[N,C] (a GEMM)."""
    dims = dict(N=n, K=k, C=c)
    op = LayerOp(
        name=name, op_type="FC", dims=dims,
        filter=TensorSpec(FILTER, (DimExpr("K"), DimExpr("C"))),
        input=TensorSpec(INPUT, (DimExpr("N"), DimExpr("C"))),
        output=TensorSpec(OUTPUT, (DimExpr("N"), DimExpr("K"))),
        iter_entries=(DimExpr("N"), DimExpr("K"), DimExpr("C")),
    )
    op.validate()
    return op


def gemm(name: str, *, m: int, n: int, k: int) -> LayerOp:
    """O[M,N] = A[M,K] @ B[K,N].  A = activations (I), B = weights (F).
    Mapped onto FC naming: N_fc = M (rows), K_fc = N (out), C_fc = K (red)."""
    return fc(name, n=m, k=n, c=k)


def pointwise_conv(name: str, *, n: int = 1, k: int, c: int,
                   y: int, x: int) -> LayerOp:
    """1x1 convolution (bottleneck / MobileNet PW): conv2d with R=S=1."""
    return conv2d(name, n=n, k=k, c=c, y=y, x=x, r=1, s=1)


def transposed_conv2d(name: str, *, n: int = 1, k: int, c: int,
                      y: int, x: int, r: int, s: int,
                      up: int = 2) -> LayerOp:
    """Transposed (up-scale) convolution modeled as its equivalent dense
    convolution over the zero-dilated input (paper Table 4 handles it as a
    CONV2D variant with structured output sparsity — the MAC count below is
    the dense-equivalent upper bound, matching MAESTRO's dense model)."""
    y_eff = y * up + r - up
    x_eff = x * up + s - up
    return conv2d(name, n=n, k=k, c=c, y=y_eff, x=x_eff, r=r, s=s)


def conv1d(name: str, *, n: int = 1, k: int, c: int, x: int,
           s: int, stride: int = 1) -> LayerOp:
    """1-D convolution (input-centric X)."""
    return conv2d(name, n=n, k=k, c=c, y=1, x=x, r=1, s=s, stride=stride)


def conv1d_outputs(name: str, *, x_out: int, s: int,
                   stride: int = 1) -> LayerOp:
    """The paper's Fig. 4 pedagogical 1-D convolution in *output-centric*
    form: dims are X (output positions) and S (filter taps); the input is
    coupled to both through a :class:`WindowExpr`."""
    dims = dict(X=x_out, S=s)
    op = LayerOp(
        name=name, op_type="CONV1D", dims=dims,
        filter=TensorSpec(FILTER, (DimExpr("S"),)),
        input=TensorSpec(INPUT, (WindowExpr("X", "S", stride),)),
        output=TensorSpec(OUTPUT, (DimExpr("X"),)),
        iter_entries=(DimExpr("X"), DimExpr("S")),
    )
    op.validate()
    return op


def conv2d_outputs(name: str, *, n: int = 1, k: int, c: int, y_out: int,
                   x_out: int, r: int, s: int, stride: int = 1) -> LayerOp:
    """Output-centric dense 2-D convolution (Y/X are *output* rows/cols);
    the natural form for the TPU mapper, where output dims are the
    shardable ones."""
    dims = dict(N=n, K=k, C=c, Y=y_out, X=x_out, R=r, S=s)
    op = LayerOp(
        name=name, op_type="CONV2D_OS", dims=dims,
        filter=TensorSpec(FILTER, (DimExpr("K"), DimExpr("C"),
                                   DimExpr("R"), DimExpr("S"))),
        input=TensorSpec(INPUT, (DimExpr("N"), DimExpr("C"),
                                 WindowExpr("Y", "R", stride),
                                 WindowExpr("X", "S", stride))),
        output=TensorSpec(OUTPUT, (DimExpr("N"), DimExpr("K"),
                                   DimExpr("Y"), DimExpr("X"))),
        iter_entries=(DimExpr("N"), DimExpr("K"), DimExpr("C"),
                      DimExpr("Y"), DimExpr("X"), DimExpr("R"),
                      DimExpr("S")),
    )
    op.validate()
    return op


def lstm_cell(name: str, *, n: int = 1, hidden: int, inp: int) -> LayerOp:
    """LSTM hidden-layer GEMM: 4 gates × hidden outputs, (inp+hidden) inputs."""
    return fc(name, n=n, k=4 * hidden, c=inp + hidden)


def attention_score(name: str, *, bh: int, q: int, kv: int,
                    d: int) -> LayerOp:
    """Q·K^T per (batch·head): used by the TPU mapper bridge."""
    return fc(name, n=bh * q, k=kv, c=d)


def output_dims(op: LayerOp) -> dict[str, int]:
    """Full output extents, e.g. {'Y_o': 112, 'X_o': 112} for a conv."""
    out = {}
    for e in op.output.entries:
        if isinstance(e, ConvExpr):
            out[f"{e.outer}_o"] = e.extent(op.dims)
    return out


def macs_per_output(op: LayerOp) -> float:
    return op.total_macs / max(1, op.output.volume(op.dims))


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def algorithmic_max_reuse(op: LayerOp) -> dict[str, float]:
    """Algorithmic maximum reuse factor per tensor ('A' bars, Fig. 11):
    total MACs that touch each element / number of elements."""
    out = {}
    for t in (op.filter, op.input, op.output):
        vol = t.volume(op.dims)
        out[t.name] = op.total_macs / vol if vol else math.inf
    return out
