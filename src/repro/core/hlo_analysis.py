"""HLO text analysis: collective bytes per primitive.

``compiled.cost_analysis()`` has FLOPs and bytes-accessed but no collective
traffic, so we parse the post-SPMD HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Sizes come from the result shape annotation (``bf16[2,16,128]{...}``),
which for collectives equals the per-participant payload.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %ag = bf16[2,1024,128]{2,1,0} all-gather(bf16[2,64,128]{...} %x), ...
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?P<shape>\(?[\w\[\],{}\s/]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"(?P<dt>\w+?)\[(?P<dims>[\d,]*)\]")


def shape_bytes(text: str) -> int:
    """Total bytes of one shape annotation (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def merged(self, other: "CollectiveStats") -> "CollectiveStats":
        b = dict(self.bytes_by_kind)
        c = dict(self.count_by_kind)
        for k, v in other.bytes_by_kind.items():
            b[k] = b.get(k, 0) + v
        for k, v in other.count_by_kind.items():
            c[k] = c.get(k, 0) + v
        return CollectiveStats(b, c)


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the HLO text.

    ``-start``/``-done`` async pairs are counted once (on -start); ops
    inside while-loop bodies are counted once per appearance — multiply by
    trip count upstream if per-step totals are needed (we report per-step
    costs, and scanned layers appear once in the body, matching a
    per-layer×trip accounting done by the caller)."""
    bytes_by: dict[str, int] = {k: 0 for k in COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group("op")
        b = shape_bytes(m.group("shape"))
        bytes_by[kind] += b
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort extraction of scan trip counts (for documentation)."""
    out = []
    for m in re.finditer(r"trip_count=(\d+)", hlo_text):
        out.append(int(m.group(1)))
    return out


def scale_scanned_collectives(stats: CollectiveStats, hlo_text: str,
                              n_layers: int) -> CollectiveStats:
    """Collectives inside the layer-scan while body execute once per layer.
    We approximate: if the HLO has a while loop whose trip count equals
    n_layers, multiply collective totals found inside by that factor.

    Conservative simplification: applied to ALL collectives when a
    layer-count while loop exists (the overwhelming majority of collective
    traffic in these models is inside the scanned stack)."""
    trips = while_trip_counts(hlo_text)
    factor = n_layers if n_layers in trips else 1
    if factor == 1:
        return stats
    return CollectiveStats(
        {k: v * factor for k, v in stats.bytes_by_kind.items()},
        dict(stats.count_by_kind))
