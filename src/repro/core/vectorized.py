"""Vectorized twin of the faithful engine.

The paper's DSE sweeps hardware parameters (#PEs, NoC bandwidth, buffer
sizes) holding (layer × dataflow) fixed.  Because the analysis in
``model.py`` is written against the backend facade, the *same code* runs
with hardware parameters as traced jnp scalars: layer dims, directive
sizes, temporal trip counts and the iteration-case structure stay static
Python ints (hybrid backend), while everything touched by ``num_pes`` /
``noc_bw`` becomes part of one small jit graph.  ``vmap`` then evaluates
the whole design grid in a single fused XLA computation — this is the
beyond-paper optimization that lifts the DSE rate orders of magnitude above
the paper's 0.17M designs/s (see EXPERIMENTS.md §Perf-A).

Output is a flat, fixed-shape feature vector per design point so the DSE
can stack millions of them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .cluster_analysis import build_dense_level, hybrid_backend
from .directives import Cluster, Dataflow
from .model import analyze, analyze_dense_level, assemble_stats, \
    blend_level_results
from .performance import HWConfig
from .tensor_analysis import LayerOp

# Feature vector layout produced by the traced evaluator.
FEATURES = ("runtime", "energy_pj", "macs", "l1_kb", "l2_kb", "util",
            "bw_req", "throughput", "edp")


def _features(s) -> jnp.ndarray:
    """Pack a Stats object into the fixed FEATURES vector (traceable)."""
    runtime = jnp.asarray(s.runtime, jnp.float32)
    energy = jnp.asarray(s.energy_pj, jnp.float32)
    macs = jnp.asarray(s.total_macs, jnp.float32)
    return jnp.stack([
        runtime,
        energy,
        macs,
        jnp.asarray(s.l1_req_kb, jnp.float32),
        jnp.asarray(s.l2_req_kb, jnp.float32),
        jnp.asarray(s.utilization, jnp.float32),
        jnp.asarray(s.peak_bw.get(0, 0), jnp.float32),
        macs / runtime,
        energy * runtime,
    ])


def stats_vector(op: LayerOp, df: Dataflow, hw: HWConfig) -> jnp.ndarray:
    """One design point -> fixed-shape feature vector (traceable)."""
    xp = hybrid_backend()
    return _features(analyze(op, df, hw, xp=xp))


@functools.lru_cache(maxsize=512)
def _build_eval(op_key, df_key, multicast: bool, reduction: bool,
                latency: float, macs_per_pe: int) -> Callable:
    op, df = _OP_REG[op_key], _DF_REG[df_key]

    def eval_one(num_pes, noc_bw):
        hw = HWConfig(num_pes=num_pes, noc_bw=noc_bw,
                      noc_latency=latency, multicast=multicast,
                      spatial_reduction=reduction,
                      macs_per_pe=macs_per_pe)
        return stats_vector(op, df, hw)

    return jax.jit(jax.vmap(eval_one))


# jit-cache registries keyed by object identity (LayerOp/Dataflow are
# frozen-ish dataclasses holding dicts — not hashable — so we key by repr).
_OP_REG: dict[str, LayerOp] = {}
_DF_REG: dict[str, Dataflow] = {}


def _reg(op: LayerOp, df: Dataflow) -> tuple[str, str]:
    ok = f"{op.name}|{sorted(op.dims.items())}|{op.op_type}"
    dk = f"{df.name}|{df.directives}"
    _OP_REG[ok] = op
    _DF_REG[dk] = df
    return ok, dk


def batched_evaluator(op: LayerOp, df: Dataflow, *, multicast: bool = True,
                      spatial_reduction: bool = True,
                      noc_latency: float = 2.0,
                      macs_per_pe: int = 1) -> Callable:
    """Returns ``f(num_pes[i], noc_bw[i]) -> features[i, F]``, jit+vmap'd.

    The returned callable evaluates the full MAESTRO analysis for every
    design point in one XLA executable."""
    ok, dk = _reg(op, df)
    return _build_eval(ok, dk, multicast, spatial_reduction, noc_latency,
                       macs_per_pe)


@dataclasses.dataclass
class BatchStats:
    """Columnar stats for a batch of design points."""
    runtime: Any
    energy_pj: Any
    macs: Any
    l1_kb: Any
    l2_kb: Any
    util: Any
    bw_req: Any
    throughput: Any
    edp: Any

    @classmethod
    def from_features(cls, feats) -> "BatchStats":
        cols = {name: feats[..., i] for i, name in enumerate(FEATURES)}
        return cls(**{
            "runtime": cols["runtime"], "energy_pj": cols["energy_pj"],
            "macs": cols["macs"], "l1_kb": cols["l1_kb"],
            "l2_kb": cols["l2_kb"], "util": cols["util"],
            "bw_req": cols["bw_req"], "throughput": cols["throughput"],
            "edp": cols["edp"]})


def evaluate_grid(op: LayerOp, df: Dataflow, num_pes, noc_bw,
                  **kw) -> BatchStats:
    """Evaluate (layer × dataflow) over arrays of hardware design points."""
    f = batched_evaluator(op, df, **kw)
    feats = f(jnp.asarray(num_pes), jnp.asarray(noc_bw))
    return BatchStats.from_features(feats)


# ----------------------------------------------------------------------
# Tile-size-traced twin: the mapping-space axis (repro.mapspace)
# ----------------------------------------------------------------------
#
# The hardware DSE above holds the dataflow fixed and traces (num_pes,
# noc_bw).  The mapping search needs the dual: hardware fixed, *tile sizes*
# traced, so thousands of candidate mappings that share one directive
# structure (same dims, order, spatial choice, cluster nesting) run through
# a single jit+vmap executable.  Trip counts, iteration-case occurrences and
# tile volumes all become traced values; the case *structure* (number of
# cases, loop order) stays static per template, which is exactly what the
# mapspace engine groups candidates by.
#
# Sizes are traced as float32: volume products reach ~1e10 on real layers,
# which would overflow int32 (JAX's default int width).  Small-integer phase
# arithmetic (trip counts, equality tests) stays exact in float32 far beyond
# any realistic dim extent (< 2^24).

@functools.lru_cache(maxsize=512)
def _build_tile_eval(op_key, df_key, var_slots: tuple[int, ...],
                     num_pes: int, noc_bw: float, multicast: bool,
                     reduction: bool, latency: float,
                     macs_per_pe: int) -> Callable:
    op, template = _OP_REG[op_key], _DF_REG[df_key]
    hw = HWConfig(num_pes=num_pes, noc_bw=noc_bw, noc_latency=latency,
                  multicast=multicast, spatial_reduction=reduction,
                  macs_per_pe=macs_per_pe)

    def eval_one(sizes, offsets):
        sizes = sizes.astype(jnp.float32)
        offsets = offsets.astype(jnp.float32)
        dirs = list(template.directives)
        for j, slot in enumerate(var_slots):
            d = dirs[slot]
            if isinstance(d, Cluster):
                dirs[slot] = Cluster(sizes[j])
            else:
                dirs[slot] = type(d)(sizes[j], offsets[j], d.dim)
        df = Dataflow(template.name, tuple(dirs))
        return stats_vector(op, df, hw)

    return jax.jit(jax.vmap(eval_one))


def batched_tile_evaluator(op: LayerOp, template: Dataflow,
                           var_slots: tuple[int, ...], *,
                           num_pes: int, noc_bw: float,
                           multicast: bool = True,
                           spatial_reduction: bool = True,
                           noc_latency: float = 2.0,
                           macs_per_pe: int = 1) -> Callable:
    """Returns ``f(sizes[i, S], offsets[i, S]) -> features[i, F]``.

    ``template`` is a structurally-complete directive program whose
    directives at positions ``var_slots`` have placeholder size/offset; the
    evaluator substitutes row ``i`` of the operand arrays for them (a
    ``Cluster`` slot consumes only its size column).  Hardware parameters
    are static per executable — the mapping search runs at a fixed reference
    design, and the co-DSE re-enters :func:`batched_evaluator` with the
    winning concrete mappings."""
    ok, dk = _reg(op, template)
    return _build_tile_eval(ok, dk, tuple(var_slots), int(num_pes),
                            float(noc_bw), multicast, spatial_reduction,
                            noc_latency, macs_per_pe)


# ----------------------------------------------------------------------
# Universal structure-as-operand evaluator: one XLA compile per
# (op × level-count) for the WHOLE mapping space
# ----------------------------------------------------------------------
#
# The tile-traced twin above still compiles once per (spatial × perm ×
# cluster) structure group, because loop order and spatial choice are
# Python-level structure of the directive program.  The universal evaluator
# moves that structure into operands too:
#
#   * the loop permutation is a *rank vector* (per searched axis, its
#     position in the data-movement order) — "innermost coupled loop" and
#     "advancing loop" become one-hot gathers over ranks;
#   * the spatial-dim choice is a *one-hot selector* blending each axis's
#     temporal and spatial phase quantities;
#   * the cluster option is a traced cluster size plus a one-hot over the
#     space's (inner dim, inner map) candidates;
#   * hardware (#PEs, NoC bandwidth) are traced per row, so a joint
#     mapping × hardware frontier runs through the same executable.
#
# Per-dim quantities are computed densely over the op's full dim universe
# (unused dims are trip-count-1 loops, exactly like ``complete()``), so a
# single jit+vmap executable per (op, level-count) evaluates every mapping
# in the space — the per-group compile cost becomes O(1).

@dataclasses.dataclass(frozen=True)
class UniversalSpec:
    """Static structure of one universal executable: everything that is
    *not* an operand.  ``cluster`` lists the (inner_dim, inner_size,
    inner_offset) candidates of the 2-level family; empty = 1 level."""
    dim_names: tuple[str, ...]
    axis_dims: tuple[str, ...]
    pinned: tuple[str, ...]
    cluster: tuple[tuple[str, int, int], ...] = ()
    # divisor-tiled spaces: only the spatial axis can produce a non-empty
    # edge phase, so case enumeration shrinks from 2^A to A+1
    single_edge: bool = False
    # layer shape as operand (repro.netspace): dim extents come from an
    # ``ext`` (i, D) operand row instead of ``op.dims``, and the cluster
    # candidates' inner size/offset from ``cin_size``/``cin_off`` (i, K)
    # rows — so ONE executable per op-class covers every layer shape of a
    # network (the ``cluster`` entries then carry only the inner-dim
    # identity; their static size/offset fields are ignored)
    ext_operand: bool = False

    @property
    def n_levels(self) -> int:
        return 2 if self.cluster else 1


def _universal_eval_one(op: LayerOp, spec: UniversalSpec, hw_static: dict):
    """Build the single-row evaluator closed over static structure."""
    axis_dims = spec.axis_dims
    a = len(axis_dims)
    missing = [d for d in spec.dim_names
               if d not in axis_dims and d not in spec.pinned]

    def eval_one(ops):
        xp = hybrid_backend()
        hw = HWConfig(num_pes=ops["pes"], noc_bw=ops["bw"], **hw_static)
        if spec.ext_operand:
            ext0 = {d: ops["ext"][j]
                    for j, d in enumerate(spec.dim_names)}
        else:
            ext0 = {d: op.dims[d] for d in spec.dim_names}
        sizes: dict = dict(ext0)   # non-searched dims: fully unrolled
        offsets: dict = dict(ext0)
        rank: dict = {}
        sp: dict = {d: 0 for d in spec.dim_names}
        for j, d in enumerate(axis_dims):
            sizes[d] = ops["sizes"][j]
            offsets[d] = ops["offsets"][j]
            rank[d] = ops["rank"][j]
            sp[d] = ops["sp"][j]
        # loop order mirrors the grouped templates: implicit (missing) dims
        # outermost, searched axes in permutation order, pinned window dims
        # innermost.  Trip-count-1 loops only need order-consistent ranks.
        for i, d in enumerate(missing):
            rank[d] = -1 - i
        for j, d in enumerate(spec.pinned):
            rank[d] = a + j

        pes = xp.maximum(ops["pes"], 1)
        if spec.cluster:
            c_eff = xp.maximum(xp.minimum(ops["csize"], pes), 1)
            top_units = xp.maximum(xp.floordiv(pes, c_eff), 1)
        else:
            c_eff = None
            top_units = pes

        level0 = build_dense_level(
            xp, op, index=0, ext=ext0, sizes=sizes, offsets=offsets,
            rank=rank, sp=sp, loop_dims=spec.dim_names,
            edge_dims=axis_dims, n_units=top_units,
            innermost=not spec.cluster, single_edge=spec.single_edge)

        if spec.cluster:
            def child_fn(m_unit):
                results = []
                for ki, (cd, csz, coff) in enumerate(spec.cluster):
                    if spec.ext_operand:
                        csz = ops["cin_size"][ki]
                        coff = ops["cin_off"][ki]
                    lvl1 = build_dense_level(
                        xp, op, index=1, ext=m_unit, sizes={cd: csz},
                        offsets={cd: coff}, rank={cd: 0}, sp={cd: 1},
                        loop_dims=(cd,), edge_dims=(cd,), n_units=c_eff,
                        innermost=True)
                    results.append(
                        analyze_dense_level(op, lvl1, xp, hw))
                if len(results) == 1:
                    return results[0]
                return blend_level_results(xp, ops["csel"], results)
            top = analyze_dense_level(op, level0, xp, hw,
                                      child_fn=child_fn)
        else:
            top = analyze_dense_level(op, level0, xp, hw)
        return _features(
            assemble_stats(op, top, spec.n_levels, hw, xp))

    return eval_one


# ----------------------------------------------------------------------
# Fused on-device reduction tail: top-k + Pareto inside the executable
# ----------------------------------------------------------------------
#
# The universal evaluator above returns the full (n, F) feature matrix,
# which makes the *host* the bottleneck of a large DSE: every chunk copies
# n x F floats back and the objective/top-k/Pareto reduction runs in numpy.
# The reduced evaluator fuses that reduction into the same XLA program:
# each chunk returns the scalar objective column (optional), the k winner
# rows, and a within-chunk Pareto-candidate mask over (energy, throughput)
# — a few scalars per design instead of the feature matrix.  An optional
# hardware tail folds the co-DSE's area/power/leakage accounting
# (``core.dse.run_dse`` semantics) into the jit so a joint mapping x
# hardware sweep needs no host post-processing either.  Chunks can stripe
# across local devices via ``jax.pmap`` (``n_devices > 1``) and donate
# their operand buffers on backends that support donation.

@dataclasses.dataclass(frozen=True)
class HWTail:
    """Static hardware-accounting tail (mirrors ``core.dse.run_dse``):
    SRAM = l1*pes + l2, area/power from the RTL-regression model, leakage
    energy added to the energy/EDP columns, budget-invalid designs masked
    out of the objective and the frontier."""
    area_power: Any               # energy.AreaPowerModel (frozen, hashable)
    area_budget_mm2: float
    power_budget_mw: float


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """Static reduction structure: objective column (canonical minimize),
    top-k width, and optional extras."""
    objective: str                # FEATURES name
    maximize: bool = False
    k: int = 8
    return_vals: bool = True      # per-row objective column (search needs
    #                               it; the paper-scale sweep does not)
    pareto: bool = True           # (energy, throughput) candidate mask
    hw: HWTail | None = None
    cols: tuple[str, ...] = ()    # extra per-row FEATURES columns to ship
    #                               back (netspace's DP composer needs the
    #                               (runtime, energy, l1, l2) of EVERY
    #                               candidate, not just the top-k rows)


def _reduce_tail(reduce: ReduceSpec, feats, ops):
    """The traced reduction: runs on (block, F) features of one shard."""
    live = ops["live"] > 0                       # padding rows never win
    obj_i = FEATURES.index(reduce.objective)
    runtime = feats[:, FEATURES.index("runtime")]
    valid = live
    if reduce.hw is not None:
        ap = reduce.hw.area_power
        pes, bw = ops["pes"], ops["bw"]
        l1 = feats[:, FEATURES.index("l1_kb")]
        l2 = feats[:, FEATURES.index("l2_kb")]
        sram_kb = l1 * pes + l2
        area = ap.area(pes, sram_kb, bw)
        power = ap.power(pes, sram_kb, bw)
        valid = live & (area <= reduce.hw.area_budget_mm2) \
            & (power <= reduce.hw.power_budget_mw)
        energy = feats[:, FEATURES.index("energy_pj")] \
            + ap.static_energy_pj(area, runtime)
        feats = feats.at[:, FEATURES.index("energy_pj")].set(energy)
        feats = feats.at[:, FEATURES.index("edp")].set(energy * runtime)
    obj = feats[:, obj_i]
    if reduce.maximize:
        obj = -obj
    obj = jnp.where(jnp.isfinite(obj) & valid, obj, jnp.inf)
    k = min(reduce.k, feats.shape[0])
    # lax.top_k is tie-stable (lower index first) — the cross-shard merge
    # relies on that for 1-vs-N-device determinism
    neg_top, top_idx = jax.lax.top_k(-obj, k)
    out = {
        "top_vals": -neg_top,
        "top_idx": top_idx,
        "top_feats": feats[top_idx],
        "n_valid": jnp.sum(valid),
    }
    if reduce.return_vals:
        out["vals"] = obj
    if reduce.cols:
        out["cols"] = feats[:, [FEATURES.index(c) for c in reduce.cols]]
    if reduce.pareto:
        e = feats[:, FEATURES.index("energy_pj")]
        t = feats[:, FEATURES.index("throughput")]
        e = jnp.where(valid & jnp.isfinite(e), e, jnp.inf)
        t = jnp.where(valid & jnp.isfinite(t), t, -jnp.inf)
        # sort-based frontier: O(n log n), not O(n^2) pairwise
        order = jnp.argsort(e)
        ts = t[order]
        prev = jnp.concatenate(
            [jnp.full((1,), -jnp.inf, ts.dtype),
             jax.lax.cummax(ts)[:-1]])
        mask = jnp.zeros(e.shape, bool).at[order].set(ts > prev)
        out["pareto_mask"] = mask & valid
        out["pareto_energy"] = e
        out["pareto_thr"] = t
    return out


def _donate() -> tuple:
    """Operand-buffer donation, skipped on backends without support (CPU
    would warn on every chunk)."""
    return (0,) if jax.default_backend() != "cpu" else ()


@functools.lru_cache(maxsize=256)
def _build_reduced(op_key: str, spec: UniversalSpec, reduce: ReduceSpec,
                   multicast: bool, reduction: bool, latency: float,
                   macs_per_pe: int, n_devices: int) -> Callable:
    op = _OP_REG[op_key]
    hw_static = dict(noc_latency=latency, multicast=multicast,
                     spatial_reduction=reduction, macs_per_pe=macs_per_pe)
    eval_one = _universal_eval_one(op, spec, hw_static)

    def chunk_fn(ops):
        feats = jax.vmap(eval_one)(
            {k: v for k, v in ops.items() if k != "live"})
        return _reduce_tail(reduce, feats, ops)

    if n_devices > 1:
        return jax.pmap(chunk_fn, donate_argnums=_donate())
    return jax.jit(chunk_fn, donate_argnums=_donate())


def universal_reduced_evaluator(op: LayerOp, spec: UniversalSpec,
                                reduce: ReduceSpec, *,
                                multicast: bool = True,
                                spatial_reduction: bool = True,
                                noc_latency: float = 2.0,
                                macs_per_pe: int = 1,
                                n_devices: int = 1) -> Callable:
    """Returns the fused evaluate-and-reduce executable.

    Input is the universal operand dict plus a ``live`` (i,) float mask
    (0 = padding row).  With ``n_devices > 1`` every array carries a
    leading device axis ``(D, block, ...)`` and the executable is a pmap —
    each device reduces its shard; the caller merges the per-shard top-k /
    frontier candidates (by (value, global index), which is deterministic
    for any device count).  Output per shard:

    ``top_vals``/``top_idx``/``top_feats``
        the k best rows by the canonicalized (minimized) objective;
    ``vals`` (optional)
        the full objective column — one scalar per design, NOT the
        (n, F) feature matrix;
    ``pareto_mask``/``pareto_energy``/``pareto_thr`` (optional)
        within-shard Pareto-candidate mask over (energy min, throughput
        max) plus the two columns for host-side frontier refinement;
    ``n_valid``
        count of live (and, with a hardware tail, budget-valid) rows."""
    ok = f"{op.name}|{sorted(op.dims.items())}|{op.op_type}"
    _OP_REG[ok] = op
    return _build_reduced(ok, spec, reduce, multicast, spatial_reduction,
                          noc_latency, macs_per_pe, n_devices)


@functools.lru_cache(maxsize=256)
def _build_universal(op_key: str, spec: UniversalSpec, multicast: bool,
                     reduction: bool, latency: float,
                     macs_per_pe: int) -> Callable:
    op = _OP_REG[op_key]
    hw_static = dict(noc_latency=latency, multicast=multicast,
                     spatial_reduction=reduction, macs_per_pe=macs_per_pe)
    return jax.jit(jax.vmap(_universal_eval_one(op, spec, hw_static)))


def universal_evaluator(op: LayerOp, spec: UniversalSpec, *,
                        multicast: bool = True,
                        spatial_reduction: bool = True,
                        noc_latency: float = 2.0,
                        macs_per_pe: int = 1) -> Callable:
    """Returns ``f(ops) -> features[i, F]`` where ``ops`` is a dict of
    per-row operand arrays encoding the ENTIRE mapping plus the hardware
    point:

    ``sizes``/``offsets`` (i, A)
        tile sizes / offsets per searched axis, canonical axis order;
    ``rank`` (i, A)
        each axis's position in the loop order (0 = outermost searched);
    ``sp`` (i, A)
        one-hot spatial-axis selector;
    ``csize`` (i,), ``csel`` (i, K)
        cluster size and one-hot over ``spec.cluster`` candidates
        (2-level specs only);
    ``pes``/``bw`` (i,)
        hardware design point per row (joint mapping × hardware search).

    One XLA executable per (op, level-count): every structure group of the
    mapping space is an operand pattern of the same compiled computation.
    See ``repro.mapspace.universal`` for the MapSpace-point encoder."""
    ok = f"{op.name}|{sorted(op.dims.items())}|{op.op_type}"
    _OP_REG[ok] = op
    return _build_universal(ok, spec, multicast, spatial_reduction,
                            noc_latency, macs_per_pe)
