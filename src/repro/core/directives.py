"""Data-centric dataflow directives (the paper's §3 IR).

A dataflow is an ordered sequence of directives:

  * ``SpatialMap(size, offset) dim``  — distribute ``dim`` across sub-clusters
    (PEs at the innermost level); each sub-cluster gets ``size`` consecutive
    indices, consecutive sub-clusters shifted by ``offset``.
  * ``TemporalMap(size, offset) dim`` — distribute ``dim`` across time steps;
    every sub-cluster sees the *same* chunk in a given step.
  * ``Cluster(size)``                 — group sub-clusters: directives above a
    Cluster see logical clusters, directives below see inside one cluster.

Directive *order* is the data-movement order: the innermost (last) map
advances first, odometer-style (paper §3.1, "Data Movement Order").

``size``/``offset`` may be the sentinel :data:`FULL`, meaning "the whole
dimension" (the paper writes ``Sz(R)``); it is resolved against a concrete
layer by :func:`resolve`.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator, Mapping, Sequence, Union

# Sentinel for "size of the mapped dimension itself".
FULL = -1


@dataclasses.dataclass(frozen=True)
class Sz:
    """Symbolic size: the full extent of dimension ``dim`` (the paper's
    ``Sz(R)`` — which frequently refers to a *different* dim than the one
    being mapped, e.g. ``TemporalMap(Sz(R), 1) Y``)."""
    dim: str

    def __str__(self) -> str:
        return f"Sz({self.dim})"


Size = Union[int, Sz]


def is_static_size(v) -> bool:
    """True for plain Python ints (including FULL); False for Sz symbols and
    traced jnp scalars.  Traced sizes appear when the mapping-space engine
    vectorizes tile sizes (``repro.mapspace``): structural checks that would
    force concretization are skipped for them — legality is enforced upstream
    by the space definition."""
    return isinstance(v, int) and not isinstance(v, bool)


@dataclasses.dataclass(frozen=True)
class TemporalMap:
    size: Size
    offset: Size
    dim: str

    def __str__(self) -> str:
        return f"TemporalMap({_sz(self.size, self.dim)},{_sz(self.offset, self.dim)}) {self.dim}"


@dataclasses.dataclass(frozen=True)
class SpatialMap:
    size: Size
    offset: Size
    dim: str

    def __str__(self) -> str:
        return f"SpatialMap({_sz(self.size, self.dim)},{_sz(self.offset, self.dim)}) {self.dim}"


@dataclasses.dataclass(frozen=True)
class Cluster:
    size: Size

    def __str__(self) -> str:
        return f"Cluster({self.size})"


Directive = Union[TemporalMap, SpatialMap, Cluster]
MapDirective = Union[TemporalMap, SpatialMap]


def _sz(v: Size, dim: str) -> str:
    if isinstance(v, Sz):
        return str(v)
    return f"Sz({dim})" if v == FULL else str(v)


def _resolve_size(v: Size, own_dim: str | None, dims: Mapping[str, int]):
    if isinstance(v, Sz):
        if v.dim not in dims:
            raise DataflowError(f"Sz({v.dim}) refers to unknown dim; "
                                f"layer dims: {sorted(dims)}")
        return dims[v.dim]
    if is_static_size(v) and v == FULL:
        if own_dim is None:
            raise DataflowError("Cluster size cannot be FULL")
        return dims[own_dim]
    return v


def _clamp(v, full):
    """min(v, full) that works for static ints and traced jnp scalars."""
    if is_static_size(v):
        return min(v, full)
    import jax.numpy as jnp
    return jnp.minimum(v, full)


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """An ordered directive program plus a human-readable name."""

    name: str
    directives: tuple[Directive, ...]

    def __post_init__(self) -> None:
        validate(self.directives)

    def __iter__(self) -> Iterator[Directive]:
        return iter(self.directives)

    def __str__(self) -> str:
        body = "\n".join(f"  {d}" for d in self.directives)
        return f"Dataflow {self.name} {{\n{body}\n}}"

    # ------------------------------------------------------------------
    @property
    def levels(self) -> tuple[tuple[MapDirective, ...], ...]:
        """Split the program into per-cluster-level map sequences.

        Level 0 is the outermost (above the first Cluster directive).
        """
        out: list[tuple[MapDirective, ...]] = []
        cur: list[MapDirective] = []
        for d in self.directives:
            if isinstance(d, Cluster):
                out.append(tuple(cur))
                cur = []
            else:
                cur.append(d)
        out.append(tuple(cur))
        return tuple(out)

    @property
    def cluster_sizes(self) -> tuple[int, ...]:
        return tuple(d.size for d in self.directives if isinstance(d, Cluster))

    def mapped_dims(self) -> set[str]:
        return {d.dim for d in self.directives if not isinstance(d, Cluster)}

    def spatial_dims(self) -> tuple[str, ...]:
        return tuple(
            d.dim for d in self.directives if isinstance(d, SpatialMap))

    def with_name(self, name: str) -> "Dataflow":
        return Dataflow(name, self.directives)


class DataflowError(ValueError):
    pass


def validate(directives: Sequence[Directive]) -> None:
    """Structural validation (paper constraints).

    * a dim is mapped at most once per cluster level;
    * Cluster sizes are positive;
    * map sizes/offsets are positive (or FULL).

    Multiple SpatialMaps at one level are allowed and mean *aligned*
    distribution — unit ``u`` takes chunk ``u`` of every spatially mapped
    dim simultaneously (the paper's Table 3 YR-P maps Y and R this way,
    which is exactly Eyeriss's diagonal input mapping).
    """
    level = 0
    seen_dims: set[str] = set()

    def _ok(v) -> bool:
        if isinstance(v, Sz) or not is_static_size(v):
            return True  # symbolic / traced — legality enforced upstream
        return v == FULL or v > 0

    for d in directives:
        if isinstance(d, Cluster):
            if is_static_size(d.size) and d.size <= 0:
                raise DataflowError(f"Cluster size must be > 0, got {d.size}")
            level += 1
            seen_dims = set()
            continue
        if not _ok(d.size):
            raise DataflowError(f"map size must be > 0, FULL or Sz: {d}")
        if not _ok(d.offset):
            raise DataflowError(f"map offset must be > 0, FULL or Sz: {d}")
        if d.dim in seen_dims:
            raise DataflowError(
                f"dim {d.dim!r} mapped twice at cluster level {level}")
        seen_dims.add(d.dim)


# ----------------------------------------------------------------------
# Resolution against a concrete layer
# ----------------------------------------------------------------------

def resolve(df: Dataflow, dims: dict[str, int]) -> Dataflow:
    """Replace FULL/Sz sentinels with concrete dimension sizes and clamp map
    sizes to the dimension extent (a map larger than the dim is the same as a
    fully-unrolled map — the paper marks these with an asterisk)."""
    out: list[Directive] = []
    for d in df.directives:
        if isinstance(d, Cluster):
            out.append(Cluster(_resolve_size(d.size, None, dims)))
            continue
        if d.dim not in dims:
            raise DataflowError(
                f"dataflow {df.name!r} maps unknown dim {d.dim!r}; "
                f"layer dims: {sorted(dims)}")
        full = dims[d.dim]
        size = _clamp(_resolve_size(d.size, d.dim, dims), full)
        offset = _clamp(_resolve_size(d.offset, d.dim, dims), full)
        out.append(type(d)(size, offset, d.dim))
    return Dataflow(df.name, tuple(out))


def complete(df: Dataflow, dims: dict[str, int]) -> Dataflow:
    """CLA-engine directive completion (the paper's "augment the given
    dataflow descriptions for missing directives"):

    * any layer dim not mentioned at the outermost level gets an implicit
      fully-unrolled TemporalMap prepended (a single iteration, so its
      position among temporal maps does not change steady-state behaviour);
    * any directive dim the layer does *not* have (e.g. K for a depth-wise
      conv, Y/X/R/S for an FC layer) is kept but resolved against an
      extent-1 dim — modeling the real under-utilization of running such a
      layer on that dataflow (e.g. NVDLA-style K-partitioning wastes PEs on
      depth-wise convolutions).
    """
    dims = dict(dims)
    for d in df.directives:
        for ref in _referenced_dims(d):
            dims.setdefault(ref, 1)
    mentioned = df.mapped_dims()
    missing = [k for k in dims if k not in mentioned]
    extra = tuple(TemporalMap(FULL, FULL, k) for k in missing)
    return resolve(Dataflow(df.name, extra + df.directives), dims)


def extended_dims(df: Dataflow, dims: dict[str, int]) -> dict[str, int]:
    """Layer dims extended with extent-1 entries for every dim the dataflow
    references but the layer lacks (see :func:`complete`)."""
    out = dict(dims)
    for d in df.directives:
        for ref in _referenced_dims(d):
            out.setdefault(ref, 1)
    return out


def _referenced_dims(d: Directive) -> list[str]:
    out = []
    if isinstance(d, Cluster):
        if isinstance(d.size, Sz):
            out.append(d.size.dim)
        return out
    out.append(d.dim)
    for v in (d.size, d.offset):
        if isinstance(v, Sz):
            out.append(v.dim)
    return out


# ----------------------------------------------------------------------
# Parser for the paper's textual syntax
# ----------------------------------------------------------------------

_LINE = re.compile(
    r"^\s*(?P<kind>SpatialMap|TemporalMap|Cluster)\s*"
    r"\(\s*(?P<a>Sz\(\w+\)|\d+)\s*(?:,\s*(?P<b>Sz\(\w+\)|\d+)\s*)?\)\s*"
    r"(?P<dim>\w+)?\s*;?\s*$",
    re.IGNORECASE,
)


def parse(text: str, name: str = "parsed") -> Dataflow:
    """Parse the paper's textual notation, e.g.::

        SpatialMap(1,1) K
        TemporalMap(64,64) C
        TemporalMap(Sz(R),Sz(R)) R
        Cluster(64)
        SpatialMap(1,1) C
    """
    dirs: list[Directive] = []
    for raw in text.strip().splitlines():
        line = raw.strip()
        if not line or line.startswith("#") or line.startswith("//"):
            continue
        m = _LINE.match(line)
        if not m:
            raise DataflowError(f"cannot parse directive line: {raw!r}")
        kind = m.group("kind").lower()
        a = _parse_num(m.group("a"))
        if kind == "cluster":
            dirs.append(Cluster(a))
            continue
        b = _parse_num(m.group("b")) if m.group("b") else a
        dim = m.group("dim")
        if not dim:
            raise DataflowError(f"map directive missing dim: {raw!r}")
        cls = SpatialMap if kind == "spatialmap" else TemporalMap
        dirs.append(cls(a, b, dim.upper()))
    return Dataflow(name, tuple(dirs))


_SZ = re.compile(r"^sz\((\w+)\)$", re.IGNORECASE)


def _parse_num(tok: str) -> Size:
    m = _SZ.match(tok.strip())
    if m:
        return Sz(m.group(1).upper())
    return int(tok)


# ----------------------------------------------------------------------
# Divisor / legality helpers (used by the mapping-space engine)
# ----------------------------------------------------------------------

def divisors(n: int) -> tuple[int, ...]:
    """All positive divisors of ``n`` in ascending order."""
    if n <= 0:
        raise ValueError(f"divisors() needs n > 0, got {n}")
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


def tile_candidates(extent: int, max_candidates: int | None = None
                    ) -> tuple[int, ...]:
    """Candidate tile sizes for a dim of ``extent``: its divisor set, thinned
    evenly (keeping 1 and the full extent) when larger than
    ``max_candidates`` so space sizes stay controllable."""
    divs = divisors(extent)
    if max_candidates is None or len(divs) <= max_candidates or \
            max_candidates < 2:
        return divs
    idx = {0, len(divs) - 1}
    for i in range(1, max_candidates - 1):
        idx.add(round(i * (len(divs) - 1) / (max_candidates - 1)))
    return tuple(divs[i] for i in sorted(idx))


def is_legal(df: Dataflow, dims: Mapping[str, int]) -> bool:
    """Legality of a concrete directive program against layer dims: every
    static map size/offset must be positive and no larger than the (extended)
    extent of its dim.  Symbolic sizes are legal by construction (``resolve``
    clamps them)."""
    ext = dict(dims)
    for d in df.directives:
        for ref in _referenced_dims(d):
            ext.setdefault(ref, 1)
    for d in df.directives:
        if isinstance(d, Cluster):
            if is_static_size(d.size) and d.size <= 0:
                return False
            continue
        for v in (d.size, d.offset):
            if not is_static_size(v) or v == FULL:
                continue
            if v <= 0 or v > ext[d.dim]:
                return False
    return True
