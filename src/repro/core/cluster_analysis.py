"""Cluster analysis (CLA) engine.

Splits a directive program into cluster levels, derives per-level sub-unit
counts, completes implicit directives, and decomposes every map directive
into *phases* — the (steady, edge) iteration classes whose cross product is
the paper's ``ExtractDataIterationCases`` (Fig. 8).

All arithmetic goes through a tiny backend facade (:class:`Backend`) so that
the exact same formulas run on Python ints (the faithful engine) and on
traced ``jnp`` scalars (the vectorized DSE engine).  Phase *structure* is
static — an edge phase always exists, possibly with occurrence count 0 — so
the jnp twin traces a fixed computation graph.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Mapping, Sequence

from .directives import (Cluster, Dataflow, MapDirective, SpatialMap,
                         TemporalMap, complete)
from .tensor_analysis import LayerOp


# ----------------------------------------------------------------------
# Backend facade
# ----------------------------------------------------------------------

class Backend:
    """Minimal numeric facade. ``py`` works on exact Python ints; ``jnp``
    works on traced JAX scalars (no Python branching on values)."""

    def __init__(self, maximum: Callable, minimum: Callable,
                 where: Callable, floordiv: Callable):
        self.maximum = maximum
        self.minimum = minimum
        self.where = where
        self.floordiv = floordiv

    def ceil_div(self, a, b):
        return self.floordiv(a + b - 1, b)

    def eq(self, a, b):
        # returns 1/0 indicator usable in arithmetic
        return self.where(a == b, 1, 0)


def py_backend() -> Backend:
    return Backend(
        maximum=lambda a, b: a if a >= b else b,
        minimum=lambda a, b: a if a <= b else b,
        where=lambda c, t, f: t if c else f,
        floordiv=lambda a, b: a // b,
    )


def jnp_backend() -> Backend:
    import jax.numpy as jnp
    return Backend(
        maximum=jnp.maximum,
        minimum=jnp.minimum,
        where=jnp.where,
        floordiv=jnp.floor_divide,
    )


def hybrid_backend() -> Backend:
    """Python math on static ints, jnp on traced values.

    This keeps everything derivable from (layer dims × directive sizes) —
    trip counts of temporal loops, tile sizes, case structure — as exact
    Python ints even while hardware parameters (PE count, NoC bandwidth)
    are traced jnp scalars, so the vectorized engine traces a small graph
    and stays bit-identical to the faithful engine."""
    import jax.numpy as jnp

    def _static(*vals) -> bool:
        return all(isinstance(v, (int, float, bool)) for v in vals)

    def maximum(a, b):
        return (a if a >= b else b) if _static(a, b) else jnp.maximum(a, b)

    def minimum(a, b):
        return (a if a <= b else b) if _static(a, b) else jnp.minimum(a, b)

    def where(c, t, f):
        if _static(c):
            return t if c else f
        return jnp.where(c, t, f)

    def floordiv(a, b):
        return a // b if _static(a, b) else jnp.floor_divide(a, b)

    return Backend(maximum=maximum, minimum=minimum, where=where,
                   floordiv=floordiv)


# ----------------------------------------------------------------------
# Phases
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Phase:
    """One iteration class of a map directive.

    count        number of (temporal) steps, or spatial folds, in this class
    size         per-unit mapped extent of the dim (max across units)
    active       number of fully-active sub-units (1 for temporal maps)
    partial_size extent of the trailing partially-filled unit (0 if none)
    """
    count: Any
    size: Any
    active: Any = 1
    partial_size: Any = 0

    @property
    def units(self):
        """Total units doing work (full + the partial straggler)."""
        return self.active if isinstance(self.partial_size, int) and \
            self.partial_size == 0 else None  # only used by py backend


@dataclasses.dataclass
class LoopInfo:
    """A map directive instantiated at a cluster level."""
    directive: MapDirective
    dim: str
    is_spatial: bool
    n_units: Any              # sub-units the spatial map distributes over
    steady: Phase
    edge: Phase

    @property
    def phases(self) -> tuple[Phase, Phase]:
        return (self.steady, self.edge)

    def total_steps(self):
        return self.steady.count + self.edge.count


def temporal_phases(xp: Backend, D, size, offset) -> tuple[Phase, Phase]:
    """Iteration classes of ``TemporalMap(size, offset)`` over a dim of
    extent ``D``: ``n = 1 + ceil((D - s)/o)`` steps, the last possibly
    partial."""
    s = xp.minimum(size, D)
    n = 1 + xp.ceil_div(xp.maximum(D - s, 0), offset)
    last = D - (n - 1) * offset          # extent of the final step
    last = xp.minimum(xp.maximum(last, 1), s)
    has_edge = 1 - xp.eq(last, s)
    steady = Phase(count=n - has_edge, size=s)
    edge = Phase(count=has_edge, size=last)
    return steady, edge


def spatial_phases(xp: Backend, D, size, offset, n_units
                   ) -> tuple[Phase, Phase]:
    """Folding classes of ``SpatialMap(size, offset)`` over ``n_units``
    sub-units (paper §3.2: insufficient PEs ⇒ the mapping folds over time).

    A full fold covers ``span = s + (n-1)·o`` indices and advances by
    ``n·o``; the final fold may activate fewer units and/or a partial
    trailing unit."""
    s = xp.minimum(size, D)
    adv = n_units * offset
    span = s + (n_units - 1) * offset
    n_folds = 1 + xp.ceil_div(xp.maximum(D - span, 0), adv)
    rem = D - (n_folds - 1) * adv        # indices left for the last fold
    rem = xp.minimum(rem, span)
    # units whose window [u·o, u·o + s) intersects [0, rem): u·o < rem
    used = xp.minimum(n_units, xp.ceil_div(rem, offset))
    # among used units, those fully covered: u·o + s <= rem
    full = xp.minimum(used, xp.maximum(
        xp.floordiv(rem - s, offset) + 1, 0))
    partial_cnt = used - full
    last_partial = xp.maximum(rem - full * offset, 0)
    last_partial = xp.minimum(last_partial, s)
    is_steady_last = xp.eq(full, n_units)
    steady = Phase(count=n_folds - 1 + is_steady_last, size=s,
                   active=n_units, partial_size=0)
    edge = Phase(count=1 - is_steady_last, size=s, active=full,
                 partial_size=xp.where(partial_cnt > 0, last_partial, 0))
    return steady, edge


# ----------------------------------------------------------------------
# Level construction
# ----------------------------------------------------------------------

@dataclasses.dataclass
class LevelSpec:
    """One cluster level: its loops (outer→inner) and sub-unit count."""
    index: int
    loops: tuple[LoopInfo, ...]
    n_units: Any                 # sub-clusters (PEs at the innermost level)
    dims: dict[str, Any]         # dim extents seen by this level
    is_innermost: bool

    def spatial_loop(self) -> LoopInfo | None:
        for lp in self.loops:
            if lp.is_spatial:
                return lp
        return None

    def spatial_loops(self) -> tuple[LoopInfo, ...]:
        return tuple(lp for lp in self.loops if lp.is_spatial)

    def steady_tile(self) -> dict[str, Any]:
        """Per-sub-unit steady mapped extents (unmapped dims pass through)."""
        m = dict(self.dims)
        for lp in self.loops:
            m[lp.dim] = lp.steady.size
        return m


def unit_counts(xp: Backend, num_pes, cluster_sizes: Sequence[int]
                ) -> list[Any]:
    """Sub-unit count per level: ``[P/Πc, c1, ..., cL]`` (paper §3.2).

    Cluster sizes are capped by the PEs actually available, innermost
    first — an 8-PE machine running a ``Cluster(64)`` dataflow forms one
    8-wide cluster (which then folds), not a phantom 64-wide one."""
    eff: list[Any] = [None] * len(cluster_sizes)
    rem = xp.maximum(num_pes, 1)
    for i in range(len(cluster_sizes) - 1, -1, -1):
        ce = xp.maximum(xp.minimum(cluster_sizes[i], rem), 1)
        eff[i] = ce
        rem = xp.maximum(xp.floordiv(rem, ce), 1)
    top = rem
    return [top, *eff]


def build_levels(xp: Backend, df: Dataflow, op: LayerOp, num_pes
                 ) -> list[LevelSpec]:
    """Instantiate every cluster level against the layer.

    Level ``l+1`` sees dim extents equal to level ``l``'s steady per-unit
    mapped sizes (paper §4.4: multi-cluster splits into single-cluster cases
    with dim size = the upper level's mapping size)."""
    df = complete(df, op.dims)
    counts = unit_counts(xp, num_pes, df.cluster_sizes)
    level_maps = df.levels
    levels: list[LevelSpec] = []
    dims: dict[str, Any] = dict(op.dims)
    for li, maps in enumerate(level_maps):
        n_units = counts[li]
        loops: list[LoopInfo] = []
        for d in maps:
            D = dims[d.dim]
            if isinstance(d, SpatialMap):
                steady, edge = spatial_phases(xp, D, d.size, d.offset,
                                              n_units)
                loops.append(LoopInfo(d, d.dim, True, n_units, steady, edge))
            else:
                steady, edge = temporal_phases(xp, D, d.size, d.offset)
                loops.append(LoopInfo(d, d.dim, False, 1, steady, edge))
        spec = LevelSpec(index=li, loops=tuple(loops), n_units=n_units,
                         dims=dict(dims),
                         is_innermost=(li == len(level_maps) - 1))
        levels.append(spec)
        dims = spec.steady_tile()
    return levels


# ----------------------------------------------------------------------
# Case enumeration (the paper's ExtractDataIterationCases)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class IterationCase:
    """One element of the cross product of per-loop phases."""
    occurrences: Any             # product of phase counts
    sizes: dict[str, Any]        # per-unit mapped extent per dim
    active_units: Any            # fully-active sub-units this case
    partial_unit_sizes: dict[str, Any]  # spatial dim -> trailing unit extent
    phase_ids: tuple[int, ...]   # 0=steady / 1=edge per loop (for debugging)


def enumerate_cases(level: LevelSpec, xp: Backend) -> list[IterationCase]:
    """Cross product of per-loop phases; occurrence = Π phase counts.

    The structure (number of cases) is static per dataflow; counts may be 0
    (e.g. when a dim divides evenly there is no edge), which keeps the jnp
    twin branch-free.

    Multiple SpatialMaps at a level are *aligned* (unit u takes chunk u of
    every spatial dim): the first spatial loop drives folding; secondary
    spatial loops contribute sizes and clamp the jointly-active unit count
    via ``min``.  Secondary loops must cover their dim in a single fold
    (true of all Table 3 dataflows)."""
    first_spatial = next((i for i, lp in enumerate(level.loops)
                          if lp.is_spatial), None)
    loop_phase_lists: list[tuple[Phase, ...]] = []
    for i, lp in enumerate(level.loops):
        if lp.is_spatial and i != first_spatial:
            # Aligned secondary spatial map: the primary drives time, so a
            # secondary never contributes fold steps.  Collapse it to its
            # covering phase (first fold).  On an under-provisioned
            # cluster (fewer PEs than the dim) the uncovered tail is
            # honestly dropped — the mapping simply cannot express it.
            st, ed = lp.phases
            if isinstance(st.count, int) and isinstance(ed.count, int):
                loop_phase_lists.append((st if st.count >= 1 else ed,))
                continue
        loop_phase_lists.append(lp.phases)
    cases: list[IterationCase] = []
    for choice in itertools.product(
            *[range(len(p)) for p in loop_phase_lists]):
        occ = 1
        sizes = dict(level.dims)
        active = None
        partials: dict[str, Any] = {}
        for i, (lp, phs, ci) in enumerate(
                zip(level.loops, loop_phase_lists, choice)):
            ph = phs[ci]
            sizes[lp.dim] = ph.size
            if lp.is_spatial and i != first_spatial:
                occ = occ * xp.where(ph.count > 0, 1, 0)
            else:
                occ = occ * ph.count
            if lp.is_spatial:
                active = ph.active if active is None \
                    else xp.minimum(active, ph.active)
                partials[lp.dim] = ph.partial_size
        cases.append(IterationCase(
            occurrences=occ, sizes=sizes,
            active_units=1 if active is None else active,
            partial_unit_sizes=partials, phase_ids=choice))
    return cases
