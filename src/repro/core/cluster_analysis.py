"""Cluster analysis (CLA) engine.

Splits a directive program into cluster levels, derives per-level sub-unit
counts, completes implicit directives, and decomposes every map directive
into *phases* — the (steady, edge) iteration classes whose cross product is
the paper's ``ExtractDataIterationCases`` (Fig. 8).

All arithmetic goes through a tiny backend facade (:class:`Backend`) so that
the exact same formulas run on Python ints (the faithful engine) and on
traced ``jnp`` scalars (the vectorized DSE engine).  Phase *structure* is
static — an edge phase always exists, possibly with occurrence count 0 — so
the jnp twin traces a fixed computation graph.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Mapping, Sequence

from .directives import (Cluster, Dataflow, MapDirective, SpatialMap,
                         TemporalMap, complete)
from .tensor_analysis import LayerOp


# ----------------------------------------------------------------------
# Backend facade
# ----------------------------------------------------------------------

class Backend:
    """Minimal numeric facade. ``py`` works on exact Python ints; ``jnp``
    works on traced JAX scalars (no Python branching on values)."""

    def __init__(self, maximum: Callable, minimum: Callable,
                 where: Callable, floordiv: Callable):
        self.maximum = maximum
        self.minimum = minimum
        self.where = where
        self.floordiv = floordiv

    def ceil_div(self, a, b):
        return self.floordiv(a + b - 1, b)

    def eq(self, a, b):
        # returns 1/0 indicator usable in arithmetic
        return self.where(a == b, 1, 0)


def py_backend() -> Backend:
    return Backend(
        maximum=lambda a, b: a if a >= b else b,
        minimum=lambda a, b: a if a <= b else b,
        where=lambda c, t, f: t if c else f,
        floordiv=lambda a, b: a // b,
    )


def jnp_backend() -> Backend:
    import jax.numpy as jnp
    return Backend(
        maximum=jnp.maximum,
        minimum=jnp.minimum,
        where=jnp.where,
        floordiv=jnp.floor_divide,
    )


def hybrid_backend() -> Backend:
    """Python math on static ints, jnp on traced values.

    This keeps everything derivable from (layer dims × directive sizes) —
    trip counts of temporal loops, tile sizes, case structure — as exact
    Python ints even while hardware parameters (PE count, NoC bandwidth)
    are traced jnp scalars, so the vectorized engine traces a small graph
    and stays bit-identical to the faithful engine."""
    import jax.numpy as jnp

    def _static(*vals) -> bool:
        return all(isinstance(v, (int, float, bool)) for v in vals)

    def maximum(a, b):
        return (a if a >= b else b) if _static(a, b) else jnp.maximum(a, b)

    def minimum(a, b):
        return (a if a <= b else b) if _static(a, b) else jnp.minimum(a, b)

    def where(c, t, f):
        if _static(c):
            return t if c else f
        return jnp.where(c, t, f)

    def floordiv(a, b):
        return a // b if _static(a, b) else jnp.floor_divide(a, b)

    return Backend(maximum=maximum, minimum=minimum, where=where,
                   floordiv=floordiv)


# ----------------------------------------------------------------------
# Phases
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Phase:
    """One iteration class of a map directive.

    count        number of (temporal) steps, or spatial folds, in this class
    size         per-unit mapped extent of the dim (max across units)
    active       number of fully-active sub-units (1 for temporal maps)
    partial_size extent of the trailing partially-filled unit (0 if none)
    """
    count: Any
    size: Any
    active: Any = 1
    partial_size: Any = 0

    @property
    def units(self):
        """Total units doing work (full + the partial straggler)."""
        return self.active if isinstance(self.partial_size, int) and \
            self.partial_size == 0 else None  # only used by py backend


@dataclasses.dataclass
class LoopInfo:
    """A map directive instantiated at a cluster level."""
    directive: MapDirective
    dim: str
    is_spatial: bool
    n_units: Any              # sub-units the spatial map distributes over
    steady: Phase
    edge: Phase

    @property
    def phases(self) -> tuple[Phase, Phase]:
        return (self.steady, self.edge)

    def total_steps(self):
        return self.steady.count + self.edge.count


def temporal_phases(xp: Backend, D, size, offset) -> tuple[Phase, Phase]:
    """Iteration classes of ``TemporalMap(size, offset)`` over a dim of
    extent ``D``: ``n = 1 + ceil((D - s)/o)`` steps, the last possibly
    partial."""
    s = xp.minimum(size, D)
    n = 1 + xp.ceil_div(xp.maximum(D - s, 0), offset)
    last = D - (n - 1) * offset          # extent of the final step
    last = xp.minimum(xp.maximum(last, 1), s)
    has_edge = 1 - xp.eq(last, s)
    steady = Phase(count=n - has_edge, size=s)
    edge = Phase(count=has_edge, size=last)
    return steady, edge


def spatial_phases(xp: Backend, D, size, offset, n_units
                   ) -> tuple[Phase, Phase]:
    """Folding classes of ``SpatialMap(size, offset)`` over ``n_units``
    sub-units (paper §3.2: insufficient PEs ⇒ the mapping folds over time).

    A full fold covers ``span = s + (n-1)·o`` indices and advances by
    ``n·o``; the final fold may activate fewer units and/or a partial
    trailing unit."""
    s = xp.minimum(size, D)
    adv = n_units * offset
    span = s + (n_units - 1) * offset
    n_folds = 1 + xp.ceil_div(xp.maximum(D - span, 0), adv)
    rem = D - (n_folds - 1) * adv        # indices left for the last fold
    rem = xp.minimum(rem, span)
    # units whose window [u·o, u·o + s) intersects [0, rem): u·o < rem
    used = xp.minimum(n_units, xp.ceil_div(rem, offset))
    # among used units, those fully covered: u·o + s <= rem
    full = xp.minimum(used, xp.maximum(
        xp.floordiv(rem - s, offset) + 1, 0))
    partial_cnt = used - full
    last_partial = xp.maximum(rem - full * offset, 0)
    last_partial = xp.minimum(last_partial, s)
    is_steady_last = xp.eq(full, n_units)
    steady = Phase(count=n_folds - 1 + is_steady_last, size=s,
                   active=n_units, partial_size=0)
    edge = Phase(count=1 - is_steady_last, size=s, active=full,
                 partial_size=xp.where(partial_cnt > 0, last_partial, 0))
    return steady, edge


# ----------------------------------------------------------------------
# Order-oblivious (dense) level representation
# ----------------------------------------------------------------------
#
# The universal structure-as-operand evaluator (core.vectorized /
# repro.mapspace.universal) cannot branch on loop *order* or on which
# directive is spatial — those are traced operands.  A DenseLevel therefore
# carries per-dim quantities over a fixed dim universe: the loop order as a
# rank vector (higher rank = closer to the innermost position), the spatial
# choice as a 0/1 one-hot, and per-dim phases blended between their
# temporal and spatial forms by that one-hot.  Dims that are not loops at a
# level pass their extent through untouched (trip-count-1 behaviour), which
# is exactly how ``complete()`` treats unmentioned dims in the faithful
# engine.

def mix(xp: Backend, s, a, b):
    """Branch-free select ``s ? a : b`` for a 0/1 indicator ``s`` (exact for
    the small-integer quantities the analysis manipulates).  Static 0/1
    indicators short-circuit so the hybrid backend keeps Python ints."""
    if isinstance(s, (int, float, bool)):
        return a if s else b
    return s * a + (1 - s) * b


@dataclasses.dataclass
class DenseLevel:
    """Order-oblivious twin of :class:`LevelSpec`.

    ``rank`` holds each loop's position in the data-movement order (any
    strictly increasing outer->inner numbering; values may be traced).
    ``sp`` holds the spatial one-hot.  ``steady``/``edge`` hold per-dim
    phases already blended between spatial and temporal semantics, and
    ``off_eff`` the stride-scaled offsets (the CLA stride rule)."""
    index: int
    ext: dict[str, Any]                # dim universe extents at this level
    loop_dims: tuple[str, ...]         # dims that are loops here (static)
    edge_dims: tuple[str, ...]         # loops whose edge phase is enumerated
    rank: dict[str, Any]               # loop-order position per loop dim
    sp: dict[str, Any]                 # spatial one-hot per loop dim
    steady: dict[str, Phase]
    edge: dict[str, Phase]
    off_eff: dict[str, Any]            # stride-scaled offsets per loop dim
    n_units: Any
    is_innermost: bool
    single_edge: bool = False          # divisor-tiled: A+1 cases, not 2^A

    def trips(self, d: str):
        return self.steady[d].count + self.edge[d].count


def build_dense_level(xp: Backend, op: LayerOp, *, index: int,
                      ext: Mapping[str, Any], sizes: Mapping[str, Any],
                      offsets: Mapping[str, Any], rank: Mapping[str, Any],
                      sp: Mapping[str, Any], loop_dims: Sequence[str],
                      edge_dims: Sequence[str], n_units: Any,
                      innermost: bool, single_edge: bool = False
                      ) -> DenseLevel:
    """Instantiate one dense level: per-dim phases computed both ways
    (temporal and spatial) and blended by the spatial one-hot, extending the
    branch-free advancing-loop rule from tile sizes to structure."""
    steady: dict[str, Phase] = {}
    edge: dict[str, Phase] = {}
    off_eff: dict[str, Any] = {}
    for d in loop_dims:
        D = ext[d]
        off = offsets[d] * op.stride_of(d)
        off_eff[d] = off
        st_t, ed_t = temporal_phases(xp, D, sizes[d], off)
        s = sp.get(d, 0)
        if isinstance(s, (int, float)) and s == 0:
            steady[d], edge[d] = st_t, ed_t
            continue
        st_s, ed_s = spatial_phases(xp, D, sizes[d], off, n_units)
        steady[d] = Phase(
            count=mix(xp, s, st_s.count, st_t.count),
            size=st_t.size,  # min(size, D) either way
            active=mix(xp, s, st_s.active, 1),
            partial_size=mix(xp, s, st_s.partial_size, 0))
        edge[d] = Phase(
            count=mix(xp, s, ed_s.count, ed_t.count),
            size=mix(xp, s, ed_s.size, ed_t.size),
            active=mix(xp, s, ed_s.active, 1),
            partial_size=mix(xp, s, ed_s.partial_size, 0))
    return DenseLevel(
        index=index, ext=dict(ext), loop_dims=tuple(loop_dims),
        edge_dims=tuple(edge_dims), rank=dict(rank), sp=dict(sp),
        steady=steady, edge=edge, off_eff=off_eff, n_units=n_units,
        is_innermost=innermost, single_edge=single_edge)


def enumerate_cases_dense(level: DenseLevel, xp: Backend,
                          single_edge: bool = False
                          ) -> list["IterationCase"]:
    """Dense twin of :func:`enumerate_cases`: the phase cross product runs
    over ``edge_dims`` only (loops whose sizes are operands and may or may
    not divide their dim); every other loop contributes its steady phase.
    The first case is the all-steady case, as in the faithful engine.

    ``single_edge`` restricts the product to the all-steady case plus one
    edge per dim (A+1 cases instead of 2^A).  Exact for divisor-tiled
    spaces (``repro.mapspace``): temporal divisor tiles never produce an
    edge phase, so at most one loop — the spatially mapped one, which
    folds over the PE array — has a non-zero edge count, and every
    multi-edge case carries zero occurrences."""
    if single_edge:
        masks = [tuple(0 for _ in level.edge_dims)]
        for i in range(len(level.edge_dims)):
            masks.append(tuple(int(j == i)
                               for j in range(len(level.edge_dims))))
    else:
        masks = itertools.product((0, 1), repeat=len(level.edge_dims))
    cases: list[IterationCase] = []
    for mask in masks:
        choice = dict(zip(level.edge_dims, mask))
        occ = 1
        sizes = dict(level.ext)
        active = 1
        partials: dict[str, Any] = {}
        for d in level.loop_dims:
            ph = level.edge[d] if choice.get(d, 0) else level.steady[d]
            sizes[d] = ph.size
            occ = occ * ph.count
            # temporal phases have active == 1 / partial == 0, so plain
            # products reproduce the engine's min-over-spatial-loops
            active = active * ph.active
            partials[d] = ph.partial_size
        cases.append(IterationCase(
            occurrences=occ, sizes=sizes, active_units=active,
            partial_unit_sizes=partials, phase_ids=tuple(mask)))
    return cases


# ----------------------------------------------------------------------
# Level construction
# ----------------------------------------------------------------------

@dataclasses.dataclass
class LevelSpec:
    """One cluster level: its loops (outer→inner) and sub-unit count."""
    index: int
    loops: tuple[LoopInfo, ...]
    n_units: Any                 # sub-clusters (PEs at the innermost level)
    dims: dict[str, Any]         # dim extents seen by this level
    is_innermost: bool

    def spatial_loop(self) -> LoopInfo | None:
        for lp in self.loops:
            if lp.is_spatial:
                return lp
        return None

    def spatial_loops(self) -> tuple[LoopInfo, ...]:
        return tuple(lp for lp in self.loops if lp.is_spatial)

    def steady_tile(self) -> dict[str, Any]:
        """Per-sub-unit steady mapped extents (unmapped dims pass through)."""
        m = dict(self.dims)
        for lp in self.loops:
            m[lp.dim] = lp.steady.size
        return m


def unit_counts(xp: Backend, num_pes, cluster_sizes: Sequence[int]
                ) -> list[Any]:
    """Sub-unit count per level: ``[P/Πc, c1, ..., cL]`` (paper §3.2).

    Cluster sizes are capped by the PEs actually available, innermost
    first — an 8-PE machine running a ``Cluster(64)`` dataflow forms one
    8-wide cluster (which then folds), not a phantom 64-wide one."""
    eff: list[Any] = [None] * len(cluster_sizes)
    rem = xp.maximum(num_pes, 1)
    for i in range(len(cluster_sizes) - 1, -1, -1):
        ce = xp.maximum(xp.minimum(cluster_sizes[i], rem), 1)
        eff[i] = ce
        rem = xp.maximum(xp.floordiv(rem, ce), 1)
    top = rem
    return [top, *eff]


def build_levels(xp: Backend, df: Dataflow, op: LayerOp, num_pes
                 ) -> list[LevelSpec]:
    """Instantiate every cluster level against the layer.

    Level ``l+1`` sees dim extents equal to level ``l``'s steady per-unit
    mapped sizes (paper §4.4: multi-cluster splits into single-cluster cases
    with dim size = the upper level's mapping size)."""
    df = complete(df, op.dims)
    counts = unit_counts(xp, num_pes, df.cluster_sizes)
    level_maps = df.levels
    levels: list[LevelSpec] = []
    dims: dict[str, Any] = dict(op.dims)
    for li, maps in enumerate(level_maps):
        n_units = counts[li]
        loops: list[LoopInfo] = []
        for d in maps:
            D = dims[d.dim]
            if isinstance(d, SpatialMap):
                steady, edge = spatial_phases(xp, D, d.size, d.offset,
                                              n_units)
                loops.append(LoopInfo(d, d.dim, True, n_units, steady, edge))
            else:
                steady, edge = temporal_phases(xp, D, d.size, d.offset)
                loops.append(LoopInfo(d, d.dim, False, 1, steady, edge))
        spec = LevelSpec(index=li, loops=tuple(loops), n_units=n_units,
                         dims=dict(dims),
                         is_innermost=(li == len(level_maps) - 1))
        levels.append(spec)
        dims = spec.steady_tile()
    return levels


# ----------------------------------------------------------------------
# Case enumeration (the paper's ExtractDataIterationCases)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class IterationCase:
    """One element of the cross product of per-loop phases."""
    occurrences: Any             # product of phase counts
    sizes: dict[str, Any]        # per-unit mapped extent per dim
    active_units: Any            # fully-active sub-units this case
    partial_unit_sizes: dict[str, Any]  # spatial dim -> trailing unit extent
    phase_ids: tuple[int, ...]   # 0=steady / 1=edge per loop (for debugging)


def enumerate_cases(level: LevelSpec, xp: Backend) -> list[IterationCase]:
    """Cross product of per-loop phases; occurrence = Π phase counts.

    The structure (number of cases) is static per dataflow; counts may be 0
    (e.g. when a dim divides evenly there is no edge), which keeps the jnp
    twin branch-free.

    Multiple SpatialMaps at a level are *aligned* (unit u takes chunk u of
    every spatial dim): the first spatial loop drives folding; secondary
    spatial loops contribute sizes and clamp the jointly-active unit count
    via ``min``.  Secondary loops must cover their dim in a single fold
    (true of all Table 3 dataflows)."""
    first_spatial = next((i for i, lp in enumerate(level.loops)
                          if lp.is_spatial), None)
    loop_phase_lists: list[tuple[Phase, ...]] = []
    for i, lp in enumerate(level.loops):
        if lp.is_spatial and i != first_spatial:
            # Aligned secondary spatial map: the primary drives time, so a
            # secondary never contributes fold steps.  Collapse it to its
            # covering phase (first fold).  On an under-provisioned
            # cluster (fewer PEs than the dim) the uncovered tail is
            # honestly dropped — the mapping simply cannot express it.
            st, ed = lp.phases
            if isinstance(st.count, int) and isinstance(ed.count, int):
                loop_phase_lists.append((st if st.count >= 1 else ed,))
                continue
        loop_phase_lists.append(lp.phases)
    cases: list[IterationCase] = []
    for choice in itertools.product(
            *[range(len(p)) for p in loop_phase_lists]):
        occ = 1
        sizes = dict(level.dims)
        active = None
        partials: dict[str, Any] = {}
        for i, (lp, phs, ci) in enumerate(
                zip(level.loops, loop_phase_lists, choice)):
            ph = phs[ci]
            sizes[lp.dim] = ph.size
            if lp.is_spatial and i != first_spatial:
                occ = occ * xp.where(ph.count > 0, 1, 0)
            else:
                occ = occ * ph.count
            if lp.is_spatial:
                active = ph.active if active is None \
                    else xp.minimum(active, ph.active)
                partials[lp.dim] = ph.partial_size
        cases.append(IterationCase(
            occurrences=occ, sizes=sizes,
            active_units=1 if active is None else active,
            partial_unit_sizes=partials, phase_ids=choice))
    return cases
