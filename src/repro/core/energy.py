"""Energy / area / power tables (paper §5: Cacti 28 nm + RTL regression).

The paper multiplies MAESTRO's activity counts by per-access energies from a
CACTI simulation (28 nm, 2 KB L1 scratchpad, 1 MB shared L2) and fits
area/power of RTL building blocks (float/fixed MAC, bus, arbiter, scratchpads)
with linear (bus) and quadratic (arbiter) regressions.  The exact constants
are not published in the text, so the values below are *documented estimates*
calibrated to the same technology class and to the paper's anchor points
(Eyeriss-scale chip: 16 mm² / 450 mW budget binds at a few hundred PEs with
~100s of KB of SRAM).  Everything is replaceable (the paper notes Accelergy
can be swapped in); tests only rely on ordering properties, not absolutes.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-event energies in pJ (28 nm class).

    Reference capacities follow the paper's CACTI setup: the L1 cost is for
    a 2 KB scratchpad, the L2 cost for a 1 MB shared buffer.  Access energy
    scales ~sqrt(capacity) with the placed buffer size (CACTI wordline/
    bitline scaling), which is what makes the DSE's energy-vs-throughput
    trade-off non-trivial (Table 5)."""
    mac: float = 0.56            # 16-bit MAC
    l1_read: float = 1.12        # 2 KB scratchpad read
    l1_write: float = 1.12
    l2_read: float = 16.6        # 1 MB shared buffer read
    l2_write: float = 16.6
    noc_hop: float = 0.8         # per element per NoC traversal
    l1_ref_kb: float = 2.0
    l2_ref_kb: float = 1024.0

    def l1_scale(self, l1_kb: Any) -> Any:
        return _sqrt_scale(l1_kb, self.l1_ref_kb)

    def l2_scale(self, l2_kb: Any) -> Any:
        return _sqrt_scale(l2_kb, self.l2_ref_kb)

    def rel(self) -> dict[str, float]:
        """Relative table normalized to one MAC (Fig. 12 style)."""
        return {
            "mac": 1.0,
            "l1": self.l1_read / self.mac,
            "l2": self.l2_read / self.mac,
            "noc": self.noc_hop / self.mac,
        }


def _sqrt_scale(kb: Any, ref_kb: float) -> Any:
    """sqrt-capacity scaling with a floor so tiny buffers don't get free."""
    if isinstance(kb, (int, float)):
        return max(kb / ref_kb, 0.04) ** 0.5
    import jax.numpy as jnp
    return jnp.maximum(kb / ref_kb, 0.04) ** 0.5


@dataclasses.dataclass(frozen=True)
class AreaPowerModel:
    """RTL-regression-style models (paper §5.2).

    area(design)  = pes·pe_area + sram_kb·sram_area_kb
                  + bus: linear in width, arbiter: quadratic in endpoints
    power(design) = analogous with per-unit powers.
    """
    pe_area_mm2: float = 0.014        # MAC + control + L0 regs
    sram_area_mm2_per_kb: float = 0.006
    bus_area_mm2_per_lane: float = 0.004     # per element/cycle of BW
    arbiter_area_coeff: float = 1.2e-6       # × endpoints²

    pe_power_mw: float = 0.9
    sram_power_mw_per_kb: float = 0.18
    bus_power_mw_per_lane: float = 1.3
    arbiter_power_coeff: float = 6.0e-5      # × endpoints²

    # Static (leakage) energy: pJ per cycle per mm² @ 28 nm / 1 GHz.  This
    # is what makes slow low-PE designs lose on *energy*, not just runtime
    # (the paper's energy-optimal KC-P design keeps 80% of the PEs of the
    # throughput-optimal one rather than collapsing to a minimal array).
    static_pj_per_cycle_mm2: float = 2.0

    def static_energy_pj(self, area_mm2: Any, runtime_cycles: Any) -> Any:
        return self.static_pj_per_cycle_mm2 * area_mm2 * runtime_cycles

    def area(self, pes: Any, sram_kb: Any, noc_bw: Any) -> Any:
        return (pes * self.pe_area_mm2
                + sram_kb * self.sram_area_mm2_per_kb
                + noc_bw * self.bus_area_mm2_per_lane
                + (pes * pes) * self.arbiter_area_coeff)

    def power(self, pes: Any, sram_kb: Any, noc_bw: Any) -> Any:
        return (pes * self.pe_power_mw
                + sram_kb * self.sram_power_mw_per_kb
                + noc_bw * self.bus_power_mw_per_lane
                + (pes * pes) * self.arbiter_power_coeff)


DEFAULT_ENERGY = EnergyModel()
DEFAULT_AREA_POWER = AreaPowerModel()

# Paper's DSE budget = reported Eyeriss chip envelope.
EYERISS_AREA_MM2 = 16.0
EYERISS_POWER_MW = 450.0
