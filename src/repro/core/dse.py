"""Hardware design-space exploration (paper §5.2, Fig. 13, Table 5).

Searches four hardware parameters — #PEs, L1 size, L2 size, NoC bandwidth —
under area/power constraints, optimizing throughput, energy, or EDP.
As in the paper, buffer sizes are not free axes: MAESTRO *reports* the
buffer requirement of each (dataflow × #PEs) design and the DSE places
exactly that amount (sweeping dataflow tile-size variants changes the
requirement).  Designs whose area/power exceed the budget are invalid.

The paper prunes invalid designs during its nested sweep (0.17M designs/s
effective).  Our evaluator is a jit+vmap'd closed form, so we evaluate
*every* design and mask — cheaper per design than branchy skipping, and
embarrassingly parallel.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Iterable, Sequence

import numpy as np
import jax.numpy as jnp

from .. import obs
from .dataflows import table3_for_layer
from .directives import Cluster, Dataflow, SpatialMap, TemporalMap
from .energy import (DEFAULT_AREA_POWER, AreaPowerModel, EYERISS_AREA_MM2,
                     EYERISS_POWER_MW)
from .tensor_analysis import LayerOp
from .vectorized import BatchStats, batched_evaluator


@dataclasses.dataclass
class DSEConfig:
    pe_range: Sequence[int] = tuple(range(8, 1025, 8))
    bw_range: Sequence[float] = tuple(float(b) for b in range(1, 129, 1))
    area_budget_mm2: float = EYERISS_AREA_MM2
    power_budget_mw: float = EYERISS_POWER_MW
    area_power: AreaPowerModel = DEFAULT_AREA_POWER
    batch: int = 65536

    def __post_init__(self) -> None:
        from ..resilience.errors import SpecError
        for f, lo in (("pe_range", 1), ("bw_range", 1e-9)):
            rng = getattr(self, f)
            if len(rng) == 0 or any(not v >= lo for v in rng):
                raise SpecError(f"{f} must be non-empty with entries "
                                f">= {lo}", field=f)
        for f in ("area_budget_mm2", "power_budget_mw"):
            if not getattr(self, f) > 0:
                raise SpecError(f"{f} must be > 0, "
                                f"got {getattr(self, f)!r}", field=f)
        if self.batch < 1:
            raise SpecError(f"batch must be >= 1, got {self.batch!r}",
                            field="batch")


@dataclasses.dataclass
class DSEResult:
    num_pes: np.ndarray
    noc_bw: np.ndarray
    stats: BatchStats
    area_mm2: np.ndarray
    power_mw: np.ndarray
    valid: np.ndarray
    n_evaluated: int
    n_valid: int
    elapsed_s: float
    tile_tag: str = "base"

    @property
    def rate_designs_per_s(self) -> float:
        return self.n_evaluated / max(self.elapsed_s, 1e-9)

    def _masked(self, col: np.ndarray, maximize: bool) -> int:
        v = np.where(self.valid, col, -np.inf if maximize else np.inf)
        return int(np.argmax(v) if maximize else np.argmin(v))

    def best(self, objective: str) -> dict[str, Any]:
        """objective in {'throughput', 'energy', 'edp'}."""
        s = self.stats
        idx = {
            "throughput": self._masked(np.asarray(s.throughput), True),
            "energy": self._masked(np.asarray(s.energy_pj), False),
            "edp": self._masked(np.asarray(s.edp), False),
        }[objective]
        return self.point(idx)

    def point(self, idx: int) -> dict[str, Any]:
        s = self.stats
        return {
            "num_pes": int(self.num_pes[idx]),
            "noc_bw": float(self.noc_bw[idx]),
            "runtime": float(np.asarray(s.runtime)[idx]),
            "energy_pj": float(np.asarray(s.energy_pj)[idx]),
            "throughput": float(np.asarray(s.throughput)[idx]),
            "edp": float(np.asarray(s.edp)[idx]),
            "l1_kb": float(np.asarray(s.l1_kb)[idx]),
            "l2_kb": float(np.asarray(s.l2_kb)[idx]),
            "util": float(np.asarray(s.util)[idx]),
            "bw_req": float(np.asarray(s.bw_req)[idx]),
            "area_mm2": float(self.area_mm2[idx]),
            "power_mw": float(self.power_mw[idx]),
            "valid": bool(self.valid[idx]),
            "tile_tag": self.tile_tag,
        }

    def pareto(self, x: str = "energy_pj", y: str = "throughput",
               y_max: bool = True) -> np.ndarray:
        """Indices of the valid pareto frontier (minimize x, max/min y)."""
        xs = np.asarray(getattr(self.stats, x))
        ys = np.asarray(getattr(self.stats, y))
        idx = np.where(self.valid)[0]
        order = idx[np.argsort(xs[idx])]
        front, best = [], -np.inf if y_max else np.inf
        for i in order:
            v = ys[i]
            if (v > best) if y_max else (v < best):
                front.append(i)
                best = v
        return np.asarray(front, dtype=np.int64)


def run_dse(op: LayerOp, df: Dataflow, cfg: DSEConfig | None = None,
            *, multicast: bool = True, spatial_reduction: bool = True,
            tile_tag: str = "base") -> DSEResult:
    """Sweep the (PEs × NoC bw) grid for one (layer × dataflow)."""
    cfg = cfg or DSEConfig()
    f = batched_evaluator(op, df, multicast=multicast,
                          spatial_reduction=spatial_reduction)
    pes_g, bw_g = np.meshgrid(np.asarray(cfg.pe_range, np.int64),
                              np.asarray(cfg.bw_range, np.float32),
                              indexing="ij")
    pes, bws = pes_g.ravel(), bw_g.ravel()
    obs.metrics().inc("dse.designs", len(pes))
    # warm up the executable so the reported rate is the steady-state rate
    with obs.span("warmup", engine="dse-grid", op=op.name, df=df.name):
        _ = f(jnp.asarray(pes[:2]), jnp.asarray(bws[:2]))
    feats_out = []
    with obs.span("device-pass", engine="dse-grid", op=op.name,
                  df=df.name, rows=len(pes)):
        t0 = time.perf_counter()
        for i in range(0, len(pes), cfg.batch):
            feats_out.append(np.asarray(
                f(jnp.asarray(pes[i:i + cfg.batch]),
                  jnp.asarray(bws[i:i + cfg.batch]))))
        elapsed = time.perf_counter() - t0
    obs.metrics().observe("dse.grid_s", elapsed)
    feats = np.concatenate(feats_out, axis=0)
    stats = BatchStats.from_features(feats)

    sram_kb = np.asarray(stats.l1_kb) * pes + np.asarray(stats.l2_kb)
    area = cfg.area_power.area(pes, sram_kb, bws)
    power = cfg.area_power.power(pes, sram_kb, bws)
    valid = (area <= cfg.area_budget_mm2) & (power <= cfg.power_budget_mw)
    # total energy = dynamic (activity counts) + static (leakage × runtime)
    static = cfg.area_power.static_energy_pj(area, np.asarray(stats.runtime))
    stats.energy_pj = np.asarray(stats.energy_pj) + static
    stats.edp = stats.energy_pj * np.asarray(stats.runtime)
    return DSEResult(
        num_pes=pes, noc_bw=bws, stats=stats, area_mm2=area,
        power_mw=power, valid=np.asarray(valid), n_evaluated=len(pes),
        n_valid=int(np.sum(valid)), elapsed_s=elapsed, tile_tag=tile_tag)


# ----------------------------------------------------------------------
# Tile-size variants: the L1/L2 axes of the paper's 4-parameter search.
# ----------------------------------------------------------------------

def tile_variants(df: Dataflow, scales: Iterable[int] = (1, 2, 4),
                  dims: Iterable[str] = ("C", "K")) -> list[tuple[str, Dataflow]]:
    """Scale the concrete (non-symbolic) tile sizes of selected temporal
    maps — each variant implies a different buffer placement, which is how
    the DSE explores the L1/L2 axes.

    Symbolic (``Sz``/``FULL``) sizes are never scaled — they already mean
    "the whole dim".  The variant tag names only the dims actually scaled
    (e.g. ``x4[C]``); scales that scale nothing (every candidate directive
    symbolic) are dropped instead of silently emitting duplicates of the
    base dataflow under a misleading tag."""
    out: list[tuple[str, Dataflow]] = []
    seen: set[tuple] = set()
    for sc in scales:
        dirs = []
        scaled: list[str] = []
        for d in df.directives:
            if (sc != 1 and isinstance(d, TemporalMap) and d.dim in dims
                    and isinstance(d.size, int) and d.size > 0):
                dirs.append(TemporalMap(max(1, d.size * sc),
                                        max(1, d.offset * sc)
                                        if isinstance(d.offset, int)
                                        and d.offset > 0
                                        else d.offset, d.dim))
                scaled.append(d.dim)
            else:
                dirs.append(d)
        variant = Dataflow(df.name, tuple(dirs))
        if variant.directives in seen:
            continue
        seen.add(variant.directives)
        tag = "base" if sc == 1 or not scaled \
            else f"x{sc}[{','.join(scaled)}]"
        out.append((tag, variant))
    return out


def run_dse_full(op: LayerOp, dataflow_name: str,
                 cfg: DSEConfig | None = None,
                 scales: Iterable[int] = (1, 2, 4)) -> list[DSEResult]:
    """The paper's full 4-parameter DSE: (PEs × bw) grid × tile variants."""
    base = table3_for_layer(dataflow_name, op)
    results = []
    for tag, dfv in tile_variants(base, scales):
        results.append(run_dse(op, dfv, cfg, tile_tag=tag))
    return results


def merge_results(results: Sequence[DSEResult]) -> dict[str, Any]:
    """Aggregate DSE statistics across variants (Fig. 13c style)."""
    n_eval = sum(r.n_evaluated for r in results)
    n_valid = sum(r.n_valid for r in results)
    elapsed = sum(r.elapsed_s for r in results)
    best = {}
    for obj in ("throughput", "energy", "edp"):
        cands = [r.best(obj) for r in results if r.n_valid]
        key = (lambda p: -p["throughput"]) if obj == "throughput" \
            else (lambda p: p["energy_pj"] if obj == "energy" else p["edp"])
        best[obj] = min(cands, key=key) if cands else None
    return {
        "n_evaluated": n_eval,
        "n_valid": n_valid,
        "elapsed_s": elapsed,
        "rate_designs_per_s": n_eval / max(elapsed, 1e-9),
        "best": best,
    }
