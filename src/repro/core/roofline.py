"""Three-term roofline from compiled dry-run artifacts (deliverable g).

    compute    = HLO_FLOPs  / (chips × 197 TFLOP/s)
    memory     = HLO_bytes  / (chips × 819 GB/s)
    collective = coll_bytes / (chips × 50 GB/s/link)
    step_time  = max(compute, memory, collective)

The max-combiner is MAESTRO's double-buffered outstanding-delay rule
(Fig. 8) applied at pod scale: ingress/egress (HBM + ICI) overlap compute.
``MODEL_FLOPS = 6·N·D`` (N = active params, D = tokens) gives the
useful-compute ratio — remat recompute and padding show up as
HLO_FLOPs > MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

V5E_PEAK_FLOPS = 197e12      # bf16, per chip
V5E_HBM_BW = 819e9           # bytes/s, per chip
V5E_ICI_BW = 50e9            # bytes/s, per link (per prompt spec)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    tokens: int
    per_device: bool = True   # cost_analysis numbers are per-device

    @property
    def compute_s(self) -> float:
        chips = 1 if self.per_device else self.chips
        return self.hlo_flops / (chips * V5E_PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        chips = 1 if self.per_device else self.chips
        return self.hlo_bytes / (chips * V5E_HBM_BW)

    @property
    def collective_s(self) -> float:
        chips = 1 if self.per_device else self.chips
        return self.collective_bytes / (chips * V5E_ICI_BW)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global)."""
        chips = self.chips if self.per_device else 1
        total = self.hlo_flops * chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.chips * V5E_PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops, "tokens": self.tokens,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "step_s": self.step_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio, "mfu": self.mfu,
        }


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward."""
    n_active = cfg.param_counts()["active"]
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens


def from_dryrun(record: dict, cfg=None) -> RooflineTerms:
    return RooflineTerms(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=record["chips"],
        hlo_flops=record.get("flops", 0.0),
        hlo_bytes=record.get("bytes_accessed", 0.0),
        collective_bytes=record.get("collective_bytes", 0.0),
        model_flops=record.get("model_flops", 0.0),
        tokens=record.get("tokens", 0),
    )


def format_table(rows: list[RooflineTerms]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'MFU':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
            f"{r.compute_s:10.3e} {r.memory_s:10.3e} "
            f"{r.collective_s:10.3e} {r.bottleneck:>10s} "
            f"{r.useful_ratio:7.3f} {r.mfu:6.3f}")
    return "\n".join(lines)


def save_json(rows: list[RooflineTerms], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=1)
