"""The paper's example dataflows (Table 3) plus the pedagogical 1-D conv
variants of Fig. 5 and the row-stationary example of Fig. 6.

Names follow the paper: the partitioning strategy is named after the
spatially mapped dims from the upper-most cluster level.  ``Sz("R")`` is the
paper's symbolic ``Sz(R)`` (resolved per layer); ``FULL`` abbreviates
``Sz(<own dim>)``.

Note Table 3's YR-P entry contains two obvious typos in the paper
(``SpatialMap(52(R),1) Y`` and ``TemporalMap(Sz(S),Sz(R)) R``); we use the
evident intent (``Sz(R)`` / ``(Sz(R),Sz(R))``), which matches the Eyeriss
row-stationary structure the entry cites.
"""
from __future__ import annotations

from .directives import (FULL, Cluster, Dataflow, SpatialMap, Sz,
                         TemporalMap)

# ----------------------------------------------------------------------
# Table 3 — the five dataflow styles used in the case studies
# ----------------------------------------------------------------------

# C-Partitioned: input-channel parallelism, large spatial reduction.
C_P = Dataflow("C-P", (
    TemporalMap(1, 1, "K"),
    TemporalMap(Sz("R"), 1, "Y"),
    TemporalMap(Sz("S"), 1, "X"),
    TemporalMap(Sz("R"), Sz("R"), "R"),
    TemporalMap(Sz("S"), Sz("S"), "S"),
    SpatialMap(1, 1, "C"),
))

# X-Partitioned: input-column parallelism, weight-stationary.
X_P = Dataflow("X-P", (
    TemporalMap(1, 1, "K"),
    TemporalMap(1, 1, "C"),
    TemporalMap(Sz("R"), Sz("R"), "R"),
    TemporalMap(Sz("S"), Sz("S"), "S"),
    TemporalMap(Sz("R"), 1, "Y"),
    SpatialMap(Sz("S"), 1, "X"),
))

# YX-Partitioned (ShiDianNao-style): 2-D activation parallelism,
# output-stationary.  The X tile is 8 output columns + halo
# (``TemporalMap(8+Sz(S)-1, 8) X``), resolved per layer via yx_p().


def yx_p(s_size: int = 3, stride: int = 1) -> Dataflow:
    # tile = 8 *output* columns: (8-1)·stride + Sz(S) input columns.
    return Dataflow("YX-P", (
        TemporalMap(1, 1, "K"),
        SpatialMap(Sz("R"), 1, "Y"),
        TemporalMap((8 - 1) * stride + s_size, 8, "X"),
        TemporalMap(1, 1, "C"),
        TemporalMap(Sz("R"), Sz("R"), "R"),
        TemporalMap(Sz("S"), Sz("S"), "S"),
        Cluster(8),
        SpatialMap(Sz("S"), 1, "X"),
    ))


YX_P = yx_p()

# YR-Partitioned (Eyeriss-style row-stationary): Y across clusters, aligned
# Y/R diagonal inside each cluster.
YR_P = Dataflow("YR-P", (
    TemporalMap(2, 2, "C"),
    TemporalMap(2, 2, "K"),
    SpatialMap(Sz("R"), 1, "Y"),
    TemporalMap(Sz("S"), 1, "X"),
    TemporalMap(Sz("R"), Sz("R"), "R"),
    TemporalMap(Sz("S"), Sz("S"), "S"),
    Cluster(Sz("R")),
    SpatialMap(1, 1, "Y"),
    SpatialMap(1, 1, "R"),
))

# KC-Partitioned (NVDLA-style): K across clusters, C inside — weight
# stationary with a 64-way spatial reduction.
KC_P = Dataflow("KC-P", (
    SpatialMap(1, 1, "K"),
    TemporalMap(64, 64, "C"),
    TemporalMap(Sz("R"), Sz("R"), "R"),
    TemporalMap(Sz("S"), Sz("S"), "S"),
    TemporalMap(Sz("R"), 1, "Y"),
    TemporalMap(Sz("S"), 1, "X"),
    Cluster(64),
    SpatialMap(1, 1, "C"),
))

TABLE3 = {"C-P": C_P, "X-P": X_P, "YX-P": YX_P, "YR-P": YR_P, "KC-P": KC_P}


def table3_for_layer(name: str, op) -> Dataflow:
    """Resolve a Table 3 dataflow's layer-dependent parameters.  ``op`` is a
    :class:`LayerOp` (or a plain dims dict for stride-1 ops)."""
    dims = op if isinstance(op, dict) else op.dims
    if name == "YX-P":
        stride = 1 if isinstance(op, dict) else op.stride_of("X")
        return yx_p(dims.get("S", 1), stride)
    return TABLE3[name]


# ----------------------------------------------------------------------
# Fig. 5 — the 1-D convolution playground.
#
# The paper's Fig. 4/5 write directives over X' (outputs) and S (weights);
# we express them over the output-centric 1-D conv op
# (:func:`repro.core.tensor_analysis.conv1d_outputs`), whose dims are
# X (output positions) and S (filter taps).
# ----------------------------------------------------------------------

FIG5_A = Dataflow("fig5-A-output-stationary", (
    SpatialMap(1, 1, "X"),       # X' spatial, one output per PE
    TemporalMap(1, 1, "S"),
))

FIG5_B = Dataflow("fig5-B-weight-stationary", (
    TemporalMap(1, 1, "S"),
    SpatialMap(1, 1, "X"),
))

FIG5_C = Dataflow("fig5-C-weight-spatial-os", (
    SpatialMap(1, 1, "S"),
    TemporalMap(1, 1, "X"),
))

FIG5_D = Dataflow("fig5-D-weight-spatial-ws", (
    TemporalMap(1, 1, "X"),
    SpatialMap(1, 1, "S"),
))

FIG5_E = Dataflow("fig5-E-tiled", (
    SpatialMap(3, 3, "S"),
    TemporalMap(2, 2, "X"),
))

FIG5_F = Dataflow("fig5-F-clustered", (
    SpatialMap(1, 1, "X"),
    Cluster(3),
    SpatialMap(1, 1, "S"),
))

FIG5 = {"A": FIG5_A, "B": FIG5_B, "C": FIG5_C, "D": FIG5_D, "E": FIG5_E,
        "F": FIG5_F}

# Fig. 4's base dataflow: SpatialMap(2,2) X', TemporalMap(3,3) S.
FIG4 = Dataflow("fig4-base", (
    SpatialMap(2, 2, "X"),
    TemporalMap(3, 3, "S"),
))

# ----------------------------------------------------------------------
# Fig. 6 — six-PE row-stationary example (2 clusters × 3 PEs)
# ----------------------------------------------------------------------

ROW_STATIONARY_6PE = Dataflow("row-stationary-6pe", (
    TemporalMap(1, 1, "K"),
    TemporalMap(1, 1, "C"),
    SpatialMap(Sz("R"), 1, "Y"),
    TemporalMap(Sz("S"), 1, "X"),
    Cluster(Sz("R")),
    SpatialMap(1, 1, "Y"),
    SpatialMap(1, 1, "R"),
))
