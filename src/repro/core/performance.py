"""Performance analysis (PA) engine pieces: the abstract hardware model and
the per-case delay math (paper §4.2, Fig. 8).

The NoC is the paper's *pipe model*: a bandwidth (elements/cycle) and an
average latency (cycles).  Communication delay of V elements is
``ceil(V / bw) + latency`` — the pipelining effect of packet-switched NoCs.
Double buffering makes the steady-state step delay
``max(ingress, compute, egress)``; the initialization case is serial
(``ingress + compute + egress``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from .cluster_analysis import Backend


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """Abstract accelerator model (paper Fig. 2).

    ``noc_bw`` is in data elements/cycle; ``noc_latency`` in cycles.
    ``multicast``/``spatial_reduction`` gate the hardware support of Table 2
    (their absence is the Table 5 ablation).  ``l1_kb``/``l2_kb`` of ``None``
    mean "place exactly what MAESTRO reports" (the paper's DSE behaviour);
    concrete values turn into validity constraints.

    The network-schedule fields (``repro.netspace``) model what single-layer
    analysis cannot see: ``dram_bw``/``dram_energy_pj`` price the off-chip
    boundary that fused layer stacks avoid crossing for intermediate
    activations, and ``reconfig_latency`` is the fixed pipeline cost of
    switching the PE array between differing mappings (on top of the
    L1/L2 drain/refill traffic, see :func:`reconfig_cycles`).
    """
    num_pes: Any
    noc_bw: Any = 32.0
    noc_latency: Any = 2.0
    macs_per_pe: int = 1
    multicast: bool = True
    spatial_reduction: bool = True
    dtype_bytes: int = 2
    l1_kb: Any = None
    l2_kb: Any = None
    freq_mhz: float = 1000.0
    dram_bw: Any = 16.0          # off-chip elements/cycle (DDR-class)
    dram_energy_pj: float = 100.0  # per element off-chip transfer (28 nm)
    reconfig_latency: Any = 0.0  # fixed cycles per dataflow switch

    def replace(self, **kw) -> "HWConfig":
        return dataclasses.replace(self, **kw)


def comm_delay(xp: Backend, volume: Any, hw: HWConfig) -> Any:
    """Pipe-model delay for ``volume`` elements (0 volume → 0 delay)."""
    d = xp.ceil_div(volume, hw.noc_bw) + hw.noc_latency
    return xp.where(volume > 0, d, 0)


def compute_delay(xp: Backend, psums: Any, hw: HWConfig) -> Any:
    return xp.ceil_div(psums, hw.macs_per_pe)


def log2_ceil(xp: Backend, x: Any) -> Any:
    if isinstance(x, int):
        return max(0, (max(x, 1) - 1)).bit_length()
    import jax.numpy as jnp
    xf = jnp.maximum(x, 1).astype(jnp.float32)
    return jnp.ceil(jnp.log2(xf)).astype(jnp.int32)


def reduction_fwd_delay(xp: Backend, active_units: Any, hw: HWConfig,
                        enabled: bool) -> Any:
    """Adder-tree spatial-reduction latency (paper GetPSumFwdDelay):
    ``ceil(log2(n))`` stages; zero when the level has no spatial reduction."""
    if not enabled:
        return 0
    return log2_ceil(xp, active_units)


def dram_cycles(xp: Backend, volume: Any, hw: HWConfig) -> Any:
    """Off-chip transfer delay for ``volume`` elements at ``hw.dram_bw``
    (0 volume → 0 delay) — the boundary cost a fused layer stack saves."""
    d = xp.ceil_div(volume, hw.dram_bw)
    return xp.where(volume > 0, d, 0)


def reconfig_cycles(xp: Backend, hw: HWConfig, *, l1_prev_kb: Any,
                    l2_prev_kb: Any, l1_next_kb: Any, l2_next_kb: Any,
                    num_pes: Any | None = None) -> Any:
    """Cycles to switch the PE array between two differing mappings: the
    outgoing mapping's L1/L2 working set drains and the incoming one's
    refills over the NoC, plus the fixed control overhead
    ``hw.reconfig_latency``.  L1 is per-PE (drained across ``num_pes``
    units); volumes convert from the KB the analysis reports."""
    pes = hw.num_pes if num_pes is None else num_pes
    kb = (l1_prev_kb + l1_next_kb) * pes + l2_prev_kb + l2_next_kb
    elems = kb * 1024.0 / hw.dtype_bytes
    return hw.reconfig_latency + xp.ceil_div(elems, hw.noc_bw)
