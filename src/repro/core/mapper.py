"""The MAESTRO ↔ TPU bridge: directive programs as mesh sharding, and the
pod as an abstract MAESTRO accelerator.

Two directions:

1. ``dataflow_to_pspec``: lower a directive program for a tensor op onto a
   mesh — SpatialMap at cluster level *l* ⇒ shard that dim over mesh axis
   *l*; temporal maps stay on-chip.  This lets the paper's Table-3 programs
   be *executed* as sharding strategies (examples/sharding_advisor.py).

2. ``analyze_tpu_mapping``: run the MAESTRO cost engines on a
   (GEMM × sharding) pair with the pod modeled as the abstract accelerator
   of Fig. 2 — chips = PEs, per-chip HBM = L1, pod-global = L2, ICI = the
   NoC pipe model.  The reuse analysis then *predicts* which collectives
   the SPMD partitioner must insert:

      input tensor decoupled from a sharded dim  -> spatial multicast
                                                    (all-gather / broadcast)
      output decoupled from a sharded dim (C-par) -> spatial reduction
                                                    (all-reduce / reduce-
                                                     scatter = psum)

   ``expected_collectives`` is cross-checked against the dry-run HLO in
   tests/test_mapper.py — the paper's Table 1 validated against XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from jax.sharding import Mesh, PartitionSpec as P

from .dataflows import KC_P
from .directives import Cluster, Dataflow, SpatialMap, TemporalMap
from .model import Stats, analyze
from .performance import HWConfig
from .reuse_analysis import MULTICAST, REDUCTION
from .tensor_analysis import LayerOp, fc

# TPU v5e constants (also used by core/roofline.py)
V5E_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
V5E_HBM_BW = 819e9               # bytes/s per chip
V5E_ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class TPUMapping:
    """A sharding choice for one GEMM-shaped op, in both vocabularies."""
    dataflow: Dataflow
    pspec_out: P
    pspec_lhs: P
    pspec_rhs: P
    expected_collectives: dict[str, str]   # tensor -> collective kind
    stats: Stats | None = None


def gemm_op(name: str, m: int, n: int, k: int) -> LayerOp:
    """O[M,N] += L[M,K] R[K,N] with MAESTRO dims N_fc=M, K_fc=N, C_fc=K."""
    return fc(name, n=m, k=n, c=k)


# FC-dim -> (tensor axis position) for pspec construction
_FC_AXES = {
    "lhs": {"N": 0, "C": 1},     # I[M, K]
    "rhs": {"C": 0, "K": 1},     # F[K, N]
    "out": {"N": 0, "K": 1},     # O[M, N]
}


def dataflow_to_pspec(df: Dataflow, mesh: Mesh, op: LayerOp
                      ) -> dict[str, P]:
    """SpatialMap at cluster level l ⇒ shard dim over mesh axis l.

    Mesh axes are ordered outer→inner to match cluster levels; the number
    of Cluster directives must be < len(mesh.axis_names)."""
    levels = df.levels
    if len(levels) > len(mesh.axis_names):
        raise ValueError(
            f"{df.name}: {len(levels)} cluster levels > mesh rank "
            f"{len(mesh.axis_names)}")
    dim_to_axis: dict[str, str] = {}
    for li, maps in enumerate(levels):
        for d in maps:
            if isinstance(d, SpatialMap):
                dim_to_axis[d.dim] = mesh.axis_names[li]
    out: dict[str, P] = {}
    for t, pos in _FC_AXES.items():
        parts: list[Any] = [None, None]
        for dim, i in pos.items():
            if dim in dim_to_axis:
                parts[i] = dim_to_axis[dim]
        out[t] = P(*parts)
    return out


def expected_collectives(df: Dataflow, op: LayerOp) -> dict[str, str]:
    """Table-1 logic → the collective XLA must insert per tensor."""
    sdims = {d.dim for d in df.directives if isinstance(d, SpatialMap)}
    out: dict[str, str] = {}
    for t in op.input_tensors():
        if sdims and not any(t.coupled_to(s) for s in sdims):
            out[t.name] = "all-gather"       # spatial multicast
    if sdims & op.reduction_dims():
        out[op.output.name] = "all-reduce"   # spatial reduction (psum)
    return out


def analyze_tpu_mapping(df: Dataflow, op: LayerOp, mesh: Mesh,
                        *, dtype_bytes: int = 2,
                        freq_hz: float = 1.0e9) -> TPUMapping:
    """MAESTRO's engines applied to the pod: chips = PEs; the NoC pipe
    model gets ICI bandwidth in elements/cycle."""
    n_chips = int(mesh.devices.size)
    elems_per_cycle = V5E_ICI_BW / freq_hz / dtype_bytes
    hw = HWConfig(num_pes=n_chips, noc_bw=elems_per_cycle,
                  noc_latency=1.0,
                  macs_per_pe=int(V5E_PEAK_FLOPS / 2 / freq_hz))
    stats = analyze(op, df, hw)
    pspecs = dataflow_to_pspec(df, mesh, op)
    return TPUMapping(
        dataflow=df,
        pspec_out=pspecs["out"], pspec_lhs=pspecs["lhs"],
        pspec_rhs=pspecs["rhs"],
        expected_collectives=expected_collectives(df, op),
        stats=stats)


# ----------------------------------------------------------------------
# Canonical LM-training mappings in directive form
# ----------------------------------------------------------------------

def megatron_tp(mesh: Mesh) -> Dataflow:
    """Tensor parallelism over output features = the paper's K-partitioned
    family (NVDLA's KC-P outer level): weights stationary per chip, inputs
    multicast (all-gather), no output reduction."""
    return Dataflow("tp-K-partitioned", (
        TemporalMap(1, 1, "N"),
        SpatialMap(1, 1, "K"),
    ))


def contraction_tp(mesh: Mesh) -> Dataflow:
    """Sharded contraction (the second GEMM of an MLP): C-partitioned —
    spatial reduction ⇒ all-reduce/reduce-scatter, exactly MAESTRO's
    C-P row of Table 1."""
    return Dataflow("tp-C-partitioned", (
        TemporalMap(1, 1, "N"),
        TemporalMap(1, 1, "K"),
        SpatialMap(1, 1, "C"),
    ))


def fsdp_dp(mesh: Mesh) -> Dataflow:
    """Data parallelism with ZeRO-3: batch spatially mapped across the
    data axis.  Weights are decoupled from N ⇒ spatial multicast — the
    FSDP all-gather.  In the *backward* GEMM (dW = Xᵀ·dY) N becomes the
    contraction dim, so the same taxonomy row flips to spatial reduction —
    the gradient reduce-scatter.  One Table-1 row explains both FSDP
    collectives."""
    return Dataflow("dp-N-partitioned", (
        SpatialMap(1, 1, "N"),
        TemporalMap(1, 1, "K"),
        TemporalMap(1, 1, "C"),
    ))
