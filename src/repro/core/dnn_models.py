"""DNN model zoo for the case studies (paper §5, Table 4).

Layer tables for VGG16, AlexNet, ResNet50, MobileNetV2, ResNeXt50 and UNet,
expressed as :class:`LayerOp` lists.  Shapes follow the original papers
(ImageNet-224 inputs unless noted; UNet uses its 572×572 input).  Residual
links / concatenations are data-movement-only and are represented by their
constituent convolutions (the paper's Table 4 treats them the same way).

Each layer is tagged ``early`` or ``late`` by the paper's rule (footnote 2):
``late if C > Y else early``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .tensor_analysis import (LayerOp, conv2d, dwconv2d, fc, pointwise_conv,
                              transposed_conv2d)


def layer_class(op: LayerOp) -> str:
    """Paper footnote 2: if C > Y → late layer, else early layer."""
    c = op.dims.get("C", 1)
    y = op.dims.get("Y", 1)
    if op.op_type == "FC":
        return "fc"
    if op.op_type == "DWCONV":
        return "dwconv"
    if op.dims.get("R", 1) == 1 and op.dims.get("S", 1) == 1 \
            and op.op_type == "CONV2D":
        return "pointwise"
    return "late" if c > y else "early"


# ----------------------------------------------------------------------
# VGG16 (Simonyan & Zisserman) — 13 CONV + 3 FC
# ----------------------------------------------------------------------

def vgg16() -> list[LayerOp]:
    cfg = [  # (name, k, c, y, x)
        ("conv1", 64, 3, 224, 224), ("conv2", 64, 64, 224, 224),
        ("conv3", 128, 64, 112, 112), ("conv4", 128, 128, 112, 112),
        ("conv5", 256, 128, 56, 56), ("conv6", 256, 256, 56, 56),
        ("conv7", 256, 256, 56, 56), ("conv8", 512, 256, 28, 28),
        ("conv9", 512, 512, 28, 28), ("conv10", 512, 512, 28, 28),
        ("conv11", 512, 512, 14, 14), ("conv12", 512, 512, 14, 14),
        ("conv13", 512, 512, 14, 14),
    ]
    layers = [conv2d(f"vgg16-{n}", k=k, c=c, y=y + 2, x=x + 2, r=3, s=3)
              for n, k, c, y, x in cfg]  # +2 = 'same' padding halo
    layers += [
        fc("vgg16-fc1", k=4096, c=25088),
        fc("vgg16-fc2", k=4096, c=4096),
        fc("vgg16-fc3", k=1000, c=4096),
    ]
    return layers


# ----------------------------------------------------------------------
# AlexNet (for the Eyeriss Fig. 9 validation point)
# ----------------------------------------------------------------------

def alexnet() -> list[LayerOp]:
    return [
        conv2d("alexnet-conv1", k=96, c=3, y=227, x=227, r=11, s=11,
               stride=4),
        conv2d("alexnet-conv2", k=256, c=48, y=31, x=31, r=5, s=5),
        conv2d("alexnet-conv3", k=384, c=256, y=15, x=15, r=3, s=3),
        conv2d("alexnet-conv4", k=384, c=192, y=15, x=15, r=3, s=3),
        conv2d("alexnet-conv5", k=256, c=192, y=15, x=15, r=3, s=3),
        fc("alexnet-fc1", k=4096, c=9216),
        fc("alexnet-fc2", k=4096, c=4096),
        fc("alexnet-fc3", k=1000, c=4096),
    ]


# ----------------------------------------------------------------------
# ResNet50 — bottleneck blocks: 1x1 reduce, 3x3, 1x1 expand
# ----------------------------------------------------------------------

def resnet50() -> list[LayerOp]:
    layers = [conv2d("resnet50-conv1", k=64, c=3, y=230, x=230, r=7, s=7,
                     stride=2)]
    # (stage, blocks, c_in_first, c_mid, c_out, y)
    stages = [
        (2, 3, 64, 64, 256, 56),
        (3, 4, 256, 128, 512, 28),
        (4, 6, 512, 256, 1024, 14),
        (5, 3, 1024, 512, 2048, 7),
    ]
    for st, blocks, c_in, c_mid, c_out, y in stages:
        for b in range(blocks):
            cin = c_in if b == 0 else c_out
            pre = f"resnet50-conv{st}_{b + 1}"
            layers.append(pointwise_conv(f"{pre}a", k=c_mid, c=cin, y=y, x=y))
            layers.append(conv2d(f"{pre}b", k=c_mid, c=c_mid, y=y + 2,
                                 x=y + 2, r=3, s=3))
            layers.append(pointwise_conv(f"{pre}c", k=c_out, c=c_mid, y=y,
                                         x=y))
    layers.append(fc("resnet50-fc1000", k=1000, c=2048))
    return layers


# ----------------------------------------------------------------------
# ResNeXt50 (32x4d) — aggregated residual blocks (grouped 3x3 modeled as
# its per-group depth of C/32; the paper lists its DWCONV-like operator)
# ----------------------------------------------------------------------

def resnext50() -> list[LayerOp]:
    layers = [conv2d("resnext50-conv1", k=64, c=3, y=230, x=230, r=7, s=7,
                     stride=2)]
    stages = [
        (2, 3, 64, 128, 256, 56),
        (3, 4, 256, 256, 512, 28),
        (4, 6, 512, 512, 1024, 14),
        (5, 3, 1024, 1024, 2048, 7),
    ]
    for st, blocks, c_in, c_mid, c_out, y in stages:
        for b in range(blocks):
            cin = c_in if b == 0 else c_out
            pre = f"resnext50-conv{st}_{b + 1}"
            layers.append(pointwise_conv(f"{pre}a", k=c_mid, c=cin, y=y, x=y))
            # 32 groups: each 3x3 sees c_mid/32 channels; aggregate MACs by
            # modeling K=c_mid, C=c_mid/32 (grouped conv equivalent cost).
            layers.append(conv2d(f"{pre}b", k=c_mid, c=max(1, c_mid // 32),
                                 y=y + 2, x=y + 2, r=3, s=3))
            layers.append(pointwise_conv(f"{pre}c", k=c_out, c=c_mid, y=y,
                                         x=y))
    layers.append(fc("resnext50-fc1000", k=1000, c=2048))
    return layers


# ----------------------------------------------------------------------
# MobileNetV2 — inverted residual bottlenecks (PW expand, DW 3x3, PW project)
# ----------------------------------------------------------------------

def mobilenet_v2() -> list[LayerOp]:
    layers = [conv2d("mnv2-conv1", k=32, c=3, y=226, x=226, r=3, s=3,
                     stride=2)]
    # (t_expand, c_out, n_blocks, stride, y_in, c_in)
    cfg = [
        (1, 16, 1, 1, 112, 32),
        (6, 24, 2, 2, 112, 16),
        (6, 32, 3, 2, 56, 24),
        (6, 64, 4, 2, 28, 32),
        (6, 96, 3, 1, 14, 64),
        (6, 160, 3, 2, 14, 96),
        (6, 320, 1, 1, 7, 160),
    ]
    for bi, (t, c_out, n, stride, y, c_in) in enumerate(cfg, start=1):
        cin = c_in
        yy = y
        for b in range(n):
            st = stride if b == 0 else 1
            hid = cin * t
            pre = f"mnv2-bneck{bi}_{b + 1}"
            if t != 1:
                layers.append(pointwise_conv(f"{pre}-pw1", k=hid, c=cin,
                                             y=yy, x=yy))
            layers.append(dwconv2d(f"{pre}-dw", c=hid, y=yy + 2, x=yy + 2,
                                   r=3, s=3, stride=st))
            yy = yy // st
            layers.append(pointwise_conv(f"{pre}-pw2", k=c_out, c=hid,
                                         y=yy, x=yy))
            cin = c_out
    layers.append(pointwise_conv("mnv2-conv-last", k=1280, c=320, y=7, x=7))
    layers.append(fc("mnv2-fc", k=1000, c=1280))
    return layers


# ----------------------------------------------------------------------
# UNet — 572x572 segmentation net with up-convolutions
# ----------------------------------------------------------------------

def unet() -> list[LayerOp]:
    layers: list[LayerOp] = []
    # encoder: double 3x3 convs (valid padding) + pool
    enc = [  # (y_in, c_in, k)
        (572, 1, 64), (570, 64, 64),
        (284, 64, 128), (282, 128, 128),
        (140, 128, 256), (138, 256, 256),
        (68, 256, 512), (66, 512, 512),
        (32, 512, 1024), (30, 1024, 1024),
    ]
    for i, (y, c, k) in enumerate(enc, start=1):
        layers.append(conv2d(f"unet-enc{i}", k=k, c=c, y=y, x=y, r=3, s=3))
    # decoder: up-conv 2x2 + double 3x3 convs
    dec = [  # (y_in_upconv, c_in, k_up, y_conv, c_conv)
        (28, 1024, 512, 56, 1024),
        (52, 512, 256, 104, 512),
        (100, 256, 128, 200, 256),
        (196, 128, 64, 392, 128),
    ]
    for i, (yu, cu, ku, yc, cc) in enumerate(dec, start=1):
        layers.append(transposed_conv2d(f"unet-up{i}", k=ku, c=cu, y=yu,
                                        x=yu, r=2, s=2, up=2))
        layers.append(conv2d(f"unet-dec{i}a", k=ku, c=cc, y=yc, x=yc,
                             r=3, s=3))
        layers.append(conv2d(f"unet-dec{i}b", k=ku, c=ku, y=yc - 2,
                             x=yc - 2, r=3, s=3))
    layers.append(pointwise_conv("unet-out", k=2, c=64, y=388, x=388))
    return layers


MODELS = {
    "vgg16": vgg16,
    "alexnet": alexnet,
    "resnet50": resnet50,
    "resnext50": resnext50,
    "mobilenet_v2": mobilenet_v2,
    "unet": unet,
}


# Representative operators used in Fig. 11 (reuse / bandwidth study).
def fig11_operators() -> dict[str, LayerOp]:
    return {
        # early layer: CONV1 in ResNet50
        "early": conv2d("fig11-early", k=64, c=3, y=230, x=230, r=7, s=7,
                        stride=2),
        # late layer: CONV13 in VGG16
        "late": conv2d("fig11-late", k=512, c=512, y=16, x=16, r=3, s=3),
        # depth-wise conv from a MobileNet-class bottleneck
        "dwconv": dwconv2d("fig11-dw", c=144, y=58, x=58, r=3, s=3),
        # point-wise conv: first conv of bottleneck1 in MobileNetV2
        "pointwise": pointwise_conv("fig11-pw", k=96, c=16, y=112, x=112),
    }


def layer_shape_key(op: LayerOp) -> tuple:
    """Analysis-identity of a layer: two layers with equal keys produce
    identical stats for any (dataflow, hardware) pair — op type, dim
    extents, conv strides, and weightlessness all participate."""
    return (op.op_type, tuple(sorted(op.dims.items())),
            tuple(op.stride_of(d) for d in sorted(op.dims)),
            op.filter.has_data)


def unique_layers(layers: Sequence[LayerOp]
                  ) -> tuple[list[LayerOp], list[int]]:
    """Shape-deduplication for network-level search: VGG16's repeated conv
    shapes and ResNet's repeated blocks collapse to one representative
    each.  Returns ``(unique, index)`` where ``unique[index[i]]`` is the
    representative of ``layers[i]`` — evaluate each distinct shape once and
    broadcast results back over ``index``."""
    unique: list[LayerOp] = []
    index: list[int] = []
    seen: dict[tuple, int] = {}
    for op in layers:
        key = layer_shape_key(op)
        at = seen.get(key)
        if at is None:
            at = len(unique)
            seen[key] = at
            unique.append(op)
        index.append(at)
    return unique, index


@dataclasses.dataclass(frozen=True)
class NetworkSummary:
    name: str
    n_layers: int
    total_macs: int
    n_unique_shapes: int = 0


def summarize(name: str) -> NetworkSummary:
    layers = MODELS[name]()
    return NetworkSummary(name, len(layers),
                          sum(l.total_macs for l in layers),
                          len(unique_layers(layers)[0]))
