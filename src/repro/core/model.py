"""MAESTRO's combined performance + cost analysis (paper Fig. 7/8).

``analyze(op, dataflow, hw)`` runs the recursive multi-cluster analysis:

  * the CLA engine instantiates cluster levels and iteration phases;
  * the RA engine supplies per-level reuse classes, traffic totals, and
    steady-state per-step deltas;
  * the PA engine turns volumes into pipe-model delays; the steady-state
    step delay is ``max(ingress, compute, egress)`` (double buffering), the
    first iteration is serial (the Fig. 8 ``IsFullInit`` special case);
  * the CA engine accumulates buffer access counts, buffer size
    requirements, and energy.

The outstanding delay of an inner cluster level is the compute delay of the
level above (paper §4.4), implemented by recursion with memoization over the
per-case tile sizes.  All math flows through the :class:`Backend` facade, so
the faithful integer engine and the traced-jnp DSE twin share this file.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from .cluster_analysis import (Backend, DenseLevel, LevelSpec, LoopInfo,
                               enumerate_cases, enumerate_cases_dense,
                               py_backend, spatial_phases, temporal_phases,
                               unit_counts)
from .directives import (FULL, Dataflow, MapDirective, SpatialMap, complete,
                         extended_dims, is_static_size)
from .energy import DEFAULT_ENERGY, EnergyModel
from .performance import (HWConfig, comm_delay, compute_delay, log2_ceil,
                          reduction_fwd_delay)
from .reuse_analysis import (OUTPUT, TensorReuse, analyze_level_traffic,
                             analyze_level_traffic_dense, classify_level,
                             dense_level_tile_sizes, psums_volume,
                             spatial_reduction_active,
                             spatial_reduction_indicator, tensor_volume,
                             level_tile_sizes)
from .tensor_analysis import LayerOp


# ----------------------------------------------------------------------

@dataclasses.dataclass
class LevelResult:
    """Analysis of ONE execution of a cluster level (one parent step)."""
    runtime: Any
    macs: Any
    counts: dict[tuple[int, str, str], Any]
    buf_req: dict[tuple[int, str], Any]       # (tier, tensor) -> elements
    peak_bw: dict[int, Any]                   # tier -> elements/cycle
    active_pe_steps: Any
    total_pe_steps: Any
    reuse: dict[int, dict[str, TensorReuse]]  # level -> tensor -> classes


@dataclasses.dataclass
class Stats:
    """End-to-end estimates for (layer × dataflow × hardware)."""
    runtime: Any                       # cycles
    total_macs: Any
    throughput: Any                    # MACs/cycle
    utilization: Any                   # fraction of PE-steps active
    counts: dict[tuple[int, str, str], Any]
    buf_req: dict[tuple[int, str], Any]
    l1_req_kb: Any
    l2_req_kb: Any
    peak_bw: dict[int, Any]            # NoC bw requirement per tier
    energy_pj: Any
    energy_breakdown: dict[str, Any]
    reuse: dict[int, dict[str, TensorReuse]]
    reuse_factor: dict[str, Any]       # L1 accesses per L2 fetch per tensor
    num_levels: int

    @property
    def edp(self) -> Any:
        return self.energy_pj * self.runtime


# ----------------------------------------------------------------------

def _build_level(xp: Backend, maps: tuple[MapDirective, ...],
                 dims: dict[str, Any], n_units: Any, index: int,
                 innermost: bool, op: LayerOp) -> LevelSpec:
    # Aligned spatial (outer, window) pairs — e.g. Eyeriss's Y/R diagonal —
    # traverse *within* a window, so their offsets are not stride-scaled.
    spatial_dims = {d.dim for d in maps if isinstance(d, SpatialMap)}
    aligned: set[str] = set()
    for e in op.output.entries:
        from .tensor_analysis import ConvExpr as _CE
        if isinstance(e, _CE) and e.outer in spatial_dims \
                and e.window in spatial_dims:
            aligned.add(e.outer)
    loops: list[LoopInfo] = []
    for d in maps:
        D = dims[d.dim]
        # FULL survives resolve() only for static programs; traced sizes
        # (mapspace vectorization) can never be the sentinel.
        size = D if is_static_size(d.size) and d.size == FULL else d.size
        offset = D if is_static_size(d.offset) and d.offset == FULL \
            else d.offset
        if d.dim not in aligned:
            offset = offset * op.stride_of(d.dim)  # CLA stride handling
        if isinstance(d, SpatialMap):
            st, ed = spatial_phases(xp, D, size, offset, n_units)
            loops.append(LoopInfo(
                dataclasses.replace(d, size=size, offset=offset),
                d.dim, True, n_units, st, ed))
        else:
            st, ed = temporal_phases(xp, D, size, offset)
            loops.append(LoopInfo(
                dataclasses.replace(d, size=size, offset=offset),
                d.dim, False, 1, st, ed))
    return LevelSpec(index=index, loops=tuple(loops), n_units=n_units,
                     dims=dict(dims), is_innermost=innermost)


def _dims_key(dims: dict[str, Any]) -> tuple | None:
    try:
        return tuple(sorted((k, int(v)) for k, v in dims.items()))
    except Exception:
        return None  # traced values — memoization disabled


def _analyze_level(op: LayerOp, level_maps, counts_units, li: int,
                   dims: dict[str, Any], xp: Backend, hw: HWConfig,
                   cache: dict) -> LevelResult:
    key = (li, _dims_key(dims))
    if key[1] is not None and key in cache:
        return cache[key]

    innermost = li == len(level_maps) - 1
    level = _build_level(xp, level_maps[li], dims, counts_units[li], li,
                         innermost, op)
    traffic = analyze_level_traffic(op, level, xp, hw.multicast,
                                    hw.spatial_reduction)
    cases = enumerate_cases(level, xp)
    has_spatial_reduction = spatial_reduction_active(op, level)

    counts: dict[tuple[int, str, str], Any] = {}
    buf_req: dict[tuple[int, str], Any] = {}
    peak_bw: dict[int, Any] = {}
    reuse_all: dict[int, dict[str, TensorReuse]] = {li: traffic.reuse}

    def bump(k, v):
        counts[k] = counts.get(k, 0) + v

    def req(k, v):
        prev = buf_req.get(k, 0)
        buf_req[k] = xp.maximum(prev, v)

    # ---- steady-state delays (per step) -------------------------------
    delta_total = 0
    for t in op.input_tensors():
        delta_total = delta_total + traffic.step_delta[t.name]
    ingress_sd = comm_delay(xp, delta_total, hw)
    egress_sd = comm_delay(xp, traffic.step_egress, hw)
    fwd = reduction_fwd_delay(xp, level.n_units, hw, has_spatial_reduction)

    # ---- per-case compute + accumulation ------------------------------
    runtime = 0
    macs = 0
    active_pe_steps = 0
    total_pe_steps = 0
    steady_compute = None

    for case in cases:
        occ = case.occurrences
        if isinstance(occ, int) and occ == 0:
            continue
        m_unit = case.sizes
        if innermost:
            psums = psums_volume(op, m_unit, xp)
            comp = compute_delay(xp, psums, hw)
            child_macs = psums
            child_active, child_total = 1, 1
            child_runtime = comp
        else:
            child = _analyze_level(op, level_maps, counts_units, li + 1,
                                   m_unit, xp, hw, cache)
            comp = child.runtime
            child_macs = child.macs
            child_active, child_total = (child.active_pe_steps,
                                         child.total_pe_steps)
            child_runtime = child.runtime
            for k, v in child.counts.items():
                bump(k, v * occ * case.active_units)
            for k, v in child.buf_req.items():
                req(k, v)
            for tier, bw in child.peak_bw.items():
                peak_bw[tier] = xp.maximum(peak_bw.get(tier, 0), bw)
            reuse_all.update(child.reuse)

        # trailing partially-filled unit (spatial edge folding)
        partial_macs = 0
        for sdim, psz in case.partial_unit_sizes.items():
            if isinstance(psz, int) and psz == 0:
                continue
            mp = dict(m_unit)
            mp[sdim] = psz
            partial_macs = partial_macs + psums_volume(op, mp, xp) \
                * xp.where(psz > 0, 1, 0)

        step = xp.maximum(xp.maximum(comp + fwd, ingress_sd), egress_sd)
        runtime = runtime + occ * step
        case_macs = occ * (case.active_units * child_macs + partial_macs)
        macs = macs + case_macs
        has_partial = 0
        for psz in case.partial_unit_sizes.values():
            has_partial = xp.maximum(has_partial, xp.where(psz > 0, 1, 0))
        active_pe_steps = active_pe_steps + occ * (
            case.active_units * child_active + has_partial * child_active)
        total_pe_steps = total_pe_steps + occ * level.n_units * child_total
        if steady_compute is None:
            steady_compute = comp  # first case = all-steady phases

        # per-unit buffer requirement at tier li+1 (double-buffered tile)
        unit_ws = 0
        for t in op.tensors():
            unit_ws = unit_ws + tensor_volume(t, m_unit, xp)
        req((li + 1, "ALL"), 2 * unit_ws)

    # ---- init case: first iteration is serial (no double buffering) ---
    full_ingress = 0
    tiles = level_tile_sizes(level, xp)
    for t in op.input_tensors():
        v = tensor_volume(t, tiles, xp)
        if not hw.multicast:
            v = v * traffic.multicast_factor[t.name]
        full_ingress = full_ingress + v
    ing_full_d = comm_delay(xp, full_ingress, hw)
    sc = steady_compute if steady_compute is not None else 0
    serial = ing_full_d + sc + fwd + egress_sd
    overlapped = xp.maximum(xp.maximum(sc + fwd, ingress_sd), egress_sd)
    runtime = runtime + (serial - overlapped)

    # ---- this level's own traffic counts ------------------------------
    for t in op.input_tensors():
        unique = traffic.ingress[t.name]
        delivered = unique * (traffic.multicast_factor[t.name]
                              if hw.multicast else 1)
        bump((li, t.name, "read"), unique)
        bump((li + 1, t.name, "write"), delivered)
    bump((li, OUTPUT, "read"), traffic.psum_readback)
    bump((li, OUTPUT, "write"), traffic.egress[OUTPUT])

    if innermost:
        # MAC operand accesses against the PE-local buffer (tier li+1)
        for t in op.input_tensors():
            bump((li + 1, t.name, "read"), macs)
        bump((li + 1, OUTPUT, "read"), macs)
        bump((li + 1, OUTPUT, "write"), macs)

    # upper buffer must hold the level working set, double-buffered
    lvl_ws = 0
    for t in op.tensors():
        lvl_ws = lvl_ws + tensor_volume(t, tiles, xp)
    req((li, "ALL"), 2 * lvl_ws)

    # NoC bandwidth requirement to avoid stalling compute (Fig. 11c)
    comp_floor = xp.maximum(sc, 1)
    peak_bw[li] = xp.maximum(
        peak_bw.get(li, 0),
        (delta_total + traffic.step_egress) / comp_floor)

    result = LevelResult(
        runtime=runtime, macs=macs, counts=counts, buf_req=buf_req,
        peak_bw=peak_bw, active_pe_steps=active_pe_steps,
        total_pe_steps=total_pe_steps, reuse=reuse_all)
    if key[1] is not None:
        cache[key] = result
    return result


# ----------------------------------------------------------------------
# Order-oblivious (dense) level driver — structure as operands
# ----------------------------------------------------------------------

def analyze_dense_level(op: LayerOp, level: DenseLevel, xp: Backend,
                        hw: HWConfig, child_fn=None) -> LevelResult:
    """Dense twin of :func:`_analyze_level` for a :class:`DenseLevel` whose
    loop order / spatial choice / sizes may all be traced operands.

    ``child_fn(case_sizes) -> LevelResult`` analyzes the inner cluster
    level for one iteration case; ``None`` marks the innermost level.  The
    accumulation mirrors the faithful engine case for case (phantom cases
    with zero occurrences contribute zero-weighted terms, exactly like the
    grouped traced engine), so results are bit-equal modulo float32."""
    li = level.index
    traffic = analyze_level_traffic_dense(op, level, xp, hw.multicast,
                                          hw.spatial_reduction)
    cases = enumerate_cases_dense(level, xp, level.single_edge)
    sra = spatial_reduction_indicator(op, level, xp)

    counts: dict[tuple[int, str, str], Any] = {}
    buf_req: dict[tuple[int, str], Any] = {}
    peak_bw: dict[int, Any] = {}

    def bump(k, v):
        counts[k] = counts.get(k, 0) + v

    def req(k, v):
        prev = buf_req.get(k, 0)
        buf_req[k] = xp.maximum(prev, v)

    # ---- steady-state delays (per step) -------------------------------
    delta_total = 0
    for t in op.input_tensors():
        delta_total = delta_total + traffic.step_delta[t.name]
    ingress_sd = comm_delay(xp, delta_total, hw)
    egress_sd = comm_delay(xp, traffic.step_egress, hw)
    fwd = sra * log2_ceil(xp, level.n_units)

    # ---- per-case compute + accumulation ------------------------------
    runtime = 0
    macs = 0
    active_pe_steps = 0
    total_pe_steps = 0
    steady_compute = None

    for case in cases:
        occ = case.occurrences
        m_unit = case.sizes
        if child_fn is None:
            psums = psums_volume(op, m_unit, xp)
            comp = compute_delay(xp, psums, hw)
            child_macs = psums
            child_active, child_total = 1, 1
        else:
            child = child_fn(m_unit)
            comp = child.runtime
            child_macs = child.macs
            child_active, child_total = (child.active_pe_steps,
                                         child.total_pe_steps)
            for k, v in child.counts.items():
                bump(k, v * occ * case.active_units)
            for k, v in child.buf_req.items():
                req(k, v)
            for tier, bw in child.peak_bw.items():
                peak_bw[tier] = xp.maximum(peak_bw.get(tier, 0), bw)

        # trailing partially-filled unit: only the spatial dim carries a
        # non-zero (one-hot-blended) partial, so one override suffices
        p_total = 0
        mp = dict(m_unit)
        for d, psz in case.partial_unit_sizes.items():
            p_total = p_total + psz
            mp[d] = (1 - level.sp.get(d, 0)) * m_unit[d] + psz
        has_partial = xp.where(p_total > 0, 1, 0)
        partial_macs = psums_volume(op, mp, xp) * has_partial

        step = xp.maximum(xp.maximum(comp + fwd, ingress_sd), egress_sd)
        runtime = runtime + occ * step
        macs = macs + occ * (case.active_units * child_macs + partial_macs)
        active_pe_steps = active_pe_steps + occ * (
            case.active_units * child_active + has_partial * child_active)
        total_pe_steps = total_pe_steps + occ * level.n_units * child_total
        if steady_compute is None:
            steady_compute = comp  # first case = all-steady phases

        unit_ws = 0
        for t in op.tensors():
            unit_ws = unit_ws + tensor_volume(t, m_unit, xp)
        req((li + 1, "ALL"), 2 * unit_ws)

    # ---- init case: first iteration is serial (no double buffering) ---
    full_ingress = 0
    tiles = dense_level_tile_sizes(level, xp)
    for t in op.input_tensors():
        v = tensor_volume(t, tiles, xp)
        if not hw.multicast:
            v = v * traffic.multicast_factor[t.name]
        full_ingress = full_ingress + v
    ing_full_d = comm_delay(xp, full_ingress, hw)
    sc = steady_compute if steady_compute is not None else 0
    serial = ing_full_d + sc + fwd + egress_sd
    overlapped = xp.maximum(xp.maximum(sc + fwd, ingress_sd), egress_sd)
    runtime = runtime + (serial - overlapped)

    # ---- this level's own traffic counts ------------------------------
    for t in op.input_tensors():
        unique = traffic.ingress[t.name]
        delivered = unique * (traffic.multicast_factor[t.name]
                              if hw.multicast else 1)
        bump((li, t.name, "read"), unique)
        bump((li + 1, t.name, "write"), delivered)
    bump((li, OUTPUT, "read"), traffic.psum_readback)
    bump((li, OUTPUT, "write"), traffic.egress[OUTPUT])

    if child_fn is None:
        for t in op.input_tensors():
            bump((li + 1, t.name, "read"), macs)
        bump((li + 1, OUTPUT, "read"), macs)
        bump((li + 1, OUTPUT, "write"), macs)

    lvl_ws = 0
    for t in op.tensors():
        lvl_ws = lvl_ws + tensor_volume(t, tiles, xp)
    req((li, "ALL"), 2 * lvl_ws)

    comp_floor = xp.maximum(sc, 1)
    peak_bw[li] = xp.maximum(
        peak_bw.get(li, 0),
        (delta_total + traffic.step_egress) / comp_floor)

    return LevelResult(
        runtime=runtime, macs=macs, counts=counts, buf_req=buf_req,
        peak_bw=peak_bw, active_pe_steps=active_pe_steps,
        total_pe_steps=total_pe_steps, reuse={li: {}})


def blend_level_results(xp: Backend, sel: Sequence[Any],
                        results: Sequence[LevelResult]) -> LevelResult:
    """One-hot blend of per-candidate :class:`LevelResult` objects (the
    cluster inner-dim selector of the universal evaluator).  All candidates
    share the same static key structure."""
    def scalar(vals):
        out = 0
        for s, v in zip(sel, vals):
            out = out + s * v
        return out

    def dicts(ds):
        # first-appearance key order, NOT a set: set iteration is
        # PYTHONHASHSEED-ordered, which would reorder the traced blend
        # sums and make cross-process results differ at the ulp level
        keys: dict[Any, None] = {}
        for d in ds:
            for k in d:
                keys.setdefault(k)
        return {k: scalar([d.get(k, 0) for d in ds]) for k in keys}

    return LevelResult(
        runtime=scalar([r.runtime for r in results]),
        macs=scalar([r.macs for r in results]),
        counts=dicts([r.counts for r in results]),
        buf_req=dicts([r.buf_req for r in results]),
        peak_bw=dicts([r.peak_bw for r in results]),
        active_pe_steps=scalar([r.active_pe_steps for r in results]),
        total_pe_steps=scalar([r.total_pe_steps for r in results]),
        reuse={})


# ----------------------------------------------------------------------

def analyze(op: LayerOp, df: Dataflow, hw: HWConfig,
            xp: Backend | None = None,
            energy_model: EnergyModel = DEFAULT_ENERGY) -> Stats:
    """Run MAESTRO's full analysis for one layer."""
    xp = xp or py_backend()
    cdf = complete(df, op.dims)
    level_maps = cdf.levels
    counts_units = unit_counts(xp, hw.num_pes, cdf.cluster_sizes)
    cache: dict = {}
    top = _analyze_level(op, level_maps, counts_units, 0,
                         extended_dims(df, op.dims), xp, hw, cache)
    return assemble_stats(op, top, len(level_maps), hw, xp, energy_model)


def assemble_stats(op: LayerOp, top: LevelResult, n_levels: int,
                   hw: HWConfig, xp: Backend,
                   energy_model: EnergyModel = DEFAULT_ENERGY) -> Stats:
    """Turn a top-level :class:`LevelResult` into end-to-end :class:`Stats`
    (buffer sizing, CACTI-style energy, utilization, reuse factors).

    Shared by the faithful/grouped engines (via :func:`analyze`) and the
    universal structure-as-operand evaluator, which builds the top
    ``LevelResult`` densely with mapping structure as traced operands."""
    em = energy_model
    bytes_ = hw.dtype_bytes
    l1_req = top.buf_req.get((n_levels, "ALL"), 0)
    l2_req = top.buf_req.get((0, "ALL"), 0)
    l1_kb = l1_req * bytes_ / 1024.0
    l2_kb = l2_req * bytes_ / 1024.0
    # CACTI-style sqrt-capacity scaling of access energy with the buffers
    # MAESTRO reports for this dataflow (paper §5: "the DSE tool places the
    # exact amount buffers MAESTRO reported").
    l1s, l2s = em.l1_scale(l1_kb), em.l2_scale(l2_kb)
    # tier 0 = global (L2); innermost tier (= n_levels) = PE-local L1;
    # intermediate tiers priced as L2-class buffers.
    e_read = {t: (em.l1_read * l1s if t == n_levels else em.l2_read * l2s)
              for t in range(n_levels + 1)}
    e_write = {t: (em.l1_write * l1s if t == n_levels else em.l2_write * l2s)
               for t in range(n_levels + 1)}

    breakdown: dict[str, Any] = {"mac": top.macs * em.mac}
    energy = breakdown["mac"]
    noc_elems = 0
    for (tier, tensor, kind), v in top.counts.items():
        label = "l1" if tier == n_levels else "l2"
        e = (e_read if kind == "read" else e_write)[tier] * v
        breakdown[label] = breakdown.get(label, 0) + e
        energy = energy + e
        if kind == "read" and tier < n_levels:
            noc_elems = noc_elems + v
    breakdown["noc"] = noc_elems * em.noc_hop
    energy = energy + breakdown["noc"]

    util = top.active_pe_steps / xp.maximum(top.total_pe_steps, 1)
    runtime = xp.maximum(top.runtime, 1)

    # reuse factor = local (L1) accesses per fetch from the top buffer
    rf: dict[str, Any] = {}
    for t in op.input_tensors():
        l1 = top.counts.get((n_levels, t.name, "read"), 0)
        l2 = top.counts.get((0, t.name, "read"), 1)
        rf[t.name] = l1 / xp.maximum(l2, 1)
    l1o = (top.counts.get((n_levels, OUTPUT, "read"), 0)
           + top.counts.get((n_levels, OUTPUT, "write"), 0))
    l2o = (top.counts.get((0, OUTPUT, "write"), 0)
           + top.counts.get((0, OUTPUT, "read"), 0))
    rf[OUTPUT] = l1o / xp.maximum(l2o, 1)

    return Stats(
        runtime=runtime,
        total_macs=top.macs,
        throughput=top.macs / runtime,
        utilization=util,
        counts=top.counts,
        buf_req=top.buf_req,
        l1_req_kb=l1_kb,
        l2_req_kb=l2_kb,
        peak_bw=top.peak_bw,
        energy_pj=energy,
        energy_breakdown=breakdown,
        reuse=top.reuse,
        reuse_factor=rf,
        num_levels=n_levels,
    )


def analyze_network(layers: list[LayerOp], df_for_layer, hw: HWConfig,
                    xp: Backend | None = None) -> dict[str, Stats]:
    """Analyze a whole DNN: ``df_for_layer(layer) -> Dataflow``. Returns
    per-layer stats; end-to-end numbers are the sums."""
    out: dict[str, Stats] = {}
    for layer in layers:
        out[layer.name] = analyze(layer, df_for_layer(layer), hw, xp)
    return out


def network_totals(stats: dict[str, Stats]) -> dict[str, Any]:
    runtime = sum(s.runtime for s in stats.values())
    energy = sum(s.energy_pj for s in stats.values())
    macs = sum(s.total_macs for s in stats.values())
    return {
        "runtime": runtime,
        "energy_pj": energy,
        "total_macs": macs,
        "throughput": macs / max(runtime, 1),
        "edp": energy * runtime,
    }
