"""Reuse analysis (RA) engine.

From the directive program and tensor coupling (TA engine), derive for every
tensor at every cluster level:

  * the *spatial* reuse class across sub-units — multicast (decoupled from
    the spatially mapped dim), halo (coupled, offset < size), unique
    (coupled, disjoint), or reduction (output decoupled from a spatially
    mapped reduction dim);
  * the *temporal* reuse class across adjacent steps — stationary (decoupled
    from the advancing dim), partial (coupled with sliding overlap), or none
    (full refetch);
  * the data volumes these imply: per-unit tiles, level-unique volumes,
    steady-state per-step deltas, and whole-level traffic totals.

The adjacent-step rule follows the paper (§4.1 RA engine): reuse is assessed
against the innermost non-fully-unrolled map directive; outer-loop advances
(rollovers) refetch whole tiles.  Totals are closed-form products over loop
trip counts, so the same code runs on ints and traced jnp scalars.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from .cluster_analysis import Backend, DenseLevel, LevelSpec, LoopInfo, mix
from .tensor_analysis import (FILTER, INPUT, OUTPUT, ConvExpr, DimExpr,
                              LayerOp, TensorSpec, WindowExpr)

# Reuse classes
MULTICAST, HALO, UNIQUE, REDUCTION = "multicast", "halo", "unique", "reduction"
STATIONARY, PARTIAL, NONE = "stationary", "partial", "none"


# ----------------------------------------------------------------------
# Volume helpers
# ----------------------------------------------------------------------

def tensor_volume(t: TensorSpec, m: Mapping[str, Any], xp: Backend,
                  override: dict[str, Any] | None = None) -> Any:
    """Volume of a tensor tile under mapped sizes ``m``; ``override`` swaps
    the extent of specific dims (used for delta/halo computations)."""
    if not t.has_data:
        return 0
    mm = dict(m)
    if override:
        mm.update(override)
    v = 1
    for e in t.entries:
        v = v * _expr_extent(e, mm, xp)
    return v


def _expr_extent(e, mm, xp: Backend):
    if isinstance(e, DimExpr):
        return mm[e.name]
    if isinstance(e, WindowExpr):
        a, w = mm[e.outer], mm[e.window]
        ext = (a - 1) * e.stride + w
        both = xp.where(a > 0, 1, 0) * xp.where(w > 0, 1, 0)
        return xp.maximum(ext, 0) * both
    assert isinstance(e, ConvExpr)
    tt, w = mm[e.outer], mm[e.window]
    ext = xp.maximum((tt - w), 0)
    return xp.floordiv(ext, e.stride) + xp.where(tt >= w, 1, 0)


def psums_volume(op: LayerOp, m: Mapping[str, Any], xp: Backend) -> Any:
    v = 1
    for e in op.iter_entries:
        v = v * _expr_extent(e, m, xp)
    return v


# ----------------------------------------------------------------------
# Classification (Table 1 reproduction)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TensorReuse:
    tensor: str
    spatial: str
    temporal: str


def advancing_loop(level: LevelSpec) -> LoopInfo | None:
    """The innermost *temporal* map directive that actually iterates — the
    dim whose advance defines adjacent-step reuse (paper RA engine).

    Spatial folding is excluded on purpose: fold trip counts depend on the
    (possibly traced) PE count, and fold refetches are already captured by
    the closed-form traffic totals.  Restricting the steady-state delta to
    temporal advances keeps the faithful and vectorized engines bit-equal.
    Trip counts of temporal loops are static Python ints whenever layer dims
    and directive sizes are static."""
    for lp in reversed(level.loops):
        if lp.is_spatial:
            continue
        steps = lp.total_steps()
        if not isinstance(steps, int) or steps > 1:
            return lp
    return None


def _is_advancing(level: LevelSpec, inner: LoopInfo, xp: Backend):
    """1/0 indicator that ``inner`` is this level's advancing loop — the
    innermost temporal map that actually iterates (see
    :func:`advancing_loop`).  Equivalent to ``inner is advancing_loop(level)``
    for static trip counts, but expressed through the backend facade so the
    vectorized engine (traced tile sizes) evaluates the same rule instead of
    concretizing a Python branch."""
    if inner.is_spatial:
        return 0
    ind = xp.where(inner.total_steps() > 1, 1, 0)
    for lp in reversed(level.loops):
        if lp is inner:
            break
        if not lp.is_spatial:
            ind = ind * xp.eq(lp.total_steps(), 1)
    return ind


def spatial_reduction_active(op: LayerOp, level: LevelSpec) -> bool:
    """True when sub-units produce partial sums for the *same* outputs:
    either a reduction dim (C) is spatially mapped, or an aligned pair of
    spatial maps covers both dims of one output ConvExpr (Eyeriss's Y/R
    diagonal — each unit computes the same output row)."""
    sdims = {lp.dim for lp in level.spatial_loops()}
    if sdims & op.reduction_dims():
        return True
    for e in op.output.entries:
        if isinstance(e, ConvExpr) and e.outer in sdims and e.window in sdims:
            return True
    return False


def _classification_adv(level: LevelSpec) -> LoopInfo | None:
    """Innermost loop that advances over *time* — spatial folds included
    when their trip count is statically known (classification only; the
    traffic math uses the temporal-only :func:`advancing_loop` so faithful
    and traced engines stay bit-equal)."""
    for lp in reversed(level.loops):
        steps = lp.total_steps()
        if isinstance(steps, int):
            if steps > 1:
                return lp
        elif not lp.is_spatial:
            return lp
    return None


def classify_tensor(op: LayerOp, t: TensorSpec, level: LevelSpec
                    ) -> TensorReuse:
    sps = level.spatial_loops()
    red = op.reduction_dims()
    if not sps:
        spatial = NONE
    elif t.name == OUTPUT and spatial_reduction_active(op, level):
        spatial = REDUCTION
    elif not any(t.coupled_to(sp.dim) for sp in sps):
        spatial = MULTICAST
    else:
        coupled = [sp for sp in sps if t.coupled_to(sp.dim)]
        d = coupled[0].directive
        spatial = HALO if _lt(d.offset, d.size) else UNIQUE

    adv = _classification_adv(level)
    if adv is None or not t.coupled_to(adv.dim):
        temporal = STATIONARY
    else:
        d = adv.directive
        temporal = PARTIAL if _lt(d.offset, d.size) else NONE
    return TensorReuse(t.name, spatial, temporal)


def _lt(a, b) -> bool:
    try:
        return bool(a < b)
    except Exception:
        # Traced size/offset (mapspace vectorization).  The classification is
        # reporting-only metadata — the traffic math below is closed-form and
        # never consumes it — so fall back to the disjoint-tiling class
        # rather than forcing concretization.
        return False


def classify_level(op: LayerOp, level: LevelSpec) -> dict[str, TensorReuse]:
    return {t.name: classify_tensor(op, t, level) for t in op.tensors()}


def reuse_opportunity_table(op: LayerOp) -> dict[tuple[str, str], dict]:
    """Programmatic regeneration of the paper's Table 1: for each (spatially
    mapped dim, innermost temporally mapped dim) pair, the coupling of each
    tensor and the implied reuse opportunity."""
    table = {}
    dims = [d for d in op.dims if op.dims[d] >= 1 and d != "N"]
    red = op.reduction_dims()
    for sd in dims:
        for td in dims:
            if td == sd:
                continue
            entry: dict[str, dict[str, str]] = {"spatial": {}, "temporal": {}}
            for t in op.tensors():
                if t.name == OUTPUT and sd in red:
                    entry["spatial"][t.name] = REDUCTION
                elif not t.coupled_to(sd):
                    entry["spatial"][t.name] = MULTICAST
                else:
                    entry["spatial"][t.name] = "-"
                if t.name == OUTPUT and td in red:
                    entry["temporal"][t.name] = REDUCTION
                elif not t.coupled_to(td):
                    entry["temporal"][t.name] = MULTICAST
                else:
                    entry["temporal"][t.name] = "-"
            table[(sd, td)] = entry
    return table


# ----------------------------------------------------------------------
# Traffic model
# ----------------------------------------------------------------------

@dataclasses.dataclass
class LevelTraffic:
    """Whole-level traffic (elements) between this level's upper buffer and
    its sub-units, plus steady-state per-step deltas for delay analysis."""
    # totals over the full level execution
    ingress: dict[str, Any]          # F, I (and O psum readback) from above
    egress: dict[str, Any]           # O commits (incl. partial spills)
    psum_readback: Any               # portion of O ingress that is re-read
    multicast_factor: dict[str, Any]  # destinations sharing each datum
    # steady-state per-step quantities (innermost advance)
    step_delta: dict[str, Any]       # new elements needed per steady step
    step_egress: Any                 # elements committed per steady step
    total_steps: Any
    reuse: dict[str, TensorReuse]


def _loop_trips(level: LevelSpec) -> list[Any]:
    return [lp.total_steps() for lp in level.loops]


def _tile_override(lp: LoopInfo, xp: Backend) -> dict[str, Any]:
    """Axis extent of the *new* data when loop ``lp`` advances one step."""
    d = lp.directive
    if lp.is_spatial:
        adv = lp.n_units * d.offset
        span = d.size + (lp.n_units - 1) * d.offset
        return {lp.dim: xp.minimum(adv, xp.minimum(span, level_dim(lp)))}
    return {lp.dim: xp.minimum(d.offset, lp.steady.size)}


def level_dim(lp: LoopInfo) -> Any:
    # full extent of the dim at this level is steady*count-ish; the steady
    # size is the safest clamp available without the LevelSpec.
    return lp.steady.size if not lp.is_spatial else \
        lp.steady.size + (lp.n_units - 1) * lp.directive.offset


def level_tile_sizes(level: LevelSpec, xp: Backend) -> dict[str, Any]:
    """Per-step *level* extents: per-unit steady size, except spatially
    mapped dims which span all active units (halo-aware union)."""
    m = level.steady_tile()
    for sp in level.spatial_loops():
        d = sp.directive
        span = sp.steady.size + (sp.n_units - 1) * d.offset
        m[sp.dim] = xp.minimum(span, level.dims[sp.dim])
    return m


# ----------------------------------------------------------------------
# Order-oblivious (dense) traffic model — structure as operands
# ----------------------------------------------------------------------
#
# The grouped engine above walks Python lists in directive order, so loop
# order and spatial choice are compile-time structure.  The dense twins
# below compute the same closed forms with the order as a *rank vector* and
# the spatial choice as a *one-hot*: "the innermost coupled loop" becomes a
# branch-free one-hot gather over ranks, and "is the advancing loop"
# becomes an indicator product — the permutation gathers that let one XLA
# executable cover every (perm × spatial) structure group.

def innermost_one_hot(xp: Backend, ranks: Sequence[Any]) -> list[Any]:
    """0/1 indicator per entry: 1 at the maximum rank (the innermost loop in
    data-movement order), 0 elsewhere.  Ranks must be pairwise distinct."""
    out = []
    for i, ri in enumerate(ranks):
        ind = 1
        for j, rj in enumerate(ranks):
            if j != i:
                ind = ind * xp.where(ri > rj, 1, 0)
        out.append(ind)
    return out


def advancing_indicators(xp: Backend, level: DenseLevel) -> dict[str, Any]:
    """Dense twin of :func:`_is_advancing`: per loop dim, a 0/1 indicator
    that it is the level's advancing loop — temporal, actually iterating,
    with every temporal loop inner to it sitting at one trip."""
    out: dict[str, Any] = {}
    for d in level.loop_dims:
        ind = (1 - level.sp.get(d, 0)) * xp.where(level.trips(d) > 1, 1, 0)
        for d2 in level.loop_dims:
            if d2 == d:
                continue
            outer = xp.where(level.rank[d2] < level.rank[d], 1, 0)
            one_trip = xp.eq(level.trips(d2), 1)
            term = mix(xp, level.sp.get(d2, 0), 1,
                       outer + (1 - outer) * one_trip)
            ind = ind * term
        out[d] = ind
    return out


def spatial_reduction_indicator(op: LayerOp, level: DenseLevel,
                                xp: Backend) -> Any:
    """Dense 0/1 twin of :func:`spatial_reduction_active`: a reduction dim
    is spatially mapped, or an aligned (outer, window) output pair is."""
    red = op.reduction_dims()
    s = 0
    for d in level.loop_dims:
        if d in red:
            s = s + level.sp.get(d, 0)
        for e in op.output.entries:
            if isinstance(e, ConvExpr) and e.outer == d \
                    and e.window in level.loop_dims:
                s = s + level.sp.get(d, 0) * level.sp.get(e.window, 0)
    return xp.minimum(s, 1)


def dense_level_tile_sizes(level: DenseLevel, xp: Backend
                           ) -> dict[str, Any]:
    """Dense twin of :func:`level_tile_sizes`: per-step level extents —
    steady per-unit size, except spatially mapped dims which span all
    active units (blended by the spatial one-hot)."""
    m = dict(level.ext)
    for d in level.loop_dims:
        s = level.steady[d].size
        span = s + (level.n_units - 1) * level.off_eff[d]
        m[d] = mix(xp, level.sp.get(d, 0),
                   xp.minimum(span, level.ext[d]), s)
    return m


def _dense_advance(level: DenseLevel, d: str, xp: Backend) -> Any:
    """Axis extent of the new data when loop ``d`` advances one step —
    dense twin of :func:`_tile_override` (spatial/temporal blended)."""
    s = level.steady[d].size
    o = level.off_eff[d]
    span = s + (level.n_units - 1) * o
    adv_sp = xp.minimum(level.n_units * o, span)
    adv_t = xp.minimum(o, s)
    return mix(xp, level.sp.get(d, 0), adv_sp, adv_t)


def analyze_level_traffic_dense(op: LayerOp, level: DenseLevel,
                                xp: Backend, multicast_hw: bool = True,
                                reduction_hw: bool = True) -> LevelTraffic:
    """Order-oblivious twin of :func:`analyze_level_traffic`.

    Produces bit-equal quantities for any single-spatial-map level: the
    innermost-coupled-loop choice, the advancing-loop rule and the
    psum-spill rule are all evaluated through rank/one-hot indicators
    instead of list positions, so loop order and spatial choice can be
    traced operands.  Reuse *classification* (reporting-only metadata) is
    structural and therefore omitted."""
    tiles = dense_level_tile_sizes(level, xp)
    trips = {d: level.trips(d) for d in level.loop_dims}
    total_steps = 1
    for d in level.loop_dims:
        total_steps = total_steps * trips[d]
    adv_ind = advancing_indicators(xp, level)

    ingress: dict[str, Any] = {}
    mfac: dict[str, Any] = {}
    step_delta: dict[str, Any] = {}

    for t in op.input_tensors():
        cl = [d for d in level.loop_dims if t.coupled_to(d)]
        tile = tensor_volume(t, tiles, xp)
        if not cl:
            ing = tile
            delta = 0
        else:
            inner = innermost_one_hot(xp, [level.rank[d] for d in cl])
            n_in = 0
            dvol = 0
            outer_prod = 1
            for w, d in zip(inner, cl):
                n_in = n_in + w * trips[d]
                dv = tensor_volume(t, tiles, xp,
                                   override={d: _dense_advance(level, d, xp)})
                dvol = dvol + w * xp.minimum(dv, tile)
                outer_prod = outer_prod * (1 + (1 - w) * (trips[d] - 1))
            ing = outer_prod * (tile + (n_in - 1) * dvol)
            ind = 0
            for w, d in zip(inner, cl):
                ind = ind + w * adv_ind[d]
            delta = ind * dvol + (1 - ind) * tile
        coupled_sp = 0
        for d in cl:
            coupled_sp = coupled_sp + level.sp.get(d, 0)
        mfac[t.name] = 1 + (1 - coupled_sp) * (level.n_units - 1)
        ingress[t.name] = ing
        step_delta[t.name] = delta if t.has_data else 0
        if not multicast_hw:
            ingress[t.name] = ingress[t.name] * mfac[t.name]
            step_delta[t.name] = step_delta[t.name] * mfac[t.name]

    # ---- output tensor ------------------------------------------------
    o = op.output
    o_tile = tensor_volume(o, tiles, xp)
    red_dims = op.reduction_dims()
    ocl = [d for d in level.loop_dims if o.coupled_to(d)]
    if ocl:
        commits = 1
        for d in ocl:
            commits = commits * trips[d]
        inner_o = innermost_one_hot(xp, [level.rank[d] for d in ocl])
        spill = 1
        for d in level.loop_dims:
            if d not in red_dims:
                continue
            outer = 0
            for w, di in zip(inner_o, ocl):
                outer = outer + w * xp.where(level.rank[d] < level.rank[di],
                                             1, 0)
            spill = spill * (1 + outer * (trips[d] - 1))
    else:
        commits = 1
        spill = 1
    egress_o = o_tile * commits * spill
    readback = o_tile * commits * (spill - 1)
    sra = spatial_reduction_indicator(op, level, xp)
    if not reduction_hw:
        m = 1 + sra * (level.n_units - 1)
        egress_o = egress_o * m
        readback = readback * m
    step_egress = xp.ceil_div(egress_o, xp.maximum(total_steps, 1))

    ingress[OUTPUT] = readback
    return LevelTraffic(
        ingress=ingress,
        egress={OUTPUT: egress_o},
        psum_readback=readback,
        multicast_factor=mfac,
        step_delta=step_delta,
        step_egress=step_egress,
        total_steps=total_steps,
        reuse={},
    )


def analyze_level_traffic(op: LayerOp, level: LevelSpec, xp: Backend,
                          multicast_hw: bool = True,
                          reduction_hw: bool = True) -> LevelTraffic:
    """Closed-form traffic totals for one level execution.

    For each input tensor T with coupled loops C(T) (trip counts > 1):
      ingress(T) = Π_{outer coupled} trips × [tile + (N_in − 1) × delta]
    where ``N_in`` is the innermost coupled loop's trips and ``delta`` is the
    tile volume with that loop's axis extent replaced by its advance (the
    sliding-window overlap credit).  Decoupled-from-everything tensors are
    fetched once.  Output egress multiplies the O-coupled trips and the trip
    counts of reduction loops *outer* to the innermost O-coupled loop
    (partial-sum spills; each spill is later read back)."""
    reuse = classify_level(op, level)
    loops = list(level.loops)
    tiles = level_tile_sizes(level, xp)
    sps = level.spatial_loops()
    sdims = {lp.dim for lp in sps}

    ingress: dict[str, Any] = {}
    mfac: dict[str, Any] = {}
    step_delta: dict[str, Any] = {}

    total_steps = 1
    for lp in loops:
        total_steps = total_steps * lp.total_steps()

    for t in op.input_tensors():
        coupled = [lp for lp in loops if t.coupled_to(lp.dim)]
        tile = tensor_volume(t, tiles, xp)
        if not coupled:
            ing = tile
            delta = 0
        else:
            inner = coupled[-1]
            outer_prod = 1
            for lp in coupled[:-1]:
                outer_prod = outer_prod * lp.total_steps()
            n_in = inner.total_steps()
            dvol = tensor_volume(t, tiles, xp,
                                 override=_tile_override(inner, xp))
            dvol = xp.minimum(dvol, tile)
            ing = outer_prod * (tile + (n_in - 1) * dvol)
            # delta = dvol iff `inner` is the advancing loop (the innermost
            # temporal map with >1 steps); computed branch-free so traced
            # tile sizes (mapspace) give the exact same rule as static ints.
            ind = _is_advancing(level, inner, xp)
            delta = ind * dvol + (1 - ind) * tile
        ingress[t.name] = ing
        # destinations per datum across sub-units
        if sps and not any(t.coupled_to(d) for d in sdims):
            mfac[t.name] = level.n_units
        else:
            mfac[t.name] = 1
        step_delta[t.name] = delta if t.has_data else 0
        if not multicast_hw:
            # no multicast HW: the NoC carries one copy per destination
            ingress[t.name] = ingress[t.name] * mfac[t.name]
            step_delta[t.name] = step_delta[t.name] * mfac[t.name]

    # ---- output tensor ------------------------------------------------
    o = op.output
    o_tile = tensor_volume(o, tiles, xp)
    o_coupled = [lp for lp in loops if o.coupled_to(lp.dim)]
    red_dims = op.reduction_dims()
    commits = 1
    for lp in o_coupled:
        commits = commits * lp.total_steps()
    # reduction loops outer to the innermost O-coupled loop force spills
    spill = 1
    if o_coupled:
        # identity search — list.index would value-compare LoopInfo
        # dataclasses, concretizing traced phase fields
        inner_idx = next(i for i, lp in enumerate(loops)
                         if lp is o_coupled[-1])
        for i, lp in enumerate(loops):
            if i < inner_idx and lp.dim in red_dims:
                spill = spill * lp.total_steps()
    else:
        # every loop is a reduction loop; single tile accumulated locally
        commits = 1
    egress_o = o_tile * commits * spill
    readback = o_tile * commits * (spill - 1)
    if spatial_reduction_active(op, level) and not reduction_hw:
        # no spatial-reduction HW: each unit ships its own partial sums up
        egress_o = egress_o * level.n_units
        readback = readback * level.n_units
    # steady per-step egress (amortized drain rate)
    step_egress = xp.ceil_div(egress_o, xp.maximum(total_steps, 1))

    ingress[OUTPUT] = readback
    return LevelTraffic(
        ingress=ingress,
        egress={OUTPUT: egress_o},
        psum_readback=readback,
        multicast_factor=mfac,
        step_delta=step_delta,
        step_egress=step_egress,
        total_steps=total_steps,
        reuse=reuse,
    )
