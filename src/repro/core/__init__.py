# MAESTRO — the paper's primary contribution, reimplemented as a
# JAX-friendly analytical cost model + DSE engine.
#
# Layers:
#   directives        data-centric dataflow IR (SpatialMap/TemporalMap/Cluster)
#   tensor_analysis   TA engine: dimension coupling per layer op
#   cluster_analysis  CLA engine: levels, phases, iteration cases
#   reuse_analysis    RA engine: reuse classes + traffic closed forms
#   performance       PA engine: pipe-model delays, double buffering
#   model             combined PA+CA recursion -> Stats
#   vectorized        the same math under jit/vmap (traced hardware params)
#   dse               design-space exploration tool (paper §5.2)
#   dataflows         Table 3 + Fig. 4/5/6 dataflow programs
#   dnn_models        VGG16/AlexNet/ResNet50/MobileNetV2/ResNeXt50/UNet zoo
#   energy            Cacti-28nm-class energy + RTL-fit area/power models
#   mapper            directive program -> TPU mesh sharding bridge
#   roofline          3-term roofline from compiled dry-run artifacts
#   hlo_analysis      HLO text -> collective bytes

from .directives import (FULL, Cluster, Dataflow, SpatialMap, Sz,
                         TemporalMap, parse, resolve, complete)
from .tensor_analysis import (LayerOp, conv1d, conv1d_outputs, conv2d,
                              conv2d_outputs, dwconv2d, fc, gemm,
                              pointwise_conv, pool2d, transposed_conv2d,
                              algorithmic_max_reuse)
from .performance import HWConfig
from .model import Stats, analyze, analyze_network, network_totals
from .energy import (DEFAULT_AREA_POWER, DEFAULT_ENERGY, AreaPowerModel,
                     EnergyModel, EYERISS_AREA_MM2, EYERISS_POWER_MW)
from . import dataflows, dnn_models

__all__ = [
    "FULL", "Cluster", "Dataflow", "SpatialMap", "Sz", "TemporalMap",
    "parse", "resolve", "complete",
    "LayerOp", "conv1d", "conv1d_outputs", "conv2d", "conv2d_outputs",
    "dwconv2d", "fc", "gemm", "pointwise_conv", "pool2d",
    "transposed_conv2d", "algorithmic_max_reuse",
    "HWConfig", "Stats", "analyze", "analyze_network", "network_totals",
    "DEFAULT_AREA_POWER", "DEFAULT_ENERGY", "AreaPowerModel", "EnergyModel",
    "EYERISS_AREA_MM2", "EYERISS_POWER_MW",
    "dataflows", "dnn_models",
]
