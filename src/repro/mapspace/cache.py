"""On-disk caches for mapping searches.

Two layers:

  * a *result* cache keyed by ``(layer, space, hardware, objective,
    budget, strategy, seed)`` so a repeated query — same layer swept again
    in a bigger co-DSE, a re-run CLI invocation, a notebook re-execution —
    returns instantly instead of paying the jit + evaluation cost.  Values
    are small JSON payloads (the winning gene tuples and their feature
    rows), not feature matrices, so the cache stays tiny and
    diff-friendly;
  * JAX's *persistent compilation cache*
    (:func:`enable_compilation_cache`), which stores the compiled XLA
    executables themselves.  With the universal evaluator there is exactly
    one executable per (op, level-count, block) — persisting it means even
    the first search of a fresh process skips the multi-second compile.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import threading
from typing import Any

from .. import obs
from ..core.tensor_analysis import LayerOp
from ..resilience.errors import CacheError
from .space import MapSpace

LOG = logging.getLogger("repro.resilience")

# Result-cache payload version.  Bumped to 3 for the PR-5 declarative
# api surface: the key now carries the engine schema version and (via
# ``extra``) the full Query fingerprint, so stale PR-4-era entries can
# never be replayed into ``Session.run``.
CACHE_VERSION = 3

# Version of the engine/query schema behind the declarative front door
# (``repro.api`` re-exports this as ``SCHEMA_VERSION``).  Bump when query
# semantics, the Report schema, or engine numerics change incompatibly.
# 2: Report carries the obs environment-provenance block in bench
# artifacts and the metrics snapshot schema exists alongside it.
ENGINE_SCHEMA_VERSION = 2

# Set once per process; repeated calls with the same directory are no-ops.
_COMPILATION_CACHE_DIR: str | None = None

# Guards the ``result_cache.entries``/``result_cache.bytes`` gauges AND
# the directory transitions they account (store's os.replace, load's
# quarantine rename).  Holding one lock across both halves is the whole
# fix: the PR-9 gauges were set from an unsynchronized directory scan in
# ``Session._result_cache_stats``, so a scan interleaving with a
# concurrent writer's replace could publish counts that no directory
# state ever had (and a late gauge() write could clobber a newer one).
# The found-by-linter regression test lives in tests/test_analysis.py.
_GAUGE_LOCK = threading.Lock()


def _account(d_entries: int, d_bytes: int) -> None:
    """Adjust the occupancy gauges; caller holds ``_GAUGE_LOCK``."""
    m = obs.metrics()
    m.gauge("result_cache.entries",
            max(0, int(m.gauge_value("result_cache.entries")) + d_entries))
    m.gauge("result_cache.bytes",
            max(0, int(m.gauge_value("result_cache.bytes")) + d_bytes))


def cache_stats(cache_dir: str | None) -> tuple[int, int]:
    """(entries, bytes) of the result cache, measured from the directory
    and published to the gauges — scan and publish under the same lock
    the writers' transitions take, so the gauges always equal a real
    directory state.  The full rescan also reconciles writes from OTHER
    processes sharing the cache dir, which incremental accounting cannot
    see."""
    entries = size = 0
    with _GAUGE_LOCK:
        if cache_dir:
            try:
                with os.scandir(cache_dir) as it:
                    for de in it:
                        if de.name.startswith("mapsearch-") \
                                and de.name.endswith(".json"):
                            entries += 1
                            try:
                                size += de.stat().st_size
                            except OSError:
                                pass
            except OSError:
                pass
        m = obs.metrics()
        m.gauge("result_cache.entries", entries)
        m.gauge("result_cache.bytes", size)
    return entries, size


def enable_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir`` so the
    universal evaluator's one-off XLA compiles amortize across processes,
    not just within one.  Returns True when the cache is active.

    Safe to call repeatedly; a different directory after the first call is
    ignored (JAX initializes the cache lazily but only honours one
    location per process)."""
    global _COMPILATION_CACHE_DIR
    if not cache_dir:
        return False
    cache_dir = os.path.expanduser(cache_dir)
    if _COMPILATION_CACHE_DIR is not None:
        return True
    try:
        import jax
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # persist even quick compiles: the universal executables are the
        # dominant cost and always worth keeping
        try:
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.0)
        except (AttributeError, ValueError):
            pass  # older jax: default threshold still persists big compiles
    except Exception:
        return False
    _COMPILATION_CACHE_DIR = cache_dir
    return True


def op_fingerprint(op: LayerOp) -> str:
    txt = f"{op.name}|{op.op_type}|{sorted(op.dims.items())}"
    return hashlib.sha256(txt.encode()).hexdigest()[:16]


def search_key(op: LayerOp, space: MapSpace, num_pes: int, noc_bw: float,
               objective: str, budget: int, strategy: str, seed: int,
               extra: str = "") -> str:
    txt = "|".join([
        f"v{CACHE_VERSION}", f"schema{ENGINE_SCHEMA_VERSION}",
        op_fingerprint(op), space.fingerprint(),
        f"pes={num_pes}", f"bw={noc_bw}", objective, f"budget={budget}",
        strategy, f"seed={seed}", extra])
    return hashlib.sha256(txt.encode()).hexdigest()[:24]


def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"mapsearch-{key}.json")


def load(cache_dir: str | None, key: str) -> dict[str, Any] | None:
    """Result-cache lookup.  A corrupt entry (truncated write, bad JSON,
    non-dict payload) is NEVER fatal: it counts as a miss, the file is
    quarantined to ``<entry>.corrupt`` so the recompute can re-store,
    and the event is logged as a one-line :class:`CacheError` warning +
    ``result_cache.corrupt`` counter."""
    if not cache_dir:
        return None
    path = _path(cache_dir, key)
    try:
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict):
            raise ValueError(f"expected a JSON object, "
                             f"got {type(payload).__name__}")
    except FileNotFoundError:
        obs.metrics().inc("result_cache.misses")
        return None
    except (OSError, ValueError) as e:
        obs.metrics().inc("result_cache.misses")
        obs.metrics().inc("result_cache.corrupt")
        err = CacheError(f"corrupt result-cache entry {path}: "
                         f"{type(e).__name__}: {e}", key=key)
        LOG.warning("%s — quarantined, treating as a miss",
                    err.one_line())
        # quarantine + gauge adjustment are ONE transition under the
        # gauge lock, so a concurrent cache_stats() scan can never
        # publish counts that still include the quarantined entry
        with _GAUGE_LOCK:
            try:
                gone = os.path.getsize(path)
                os.replace(path, path + ".corrupt")
            except OSError:
                pass               # e.g. unreadable due to permissions
            else:
                _account(-1, -gone)
        return None
    if payload.get("version") != CACHE_VERSION:
        obs.metrics().inc("result_cache.misses")
        return None
    obs.metrics().inc("result_cache.hits")
    return payload


def store(cache_dir: str | None, key: str, payload: dict[str, Any]) -> None:
    if not cache_dir:
        return
    obs.metrics().inc("result_cache.stores")
    os.makedirs(cache_dir, exist_ok=True)
    payload = dict(payload, version=CACHE_VERSION)
    # unique temp name per writer (matches sweepckpt's commit protocol):
    # concurrent server workers sharing a cache dir each write their own
    # temp file, so no interleaved writes can produce a torn entry — the
    # last os.replace wins whole
    tmp = (_path(cache_dir, key)
           + f".tmp-{os.getpid()}-{threading.get_ident()}")
    with open(tmp, "w") as f:
        json.dump(payload, f)
    # the commit (os.replace) and its gauge delta happen under one lock:
    # the occupancy gauges track every directory transition instead of
    # waiting for the next metrics() scan, and concurrent writers can
    # never interleave a scan between replace and publish
    dst = _path(cache_dir, key)
    with _GAUGE_LOCK:
        try:
            old = os.path.getsize(dst)
            fresh = 0
        except OSError:
            old, fresh = 0, 1
        new = os.path.getsize(tmp)
        os.replace(tmp, dst)
        _account(fresh, new - old)
