"""On-disk result cache for mapping searches.

Keyed by ``(layer, space, hardware, objective, budget, strategy, seed)`` so
a repeated query — same layer swept again in a bigger co-DSE, a re-run CLI
invocation, a notebook re-execution — returns instantly instead of paying
the jit + evaluation cost.  Values are small JSON payloads (the winning
gene tuples and their feature rows), not feature matrices, so the cache
stays tiny and diff-friendly.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any

from ..core.tensor_analysis import LayerOp
from .space import MapSpace

CACHE_VERSION = 1


def op_fingerprint(op: LayerOp) -> str:
    txt = f"{op.name}|{op.op_type}|{sorted(op.dims.items())}"
    return hashlib.sha256(txt.encode()).hexdigest()[:16]


def search_key(op: LayerOp, space: MapSpace, num_pes: int, noc_bw: float,
               objective: str, budget: int, strategy: str, seed: int,
               extra: str = "") -> str:
    txt = "|".join([
        f"v{CACHE_VERSION}", op_fingerprint(op), space.fingerprint(),
        f"pes={num_pes}", f"bw={noc_bw}", objective, f"budget={budget}",
        strategy, f"seed={seed}", extra])
    return hashlib.sha256(txt.encode()).hexdigest()[:24]


def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"mapsearch-{key}.json")


def load(cache_dir: str | None, key: str) -> dict[str, Any] | None:
    if not cache_dir:
        return None
    try:
        with open(_path(cache_dir, key)) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if payload.get("version") != CACHE_VERSION:
        return None
    return payload


def store(cache_dir: str | None, key: str, payload: dict[str, Any]) -> None:
    if not cache_dir:
        return
    os.makedirs(cache_dir, exist_ok=True)
    payload = dict(payload, version=CACHE_VERSION)
    tmp = _path(cache_dir, key) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, _path(cache_dir, key))
