"""Mapping-space search strategies behind one ``search()`` API.

Four strategies, auto-selected by space size vs budget:

  * ``exhaustive`` — every point, when the space fits the budget;
  * ``random`` — uniform sampling over the whole space;
  * ``greedy`` — hill-climbing refinement of the random phase's best
    point: neighbors mutate one gene at a time, *including* structural
    genes (spatial / permutation / cluster);
  * ``genetic`` — crossover + mutation over the gene encoding with large
    populations.

Structure genes are ordinary search moves because evaluation runs through
the universal structure-as-operand evaluator (``mapspace.universal``): the
whole space costs at most two XLA compiles, so nothing clamps how many
(spatial × perm × cluster) groups a strategy may visit.  Before
evaluation, candidate points are deduped against analysis-equivalent
permutations and optionally bounded by L1/L2 buffer budgets
(``space.prune_by_budget``).

Everything is deterministic under ``seed``.  Objective values come from the
batched feature vector (``core.vectorized.FEATURES``); lower-is-better
except throughput.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Sequence

import numpy as np

from ..core.directives import Dataflow
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES
from . import cache as _cache
from .batched import FEATURE_INDEX, EvalStats, evaluate_points
from .space import MapSpace, Point, build_space, dedupe_equivalent_points, \
    enumerate_points, point_dataflow, prune_by_budget, sample_points

# objective -> (feature column, maximize?)
OBJECTIVES = {
    "edp": ("edp", False),
    "energy": ("energy_pj", False),
    "runtime": ("runtime", False),
    "throughput": ("throughput", True),
}

STRATEGIES = ("exhaustive", "random", "greedy", "genetic")


@dataclasses.dataclass
class SearchResult:
    objective: str
    strategy: str
    space: MapSpace
    best_point: Point
    best_value: float
    best_stats: dict[str, float]
    top_k: list[dict[str, Any]]       # [{point, value, stats}]
    n_evaluated: int
    n_groups: int
    elapsed_s: float
    eval_s: float
    compile_s: float
    n_steady: int = 0                 # rows in steady-timed batched calls
    n_compiles: int = 0               # XLA compiles triggered
    cached: bool = False

    @property
    def best_dataflow(self) -> Dataflow:
        return point_dataflow(self.space, self.best_point)

    @property
    def mappings_per_s(self) -> float:
        """Steady-state batched evaluation rate, on the SAME definition as
        :class:`EvalStats.mappings_per_s`: steady-timed rows (padding and
        first-call compile re-runs excluded) over steady evaluation time.
        Compiles are a one-off amortized across repeated queries (cf. the
        on-disk result cache and the jax compilation cache)."""
        if not self.n_steady:
            return 0.0
        return self.n_steady / max(self.eval_s, 1e-9)


def _objective_column(feats: np.ndarray, objective: str) -> np.ndarray:
    col, maximize = OBJECTIVES[objective]
    v = feats[:, FEATURE_INDEX[col]].astype(np.float64)
    v = np.where(np.isfinite(v), v, np.inf if not maximize else -np.inf)
    return -v if maximize else v  # canonical: minimize


def _stats_dict(row: np.ndarray) -> dict[str, float]:
    return {name: float(row[i]) for i, name in enumerate(FEATURES)}


def _neighbors(space: MapSpace, pt: Point) -> list[Point]:
    """One-gene mutations.  Structural genes (spatial / perm / cluster)
    move freely: with the universal evaluator a new structure group is just
    a different operand pattern, not a new XLA compile."""
    ranges = space.gene_ranges()
    out = []
    for gi in range(len(pt)):
        for delta in (-1, 1):
            g = pt[gi] + delta
            if not 0 <= g < ranges[gi]:
                continue
            out.append(pt[:gi] + (g,) + pt[gi + 1:])
    return out


def _random_point(space: MapSpace, rng: np.random.Generator) -> Point:
    return tuple(int(rng.integers(r)) for r in space.gene_ranges())


def _genetic_loop(space: MapSpace, rng: np.random.Generator, budget: int,
                  run, evaluated: dict[Point, float], *,
                  population: int, mutate_p: float = 0.15,
                  tournament: int = 3) -> None:
    """Crossover + mutation over the gene encoding (ROADMAP item).  Large
    populations are practical because structural genes no longer trigger
    compiles — the whole generation is one batched evaluate call."""
    ranges = space.gene_ranges()
    population = max(4, min(population, budget))
    run(sample_points(space, rng, population))
    stalls = 0
    while len(evaluated) < budget and evaluated and stalls < 8:
        before = len(evaluated)
        pool = sorted(evaluated, key=evaluated.get)[:population]

        def pick() -> Point:
            idx = rng.integers(len(pool), size=tournament).min()
            return pool[int(idx)]

        children: list[Point] = []
        seen: set[Point] = set()
        attempts = 0
        want = min(population, budget - len(evaluated))
        while len(children) < want and attempts < 20 * want:
            attempts += 1
            a, b = pick(), pick()
            mask = rng.random(len(ranges))
            child = tuple(
                (int(rng.integers(r)) if m < mutate_p else
                 (ga if m < (1 + mutate_p) / 2 else gb))
                for ga, gb, m, r in zip(a, b, mask, ranges))
            if child in seen or child in evaluated:
                continue
            seen.add(child)
            children.append(child)
        if not children:
            # population converged: re-seed with fresh uniform points
            children = sample_points(space, rng, want, exclude=set(evaluated))
            if not children:
                break
        run(children)
        # budget pruning may silently drop every child: bound the loop so
        # a feasible set smaller than the budget terminates instead of
        # spinning forever
        stalls = stalls + 1 if len(evaluated) == before else 0


def search(op: LayerOp, objective: str = "edp", budget: int = 2000, *,
           space: MapSpace | None = None, num_pes: int = 256,
           noc_bw: float = 32.0, strategy: str = "auto", seed: int = 0,
           top_k: int = 8, max_groups: int | None = None,
           refine_frac: float = 0.3, block: int = 1024,
           population: int | None = None,
           l1_budget_kb: float | None = None,
           l2_budget_kb: float | None = None,
           cache_dir: str | None = None, engine: str = "universal",
           multicast: bool = True, spatial_reduction: bool = True
           ) -> SearchResult:
    """Search the mapping space of ``op`` for the best dataflow at a fixed
    hardware point.  ``budget`` caps evaluated mappings; ``strategy`` is
    ``auto`` or one of ``exhaustive`` / ``random`` / ``greedy`` /
    ``genetic``.

    ``max_groups`` is legacy: the universal evaluator made structure-group
    exploration compile-free, so nothing is clamped anymore (the value
    still participates in the result-cache key for reproducibility).
    ``l1_budget_kb``/``l2_budget_kb`` drop over-budget tile sets before
    evaluation."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {sorted(OBJECTIVES)}")
    space = space or build_space(op)
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()

    if strategy == "auto":
        strategy = "exhaustive" if space.size <= budget else "greedy"
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")

    key = _cache.search_key(
        op, space, num_pes, noc_bw, objective, budget, strategy, seed,
        extra=f"mc={multicast},sr={spatial_reduction},mg={max_groups},"
              f"rf={refine_frac},blk={block},tk={top_k},"
              f"pop={population},l1={l1_budget_kb},l2={l2_budget_kb},"
              f"eng={engine}")
    hit = _cache.load(cache_dir, key)
    if hit is not None:
        return SearchResult(
            objective=objective, strategy=hit["strategy"], space=space,
            best_point=tuple(hit["best_point"]),
            best_value=hit["best_value"], best_stats=hit["best_stats"],
            top_k=[{"point": tuple(e["point"]), "value": e["value"],
                    "stats": e["stats"]} for e in hit["top_k"]],
            n_evaluated=hit["n_evaluated"], n_groups=hit["n_groups"],
            elapsed_s=time.perf_counter() - t_start,
            eval_s=hit["eval_s"], compile_s=hit["compile_s"],
            n_steady=hit.get("n_steady", 0),
            n_compiles=hit.get("n_compiles", 0), cached=True)

    ev = dict(num_pes=num_pes, noc_bw=noc_bw, block=block,
              multicast=multicast, spatial_reduction=spatial_reduction,
              engine=engine)
    stats = EvalStats()
    evaluated: dict[Point, float] = {}
    rows: dict[Point, np.ndarray] = {}

    def run(points: Sequence[Point]) -> None:
        points = [p for p in points if p not in evaluated]
        points = prune_by_budget(op, space, points, l1_kb=l1_budget_kb,
                                 l2_kb=l2_budget_kb)
        if not points:
            return
        # analysis-equivalent permutations collapse to one evaluated row
        reps, back = dedupe_equivalent_points(op, space, points)
        feats, st = evaluate_points(op, space, reps, **ev)
        stats.merge(st)
        vals = _objective_column(feats, objective)
        for i, p in enumerate(points):
            evaluated[p] = float(vals[back[i]])
            rows[p] = feats[back[i]]

    if strategy == "exhaustive":
        pts = list(itertools.islice(enumerate_points(space), budget))
        if space.size > budget:
            # enumerate_points orders structural genes outermost, so the
            # kept prefix only covers the leading structure group(s) — say
            # so rather than reporting a full sweep
            strategy = "exhaustive[truncated]"
        run(pts)
    elif strategy == "genetic":
        pop = population or max(32, min(10_000, budget // 4))
        _genetic_loop(space, rng, budget, run, evaluated, population=pop)
    else:
        n_refine = int(budget * refine_frac) if strategy == "greedy" else 0
        run(sample_points(space, rng, budget - n_refine))
        if strategy == "greedy" and evaluated:
            spent_guard = 0
            while len(evaluated) < budget and spent_guard < 64:
                spent_guard += 1
                best = min(evaluated, key=evaluated.get)
                nbrs = [p for p in _neighbors(space, best)
                        if p not in evaluated][:budget - len(evaluated)]
                if not nbrs:
                    break
                run(nbrs)
                if evaluated[min(evaluated, key=evaluated.get)] >= \
                        evaluated[best]:
                    break  # converged: no neighbor improved

    if not evaluated:
        raise RuntimeError("search evaluated no mappings "
                           "(empty space, or budgets pruned everything?)")

    groups = {space.group_key(p) for p in evaluated}
    order = sorted(evaluated, key=evaluated.get)
    _, maximize = OBJECTIVES[objective]

    def value_of(p: Point) -> float:
        return -evaluated[p] if maximize else evaluated[p]

    best = order[0]
    result = SearchResult(
        objective=objective, strategy=strategy, space=space,
        best_point=best, best_value=value_of(best),
        best_stats=_stats_dict(rows[best]),
        top_k=[{"point": p, "value": value_of(p),
                "stats": _stats_dict(rows[p])} for p in order[:top_k]],
        n_evaluated=len(evaluated), n_groups=len(groups),
        elapsed_s=time.perf_counter() - t_start,
        eval_s=stats.eval_s, compile_s=stats.compile_s,
        n_steady=stats.n_steady, n_compiles=stats.n_compiles)

    _cache.store(cache_dir, key, {
        "strategy": result.strategy,
        "best_point": list(best), "best_value": result.best_value,
        "best_stats": result.best_stats,
        "top_k": [{"point": list(e["point"]), "value": e["value"],
                   "stats": e["stats"]} for e in result.top_k],
        "n_evaluated": result.n_evaluated, "n_groups": result.n_groups,
        "eval_s": result.eval_s, "compile_s": result.compile_s,
        "n_steady": result.n_steady, "n_compiles": result.n_compiles})
    return result
