"""Mapping-space search strategies behind one ``search()`` API.

Three strategies, auto-selected by space size vs budget:

  * ``exhaustive`` — every point, when the space (and its jit-group count)
    fits the budget;
  * ``random`` — uniform sampling over a deterministic subset of structure
    groups (each group is a separate XLA compile, so unbounded group
    exploration would spend the budget on compiles, not evaluations);
  * ``greedy`` — hill-climbing refinement of the random phase's best point:
    neighbors mutate one gene at a time, structural moves are restricted to
    already-compiled groups.

Everything is deterministic under ``seed``.  Objective values come from the
batched feature vector (``core.vectorized.FEATURES``); lower-is-better
except throughput.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Sequence

import numpy as np

from ..core.directives import Dataflow
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES
from . import cache as _cache
from .batched import FEATURE_INDEX, EvalStats, evaluate_points
from .space import MapSpace, Point, build_space, enumerate_points, \
    point_dataflow, sample_points

# objective -> (feature column, maximize?)
OBJECTIVES = {
    "edp": ("edp", False),
    "energy": ("energy_pj", False),
    "runtime": ("runtime", False),
    "throughput": ("throughput", True),
}


@dataclasses.dataclass
class SearchResult:
    objective: str
    strategy: str
    space: MapSpace
    best_point: Point
    best_value: float
    best_stats: dict[str, float]
    top_k: list[dict[str, Any]]       # [{point, value, stats}]
    n_evaluated: int
    n_groups: int
    elapsed_s: float
    eval_s: float
    compile_s: float
    cached: bool = False

    @property
    def best_dataflow(self) -> Dataflow:
        return point_dataflow(self.space, self.best_point)

    @property
    def mappings_per_s(self) -> float:
        """Steady-state batched evaluation rate (compiles excluded — they
        are a one-off amortized across repeated queries, cf. the on-disk
        cache)."""
        return self.n_evaluated / max(self.eval_s, 1e-9)


def _objective_column(feats: np.ndarray, objective: str) -> np.ndarray:
    col, maximize = OBJECTIVES[objective]
    v = feats[:, FEATURE_INDEX[col]].astype(np.float64)
    v = np.where(np.isfinite(v), v, np.inf if not maximize else -np.inf)
    return -v if maximize else v  # canonical: minimize


def _stats_dict(row: np.ndarray) -> dict[str, float]:
    return {name: float(row[i]) for i, name in enumerate(FEATURES)}


def _select_groups(space: MapSpace, max_groups: int,
                   rng: np.random.Generator) -> list:
    keys = space.group_keys()
    if len(keys) <= max_groups:
        return keys
    # evenly-strided subset with a seeded phase: spreads across spatial /
    # perm / cluster choices instead of clustering at the list head
    stride = len(keys) / max_groups
    phase = float(rng.uniform(0, stride))
    return [keys[int(phase + i * stride) % len(keys)]
            for i in range(max_groups)]


def _neighbors(space: MapSpace, pt: Point,
               allowed_groups: set) -> list[Point]:
    """One-gene mutations; structural genes only move within groups that
    are already compiled (allowed_groups)."""
    ranges = space.gene_ranges()
    out = []
    for gi in range(len(pt)):
        for delta in (-1, 1):
            g = pt[gi] + delta
            if not 0 <= g < ranges[gi]:
                continue
            cand = pt[:gi] + (g,) + pt[gi + 1:]
            if gi < 3 and space.group_key(cand) not in allowed_groups:
                continue
            out.append(cand)
    return out


def search(op: LayerOp, objective: str = "edp", budget: int = 2000, *,
           space: MapSpace | None = None, num_pes: int = 256,
           noc_bw: float = 32.0, strategy: str = "auto", seed: int = 0,
           top_k: int = 8, max_groups: int = 12, refine_frac: float = 0.3,
           block: int = 1024, cache_dir: str | None = None,
           multicast: bool = True, spatial_reduction: bool = True
           ) -> SearchResult:
    """Search the mapping space of ``op`` for the best dataflow at a fixed
    hardware point.  ``budget`` caps evaluated mappings; ``strategy`` is
    ``auto`` / ``exhaustive`` / ``random`` / ``greedy``."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {sorted(OBJECTIVES)}")
    space = space or build_space(op)
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()

    if strategy == "auto":
        strategy = "exhaustive" \
            if space.size <= budget and space.n_groups <= max_groups \
            else "greedy"
    if strategy not in ("exhaustive", "random", "greedy"):
        raise ValueError(f"unknown strategy {strategy!r}")

    key = _cache.search_key(
        op, space, num_pes, noc_bw, objective, budget, strategy, seed,
        extra=f"mc={multicast},sr={spatial_reduction},mg={max_groups},"
              f"rf={refine_frac},blk={block},tk={top_k}")
    hit = _cache.load(cache_dir, key)
    if hit is not None:
        return SearchResult(
            objective=objective, strategy=hit["strategy"], space=space,
            best_point=tuple(hit["best_point"]),
            best_value=hit["best_value"], best_stats=hit["best_stats"],
            top_k=[{"point": tuple(e["point"]), "value": e["value"],
                    "stats": e["stats"]} for e in hit["top_k"]],
            n_evaluated=hit["n_evaluated"], n_groups=hit["n_groups"],
            elapsed_s=time.perf_counter() - t_start,
            eval_s=hit["eval_s"], compile_s=hit["compile_s"], cached=True)

    ev = dict(num_pes=num_pes, noc_bw=noc_bw, block=block,
              multicast=multicast, spatial_reduction=spatial_reduction)
    stats = EvalStats()
    evaluated: dict[Point, float] = {}
    rows: dict[Point, np.ndarray] = {}

    def run(points: Sequence[Point]) -> None:
        points = [p for p in points if p not in evaluated]
        if not points:
            return
        feats, st = evaluate_points(op, space, points, **ev)
        stats.merge(st)
        vals = _objective_column(feats, objective)
        for i, p in enumerate(points):
            evaluated[p] = float(vals[i])
            rows[p] = feats[i]

    if strategy == "exhaustive":
        pts = list(itertools.islice(enumerate_points(space), budget))
        if space.size > budget:
            # enumerate_points orders structural genes outermost, so the
            # kept prefix only covers the leading structure group(s) — say
            # so rather than reporting a full sweep
            strategy = "exhaustive[truncated]"
        run(pts)
        groups = {space.group_key(p) for p in evaluated}
    else:
        groups_list = _select_groups(space, max_groups, rng)
        groups = set(groups_list)
        n_refine = int(budget * refine_frac) if strategy == "greedy" else 0
        run(sample_points(space, rng, budget - n_refine, groups_list))
        if strategy == "greedy" and evaluated:
            spent_guard = 0
            while len(evaluated) < budget and spent_guard < 64:
                spent_guard += 1
                best = min(evaluated, key=evaluated.get)
                nbrs = [p for p in _neighbors(space, best, groups)
                        if p not in evaluated][:budget - len(evaluated)]
                if not nbrs:
                    break
                run(nbrs)
                if evaluated[min(evaluated, key=evaluated.get)] >= \
                        evaluated[best]:
                    break  # converged: no neighbor improved

    if not evaluated:
        raise RuntimeError("search evaluated no mappings (empty space?)")

    order = sorted(evaluated, key=evaluated.get)
    _, maximize = OBJECTIVES[objective]

    def value_of(p: Point) -> float:
        return -evaluated[p] if maximize else evaluated[p]

    best = order[0]
    result = SearchResult(
        objective=objective, strategy=strategy, space=space,
        best_point=best, best_value=value_of(best),
        best_stats=_stats_dict(rows[best]),
        top_k=[{"point": p, "value": value_of(p),
                "stats": _stats_dict(rows[p])} for p in order[:top_k]],
        n_evaluated=len(evaluated), n_groups=len(groups),
        elapsed_s=time.perf_counter() - t_start,
        eval_s=stats.eval_s, compile_s=stats.compile_s)

    _cache.store(cache_dir, key, {
        "strategy": result.strategy,
        "best_point": list(best), "best_value": result.best_value,
        "best_stats": result.best_stats,
        "top_k": [{"point": list(e["point"]), "value": e["value"],
                   "stats": e["stats"]} for e in result.top_k],
        "n_evaluated": result.n_evaluated, "n_groups": result.n_groups,
        "eval_s": result.eval_s, "compile_s": result.compile_s})
    return result
