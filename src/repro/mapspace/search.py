"""Mapping-space search strategies behind one ``search()`` API.

Four strategies, auto-selected by space size vs budget:

  * ``exhaustive`` — every point, when the space fits the budget;
  * ``random`` — uniform sampling over the whole space;
  * ``greedy`` — hill-climbing refinement of the random phase's best
    point: neighbors mutate one gene at a time, *including* structural
    genes (spatial / permutation / cluster);
  * ``genetic`` — crossover + mutation over the gene encoding with large
    populations.

Two execution pipelines share the strategies:

  * ``pipeline="gene"`` (default) — integer **gene matrices** are the
    native currency end to end: vectorized enumeration/sampling
    (``space.enumerate_genes`` / ``sample_genes``), vectorized
    budget-pruning and equivalence-dedupe, numpy-gather operand encoding
    (``universal.encode_genes``), async double-buffered dispatch striped
    over local devices, and the objective/top-k reduction fused into the
    XLA executable (``universal.evaluate_genes``).  The host never sees a
    full feature matrix — only the objective column and k winner rows.
  * ``pipeline="legacy"`` — the tuple-point path (per-point Python encode
    + host numpy reduction), kept intact as a parity oracle and
    baseline: both pipelines evaluate identical candidate sets under a
    fixed seed and must report matching top-k values.

The genetic strategy's selection/crossover/mutation run on-device via
``jax.random`` over gene matrices in the gene pipeline (the legacy
pipeline keeps the original numpy loop).

Everything is deterministic under ``seed`` — including the sharded gene
pipeline, whose per-shard top-k merge is by (value, global index) and so
yields identical results at any device count.  Objective values come
from the batched feature vector (``core.vectorized.FEATURES``);
lower-is-better except throughput.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from ..core.directives import Dataflow
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES
from ..resilience import SpecError, SweepCheckpoint
from . import cache as _cache
from .batched import FEATURE_INDEX, EvalStats, evaluate_points
from .space import (MapSpace, Point, build_space, dedupe_equivalent_genes,
                    dedupe_equivalent_points, enumerate_genes,
                    enumerate_points, flat_index, point_dataflow,
                    points_from_genes, prune_by_budget,
                    prune_genes_by_budget, sample_genes, sample_points)
from .universal import evaluate_genes

# objective -> (feature column, maximize?)
OBJECTIVES = {
    "edp": ("edp", False),
    "energy": ("energy_pj", False),
    "runtime": ("runtime", False),
    "throughput": ("throughput", True),
}

STRATEGIES = ("exhaustive", "random", "greedy", "genetic")
PIPELINES = ("gene", "legacy")


@dataclasses.dataclass
class SearchResult:
    objective: str
    strategy: str
    space: MapSpace
    best_point: Point
    best_value: float
    best_stats: dict[str, float]
    top_k: list[dict[str, Any]]       # [{point, value, stats}]
    n_evaluated: int
    n_groups: int
    elapsed_s: float
    eval_s: float
    compile_s: float
    n_steady: int = 0                 # rows in steady-timed batched calls
    n_compiles: int = 0               # XLA compiles triggered
    cached: bool = False
    pipeline: str = "legacy"
    encode_s: float = 0.0             # host operand-encode time
    n_devices: int = 1
    wall_s: float = 0.0               # original search wall (survives the
    #                                   result cache, unlike elapsed_s)                # devices the eval striped across

    @property
    def best_dataflow(self) -> Dataflow:
        return point_dataflow(self.space, self.best_point)

    @property
    def mappings_per_s(self) -> float:
        """Steady-state batched evaluation rate, on the SAME definition as
        :class:`EvalStats.mappings_per_s`: steady-timed rows (padding and
        first-call compile re-runs excluded) over steady evaluation time.
        Compiles are a one-off amortized across repeated queries (cf. the
        on-disk result cache and the jax compilation cache)."""
        if not self.n_steady:
            return 0.0
        return self.n_steady / max(self.eval_s, 1e-9)

    @property
    def end_to_end_mappings_per_s(self) -> float:
        """User-observable throughput: evaluated mappings over the FULL
        search wall time — enumeration/sampling, pruning, dedupe, operand
        encode, dispatch and reduction — excluding only the one-off XLA
        compile (amortized by the persistent compilation cache).  This is
        the number to compare against the paper's 0.17M designs/s.
        Quoted on the ORIGINAL run's wall (``wall_s``) so a result-cache
        hit reports the rate of the search it replays, not of the cache
        load."""
        denom = self.wall_s - self.compile_s
        if denom <= 0:
            return 0.0
        return self.n_evaluated / denom


def _objective_column(feats: np.ndarray, objective: str) -> np.ndarray:
    col, maximize = OBJECTIVES[objective]
    v = feats[:, FEATURE_INDEX[col]].astype(np.float64)
    v = np.where(np.isfinite(v), v, np.inf if not maximize else -np.inf)
    return -v if maximize else v  # canonical: minimize


def _stats_dict(row: np.ndarray) -> dict[str, float]:
    return {name: float(row[i]) for i, name in enumerate(FEATURES)}


def _neighbors(space: MapSpace, pt: Point) -> list[Point]:
    """One-gene mutations.  Structural genes (spatial / perm / cluster)
    move freely: with the universal evaluator a new structure group is just
    a different operand pattern, not a new XLA compile."""
    ranges = space.gene_ranges()
    out = []
    for gi in range(len(pt)):
        for delta in (-1, 1):
            g = pt[gi] + delta
            if not 0 <= g < ranges[gi]:
                continue
            out.append(pt[:gi] + (g,) + pt[gi + 1:])
    return out


def _neighbor_genes(space: MapSpace, row: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_neighbors` over one gene row."""
    ranges = np.asarray(space.gene_ranges(), np.int64)
    g = len(ranges)
    eye = np.eye(g, dtype=np.int64)
    cand = np.stack([row[None] - eye, row[None] + eye], axis=1)
    cand = cand.reshape(2 * g, g)            # g0-1, g0+1, g1-1, ...
    ok = np.all((cand >= 0) & (cand < ranges[None, :]), axis=1)
    return cand[ok]


def _random_point(space: MapSpace, rng: np.random.Generator) -> Point:
    return tuple(int(rng.integers(r)) for r in space.gene_ranges())


# ----------------------------------------------------------------------
# Legacy tuple-point pipeline (parity oracle / baseline)
# ----------------------------------------------------------------------

def _genetic_loop(space: MapSpace, rng: np.random.Generator, budget: int,
                  run, evaluated: dict[Point, float], *,
                  population: int, mutate_p: float = 0.15,
                  tournament: int = 3) -> None:
    """Crossover + mutation over the gene encoding (ROADMAP item).  Large
    populations are practical because structural genes no longer trigger
    compiles — the whole generation is one batched evaluate call."""
    ranges = space.gene_ranges()
    population = max(4, min(population, budget))
    run(sample_points(space, rng, population))
    stalls = 0
    while len(evaluated) < budget and evaluated and stalls < 8:
        before = len(evaluated)
        pool = sorted(evaluated, key=evaluated.get)[:population]

        def pick() -> Point:
            idx = rng.integers(len(pool), size=tournament).min()
            return pool[int(idx)]

        children: list[Point] = []
        seen: set[Point] = set()
        attempts = 0
        want = min(population, budget - len(evaluated))
        while len(children) < want and attempts < 20 * want:
            attempts += 1
            a, b = pick(), pick()
            mask = rng.random(len(ranges))
            child = tuple(
                (int(rng.integers(r)) if m < mutate_p else
                 (ga if m < (1 + mutate_p) / 2 else gb))
                for ga, gb, m, r in zip(a, b, mask, ranges))
            if child in seen or child in evaluated:
                continue
            seen.add(child)
            children.append(child)
        if not children:
            # population converged: re-seed with fresh uniform points
            children = sample_points(space, rng, want, exclude=set(evaluated))
            if not children:
                break
        run(children)
        # budget pruning may silently drop every child: bound the loop so
        # a feasible set smaller than the budget terminates instead of
        # spinning forever
        stalls = stalls + 1 if len(evaluated) == before else 0


def _search_legacy(op, space, rng, objective, budget, strategy, *,
                   refine_frac, population, l1_budget_kb, l2_budget_kb,
                   ev, stats) -> tuple[dict, dict, str]:
    """The tuple-point path: per-point encode, host numpy objective —
    kept as the gene pipeline's parity oracle and baseline.  Candidate
    generation (enumeration order, uniform sampling draws, neighbor
    order) is shared with the gene pipeline so a fixed seed yields
    identical candidate sets in both; only the genetic strategy's child
    generation differs (numpy loop here, on-device ``jax.random``
    there)."""
    evaluated: dict[Point, float] = {}
    rows: dict[Point, np.ndarray] = {}

    def run(points: Sequence[Point]) -> None:
        points = [p for p in points if p not in evaluated]
        points = prune_by_budget(op, space, points, l1_kb=l1_budget_kb,
                                 l2_kb=l2_budget_kb)
        if not points:
            return
        # analysis-equivalent permutations collapse to one evaluated row
        reps, back = dedupe_equivalent_points(op, space, points)
        feats, st = evaluate_points(op, space, reps, **ev)
        stats.merge(st)
        vals = _objective_column(feats, objective)
        for i, p in enumerate(points):
            evaluated[p] = float(vals[back[i]])
            rows[p] = feats[back[i]]

    if strategy == "exhaustive":
        pts = list(itertools.islice(enumerate_points(space), budget))
        if space.size > budget:
            # enumerate_points orders structural genes outermost, so the
            # kept prefix only covers the leading structure group(s) — say
            # so rather than reporting a full sweep
            strategy = "exhaustive[truncated]"
        run(pts)
    elif strategy == "genetic":
        pop = population or max(32, min(10_000, budget // 4))
        _genetic_loop(space, rng, budget, run, evaluated, population=pop)
    else:
        n_refine = int(budget * refine_frac) if strategy == "greedy" else 0
        run(points_from_genes(
            sample_genes(space, rng, budget - n_refine)))
        if strategy == "greedy" and evaluated:
            spent_guard = 0
            while len(evaluated) < budget and spent_guard < 64:
                spent_guard += 1
                best = min(evaluated, key=evaluated.get)
                nbrs = [p for p in _neighbors(space, best)
                        if p not in evaluated][:budget - len(evaluated)]
                if not nbrs:
                    break
                run(nbrs)
                if evaluated[min(evaluated, key=evaluated.get)] >= \
                        evaluated[best]:
                    break  # converged: no neighbor improved
    return evaluated, rows, strategy


def static_candidates(space: MapSpace, strategy: str, budget: int,
                      seed: int) -> tuple[np.ndarray, str]:
    """The candidate gene matrix a NON-adaptive search evaluates:
    ``exhaustive`` (or ``auto`` with the space inside the budget) yields
    the first ``budget`` enumerated rows; ``random`` (or ``auto``
    otherwise) yields ``sample_genes`` draws from a fresh
    ``default_rng(seed)``.  For an EXPLICIT ``exhaustive``/``random``
    strategy these are the exact candidate sets ``search()`` evaluates
    under the same seed — the ``repro.netspace`` parity guarantee.  Note
    the ``auto`` fallbacks differ: ``search()`` escalates an oversized
    space to adaptive ``greedy`` refinement, which a one-pass batch
    evaluator cannot replay, so ``auto`` here falls back to ``random``.
    Returns ``(genes, resolved_strategy)``."""
    if strategy == "auto":
        strategy = "exhaustive" if space.size <= budget else "random"
    if strategy == "exhaustive":
        if space.size > budget:
            return (enumerate_genes(space, 0, budget),
                    "exhaustive[truncated]")
        return enumerate_genes(space), "exhaustive"
    if strategy == "random":
        rng = np.random.default_rng(seed)
        return sample_genes(space, rng, budget), "random"
    raise ValueError(f"static_candidates: strategy must be auto/"
                     f"exhaustive/random, got {strategy!r}")


# ----------------------------------------------------------------------
# Gene-matrix pipeline (default)
# ----------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("ranges", "n", "mutate_p",
                                    "tournament"))
def _gene_children(key, pool, ranges: tuple, n: int,
                   mutate_p: float = 0.15, tournament: int = 3):
    """On-device genetic step over a val-sorted (best-first) gene pool:
    min-index tournament selection, uniform crossover, per-gene uniform
    mutation — all via ``jax.random``, one tiny XLA program per pool
    shape."""
    p = pool.shape[0]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ia = jnp.min(jax.random.randint(k1, (n, tournament), 0, p), axis=1)
    ib = jnp.min(jax.random.randint(k2, (n, tournament), 0, p), axis=1)
    a, b = pool[ia], pool[ib]
    m = jax.random.uniform(k3, (n, pool.shape[1]))
    r = jnp.asarray(ranges, pool.dtype)
    rand_g = jnp.floor(jax.random.uniform(k4, m.shape) * r) \
        .astype(pool.dtype)
    return jnp.where(m < mutate_p, rand_g,
                     jnp.where(m < (1.0 + mutate_p) / 2.0, a, b))


class _GeneSearch:
    """Search state over gene matrices: distinctness via flat indices,
    values host-resident as one scalar column, features never
    materialized beyond the final top-k rows."""

    def __init__(self, op, space, objective, *, l1_kb, l2_kb, ev, stats,
                 budget, ckpt_factory=None):
        self.op, self.space = op, space
        self.col, self.maximize = OBJECTIVES[objective]
        self.l1_kb, self.l2_kb = l1_kb, l2_kb
        self.ev, self.stats = ev, stats
        self.budget = budget
        # checkpointing: every evaluate_genes call this search issues is
        # numbered; the search path is deterministic under (seed, space),
        # so a resumed process replays the same call sequence and call i
        # finds call i's checkpoint (earlier completed calls re-execute
        # warm — bounded loss, bit-identical results)
        self.ckpt_factory = ckpt_factory
        self.call_seq = 0
        self.seen = np.empty(0, np.int64)      # sorted flat indices
        self.genes: list[np.ndarray] = []
        self.vals: list[np.ndarray] = []
        self.n = 0
        self.best_val = np.inf
        self.best_row: np.ndarray | None = None

    def run(self, g: np.ndarray) -> int:
        """Evaluate the not-yet-seen rows of ``g``; returns how many new
        rows received values."""
        g = np.asarray(g, np.int64).reshape(-1, len(
            self.space.gene_ranges()))
        if not g.shape[0]:
            return 0
        flat = flat_index(self.space, g)
        _, first = np.unique(flat, return_index=True)
        first = np.sort(first)                  # first occurrence, in order
        g, flat = g[first], flat[first]
        fresh = ~np.isin(flat, self.seen, assume_unique=True)
        g, flat = g[fresh], flat[fresh]
        g, flat = (g[:max(self.budget - self.n, 0)],
                   flat[:max(self.budget - self.n, 0)])
        if not g.shape[0]:
            return 0
        kept = prune_genes_by_budget(self.op, self.space, g,
                                     l1_kb=self.l1_kb, l2_kb=self.l2_kb)
        if kept.shape[0] != g.shape[0]:
            flat = flat_index(self.space, kept)
        g = kept
        if not g.shape[0]:
            return 0
        reps, back = dedupe_equivalent_genes(self.op, self.space, g)
        ckpt = (self.ckpt_factory(self.call_seq)
                if self.ckpt_factory else None)
        self.call_seq += 1
        res = evaluate_genes(self.op, self.space, g[reps],
                             objective=self.col, maximize=self.maximize,
                             return_vals=True, pareto=False, ckpt=ckpt,
                             **self.ev)
        v = res.vals[back]
        self.seen = np.union1d(self.seen, flat)
        self.genes.append(g)
        self.vals.append(v)
        self.n += g.shape[0]
        groups = np.unique(g[:, :3], axis=0)
        self.stats.merge(EvalStats(
            n_points=g.shape[0], n_groups=groups.shape[0],
            n_steady=res.run.n_steady, n_compiles=res.run.n_compiles,
            compile_s=res.run.compile_s, eval_s=res.run.eval_s,
            encode_s=res.run.encode_s))
        i = int(np.argmin(v))
        # all-inf chunks still seed the incumbent (first insertion order,
        # like the legacy dict min) so greedy never climbs from None
        if self.best_row is None or v[i] < self.best_val:
            self.best_val = float(v[i])
            self.best_row = g[i]
        return g.shape[0]

    def all(self) -> tuple[np.ndarray, np.ndarray]:
        return (np.concatenate(self.genes) if self.genes
                else np.empty((0, 0), np.int64),
                np.concatenate(self.vals) if self.vals
                else np.empty((0,)))


def _search_genes(op, space, rng, objective, budget, strategy, *, seed,
                  refine_frac, population, st: _GeneSearch) -> str:
    if strategy == "exhaustive":
        if space.size > budget:
            strategy = "exhaustive[truncated]"
        # like the legacy islice: the first `budget` enumerated points,
        # whether or not budget pruning later drops some of them
        end = min(space.size, budget)
        step = max(65536, st.ev["block"] * 8)
        for lo in range(0, end, step):
            st.run(enumerate_genes(space, lo, min(lo + step, end)))
    elif strategy == "genetic":
        pop = max(4, min(population or max(32, min(10_000, budget // 4)),
                         budget))
        st.run(sample_genes(space, rng, pop))
        key = jax.random.PRNGKey(seed)
        ranges = tuple(int(r) for r in space.gene_ranges())
        stalls = 0
        while st.n < budget and st.n and stalls < 8:
            before = st.n
            allg, allv = st.all()
            order = np.argsort(allv, kind="stable")[:pop]
            pool = allg[order]
            if pool.shape[0] < pop:   # pad to a fixed pool shape (1 jit)
                pool = np.concatenate(
                    [pool, np.repeat(pool[-1:], pop - pool.shape[0], 0)])
            want = min(pop, budget - st.n)
            key, sub = jax.random.split(key)
            children = np.asarray(_gene_children(
                sub, pool.astype(np.int32), ranges, pop))[:want]
            st.run(children)
            if st.n == before:        # converged: re-seed fresh uniform
                st.run(sample_genes(space, rng, want,
                                    exclude_flat=st.seen))
            stalls = stalls + 1 if st.n == before else 0
    else:
        n_refine = int(budget * refine_frac) if strategy == "greedy" else 0
        st.run(sample_genes(space, rng, budget - n_refine))
        if strategy == "greedy" and st.n:
            spent_guard = 0
            while st.n < budget and spent_guard < 64:
                spent_guard += 1
                prev_best = st.best_val
                nbrs = _neighbor_genes(space, st.best_row)
                if not st.run(nbrs[:budget - st.n]):
                    break
                if st.best_val >= prev_best:
                    break  # converged: no neighbor improved
    return strategy


def search(op: LayerOp, objective: str = "edp", budget: int = 2000,
           **kwargs) -> SearchResult:
    """Search the mapping space of ``op`` for the best dataflow at a fixed
    hardware point — the legacy entry point, now a thin wrapper over the
    declarative session path (``repro.api``): the shared default session
    owns process-level caches and query accounting, and forwards verbatim
    to :func:`search_impl` (bit-equal by construction; see
    ``tests/test_api.py``).  Accepts exactly :func:`search_impl`'s
    keywords."""
    from ..api.session import default_session
    return default_session().run_search(op, objective=objective,
                                        budget=budget, **kwargs)


def search_impl(op: LayerOp, objective: str = "edp", budget: int = 2000,
                *, space: MapSpace | None = None, num_pes: int = 256,
                noc_bw: float = 32.0, strategy: str = "auto",
                seed: int = 0,
                top_k: int = 8, max_groups: int | None = None,
                refine_frac: float = 0.3, block: int = 1024,
                population: int | None = None,
                l1_budget_kb: float | None = None,
                l2_budget_kb: float | None = None,
                cache_dir: str | None = None, engine: str = "universal",
                pipeline: str = "gene", devices: int | None = None,
                multicast: bool = True, spatial_reduction: bool = True,
                cache_extra: str = "",
                ckpt_dir: str | None = None) -> SearchResult:
    """The per-layer mapping-search engine behind :func:`search` and
    ``repro.api.Session``.  ``budget`` caps evaluated mappings;
    ``strategy`` is ``auto`` or one of ``exhaustive`` / ``random`` /
    ``greedy`` / ``genetic``.

    ``pipeline="gene"`` (default) runs the device-resident gene-matrix
    pipeline — vectorized host side, fused on-device reduction, chunks
    striped over ``devices`` local devices (default all) with async
    double buffering.  ``pipeline="legacy"`` is the tuple-point parity
    oracle.  Both are deterministic under ``seed`` and evaluate identical
    candidate sets for ``exhaustive``; sampling draws also coincide
    across pipelines except for the genetic strategy (whose gene-pipeline
    selection runs on-device via ``jax.random``).

    ``max_groups`` is legacy: the universal evaluator made structure-group
    exploration compile-free, so nothing is clamped anymore (the value
    still participates in the result-cache key for reproducibility).
    ``l1_budget_kb``/``l2_budget_kb`` drop over-budget tile sets before
    evaluation.  ``cache_extra`` is an opaque component of the disk-cache
    key (the session path passes the full ``Query`` fingerprint).

    With ``ckpt_dir``, every gene-pipeline evaluation pass checkpoints
    under a key derived from the result-cache key, so a killed search
    resumes from the last chunk boundary bit-identically (rerun the same
    call after the kill)."""
    if objective not in OBJECTIVES:
        raise SpecError(f"objective must be one of {sorted(OBJECTIVES)}",
                        field="objective")
    if pipeline not in PIPELINES:
        raise SpecError(f"pipeline must be one of {PIPELINES}",
                        field="pipeline")
    space = space or build_space(op)
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()

    if strategy == "auto":
        strategy = "exhaustive" if space.size <= budget else "greedy"
    if strategy not in STRATEGIES:
        raise SpecError(f"unknown strategy {strategy!r}", field="strategy")

    key = _cache.search_key(
        op, space, num_pes, noc_bw, objective, budget, strategy, seed,
        extra=f"mc={multicast},sr={spatial_reduction},mg={max_groups},"
              f"rf={refine_frac},blk={block},tk={top_k},"
              f"pop={population},l1={l1_budget_kb},l2={l2_budget_kb},"
              f"eng={engine},pipe={pipeline},q={cache_extra}")
    hit = _cache.load(cache_dir, key)
    if hit is not None:
        return SearchResult(
            objective=objective, strategy=hit["strategy"], space=space,
            best_point=tuple(hit["best_point"]),
            best_value=hit["best_value"], best_stats=hit["best_stats"],
            top_k=[{"point": tuple(e["point"]), "value": e["value"],
                    "stats": e["stats"]} for e in hit["top_k"]],
            n_evaluated=hit["n_evaluated"], n_groups=hit["n_groups"],
            elapsed_s=time.perf_counter() - t_start,
            eval_s=hit["eval_s"], compile_s=hit["compile_s"],
            n_steady=hit.get("n_steady", 0),
            n_compiles=hit.get("n_compiles", 0), cached=True,
            pipeline=hit.get("pipeline", pipeline),
            encode_s=hit.get("encode_s", 0.0),
            n_devices=hit.get("n_devices", 1),
            wall_s=hit.get("wall_s", 0.0))

    stats = EvalStats()
    n_devices = 1
    if pipeline == "legacy":
        ev = dict(num_pes=num_pes, noc_bw=noc_bw, block=block,
                  multicast=multicast, spatial_reduction=spatial_reduction,
                  engine=engine)
        evaluated, rows, strategy = _search_legacy(
            op, space, rng, objective, budget, strategy,
            refine_frac=refine_frac, population=population,
            l1_budget_kb=l1_budget_kb, l2_budget_kb=l2_budget_kb,
            ev=ev, stats=stats)
        if not evaluated:
            raise RuntimeError("search evaluated no mappings "
                               "(empty space, or budgets pruned "
                               "everything?)")
        groups = {space.group_key(p) for p in evaluated}
        n_groups = len(groups)
        order_pts = sorted(evaluated, key=evaluated.get)
        top_pts = order_pts[:top_k]
        top_vals = [evaluated[p] for p in top_pts]
        top_feats = [rows[p] for p in top_pts]
    else:
        ev = dict(num_pes=num_pes, noc_bw=noc_bw, block=block,
                  multicast=multicast,
                  spatial_reduction=spatial_reduction,
                  n_devices=devices, k=top_k)
        ckpt_factory = None
        if ckpt_dir:
            ckpt_factory = lambda seq: SweepCheckpoint(  # noqa: E731
                ckpt_dir, f"{key[:20]}-c{seq}", every_chunks=1)
        st = _GeneSearch(op, space, objective, l1_kb=l1_budget_kb,
                         l2_kb=l2_budget_kb, ev=ev, stats=stats,
                         budget=budget, ckpt_factory=ckpt_factory)
        strategy = _search_genes(op, space, rng, objective, budget,
                                 strategy, seed=seed,
                                 refine_frac=refine_frac,
                                 population=population, st=st)
        if not st.n:
            raise RuntimeError("search evaluated no mappings "
                               "(empty space, or budgets pruned "
                               "everything?)")
        allg, allv = st.all()
        groups = np.unique(allg[:, :3], axis=0)
        n_groups = groups.shape[0]
        order = np.argsort(allv, kind="stable")[:top_k]
        top_pts = [tuple(int(x) for x in allg[i]) for i in order]
        top_vals = [float(allv[i]) for i in order]
        # one tiny warm pass fetches the winners' feature rows — the only
        # full feature rows the gene pipeline ever materializes
        fin = evaluate_genes(op, space, allg[order], objective=st.col,
                             maximize=st.maximize, return_vals=True,
                             pareto=False, **ev)
        by_row = {t["row"]: t["feats"] for t in fin.top}
        top_feats = [by_row[i] for i in range(len(order))]
        n_devices = fin.run.n_devices
        n_evaluated = st.n

    _, maximize = OBJECTIVES[objective]

    def actual(v: float) -> float:
        return -v if maximize else v

    result = SearchResult(
        objective=objective, strategy=strategy, space=space,
        best_point=top_pts[0], best_value=actual(top_vals[0]),
        best_stats=_stats_dict(top_feats[0]),
        top_k=[{"point": p, "value": actual(v),
                "stats": _stats_dict(f)}
               for p, v, f in zip(top_pts, top_vals, top_feats)],
        n_evaluated=(len(evaluated) if pipeline == "legacy"
                     else n_evaluated),
        n_groups=n_groups,
        elapsed_s=time.perf_counter() - t_start,
        eval_s=stats.eval_s, compile_s=stats.compile_s,
        n_steady=stats.n_steady, n_compiles=stats.n_compiles,
        pipeline=pipeline, encode_s=stats.encode_s,
        n_devices=n_devices,
        wall_s=time.perf_counter() - t_start)

    _cache.store(cache_dir, key, {
        "strategy": result.strategy,
        "best_point": list(result.best_point),
        "best_value": result.best_value,
        "best_stats": result.best_stats,
        "top_k": [{"point": list(e["point"]), "value": e["value"],
                   "stats": e["stats"]} for e in result.top_k],
        "n_evaluated": result.n_evaluated, "n_groups": result.n_groups,
        "eval_s": result.eval_s, "compile_s": result.compile_s,
        "n_steady": result.n_steady, "n_compiles": result.n_compiles,
        "pipeline": result.pipeline, "encode_s": result.encode_s,
        "n_devices": result.n_devices, "wall_s": result.wall_s})
    return result
