"""Joint mapping × hardware co-DSE (the paper's full 480M-design search,
both axes at once).

``co_search`` first runs the mapping search at a reference hardware point,
then crosses the top-k distinct mappings with the existing hardware DSE grid
(``core.dse.run_dse``: PEs × NoC bandwidth under area/power budgets, buffers
placed per MAESTRO's reported requirement) and merges everything into one
Pareto frontier.  Table 3 baselines can ride along in the same sweep so the
frontier directly answers "what does mapping search buy over the paper's
fixed dataflows?".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np

from ..core.dataflows import table3_for_layer
from ..core.directives import Dataflow
from ..core.dse import DSEConfig, DSEResult, run_dse
from ..core.tensor_analysis import LayerOp
from .search import SearchResult, search
from .space import MapSpace


@dataclasses.dataclass
class CoDSEResult:
    search: SearchResult
    dse: list[tuple[str, DSEResult]]      # (mapping label, hw sweep)
    pareto: list[dict[str, Any]]          # merged frontier, energy-sorted
    best: dict[str, dict[str, Any] | None]  # per objective, across all
    n_evaluated: int                      # mappings + hw designs
    elapsed_s: float


def merged_pareto(results: Sequence[tuple[str, DSEResult]],
                  x: str = "energy_pj", y: str = "throughput"
                  ) -> list[dict[str, Any]]:
    """Valid-design Pareto frontier (min x, max y) across several hardware
    sweeps; each frontier point carries its mapping label."""
    pts = []
    for label, r in results:
        xs = np.asarray(getattr(r.stats, x))
        ys = np.asarray(getattr(r.stats, y))
        for i in np.where(r.valid)[0]:
            pts.append((float(xs[i]), float(ys[i]), label, r, int(i)))
    pts.sort(key=lambda t: (t[0], -t[1]))
    front: list[dict[str, Any]] = []
    best_y = -np.inf
    for xv, yv, label, r, i in pts:
        if yv > best_y:
            best_y = yv
            front.append({"mapping": label, x: xv, y: yv, **r.point(i)})
    return front


def co_search(op: LayerOp, objective: str = "edp",
              mapping_budget: int = 2000, top_k: int = 4,
              cfg: DSEConfig | None = None, *, num_pes: int = 256,
              noc_bw: float = 32.0, seed: int = 0,
              space: MapSpace | None = None,
              include_table3: Sequence[str] = (),
              cache_dir: str | None = None,
              search_kwargs: dict[str, Any] | None = None) -> CoDSEResult:
    """Joint DSE: mapping search at ``(num_pes, noc_bw)``, then the hardware
    grid for each of the ``top_k`` distinct found mappings (plus any
    requested Table 3 baselines), merged into one Pareto frontier."""
    t0 = time.perf_counter()
    sr = search(op, objective=objective, budget=mapping_budget,
                space=space, num_pes=num_pes, noc_bw=noc_bw, seed=seed,
                cache_dir=cache_dir, **(search_kwargs or {}))

    flows: list[tuple[str, Dataflow]] = []
    seen: set[tuple] = set()
    from .space import point_dataflow
    for entry in sr.top_k:
        df = point_dataflow(sr.space, entry["point"])
        if df.directives in seen:
            continue
        seen.add(df.directives)
        flows.append((df.name, df))
        if len(flows) >= top_k:
            break
    for name in include_table3:
        flows.append((f"table3:{name}", table3_for_layer(name, op)))

    cfg = cfg or DSEConfig()
    sweeps: list[tuple[str, DSEResult]] = []
    for label, df in flows:
        sweeps.append((label, run_dse(op, df, cfg, tile_tag=label)))

    best: dict[str, dict[str, Any] | None] = {}
    for obj in ("throughput", "energy", "edp"):
        cands = [dict(r.best(obj), mapping=label)
                 for label, r in sweeps if r.n_valid]
        if not cands:
            best[obj] = None
            continue
        sign = (lambda p: -p["throughput"]) if obj == "throughput" else \
            (lambda p: p["energy_pj"] if obj == "energy" else p["edp"])
        best[obj] = min(cands, key=sign)

    return CoDSEResult(
        search=sr,
        dse=sweeps,
        pareto=merged_pareto(sweeps),
        best=best,
        n_evaluated=sr.n_evaluated + sum(r.n_evaluated for _, r in sweeps),
        elapsed_s=time.perf_counter() - t0)
