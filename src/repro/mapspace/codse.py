"""Joint mapping × hardware co-DSE (the paper's full 480M-design search,
both axes at once).

``co_search`` runs the mapping search at a reference hardware point, then
crosses the top-k distinct mappings with the (PEs × NoC bandwidth) grid in
a SINGLE merged frontier: the hardware point is a traced operand of the
same universal executable the mapping search already compiled
(``mapspace.universal``), so the joint sweep triggers **no additional XLA
compiles** — mapping genes and hardware axes are one operand space, not
two staged searches.  Area/power budgets and leakage energy follow
``core.dse.run_dse`` exactly, and Table 3 baselines can ride along (via the
legacy per-dataflow evaluator) so the frontier directly answers "what does
mapping search buy over the paper's fixed dataflows?".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np

from .. import obs
from ..core.dataflows import table3_for_layer
from ..core.dse import DSEConfig, DSEResult, run_dse
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES, BatchStats, HWTail
from ..resilience import (SweepCheckpoint, array_hash, check_cancel,
                          fault_point, pack_top, unpack_top)
from .search import OBJECTIVES, SearchResult, search
from .space import (MapSpace, genes_from_points, point_dataflow,
                    sample_genes)
from .universal import (evaluate_genes, evaluate_points_universal,
                        pareto_front)


@dataclasses.dataclass
class JointSweepResult:
    """One paper-scale device-resident sweep over (gene matrix x hardware
    grid).  The full cross product runs through the gene pipeline's fused
    reduction tail — area/power/leakage accounting inside the jit, only
    top-k winners and the (energy, throughput) frontier come back, never
    an (n, F) feature matrix."""
    n_designs: int
    n_mappings: int
    n_hw: int
    n_valid: int
    objective: str
    top: list[dict[str, Any]]             # best designs (mapping + hw)
    pareto: list[dict[str, Any]]          # exact valid-design frontier
    elapsed_s: float
    compile_s: float
    n_compiles: int
    n_devices: int = 1

    @property
    def designs_per_s(self) -> float:
        """End-to-end rate excluding the one-off XLA compile — the number
        to hold against the paper's 480M designs at 0.17M/s."""
        return self.n_designs / max(self.elapsed_s - self.compile_s, 1e-9)


@dataclasses.dataclass
class CoDSEResult:
    search: SearchResult
    dse: list[tuple[str, DSEResult]]      # (mapping label, hw sweep)
    pareto: list[dict[str, Any]]          # merged frontier, energy-sorted
    best: dict[str, dict[str, Any] | None]  # per objective, across all
    n_evaluated: int                      # mappings + joint hw designs
    elapsed_s: float
    n_compiles: int = 0                   # XLA compiles for the joint sweep
    joint: JointSweepResult | None = None  # paper-scale gene sweep


def merged_pareto(results: Sequence[tuple[str, DSEResult]],
                  x: str = "energy_pj", y: str = "throughput"
                  ) -> list[dict[str, Any]]:
    """Valid-design Pareto frontier (min x, max y) across several hardware
    sweeps; each frontier point carries its mapping label."""
    pts = []
    for label, r in results:
        xs = np.asarray(getattr(r.stats, x))
        ys = np.asarray(getattr(r.stats, y))
        for i in np.where(r.valid)[0]:
            pts.append((float(xs[i]), float(ys[i]), label, r, int(i)))
    pts.sort(key=lambda t: (t[0], -t[1]))
    front: list[dict[str, Any]] = []
    best_y = -np.inf
    for xv, yv, label, r, i in pts:
        if yv > best_y:
            best_y = yv
            front.append({"mapping": label, x: xv, y: yv, **r.point(i)})
    return front


def hw_grid(cfg: DSEConfig) -> tuple[np.ndarray, np.ndarray]:
    """The flattened (PEs, NoC bandwidth) design grid of a
    :class:`DSEConfig` — the hardware axis every joint sweep (per-mapping,
    paper-scale gene, and netspace's network-level co-search) crosses its
    mapping rows with."""
    pes_g, bw_g = np.meshgrid(np.asarray(cfg.pe_range, np.int64),
                              np.asarray(cfg.bw_range, np.float32),
                              indexing="ij")
    return pes_g.ravel(), bw_g.ravel()


def _joint_sweep(op: LayerOp, space: MapSpace, point, label: str,
                 cfg: DSEConfig, *, block: int, multicast: bool,
                 spatial_reduction: bool) -> tuple[DSEResult, int]:
    """One mapping × full (PEs × bw) grid through the universal executable
    — hardware as operands, identical budget/leakage accounting to
    ``core.dse.run_dse``."""
    pes, bws = hw_grid(cfg)
    t0 = time.perf_counter()
    feats, run = evaluate_points_universal(
        op, space, [point] * len(pes), num_pes=pes, noc_bw=bws,
        block=block, multicast=multicast,
        spatial_reduction=spatial_reduction)
    elapsed = time.perf_counter() - t0
    stats = BatchStats.from_features(feats)

    sram_kb = np.asarray(stats.l1_kb) * pes + np.asarray(stats.l2_kb)
    area = cfg.area_power.area(pes, sram_kb, bws)
    power = cfg.area_power.power(pes, sram_kb, bws)
    valid = (area <= cfg.area_budget_mm2) & (power <= cfg.power_budget_mw)
    static = cfg.area_power.static_energy_pj(area, np.asarray(stats.runtime))
    stats.energy_pj = np.asarray(stats.energy_pj) + static
    stats.edp = stats.energy_pj * np.asarray(stats.runtime)
    return DSEResult(
        num_pes=pes, noc_bw=bws, stats=stats, area_mm2=area,
        power_mw=power, valid=np.asarray(valid), n_evaluated=len(pes),
        n_valid=int(np.sum(valid)), elapsed_s=elapsed,
        tile_tag=label), run.n_compiles


def joint_sweep(op: LayerOp, space: MapSpace, genes: np.ndarray,
                cfg: DSEConfig | None = None, *, objective: str = "edp",
                k: int = 16, block: int = 8192,
                n_devices: int | None = None,
                chunk_designs: int = 1 << 18,
                multicast: bool = True, spatial_reduction: bool = True,
                ckpt: SweepCheckpoint | None = None
                ) -> JointSweepResult:
    """Paper-scale joint DSE: every row of ``genes`` crossed with the full
    (PEs x NoC bandwidth) grid of ``cfg`` — ``len(genes) * |grid|``
    designs — streamed through the gene pipeline with the hardware
    accounting of ``core.dse.run_dse`` (SRAM placement, area/power
    budgets, leakage energy) fused into the executable.  The cross
    product is never materialized on the host: design chunks gather their
    mapping row and hardware point from the flat design index on the fly.

    This is the reproduction of the paper's 480M-design search shape:
    mapping and hardware axes in ONE operand space, at most two XLA
    compiles, any local device count.

    With ``ckpt`` the sweep persists its accumulators (design-chunk
    cursor, top entries, frontier candidates) after every completed
    design chunk, so a killed 10M+-design sweep resumes from the last
    chunk boundary bit-identically; the in-flight inner chunk restarts
    from scratch (design chunks are the durable unit)."""
    t0 = time.perf_counter()
    cfg = cfg or DSEConfig()
    genes = np.asarray(genes, np.int64)
    pes, bws = hw_grid(cfg)
    pes = pes.astype(np.float32)
    m, h = genes.shape[0], pes.shape[0]
    n = m * h
    col, maximize = OBJECTIVES[objective]
    tail = HWTail(area_power=cfg.area_power,
                  area_budget_mm2=cfg.area_budget_mm2,
                  power_budget_mw=cfg.power_budget_mw)
    top_entries: list[tuple[float, int, np.ndarray]] = []
    front_cands: list[dict[str, Any]] = []
    n_valid = 0
    n_compiles = 0
    compile_s = 0.0
    n_dev = 1

    start_lo = 0
    ckpt_meta: dict | None = None
    if ckpt is not None:
        ckpt_meta = {"key": ckpt.key, "n": int(n), "m": int(m),
                     "h": int(h), "chunk_designs": int(chunk_designs),
                     "block": int(block), "objective": objective,
                     "k": int(k), "content": array_hash(genes, pes, bws)}
        st = ckpt.load(ckpt_meta)
        if st is not None:
            start_lo = int(st["cursor"])
            n_valid = int(st["n_valid"])
            top_entries.extend(unpack_top(st))
            for r, e, t in zip(st["front_rows"], st["front_e"],
                               st["front_t"]):
                front_cands.append({"row": int(r), "energy_pj": float(e),
                                    "throughput": float(t)})

    for lo in range(start_lo, n, chunk_designs):
        check_cancel("design-chunk")
        fault_point("design-chunk")
        hi = min(lo + chunk_designs, n)
        flat = np.arange(lo, hi, dtype=np.int64)
        gi, hwi = flat // h, flat % h
        # container span only (inner compile/device-pass spans carry the
        # phase attribution) — names one (design x mapping) tile in a
        # request's trace
        with obs.span("design-chunk", lo=int(lo), rows=int(hi - lo)):
            res = evaluate_genes(
                op, space, genes[gi], objective=col, maximize=maximize,
                k=k, num_pes=pes[hwi], noc_bw=bws[hwi], block=block,
                n_devices=n_devices, multicast=multicast,
                spatial_reduction=spatial_reduction, return_vals=False,
                pareto=True, hw_tail=tail)
        n_valid += res.run.n_valid
        n_compiles += res.run.n_compiles
        compile_s += res.run.compile_s
        n_dev = max(n_dev, res.run.n_devices)
        for t in res.top:
            if np.isfinite(t["value"]):
                top_entries.append((t["value"], lo + t["row"],
                                    t["feats"]))
        for p in res.pareto:
            front_cands.append({**p, "row": lo + p["row"]})
        if ckpt is not None:
            # a design chunk is minutes of device work at paper scale —
            # checkpoint unconditionally at every chunk boundary
            ckpt.save(
                {"cursor": hi, "n_valid": n_valid,
                 **pack_top(top_entries),
                 "front_rows": np.array(
                     [c["row"] for c in front_cands], np.int64),
                 "front_e": np.array(
                     [c["energy_pj"] for c in front_cands], np.float64),
                 "front_t": np.array(
                     [c["throughput"] for c in front_cands], np.float64)},
                ckpt_meta)
    if ckpt is not None:
        ckpt.clear()               # completed: the checkpoint is spent

    def design(row: int, feats: np.ndarray | None) -> dict[str, Any]:
        gi, hwi = row // h, row % h
        d = {"point": tuple(int(x) for x in genes[gi]),
             "num_pes": int(pes[hwi]), "noc_bw": float(bws[hwi])}
        if feats is not None:
            d.update({name: float(feats[i])
                      for i, name in enumerate(FEATURES)})
            sram = d["l1_kb"] * d["num_pes"] + d["l2_kb"]
            d["area_mm2"] = float(cfg.area_power.area(
                d["num_pes"], sram, d["noc_bw"]))
            d["power_mw"] = float(cfg.area_power.power(
                d["num_pes"], sram, d["noc_bw"]))
        return d

    top_entries.sort(key=lambda e: (e[0], e[1]))
    top = []
    for v, row, feats in top_entries[:k]:
        d = design(row, feats)
        d["value"] = -v if maximize else v
        top.append(d)
    front = [dict(design(c["row"], None), energy_pj=c["energy_pj"],
                  throughput=c["throughput"])
             for c in pareto_front(front_cands)]
    return JointSweepResult(
        n_designs=n, n_mappings=m, n_hw=h, n_valid=n_valid,
        objective=objective, top=top, pareto=front,
        elapsed_s=time.perf_counter() - t0, compile_s=compile_s,
        n_compiles=n_compiles, n_devices=n_dev)


def co_search(op: LayerOp, objective: str = "edp",
              mapping_budget: int = 2000, top_k: int = 4,
              cfg: DSEConfig | None = None, **kwargs) -> CoDSEResult:
    """Joint mapping × hardware co-DSE — the legacy entry point, now a
    thin wrapper over the declarative session path (``repro.api``);
    forwards verbatim to :func:`co_search_impl` (bit-equal by
    construction, see ``tests/test_api.py``)."""
    from ..api.session import default_session
    return default_session().run_co_search(
        op, objective=objective, mapping_budget=mapping_budget,
        top_k=top_k, cfg=cfg, **kwargs)


def co_search_impl(op: LayerOp, objective: str = "edp",
                   mapping_budget: int = 2000, top_k: int = 4,
                   cfg: DSEConfig | None = None, *, num_pes: int = 256,
                   noc_bw: float = 32.0, seed: int = 0,
                   space: MapSpace | None = None,
                   include_table3: Sequence[str] = (),
                   cache_dir: str | None = None,
                   joint_genes: int = 0, joint_block: int = 8192,
                   cache_extra: str = "",
                   ckpt_dir: str | None = None,
                   search_kwargs: dict[str, Any] | None = None
                   ) -> CoDSEResult:
    """Joint DSE in one frontier: mapping search at ``(num_pes, noc_bw)``,
    then the hardware grid for each of the ``top_k`` distinct found
    mappings — evaluated through the same universal executable with the
    hardware point as a per-row operand (no staging, no re-compilation) —
    plus any requested Table 3 baselines, merged into one Pareto
    frontier.

    ``joint_genes > 0`` additionally runs the paper-scale sweep
    (:func:`joint_sweep`): that many uniformly sampled mappings (plus the
    search winners) crossed with the FULL hardware grid — ``(joint_genes
    + top_k) * |grid|`` designs through the fused device-resident
    pipeline — and merges its frontier/bests into the result."""
    t0 = time.perf_counter()
    search_kwargs = dict(search_kwargs or {})
    block = search_kwargs.get("block", 1024)
    multicast = search_kwargs.get("multicast", True)
    spatial_reduction = search_kwargs.get("spatial_reduction", True)
    sr = search(op, objective=objective, budget=mapping_budget,
                space=space, num_pes=num_pes, noc_bw=noc_bw, seed=seed,
                cache_dir=cache_dir, cache_extra=cache_extra,
                ckpt_dir=ckpt_dir, **search_kwargs)

    picked: list[tuple[str, tuple]] = []
    seen: set[tuple] = set()
    for entry in sr.top_k:
        df = point_dataflow(sr.space, entry["point"])
        if df.directives in seen:
            continue
        seen.add(df.directives)
        picked.append((df.name, entry["point"]))
        if len(picked) >= top_k:
            break

    cfg = cfg or DSEConfig()
    sweeps: list[tuple[str, DSEResult]] = []
    n_compiles = 0
    for label, point in picked:
        r, nc = _joint_sweep(op, sr.space, point, label, cfg, block=block,
                             multicast=multicast,
                             spatial_reduction=spatial_reduction)
        n_compiles += nc
        sweeps.append((label, r))
    for name in include_table3:
        sweeps.append((f"table3:{name}",
                       run_dse(op, table3_for_layer(name, op), cfg,
                               multicast=multicast,
                               spatial_reduction=spatial_reduction,
                               tile_tag=f"table3:{name}")))

    joint: JointSweepResult | None = None
    if joint_genes > 0:
        rng = np.random.default_rng(seed + 1)
        gm = sample_genes(sr.space, rng, joint_genes)
        winners = genes_from_points([p for _, p in picked])
        gm = np.concatenate([winners, gm]) if len(winners) else gm
        jc = SweepCheckpoint(
            ckpt_dir, f"joint-{op.name}-{objective}-{joint_genes}-"
            f"{seed}-{cache_extra or 'local'}") if ckpt_dir else None
        joint = joint_sweep(op, sr.space, gm, cfg, objective=objective,
                            block=joint_block, multicast=multicast,
                            spatial_reduction=spatial_reduction,
                            ckpt=jc)
        n_compiles += joint.n_compiles

    best: dict[str, dict[str, Any] | None] = {}
    for obj in ("throughput", "energy", "edp"):
        cands = [dict(r.best(obj), mapping=label)
                 for label, r in sweeps if r.n_valid]
        if joint is not None and joint.objective == obj and joint.top:
            cands.append(dict(joint.top[0],
                              mapping=f"joint:{joint.top[0]['point']}"))
        if not cands:
            best[obj] = None
            continue
        sign = (lambda p: -p["throughput"]) if obj == "throughput" else \
            (lambda p: p["energy_pj"] if obj == "energy" else p["edp"])
        best[obj] = min(cands, key=sign)

    pareto = merged_pareto(sweeps)
    if joint is not None and joint.pareto:
        pareto = pareto_front(
            pareto + [dict(p, mapping=f"joint:{p['point']}")
                      for p in joint.pareto])

    return CoDSEResult(
        search=sr,
        dse=sweeps,
        pareto=pareto,
        best=best,
        n_evaluated=sr.n_evaluated + sum(r.n_evaluated for _, r in sweeps)
        + (joint.n_designs if joint else 0),
        elapsed_s=time.perf_counter() - t0,
        n_compiles=sr.n_compiles + n_compiles,
        joint=joint)
