"""Joint mapping × hardware co-DSE (the paper's full 480M-design search,
both axes at once).

``co_search`` runs the mapping search at a reference hardware point, then
crosses the top-k distinct mappings with the (PEs × NoC bandwidth) grid in
a SINGLE merged frontier: the hardware point is a traced operand of the
same universal executable the mapping search already compiled
(``mapspace.universal``), so the joint sweep triggers **no additional XLA
compiles** — mapping genes and hardware axes are one operand space, not
two staged searches.  Area/power budgets and leakage energy follow
``core.dse.run_dse`` exactly, and Table 3 baselines can ride along (via the
legacy per-dataflow evaluator) so the frontier directly answers "what does
mapping search buy over the paper's fixed dataflows?".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np

from ..core.dataflows import table3_for_layer
from ..core.dse import DSEConfig, DSEResult, run_dse
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import BatchStats
from .search import SearchResult, search
from .space import MapSpace, point_dataflow
from .universal import evaluate_points_universal


@dataclasses.dataclass
class CoDSEResult:
    search: SearchResult
    dse: list[tuple[str, DSEResult]]      # (mapping label, hw sweep)
    pareto: list[dict[str, Any]]          # merged frontier, energy-sorted
    best: dict[str, dict[str, Any] | None]  # per objective, across all
    n_evaluated: int                      # mappings + joint hw designs
    elapsed_s: float
    n_compiles: int = 0                   # XLA compiles for the joint sweep


def merged_pareto(results: Sequence[tuple[str, DSEResult]],
                  x: str = "energy_pj", y: str = "throughput"
                  ) -> list[dict[str, Any]]:
    """Valid-design Pareto frontier (min x, max y) across several hardware
    sweeps; each frontier point carries its mapping label."""
    pts = []
    for label, r in results:
        xs = np.asarray(getattr(r.stats, x))
        ys = np.asarray(getattr(r.stats, y))
        for i in np.where(r.valid)[0]:
            pts.append((float(xs[i]), float(ys[i]), label, r, int(i)))
    pts.sort(key=lambda t: (t[0], -t[1]))
    front: list[dict[str, Any]] = []
    best_y = -np.inf
    for xv, yv, label, r, i in pts:
        if yv > best_y:
            best_y = yv
            front.append({"mapping": label, x: xv, y: yv, **r.point(i)})
    return front


def _joint_sweep(op: LayerOp, space: MapSpace, point, label: str,
                 cfg: DSEConfig, *, block: int, multicast: bool,
                 spatial_reduction: bool) -> tuple[DSEResult, int]:
    """One mapping × full (PEs × bw) grid through the universal executable
    — hardware as operands, identical budget/leakage accounting to
    ``core.dse.run_dse``."""
    pes_g, bw_g = np.meshgrid(np.asarray(cfg.pe_range, np.int64),
                              np.asarray(cfg.bw_range, np.float32),
                              indexing="ij")
    pes, bws = pes_g.ravel(), bw_g.ravel()
    t0 = time.perf_counter()
    feats, run = evaluate_points_universal(
        op, space, [point] * len(pes), num_pes=pes, noc_bw=bws,
        block=block, multicast=multicast,
        spatial_reduction=spatial_reduction)
    elapsed = time.perf_counter() - t0
    stats = BatchStats.from_features(feats)

    sram_kb = np.asarray(stats.l1_kb) * pes + np.asarray(stats.l2_kb)
    area = cfg.area_power.area(pes, sram_kb, bws)
    power = cfg.area_power.power(pes, sram_kb, bws)
    valid = (area <= cfg.area_budget_mm2) & (power <= cfg.power_budget_mw)
    static = cfg.area_power.static_energy_pj(area, np.asarray(stats.runtime))
    stats.energy_pj = np.asarray(stats.energy_pj) + static
    stats.edp = stats.energy_pj * np.asarray(stats.runtime)
    return DSEResult(
        num_pes=pes, noc_bw=bws, stats=stats, area_mm2=area,
        power_mw=power, valid=np.asarray(valid), n_evaluated=len(pes),
        n_valid=int(np.sum(valid)), elapsed_s=elapsed,
        tile_tag=label), run.n_compiles


def co_search(op: LayerOp, objective: str = "edp",
              mapping_budget: int = 2000, top_k: int = 4,
              cfg: DSEConfig | None = None, *, num_pes: int = 256,
              noc_bw: float = 32.0, seed: int = 0,
              space: MapSpace | None = None,
              include_table3: Sequence[str] = (),
              cache_dir: str | None = None,
              search_kwargs: dict[str, Any] | None = None) -> CoDSEResult:
    """Joint DSE in one frontier: mapping search at ``(num_pes, noc_bw)``,
    then the hardware grid for each of the ``top_k`` distinct found
    mappings — evaluated through the same universal executable with the
    hardware point as a per-row operand (no staging, no re-compilation) —
    plus any requested Table 3 baselines, merged into one Pareto
    frontier."""
    t0 = time.perf_counter()
    search_kwargs = dict(search_kwargs or {})
    block = search_kwargs.get("block", 1024)
    multicast = search_kwargs.get("multicast", True)
    spatial_reduction = search_kwargs.get("spatial_reduction", True)
    sr = search(op, objective=objective, budget=mapping_budget,
                space=space, num_pes=num_pes, noc_bw=noc_bw, seed=seed,
                cache_dir=cache_dir, **search_kwargs)

    picked: list[tuple[str, tuple]] = []
    seen: set[tuple] = set()
    for entry in sr.top_k:
        df = point_dataflow(sr.space, entry["point"])
        if df.directives in seen:
            continue
        seen.add(df.directives)
        picked.append((df.name, entry["point"]))
        if len(picked) >= top_k:
            break

    cfg = cfg or DSEConfig()
    sweeps: list[tuple[str, DSEResult]] = []
    n_compiles = 0
    for label, point in picked:
        r, nc = _joint_sweep(op, sr.space, point, label, cfg, block=block,
                             multicast=multicast,
                             spatial_reduction=spatial_reduction)
        n_compiles += nc
        sweeps.append((label, r))
    for name in include_table3:
        sweeps.append((f"table3:{name}",
                       run_dse(op, table3_for_layer(name, op), cfg,
                               multicast=multicast,
                               spatial_reduction=spatial_reduction,
                               tile_tag=f"table3:{name}")))

    best: dict[str, dict[str, Any] | None] = {}
    for obj in ("throughput", "energy", "edp"):
        cands = [dict(r.best(obj), mapping=label)
                 for label, r in sweeps if r.n_valid]
        if not cands:
            best[obj] = None
            continue
        sign = (lambda p: -p["throughput"]) if obj == "throughput" else \
            (lambda p: p["energy_pj"] if obj == "energy" else p["edp"])
        best[obj] = min(cands, key=sign)

    return CoDSEResult(
        search=sr,
        dse=sweeps,
        pareto=merged_pareto(sweeps),
        best=best,
        n_evaluated=sr.n_evaluated + sum(r.n_evaluated for _, r in sweeps),
        elapsed_s=time.perf_counter() - t0,
        n_compiles=sr.n_compiles + n_compiles)
