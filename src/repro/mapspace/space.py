"""Mapping-space definition: the legal data-centric programs for a layer.

The paper's 480M-design search has two axes: hardware (``core.dse``) and
*mapping* — which this module defines.  A candidate mapping is encoded as a
small integer gene tuple::

    point = (spatial_idx, perm_idx, cluster_idx, tile_0, ..., tile_{A-1})

over a :class:`MapSpace` with

  * one :class:`TileAxis` per searched layer dim, whose candidate tile sizes
    come from the dim's divisor set (``directives.tile_candidates``) — for
    sliding-window outer dims (Y/X of a conv) candidates tile the *output*
    extent and carry the input halo, so every tile yields whole outputs;
  * a choice of which axis is spatially mapped (the paper's partitioning
    strategy, Table 3's "-P" suffix);
  * a permutation of the axes (the data-movement order);
  * an optional second cluster level (``Cluster(c); SpatialMap(1,1) d`` —
    the NVDLA/Eyeriss-style nesting of Table 3).

Window dims themselves (R/S) are pinned fully-unrolled with symbolic
``Sz(...)`` sizes, exercising ``resolve``/``complete`` exactly like the
Table 3 programs.  Legality is enforced at construction: every tile size
divides (window dims: tiles the output of) its dim, so no directive ever
exceeds its extent — points never need post-hoc filtering.

Points sharing ``(spatial_idx, perm_idx, cluster_idx)`` share one directive
*structure* and differ only in tile sizes, which is precisely the grouping
the batched evaluator (``mapspace.batched``) vectorizes over.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Iterator, Sequence

import numpy as np

from ..core.directives import (Cluster, Dataflow, SpatialMap, Sz,
                               TemporalMap, tile_candidates)
from ..core.tensor_analysis import ConvExpr, LayerOp

Point = tuple  # (spatial_idx, perm_idx, cluster_idx, *tile_idxs)
GroupKey = tuple  # (spatial_idx, perm_idx, cluster_idx)


@dataclasses.dataclass(frozen=True)
class TileAxis:
    """Candidate (size, offset) pairs for one searched dim.  For window-outer
    dims the offset is in *output* steps (the engine stride-scales it), for
    plain dims offset == size (disjoint tiling — no recompute)."""
    dim: str
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.sizes) != len(self.offsets) or not self.sizes:
            raise ValueError(f"axis {self.dim}: sizes/offsets mismatch")

    @property
    def n(self) -> int:
        return len(self.sizes)


@dataclasses.dataclass(frozen=True)
class ClusterOption:
    """Second cluster level: ``Cluster(size); SpatialMap(inner_size,
    inner_offset) inner_dim``.  For window-outer inner dims (X/Y of a conv)
    the inner map slides — ``SpatialMap(Sz(S),1) X`` — which is exactly the
    ShiDianNao/Eyeriss-style nesting of Table 3's YX-P/YR-P; plain dims get
    the NVDLA-style unit mapping ``SpatialMap(1,1)``."""
    size: int
    inner_dim: str
    inner_size: int | Sz = 1
    inner_offset: int | Sz = 1


@dataclasses.dataclass(frozen=True)
class MapSpace:
    op_name: str
    dims: tuple[tuple[str, int], ...]       # layer dims (fingerprint anchor)
    axes: tuple[TileAxis, ...]
    perms: tuple[tuple[int, ...], ...]      # axis-index orderings
    spatial_choices: tuple[int, ...]        # axis indices
    cluster_options: tuple[ClusterOption | None, ...]
    pinned: tuple[str, ...]                 # window dims, fully unrolled

    # ------------------------------------------------------------------
    @property
    def size(self) -> int:
        n = len(self.spatial_choices) * len(self.perms) \
            * len(self.cluster_options)
        for ax in self.axes:
            n *= ax.n
        return n

    @property
    def n_groups(self) -> int:
        return len(self.spatial_choices) * len(self.perms) \
            * len(self.cluster_options)

    def group_key(self, point: Point) -> GroupKey:
        return tuple(point[:3])

    def group_keys(self) -> list[GroupKey]:
        return [  # deterministic order: spatial outer, then perm, cluster
            (s, p, c)
            for s in range(len(self.spatial_choices))
            for p in range(len(self.perms))
            for c in range(len(self.cluster_options))]

    def gene_ranges(self) -> tuple[int, ...]:
        return (len(self.spatial_choices), len(self.perms),
                len(self.cluster_options)) + tuple(ax.n for ax in self.axes)

    def fingerprint(self) -> str:
        txt = "|".join([
            self.op_name, str(self.dims),
            str([(ax.dim, ax.sizes, ax.offsets) for ax in self.axes]),
            str(self.perms), str(self.spatial_choices),
            str(self.cluster_options), str(self.pinned)])
        return hashlib.sha256(txt.encode()).hexdigest()[:16]


class MapSpaceError(ValueError):
    pass


# ----------------------------------------------------------------------
# Construction
# ----------------------------------------------------------------------

def _window_info(op: LayerOp) -> dict[str, tuple[str, int]]:
    """outer dim -> (window dim, stride) for the op's output sliding
    windows (input-centric convs)."""
    out = {}
    for e in op.output.entries:
        if isinstance(e, ConvExpr):
            out[e.outer] = (e.window, e.stride)
    return out


def _pinned_dims(op: LayerOp) -> tuple[str, ...]:
    """Window (filter-tap) dims: R/S of a conv — pinned fully unrolled."""
    pinned = []
    for t in (op.output, op.input):
        for e in t.entries:
            w = getattr(e, "window", None)
            if w and w in op.dims and w not in pinned:
                pinned.append(w)
    return tuple(pinned)


def build_space(op: LayerOp, *,
                dims: Sequence[str] | None = None,
                spatial_dims: Sequence[str] | None = None,
                max_tiles_per_dim: int = 6,
                perm_mode: str = "auto",
                cluster: bool = True,
                cluster_sizes: Sequence[int] = (64,),
                cluster_inner_dims: Sequence[str] | None = None) -> MapSpace:
    """Derive the default legal mapping space for ``op``.

    ``perm_mode``: ``"all"`` enumerates every axis ordering, ``"rotations"``
    only the cyclic shifts of the canonical order (one choice of innermost
    axis each — the order decision that dominates reuse), ``"auto"`` picks
    ``all`` for ≤3 axes else ``rotations``.  Keeping the structural axes
    small matters: each (spatial × perm × cluster) combination is a separate
    XLA executable; tile axes are free (vectorized).
    """
    windows = _window_info(op)
    pinned = _pinned_dims(op)
    if dims is None:
        dims = [d for d in op.dims
                if op.dims[d] > 1 and d not in pinned and d != "N"]
    dims = list(dims)
    if not dims:
        raise MapSpaceError(f"{op.name}: no searchable dims")
    for d in dims:
        if d not in op.dims:
            raise MapSpaceError(f"{op.name}: unknown dim {d!r}")
        if d in pinned:
            raise MapSpaceError(f"{op.name}: {d!r} is a window dim (pinned)")

    axes = []
    for d in dims:
        extent = op.dims[d]
        if d in windows:
            w, stride = windows[d]
            out_extent = (extent - op.dims[w]) // stride + 1
            cand = tile_candidates(max(out_extent, 1), max_tiles_per_dim)
            sizes = tuple((t - 1) * stride + op.dims[w] for t in cand)
            offsets = cand  # output steps; the CLA engine stride-scales
        else:
            cand = tile_candidates(extent, max_tiles_per_dim)
            sizes = offsets = cand
        axes.append(TileAxis(d, sizes, offsets))

    a = len(axes)
    if perm_mode == "auto":
        perm_mode = "all" if a <= 3 else "rotations"
    if perm_mode == "all":
        perms = tuple(itertools.permutations(range(a)))
    elif perm_mode == "rotations":
        base = tuple(range(a))
        perms = tuple(base[r:] + base[:r] for r in range(a))
    else:
        raise MapSpaceError(f"unknown perm_mode {perm_mode!r}")

    if spatial_dims is None:
        spatial_dims = dims
    spatial_choices = tuple(dims.index(d) for d in spatial_dims)

    options: list[ClusterOption | None] = [None]
    if cluster:
        if cluster_inner_dims is None:
            red = op.reduction_dims()
            cluster_inner_dims = [d for d in dims
                                  if d in red and op.dims[d] > 1][:1]
            # plus one sliding-window inner (the YX-P/YR-P nesting style)
            win_outer = [d for d in windows if op.dims[d] > 1]
            cluster_inner_dims += win_outer[-1:]
        for d in cluster_inner_dims:
            if d in windows:
                w, stride = windows[d]
                useful = (op.dims[d] - op.dims[w]) // stride + 1
                inner: tuple = (Sz(w), 1)
            else:
                useful = op.dims[d]
                inner = (1, 1)
            for c in dict.fromkeys(min(c, useful) for c in cluster_sizes):
                if c > 1:
                    options.append(ClusterOption(c, d, *inner))

    return MapSpace(
        op_name=op.name,
        dims=tuple(sorted(op.dims.items())),
        axes=tuple(axes),
        perms=perms,
        spatial_choices=spatial_choices,
        cluster_options=tuple(options),
        pinned=pinned,
    )


# ----------------------------------------------------------------------
# Point <-> Dataflow
# ----------------------------------------------------------------------

def point_dataflow(space: MapSpace, point: Point,
                   name: str | None = None) -> Dataflow:
    """Materialize one gene tuple as a concrete directive program."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    spatial_axis = space.spatial_choices[s_i]
    dirs = []
    for ai in space.perms[p_i]:
        ax = space.axes[ai]
        t = tiles[ai]
        cls = SpatialMap if ai == spatial_axis else TemporalMap
        dirs.append(cls(ax.sizes[t], ax.offsets[t], ax.dim))
    for d in space.pinned:
        dirs.append(TemporalMap(Sz(d), Sz(d), d))
    copt = space.cluster_options[c_i]
    if copt is not None:
        dirs.append(Cluster(copt.size))
        dirs.append(SpatialMap(copt.inner_size, copt.inner_offset,
                               copt.inner_dim))
    if name is None:
        name = f"ms:{space.op_name}:" + "-".join(str(g) for g in point)
    return Dataflow(name, tuple(dirs))


def group_template(space: MapSpace, key: GroupKey
                   ) -> tuple[Dataflow, tuple[int, ...]]:
    """Placeholder program + variable directive slots for one structural
    group.  Operand column ``j`` of the batched evaluator corresponds to the
    ``j``-th directive, i.e. axis ``space.perms[p][j]``."""
    s_i, p_i, c_i = key
    point = (s_i, p_i, c_i) + tuple(0 for _ in space.axes)
    df = point_dataflow(space, point, name=f"ms-tmpl:{space.op_name}:"
                                           f"{s_i}-{p_i}-{c_i}")
    return df, tuple(range(len(space.axes)))


def point_operands(space: MapSpace, points: Sequence[Point]
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Stack (sizes, offsets) operand rows for points of ONE group, columns
    in the group's perm order."""
    p_i = points[0][1]
    perm = space.perms[p_i]
    n, a = len(points), len(space.axes)
    sizes = np.empty((n, a), np.float32)
    offsets = np.empty((n, a), np.float32)
    for i, pt in enumerate(points):
        tiles = pt[3:]
        for j, ai in enumerate(perm):
            ax = space.axes[ai]
            sizes[i, j] = ax.sizes[tiles[ai]]
            offsets[i, j] = ax.offsets[tiles[ai]]
    return sizes, offsets


# ----------------------------------------------------------------------
# Space pruning: equivalent-permutation dedupe + buffer-budget bounds
# ----------------------------------------------------------------------

def _resolve_sz(v, op: LayerOp) -> int:
    return op.dims[v.dim] if isinstance(v, Sz) else int(v)


def _point_ranks(space: MapSpace, op: LayerOp, point: Point
                 ) -> tuple[dict[str, float], dict[str, int]]:
    """Loop-order ranks (higher = inner) and trip counts per dim for one
    point, mirroring the grouped templates: implicit dims outermost,
    searched axes in permutation order, pinned window dims innermost."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    a = len(space.axes)
    rank: dict[str, float] = {}
    trips: dict[str, int] = {}
    searched = {ax.dim for ax in space.axes}
    missing = [d for d in op.dims
               if d not in searched and d not in space.pinned]
    for i, d in enumerate(missing):
        rank[d] = -1 - i
        trips[d] = 1
    spatial_axis = space.spatial_choices[s_i]
    for pos, ai in enumerate(space.perms[p_i]):
        ax = space.axes[ai]
        rank[ax.dim] = pos
        ext = op.dims[ax.dim]
        size = min(ax.sizes[tiles[ai]], ext)
        off = ax.offsets[tiles[ai]] * op.stride_of(ax.dim)
        if ai == spatial_axis:
            # spatial folding depends on the PE count, unknown here —
            # conservatively treat the spatial loop as multi-trip so it is
            # never deduped out of the order signature
            trips[ax.dim] = 2
        else:
            trips[ax.dim] = 1 + -(-max(ext - size, 0) // off)
    for j, d in enumerate(space.pinned):
        rank[d] = a + j
        trips[d] = 1
    return rank, trips


def canonical_signature(op: LayerOp, space: MapSpace, point: Point
                        ) -> tuple:
    """Equivalence signature: two points with equal signatures produce
    bit-identical analysis results even when their permutation genes
    differ.

    Permutations that differ only in the position of trip-count-1 loops
    (tile size covering the whole dim) are *almost* interchangeable; the
    engine's residual order sensitivities are the identity of each
    tensor's innermost coupled loop and which reduction loops sit outer to
    the output's innermost coupled loop (the psum-spill rule).  The
    signature captures exactly those, so deduping on it is lossless."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    rank, trips = _point_ranks(space, op, point)
    perm_order = tuple(ai for ai in space.perms[p_i]
                       if trips[space.axes[ai].dim] > 1)
    inners = []
    for t in op.tensors():
        cl = [d for d in rank if t.coupled_to(d)]
        inners.append(max(cl, key=rank.get) if cl else None)
    ocl = [d for d in rank if op.output.coupled_to(d)]
    red_flags: tuple = ()
    if ocl:
        inner_o = max(ocl, key=rank.get)
        red_flags = tuple(
            sorted(d for d in rank
                   if d in op.reduction_dims() and trips[d] > 1
                   and rank[d] < rank[inner_o]))
    return (s_i, c_i, tiles, perm_order, tuple(inners), red_flags)


def dedupe_equivalent_points(op: LayerOp, space: MapSpace,
                             points: Sequence[Point]
                             ) -> tuple[list[Point], list[int]]:
    """Collapse analysis-equivalent points (ROADMAP "richer space
    pruning").  Returns ``(representatives, rep_index_per_point)`` so
    callers evaluate only the representatives and scatter features back."""
    reps: list[Point] = []
    index: dict[tuple, int] = {}
    back: list[int] = []
    for pt in points:
        sig = canonical_signature(op, space, pt)
        at = index.get(sig)
        if at is None:
            at = len(reps)
            index[sig] = at
            reps.append(pt)
        back.append(at)
    return reps, back


def buffer_estimate_kb(op: LayerOp, space: MapSpace, point: Point,
                       dtype_bytes: int = 2) -> tuple[float, float]:
    """Closed-form (L1, L2) working-set lower bounds in KB for one point —
    double-buffered per-PE tile and per-level steady tile.  Lower bounds by
    construction (spatial spans only grow the true L2 requirement), so
    budget pruning never drops a feasible mapping."""
    sizes = dict(op.dims)
    for ai, ax in enumerate(space.axes):
        sizes[ax.dim] = min(ax.sizes[point[3 + ai]], op.dims[ax.dim])
    l2 = 2 * sum(t.volume(sizes) for t in op.tensors())
    inner = dict(sizes)
    copt = space.cluster_options[point[2]]
    if copt is not None:
        inner[copt.inner_dim] = min(_resolve_sz(copt.inner_size, op),
                                    inner[copt.inner_dim])
    l1 = 2 * sum(t.volume(inner) for t in op.tensors())
    return (l1 * dtype_bytes / 1024.0, l2 * dtype_bytes / 1024.0)


def prune_by_budget(op: LayerOp, space: MapSpace,
                    points: Sequence[Point], *,
                    l1_kb: float | None = None,
                    l2_kb: float | None = None,
                    dtype_bytes: int = 2) -> list[Point]:
    """Drop points whose working-set lower bound exceeds the L1/L2 buffer
    budget — before any evaluation (ROADMAP "bound tile sets by buffer
    budgets")."""
    if l1_kb is None and l2_kb is None:
        return list(points)
    out = []
    for pt in points:
        e1, e2 = buffer_estimate_kb(op, space, pt, dtype_bytes)
        if l1_kb is not None and e1 > l1_kb:
            continue
        if l2_kb is not None and e2 > l2_kb:
            continue
        out.append(pt)
    return out


# ----------------------------------------------------------------------
# Enumeration / sampling
# ----------------------------------------------------------------------

def enumerate_points(space: MapSpace) -> Iterator[Point]:
    """All points, grouped (structural genes outermost) so consumers hit
    each jit group exactly once."""
    for s, p, c in space.group_keys():
        for tiles in itertools.product(*[range(ax.n) for ax in space.axes]):
            yield (s, p, c) + tiles


def sample_points(space: MapSpace, rng: np.random.Generator, n: int,
                  group_keys: Sequence[GroupKey] | None = None,
                  exclude: set[Point] | None = None) -> list[Point]:
    """Up to ``n`` distinct uniform points (optionally restricted to a group
    subset), deterministic under the caller's rng."""
    keys = list(group_keys) if group_keys is not None \
        else space.group_keys()
    out: list[Point] = []
    seen = set(exclude) if exclude else set()
    tiles_per_group = 1
    for ax in space.axes:
        tiles_per_group *= ax.n
    limit = len(keys) * tiles_per_group
    attempts = 0
    while len(out) < n and attempts < 20 * n and len(seen) < limit + \
            (len(exclude) if exclude else 0):
        attempts += 1
        key = keys[int(rng.integers(len(keys)))]
        tiles = tuple(int(rng.integers(ax.n)) for ax in space.axes)
        pt = key + tiles
        if pt in seen:
            continue
        seen.add(pt)
        out.append(pt)
    return out
