"""Mapping-space definition: the legal data-centric programs for a layer.

The paper's 480M-design search has two axes: hardware (``core.dse``) and
*mapping* — which this module defines.  A candidate mapping is encoded as a
small integer gene tuple::

    point = (spatial_idx, perm_idx, cluster_idx, tile_0, ..., tile_{A-1})

over a :class:`MapSpace` with

  * one :class:`TileAxis` per searched layer dim, whose candidate tile sizes
    come from the dim's divisor set (``directives.tile_candidates``) — for
    sliding-window outer dims (Y/X of a conv) candidates tile the *output*
    extent and carry the input halo, so every tile yields whole outputs;
  * a choice of which axis is spatially mapped (the paper's partitioning
    strategy, Table 3's "-P" suffix);
  * a permutation of the axes (the data-movement order);
  * an optional second cluster level (``Cluster(c); SpatialMap(1,1) d`` —
    the NVDLA/Eyeriss-style nesting of Table 3).

Window dims themselves (R/S) are pinned fully-unrolled with symbolic
``Sz(...)`` sizes, exercising ``resolve``/``complete`` exactly like the
Table 3 programs.  Legality is enforced at construction: every tile size
divides (window dims: tiles the output of) its dim, so no directive ever
exceeds its extent — points never need post-hoc filtering.

Points sharing ``(spatial_idx, perm_idx, cluster_idx)`` share one directive
*structure* and differ only in tile sizes, which is precisely the grouping
the batched evaluator (``mapspace.batched``) vectorizes over.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Iterator, Sequence

import numpy as np

from ..core.directives import (Cluster, Dataflow, SpatialMap, Sz,
                               TemporalMap, tile_candidates)
from ..core.tensor_analysis import ConvExpr, LayerOp

Point = tuple  # (spatial_idx, perm_idx, cluster_idx, *tile_idxs)
GroupKey = tuple  # (spatial_idx, perm_idx, cluster_idx)


@dataclasses.dataclass(frozen=True)
class TileAxis:
    """Candidate (size, offset) pairs for one searched dim.  For window-outer
    dims the offset is in *output* steps (the engine stride-scales it), for
    plain dims offset == size (disjoint tiling — no recompute)."""
    dim: str
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.sizes) != len(self.offsets) or not self.sizes:
            raise ValueError(f"axis {self.dim}: sizes/offsets mismatch")

    @property
    def n(self) -> int:
        return len(self.sizes)


@dataclasses.dataclass(frozen=True)
class ClusterOption:
    """Second cluster level: ``Cluster(size); SpatialMap(inner_size,
    inner_offset) inner_dim``.  For window-outer inner dims (X/Y of a conv)
    the inner map slides — ``SpatialMap(Sz(S),1) X`` — which is exactly the
    ShiDianNao/Eyeriss-style nesting of Table 3's YX-P/YR-P; plain dims get
    the NVDLA-style unit mapping ``SpatialMap(1,1)``."""
    size: int
    inner_dim: str
    inner_size: int | Sz = 1
    inner_offset: int | Sz = 1


@dataclasses.dataclass(frozen=True)
class MapSpace:
    op_name: str
    dims: tuple[tuple[str, int], ...]       # layer dims (fingerprint anchor)
    axes: tuple[TileAxis, ...]
    perms: tuple[tuple[int, ...], ...]      # axis-index orderings
    spatial_choices: tuple[int, ...]        # axis indices
    cluster_options: tuple[ClusterOption | None, ...]
    pinned: tuple[str, ...]                 # window dims, fully unrolled

    # ------------------------------------------------------------------
    @property
    def size(self) -> int:
        n = len(self.spatial_choices) * len(self.perms) \
            * len(self.cluster_options)
        for ax in self.axes:
            n *= ax.n
        return n

    @property
    def n_groups(self) -> int:
        return len(self.spatial_choices) * len(self.perms) \
            * len(self.cluster_options)

    def group_key(self, point: Point) -> GroupKey:
        return tuple(point[:3])

    def group_keys(self) -> list[GroupKey]:
        return [  # deterministic order: spatial outer, then perm, cluster
            (s, p, c)
            for s in range(len(self.spatial_choices))
            for p in range(len(self.perms))
            for c in range(len(self.cluster_options))]

    def gene_ranges(self) -> tuple[int, ...]:
        return (len(self.spatial_choices), len(self.perms),
                len(self.cluster_options)) + tuple(ax.n for ax in self.axes)

    def fingerprint(self) -> str:
        txt = "|".join([
            self.op_name, str(self.dims),
            str([(ax.dim, ax.sizes, ax.offsets) for ax in self.axes]),
            str(self.perms), str(self.spatial_choices),
            str(self.cluster_options), str(self.pinned)])
        return hashlib.sha256(txt.encode()).hexdigest()[:16]


class MapSpaceError(ValueError):
    pass


# ----------------------------------------------------------------------
# Construction
# ----------------------------------------------------------------------

def _window_info(op: LayerOp) -> dict[str, tuple[str, int]]:
    """outer dim -> (window dim, stride) for the op's output sliding
    windows (input-centric convs)."""
    out = {}
    for e in op.output.entries:
        if isinstance(e, ConvExpr):
            out[e.outer] = (e.window, e.stride)
    return out


def _pinned_dims(op: LayerOp) -> tuple[str, ...]:
    """Window (filter-tap) dims: R/S of a conv — pinned fully unrolled."""
    pinned = []
    for t in (op.output, op.input):
        for e in t.entries:
            w = getattr(e, "window", None)
            if w and w in op.dims and w not in pinned:
                pinned.append(w)
    return tuple(pinned)


def build_space(op: LayerOp, *,
                dims: Sequence[str] | None = None,
                spatial_dims: Sequence[str] | None = None,
                max_tiles_per_dim: int = 6,
                perm_mode: str = "auto",
                cluster: bool = True,
                cluster_sizes: Sequence[int] = (64,),
                cluster_inner_dims: Sequence[str] | None = None) -> MapSpace:
    """Derive the default legal mapping space for ``op``.

    ``perm_mode``: ``"all"`` enumerates every axis ordering, ``"rotations"``
    only the cyclic shifts of the canonical order (one choice of innermost
    axis each — the order decision that dominates reuse), ``"auto"`` picks
    ``all`` for ≤3 axes else ``rotations``.  Keeping the structural axes
    small matters: each (spatial × perm × cluster) combination is a separate
    XLA executable; tile axes are free (vectorized).
    """
    windows = _window_info(op)
    pinned = _pinned_dims(op)
    if dims is None:
        dims = [d for d in op.dims
                if op.dims[d] > 1 and d not in pinned and d != "N"]
    dims = list(dims)
    if not dims:
        raise MapSpaceError(f"{op.name}: no searchable dims")
    for d in dims:
        if d not in op.dims:
            raise MapSpaceError(f"{op.name}: unknown dim {d!r}")
        if d in pinned:
            raise MapSpaceError(f"{op.name}: {d!r} is a window dim (pinned)")

    axes = []
    for d in dims:
        extent = op.dims[d]
        if d in windows:
            w, stride = windows[d]
            out_extent = (extent - op.dims[w]) // stride + 1
            cand = tile_candidates(max(out_extent, 1), max_tiles_per_dim)
            sizes = tuple((t - 1) * stride + op.dims[w] for t in cand)
            offsets = cand  # output steps; the CLA engine stride-scales
        else:
            cand = tile_candidates(extent, max_tiles_per_dim)
            sizes = offsets = cand
        axes.append(TileAxis(d, sizes, offsets))

    a = len(axes)
    if perm_mode == "auto":
        perm_mode = "all" if a <= 3 else "rotations"
    if perm_mode == "all":
        perms = tuple(itertools.permutations(range(a)))
    elif perm_mode == "rotations":
        base = tuple(range(a))
        perms = tuple(base[r:] + base[:r] for r in range(a))
    else:
        raise MapSpaceError(f"unknown perm_mode {perm_mode!r}")

    if spatial_dims is None:
        spatial_dims = dims
    spatial_choices = tuple(dims.index(d) for d in spatial_dims)

    options: list[ClusterOption | None] = [None]
    if cluster:
        if cluster_inner_dims is None:
            red = op.reduction_dims()
            cluster_inner_dims = [d for d in dims
                                  if d in red and op.dims[d] > 1][:1]
            # plus one sliding-window inner (the YX-P/YR-P nesting style)
            win_outer = [d for d in windows if op.dims[d] > 1]
            cluster_inner_dims += win_outer[-1:]
        for d in cluster_inner_dims:
            if d in windows:
                w, stride = windows[d]
                useful = (op.dims[d] - op.dims[w]) // stride + 1
                inner: tuple = (Sz(w), 1)
            else:
                useful = op.dims[d]
                inner = (1, 1)
            for c in dict.fromkeys(min(c, useful) for c in cluster_sizes):
                if c > 1:
                    options.append(ClusterOption(c, d, *inner))

    return MapSpace(
        op_name=op.name,
        dims=tuple(sorted(op.dims.items())),
        axes=tuple(axes),
        perms=perms,
        spatial_choices=spatial_choices,
        cluster_options=tuple(options),
        pinned=pinned,
    )


# ----------------------------------------------------------------------
# Point <-> Dataflow
# ----------------------------------------------------------------------

def point_dataflow(space: MapSpace, point: Point,
                   name: str | None = None) -> Dataflow:
    """Materialize one gene tuple as a concrete directive program."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    spatial_axis = space.spatial_choices[s_i]
    dirs = []
    for ai in space.perms[p_i]:
        ax = space.axes[ai]
        t = tiles[ai]
        cls = SpatialMap if ai == spatial_axis else TemporalMap
        dirs.append(cls(ax.sizes[t], ax.offsets[t], ax.dim))
    for d in space.pinned:
        dirs.append(TemporalMap(Sz(d), Sz(d), d))
    copt = space.cluster_options[c_i]
    if copt is not None:
        dirs.append(Cluster(copt.size))
        dirs.append(SpatialMap(copt.inner_size, copt.inner_offset,
                               copt.inner_dim))
    if name is None:
        name = f"ms:{space.op_name}:" + "-".join(str(g) for g in point)
    return Dataflow(name, tuple(dirs))


def group_template(space: MapSpace, key: GroupKey
                   ) -> tuple[Dataflow, tuple[int, ...]]:
    """Placeholder program + variable directive slots for one structural
    group.  Operand column ``j`` of the batched evaluator corresponds to the
    ``j``-th directive, i.e. axis ``space.perms[p][j]``."""
    s_i, p_i, c_i = key
    point = (s_i, p_i, c_i) + tuple(0 for _ in space.axes)
    df = point_dataflow(space, point, name=f"ms-tmpl:{space.op_name}:"
                                           f"{s_i}-{p_i}-{c_i}")
    return df, tuple(range(len(space.axes)))


def point_operands(space: MapSpace, points: Sequence[Point]
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Stack (sizes, offsets) operand rows for points of ONE group, columns
    in the group's perm order."""
    p_i = points[0][1]
    perm = space.perms[p_i]
    n, a = len(points), len(space.axes)
    sizes = np.empty((n, a), np.float32)
    offsets = np.empty((n, a), np.float32)
    for i, pt in enumerate(points):
        tiles = pt[3:]
        for j, ai in enumerate(perm):
            ax = space.axes[ai]
            sizes[i, j] = ax.sizes[tiles[ai]]
            offsets[i, j] = ax.offsets[tiles[ai]]
    return sizes, offsets


def pad_tile_axes(space: MapSpace, counts: Sequence[int]) -> MapSpace:
    """Pad each tile axis to ``counts[ai]`` candidates by repeating its last
    (full-extent) candidate — the same padding rule ``gene_tables`` applies
    internally.  Padded spaces of different layers share identical
    ``gene_ranges()``, which is what lets ``repro.netspace`` use ONE gene
    layout (and one compiled executable) across every layer of an op-class;
    duplicate candidates introduced by padding are analysis-equivalent and
    collapse in ``dedupe_equivalent_genes``."""
    axes = []
    for ax, n in zip(space.axes, counts):
        if n < ax.n:
            raise MapSpaceError(
                f"axis {ax.dim}: cannot pad {ax.n} candidates down to {n}")
        pad = n - ax.n
        axes.append(TileAxis(
            ax.dim, ax.sizes + (ax.sizes[-1],) * pad,
            ax.offsets + (ax.offsets[-1],) * pad))
    return dataclasses.replace(space, axes=tuple(axes))


# ----------------------------------------------------------------------
# Space pruning: equivalent-permutation dedupe + buffer-budget bounds
# ----------------------------------------------------------------------

def _resolve_sz(v, op: LayerOp) -> int:
    return op.dims[v.dim] if isinstance(v, Sz) else int(v)


def _point_ranks(space: MapSpace, op: LayerOp, point: Point
                 ) -> tuple[dict[str, float], dict[str, int]]:
    """Loop-order ranks (higher = inner) and trip counts per dim for one
    point, mirroring the grouped templates: implicit dims outermost,
    searched axes in permutation order, pinned window dims innermost."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    a = len(space.axes)
    rank: dict[str, float] = {}
    trips: dict[str, int] = {}
    searched = {ax.dim for ax in space.axes}
    missing = [d for d in op.dims
               if d not in searched and d not in space.pinned]
    for i, d in enumerate(missing):
        rank[d] = -1 - i
        trips[d] = 1
    spatial_axis = space.spatial_choices[s_i]
    for pos, ai in enumerate(space.perms[p_i]):
        ax = space.axes[ai]
        rank[ax.dim] = pos
        ext = op.dims[ax.dim]
        size = min(ax.sizes[tiles[ai]], ext)
        off = ax.offsets[tiles[ai]] * op.stride_of(ax.dim)
        if ai == spatial_axis:
            # spatial folding depends on the PE count, unknown here —
            # conservatively treat the spatial loop as multi-trip so it is
            # never deduped out of the order signature
            trips[ax.dim] = 2
        else:
            trips[ax.dim] = 1 + -(-max(ext - size, 0) // off)
    for j, d in enumerate(space.pinned):
        rank[d] = a + j
        trips[d] = 1
    return rank, trips


def canonical_signature(op: LayerOp, space: MapSpace, point: Point
                        ) -> tuple:
    """Equivalence signature: two points with equal signatures produce
    bit-identical analysis results even when their permutation genes
    differ.

    Permutations that differ only in the position of trip-count-1 loops
    (tile size covering the whole dim) are *almost* interchangeable; the
    engine's residual order sensitivities are the identity of each
    tensor's innermost coupled loop and which reduction loops sit outer to
    the output's innermost coupled loop (the psum-spill rule).  The
    signature captures exactly those, so deduping on it is lossless."""
    s_i, p_i, c_i = point[:3]
    tiles = point[3:]
    rank, trips = _point_ranks(space, op, point)
    perm_order = tuple(ai for ai in space.perms[p_i]
                       if trips[space.axes[ai].dim] > 1)
    inners = []
    for t in op.tensors():
        cl = [d for d in rank if t.coupled_to(d)]
        inners.append(max(cl, key=rank.get) if cl else None)
    ocl = [d for d in rank if op.output.coupled_to(d)]
    red_flags: tuple = ()
    if ocl:
        inner_o = max(ocl, key=rank.get)
        red_flags = tuple(
            sorted(d for d in rank
                   if d in op.reduction_dims() and trips[d] > 1
                   and rank[d] < rank[inner_o]))
    return (s_i, c_i, tiles, perm_order, tuple(inners), red_flags)


def dedupe_equivalent_points(op: LayerOp, space: MapSpace,
                             points: Sequence[Point]
                             ) -> tuple[list[Point], list[int]]:
    """Collapse analysis-equivalent points (ROADMAP "richer space
    pruning").  Returns ``(representatives, rep_index_per_point)`` so
    callers evaluate only the representatives and scatter features back."""
    reps: list[Point] = []
    index: dict[tuple, int] = {}
    back: list[int] = []
    for pt in points:
        sig = canonical_signature(op, space, pt)
        at = index.get(sig)
        if at is None:
            at = len(reps)
            index[sig] = at
            reps.append(pt)
        back.append(at)
    return reps, back


def buffer_estimate_kb(op: LayerOp, space: MapSpace, point: Point,
                       dtype_bytes: int = 2) -> tuple[float, float]:
    """Closed-form (L1, L2) working-set lower bounds in KB for one point —
    double-buffered per-PE tile and per-level steady tile.  Lower bounds by
    construction (spatial spans only grow the true L2 requirement), so
    budget pruning never drops a feasible mapping."""
    sizes = dict(op.dims)
    for ai, ax in enumerate(space.axes):
        sizes[ax.dim] = min(ax.sizes[point[3 + ai]], op.dims[ax.dim])
    l2 = 2 * sum(t.volume(sizes) for t in op.tensors())
    inner = dict(sizes)
    copt = space.cluster_options[point[2]]
    if copt is not None:
        inner[copt.inner_dim] = min(_resolve_sz(copt.inner_size, op),
                                    inner[copt.inner_dim])
    l1 = 2 * sum(t.volume(inner) for t in op.tensors())
    return (l1 * dtype_bytes / 1024.0, l2 * dtype_bytes / 1024.0)


def prune_by_budget(op: LayerOp, space: MapSpace,
                    points: Sequence[Point], *,
                    l1_kb: float | None = None,
                    l2_kb: float | None = None,
                    dtype_bytes: int = 2) -> list[Point]:
    """Drop points whose working-set lower bound exceeds the L1/L2 buffer
    budget — before any evaluation (ROADMAP "bound tile sets by buffer
    budgets")."""
    if l1_kb is None and l2_kb is None:
        return list(points)
    out = []
    for pt in points:
        e1, e2 = buffer_estimate_kb(op, space, pt, dtype_bytes)
        if l1_kb is not None and e1 > l1_kb:
            continue
        if l2_kb is not None and e2 > l2_kb:
            continue
        out.append(pt)
    return out


# ----------------------------------------------------------------------
# Gene matrices: the vectorized native currency of the search
# ----------------------------------------------------------------------
#
# A *gene matrix* is an ``(n, G)`` int64 array whose rows are points in
# gene-tuple layout: ``(spatial_idx, perm_idx, cluster_idx, tile_0, ...,
# tile_{A-1})``.  Everything the search pipeline does per point — index
# decode, operand encode, equivalence signatures, buffer bounds — is
# expressed as numpy gathers over per-space lookup tables, so the host
# side scales to millions of candidates without Python per-point loops.

def genes_from_points(points: Sequence[Point]) -> np.ndarray:
    """Stack tuple points into an (n, G) int64 gene matrix."""
    return np.asarray(points, dtype=np.int64).reshape(len(points), -1)


def points_from_genes(genes: np.ndarray) -> list[Point]:
    """Gene matrix rows back to tuple points (API edges only)."""
    return [tuple(int(g) for g in row) for row in np.asarray(genes)]


def decode_indices(space: MapSpace, idx) -> np.ndarray:
    """Mixed-radix flat index -> gene matrix, vectorized.

    The digit order matches :func:`enumerate_points`: structural genes
    outermost (spatial, then perm, then cluster), tile genes innermost with
    the LAST axis fastest — so ``decode_indices(space, np.arange(n))``
    reproduces the first ``n`` enumerated points exactly."""
    idx = np.ascontiguousarray(np.asarray(idx, dtype=np.int64))
    radices = space.gene_ranges()
    out = np.empty((idx.shape[0], len(radices)), dtype=np.int64)
    for j in range(len(radices) - 1, -1, -1):
        out[:, j] = idx % radices[j]
        idx = idx // radices[j]
    return out


def flat_index(space: MapSpace, genes: np.ndarray) -> np.ndarray:
    """Inverse of :func:`decode_indices`: gene rows -> flat int64 indices
    (used for O(1) distinctness bookkeeping during sampling/search)."""
    genes = np.asarray(genes, dtype=np.int64)
    radices = space.gene_ranges()
    flat = np.zeros(genes.shape[0], dtype=np.int64)
    for j in range(len(radices)):
        flat = flat * radices[j] + genes[:, j]
    return flat


def enumerate_genes(space: MapSpace, start: int = 0,
                    stop: int | None = None) -> np.ndarray:
    """Vectorized enumeration: gene rows ``start..stop`` in the canonical
    :func:`enumerate_points` order, with no Python per-point loop."""
    stop = space.size if stop is None else min(stop, space.size)
    return decode_indices(space, np.arange(start, max(stop, start),
                                           dtype=np.int64))


def sample_genes(space: MapSpace, rng: np.random.Generator, n: int,
                 exclude_flat=None) -> np.ndarray:
    """Up to ``n`` distinct uniform gene rows, deterministic under the
    caller's rng.  Draws flat indices in vectorized batches; only the
    distinctness filter touches a host set (O(n), independent of the
    space size).  ``exclude_flat`` is an iterable of flat indices that
    must not be re-proposed."""
    seen: set[int] = set(int(f) for f in exclude_flat) \
        if exclude_flat is not None else set()
    out: list[int] = []
    drawn = 0
    while len(out) < n and drawn < 20 * n and len(seen) < space.size:
        m = max(2 * (n - len(out)), 64)
        drawn += m
        for f in rng.integers(space.size, size=m).tolist():
            if f in seen:
                continue
            seen.add(f)
            out.append(f)
            if len(out) >= n:
                break
    return decode_indices(space, np.asarray(out, dtype=np.int64))


@dataclasses.dataclass
class GeneTables:
    """Per-(op, space) lookup tables mapping gene columns to everything the
    pipeline needs — built once per space (small Python loops over the
    space *structure*), then applied to arbitrarily large gene matrices by
    pure numpy gathers."""
    # operand encode
    size_tab: np.ndarray          # (A, maxN) f32 tile sizes (padded)
    off_tab: np.ndarray           # (A, maxN) f32 tile offsets
    perm_rank: np.ndarray         # (P, A) f32: axis ai's loop position
    spatial_axis: np.ndarray      # (S,) int64 axis index per spatial choice
    cluster_is_none: np.ndarray   # (C,) bool
    csize_tab: np.ndarray         # (C,) f32 cluster size (0 for None)
    # equivalence signatures
    clamped_tab: np.ndarray       # (A, maxN) int64 min(size, extent)
    trips_tab: np.ndarray         # (A, maxN) int64 non-spatial trip count
    red_axis: np.ndarray          # (A,) bool axis dim is a reduction dim
    inner_masks: tuple            # per dynamic-inner tensor: (A,) bool mask
    out_mask: np.ndarray | None   # (A,) bool output-coupled axes, dynamic
    out_static_rank: float        # rank of output's inner loop when static
    # buffer bounds (KB are derived later; volumes are exact ints)
    vol_static: np.ndarray        # (T,) int64 per-tensor static factor
    vol_tab: np.ndarray           # (T, A, maxN) int64 per-axis factors
    l1_axis_tab: np.ndarray       # (C, T, A, maxN) clamped per-axis factors
    l1_static_tab: np.ndarray     # (C, T) int64 full static factor (L1)


_TABLES: dict[tuple[int, int], tuple[LayerOp, MapSpace, GeneTables]] = {}
_TABLES_MAX = 64   # FIFO bound: a model-zoo sweep must not pin every
#                    (op, space) pair's tables for the process lifetime


def _sizes_env(op: LayerOp, overrides: dict[str, int]) -> dict[str, int]:
    env = dict(op.dims)
    env.update(overrides)
    return env


def gene_tables(op: LayerOp, space: MapSpace) -> GeneTables:
    """Build (and cache) the lookup tables for one (op, space) pair."""
    key = (id(op), id(space))
    hit = _TABLES.get(key)
    if hit is not None and hit[0] is op and hit[1] is space:
        return hit[2]

    a = len(space.axes)
    max_n = max(ax.n for ax in space.axes)
    size_tab = np.zeros((a, max_n), np.float32)
    off_tab = np.ones((a, max_n), np.float32)
    clamped_tab = np.ones((a, max_n), np.int64)
    trips_tab = np.ones((a, max_n), np.int64)
    for ai, ax in enumerate(space.axes):
        ext = op.dims[ax.dim]
        stride = op.stride_of(ax.dim)
        for t in range(ax.n):
            size_tab[ai, t] = ax.sizes[t]
            off_tab[ai, t] = ax.offsets[t]
            clamped_tab[ai, t] = min(ax.sizes[t], ext)
            off = ax.offsets[t] * stride
            trips_tab[ai, t] = 1 + (max(ext - clamped_tab[ai, t], 0)
                                    + off - 1) // off
        for t in range(ax.n, max_n):  # pad with the last real candidate
            size_tab[ai, t] = size_tab[ai, ax.n - 1]
            off_tab[ai, t] = off_tab[ai, ax.n - 1]
            clamped_tab[ai, t] = clamped_tab[ai, ax.n - 1]
            trips_tab[ai, t] = trips_tab[ai, ax.n - 1]

    perm_rank = np.zeros((len(space.perms), a), np.float32)
    for p, perm in enumerate(space.perms):
        for pos, ai in enumerate(perm):
            perm_rank[p, ai] = pos

    spatial_axis = np.asarray(space.spatial_choices, np.int64)
    cluster_is_none = np.asarray(
        [c is None for c in space.cluster_options], bool)
    csize_tab = np.asarray(
        [0.0 if c is None else float(c.size)
         for c in space.cluster_options], np.float32)

    # --- signature statics -------------------------------------------
    axis_dims = [ax.dim for ax in space.axes]
    red = op.reduction_dims()
    red_axis = np.asarray([d in red for d in axis_dims], bool)
    inner_masks = []
    out_mask = None
    out_static_rank = -np.inf  # no coupled loop at all -> no psum spill
    for t in op.tensors():
        coupled_pinned = any(t.coupled_to(d) for d in space.pinned)
        mask = np.asarray([t.coupled_to(d) for d in axis_dims], bool)
        dynamic = not coupled_pinned and mask.any()
        if t is op.output:
            if dynamic:
                out_mask = mask
            elif coupled_pinned or any(
                    t.coupled_to(d) for d in op.dims
                    if d not in axis_dims and d not in space.pinned):
                # inner coupled loop is static: pinned dims sit inside all
                # searched axes (rank >= A), implicit dims outside (rank<0)
                out_static_rank = float(a) if coupled_pinned else -1.0
        if dynamic:
            inner_masks.append(mask)

    # --- buffer-bound volume tables ----------------------------------
    tensors = op.tensors()
    vol_static = np.ones(len(tensors), np.int64)
    vol_tab = np.ones((len(tensors), a, max_n), np.int64)
    n_c = len(space.cluster_options)
    l1_axis_tab = np.zeros((n_c, len(tensors), a, max_n), np.int64)
    l1_static_tab = np.ones((n_c, len(tensors)), np.int64)
    axis_of = {ax.dim: ai for ai, ax in enumerate(space.axes)}
    for ti, t in enumerate(tensors):
        if not t.has_data:
            vol_static[ti] = 0
        for e in t.entries:
            searched = [d for d in e.dims if d in axis_of]
            if not searched:
                vol_static[ti] *= e.extent(op.dims)
                continue
            (d,) = searched  # window dims are pinned, never searched
            ai = axis_of[d]
            for tt in range(max_n):
                env = _sizes_env(op, {d: int(clamped_tab[ai, tt])})
                vol_tab[ti, ai, tt] *= e.extent(env)
    for ci, copt in enumerate(space.cluster_options):
        if copt is None:
            l1_axis_tab[ci] = vol_tab
            l1_static_tab[ci] = vol_static
            continue
        dc = copt.inner_dim
        m0 = min(_resolve_sz(copt.inner_size, op), op.dims[dc])
        for ti, t in enumerate(tensors):
            l1_axis_tab[ci, ti] = vol_tab[ti]
            # static factor recomputed outright (never a truncating ratio)
            static = 0 if not t.has_data else 1
            for e in t.entries:
                searched = [d for d in e.dims if d in axis_of]
                if not searched:
                    static *= e.extent(_sizes_env(op, {dc: m0})) \
                        if dc in e.dims else e.extent(op.dims)
                elif dc in e.dims:
                    # searched-axis factor with the cluster-inner clamp:
                    # divide this entry's base extent out (exact — the
                    # table is a product of entry extents), multiply the
                    # clamped one in
                    ai = axis_of[searched[0]]
                    for tt in range(max_n):
                        env = {searched[0]: int(clamped_tab[ai, tt])}
                        base = e.extent(_sizes_env(op, env))
                        env[dc] = min(m0, env.get(dc, op.dims[dc]))
                        new = e.extent(_sizes_env(op, env))
                        cur = l1_axis_tab[ci, ti, ai, tt]
                        l1_axis_tab[ci, ti, ai, tt] = \
                            cur // max(base, 1) * new
            l1_static_tab[ci, ti] = static

    tables = GeneTables(
        size_tab=size_tab, off_tab=off_tab, perm_rank=perm_rank,
        spatial_axis=spatial_axis, cluster_is_none=cluster_is_none,
        csize_tab=csize_tab, clamped_tab=clamped_tab, trips_tab=trips_tab,
        red_axis=red_axis, inner_masks=tuple(inner_masks),
        out_mask=out_mask, out_static_rank=out_static_rank,
        vol_static=vol_static, vol_tab=vol_tab, l1_axis_tab=l1_axis_tab,
        l1_static_tab=l1_static_tab)
    while len(_TABLES) >= _TABLES_MAX:
        _TABLES.pop(next(iter(_TABLES)))
    _TABLES[key] = (op, space, tables)
    return tables


def _gene_multi_rank(op: LayerOp, space: MapSpace, genes: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(multi-trip mask, loop rank) per searched axis for each gene row —
    the per-point ingredients of the equivalence signature."""
    tb = gene_tables(op, space)
    n, a = genes.shape[0], len(space.axes)
    tiles = genes[:, 3:]
    rank = tb.perm_rank[genes[:, 1]].astype(np.int64)       # (n, A)
    trips = tb.trips_tab[np.arange(a)[None, :], tiles]      # (n, A)
    multi = trips > 1
    # the spatial axis folds over an unknown PE count: always multi-trip
    sp_axis = tb.spatial_axis[genes[:, 0]]                  # (n,)
    multi[np.arange(n), sp_axis] = True
    return multi, rank


def gene_signatures(op: LayerOp, space: MapSpace, genes: np.ndarray
                    ) -> np.ndarray:
    """Vectorized :func:`canonical_signature`: an (n, S) int64 matrix whose
    rows are equal exactly when the legacy per-point signatures are equal
    (see the partition-parity test)."""
    tb = gene_tables(op, space)
    genes = np.asarray(genes, np.int64)
    n, a = genes.shape[0], len(space.axes)
    multi, rank = _gene_multi_rank(op, space, genes)
    # relative order of the multi-trip axes (== perm_order up to bijection)
    relorder = np.sum(multi[:, None, :]
                      & (rank[:, None, :] < rank[:, :, None]), axis=2)
    relorder = np.where(multi, relorder, -1)                # (n, A)
    cols = [genes[:, 0:1], genes[:, 2:3], genes[:, 3:], relorder]
    # innermost coupled loop per tensor (only dynamic tensors vary)
    for mask in tb.inner_masks:
        masked = np.where(mask[None, :], rank, np.int64(-10 ** 9))
        cols.append(np.argmax(masked, axis=1)[:, None])
    # psum-spill flags: reduction axes outer to the output's inner loop
    if tb.out_mask is not None:
        masked = np.where(tb.out_mask[None, :], rank, np.int64(-10 ** 9))
        rank_o = np.max(masked, axis=1).astype(np.float64)
    else:
        rank_o = np.full(n, tb.out_static_rank)
    red_bits = (tb.red_axis[None, :] & multi
                & (rank < rank_o[:, None])).astype(np.int64)
    cols.append(red_bits)
    return np.concatenate(cols, axis=1)


def dedupe_equivalent_genes(op: LayerOp, space: MapSpace,
                            genes: np.ndarray
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized analysis-equivalence dedupe over a gene matrix.

    Returns ``(rep_rows, back)``: ``rep_rows`` indexes the first-occurrence
    representative rows (in input order, like the legacy scalar loop) and
    ``back[i]`` maps row ``i`` onto its representative's position."""
    sig = gene_signatures(op, space, genes)
    _, first, inv = np.unique(sig, axis=0, return_index=True,
                              return_inverse=True)
    order = np.argsort(first, kind="stable")
    pos = np.empty(len(order), np.int64)
    pos[order] = np.arange(len(order))
    return first[order], pos[inv.ravel()]


def buffer_estimates_genes(op: LayerOp, space: MapSpace,
                           genes: np.ndarray, dtype_bytes: int = 2
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`buffer_estimate_kb` over a gene matrix: per-row
    (L1, L2) working-set lower bounds in KB, bit-identical to the scalar
    loop (exact integer volumes, same float conversion)."""
    tb = gene_tables(op, space)
    genes = np.asarray(genes, np.int64)
    n, a = genes.shape[0], len(space.axes)
    tiles = genes[:, 3:]
    ar = np.arange(a)[None, :]
    l2_vol = np.zeros(n, np.int64)
    l1_vol = np.zeros(n, np.int64)
    c_idx = genes[:, 2]
    for ti in range(len(op.tensors())):
        factors = tb.vol_tab[ti][ar, tiles]                 # (n, A)
        l2_vol += tb.vol_static[ti] * np.prod(factors, axis=1)
        # gather per-row cluster replacement tables: (n, A)
        l1_factors = tb.l1_axis_tab[c_idx[:, None], ti, ar, tiles]
        l1_vol += tb.l1_static_tab[c_idx, ti] * np.prod(l1_factors, axis=1)
    scale = 2 * dtype_bytes / 1024.0
    return l1_vol * scale, l2_vol * scale


def prune_genes_by_budget(op: LayerOp, space: MapSpace, genes: np.ndarray,
                          *, l1_kb: float | None = None,
                          l2_kb: float | None = None,
                          dtype_bytes: int = 2) -> np.ndarray:
    """Vectorized :func:`prune_by_budget`: returns the kept rows."""
    if l1_kb is None and l2_kb is None:
        return np.asarray(genes, np.int64)
    e1, e2 = buffer_estimates_genes(op, space, genes, dtype_bytes)
    keep = np.ones(len(e1), bool)
    if l1_kb is not None:
        keep &= e1 <= l1_kb
    if l2_kb is not None:
        keep &= e2 <= l2_kb
    return np.asarray(genes, np.int64)[keep]


# ----------------------------------------------------------------------
# Enumeration / sampling
# ----------------------------------------------------------------------

def enumerate_points(space: MapSpace) -> Iterator[Point]:
    """All points, grouped (structural genes outermost) so consumers hit
    each jit group exactly once."""
    for s, p, c in space.group_keys():
        for tiles in itertools.product(*[range(ax.n) for ax in space.axes]):
            yield (s, p, c) + tiles


def sample_points(space: MapSpace, rng: np.random.Generator, n: int,
                  group_keys: Sequence[GroupKey] | None = None,
                  exclude: set[Point] | None = None) -> list[Point]:
    """Up to ``n`` distinct uniform points (optionally restricted to a group
    subset), deterministic under the caller's rng."""
    keys = list(group_keys) if group_keys is not None \
        else space.group_keys()
    out: list[Point] = []
    seen = set(exclude) if exclude else set()
    tiles_per_group = 1
    for ax in space.axes:
        tiles_per_group *= ax.n
    limit = len(keys) * tiles_per_group
    attempts = 0
    while len(out) < n and attempts < 20 * n and len(seen) < limit + \
            (len(exclude) if exclude else 0):
        attempts += 1
        key = keys[int(rng.integers(len(keys)))]
        tiles = tuple(int(rng.integers(ax.n)) for ax in space.axes)
        pt = key + tiles
        if pt in seen:
            continue
        seen.add(pt)
        out.append(pt)
    return out
