"""Mapping-space search engine: auto-search over legal data-centric
directive programs plus joint mapping × hardware co-DSE.

Evaluation runs through the *universal* structure-as-operand evaluator:
one XLA executable per (op, level-count) whose vmapped operands encode the
entire mapping — tile sizes, loop permutation (rank vector), spatial
choice (one-hot), cluster option, and the hardware point — so exploring
every structure group costs at most two compiles.

Quick start::

    from repro.core import tensor_analysis as ta
    from repro.mapspace import search

    op = ta.conv2d("conv", k=128, c=64, y=32, x=32, r=3, s=3)
    result = search(op, objective="edp", budget=1000)
    print(result.best_dataflow)
    print(result.best_stats["edp"], result.mappings_per_s)

See ``repro.launch.mapsearch`` for the CLI.
"""
from .batched import EvalStats, evaluate_points, measure_rate
from .cache import enable_compilation_cache
from .codse import CoDSEResult, co_search, merged_pareto
from .search import OBJECTIVES, STRATEGIES, SearchResult, search
from .space import (ClusterOption, MapSpace, MapSpaceError, TileAxis,
                    build_space, buffer_estimate_kb, canonical_signature,
                    dedupe_equivalent_points, enumerate_points,
                    group_template, point_dataflow, prune_by_budget,
                    sample_points)
from .universal import (compile_count, evaluate_points_universal,
                        universal_specs)

__all__ = [
    "ClusterOption", "CoDSEResult", "EvalStats", "MapSpace",
    "MapSpaceError", "OBJECTIVES", "STRATEGIES", "SearchResult",
    "TileAxis", "build_space", "buffer_estimate_kb", "canonical_signature",
    "co_search", "compile_count", "dedupe_equivalent_points",
    "enable_compilation_cache", "enumerate_points",
    "evaluate_points", "evaluate_points_universal", "group_template",
    "measure_rate", "merged_pareto", "point_dataflow", "prune_by_budget",
    "sample_points", "search", "universal_specs",
]
