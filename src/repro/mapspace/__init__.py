"""Mapping-space search engine: auto-search over legal data-centric
directive programs plus joint mapping × hardware co-DSE.

Quick start::

    from repro.core import tensor_analysis as ta
    from repro.mapspace import search

    op = ta.conv2d("conv", k=128, c=64, y=32, x=32, r=3, s=3)
    result = search(op, objective="edp", budget=1000)
    print(result.best_dataflow)
    print(result.best_stats["edp"], result.mappings_per_s)

See ``repro.launch.mapsearch`` for the CLI.
"""
from .batched import EvalStats, evaluate_points, measure_rate
from .codse import CoDSEResult, co_search, merged_pareto
from .search import OBJECTIVES, SearchResult, search
from .space import (ClusterOption, MapSpace, MapSpaceError, TileAxis,
                    build_space, enumerate_points, group_template,
                    point_dataflow, sample_points)

__all__ = [
    "ClusterOption", "CoDSEResult", "EvalStats", "MapSpace",
    "MapSpaceError", "OBJECTIVES", "SearchResult", "TileAxis",
    "build_space", "co_search", "enumerate_points", "evaluate_points",
    "group_template", "measure_rate", "merged_pareto", "point_dataflow",
    "sample_points", "search",
]
