"""Mapping-space search engine: auto-search over legal data-centric
directive programs plus joint mapping × hardware co-DSE.

Evaluation runs through the *universal* structure-as-operand evaluator:
one XLA executable per (op, level-count) whose vmapped operands encode the
entire mapping — tile sizes, loop permutation (rank vector), spatial
choice (one-hot), cluster option, and the hardware point — so exploring
every structure group costs at most two compiles.

Quick start::

    from repro.core import tensor_analysis as ta
    from repro.mapspace import search

    op = ta.conv2d("conv", k=128, c=64, y=32, x=32, r=3, s=3)
    result = search(op, objective="edp", budget=1000)
    print(result.best_dataflow)
    print(result.best_stats["edp"], result.mappings_per_s)

See ``repro.launch.mapsearch`` for the CLI.
"""
from .batched import EvalStats, evaluate_points, measure_rate
from .cache import enable_compilation_cache
from .codse import (CoDSEResult, JointSweepResult, co_search,
                    co_search_impl, hw_grid, joint_sweep, merged_pareto)
from .search import (OBJECTIVES, PIPELINES, STRATEGIES, SearchResult,
                     search, search_impl, static_candidates)
from .space import (ClusterOption, GeneTables, MapSpace, MapSpaceError,
                    TileAxis, build_space, buffer_estimate_kb,
                    buffer_estimates_genes, canonical_signature,
                    decode_indices, dedupe_equivalent_genes,
                    dedupe_equivalent_points, enumerate_genes,
                    enumerate_points, flat_index, gene_tables,
                    genes_from_points, group_template, pad_tile_axes,
                    point_dataflow, points_from_genes, prune_by_budget,
                    prune_genes_by_budget, sample_genes, sample_points)
from .universal import (GeneEval, GeneRun, compile_count, encode_genes,
                        evaluate_genes, evaluate_points_universal,
                        universal_specs)

__all__ = [
    "ClusterOption", "CoDSEResult", "EvalStats", "GeneEval", "GeneRun",
    "GeneTables", "JointSweepResult", "MapSpace", "MapSpaceError",
    "OBJECTIVES", "PIPELINES", "STRATEGIES", "SearchResult", "TileAxis",
    "build_space", "buffer_estimate_kb", "buffer_estimates_genes",
    "canonical_signature", "co_search", "compile_count", "decode_indices",
    "dedupe_equivalent_genes", "dedupe_equivalent_points",
    "enable_compilation_cache", "encode_genes", "enumerate_genes",
    "enumerate_points", "evaluate_genes", "evaluate_points",
    "evaluate_points_universal", "flat_index", "gene_tables",
    "genes_from_points", "group_template", "hw_grid", "joint_sweep",
    "measure_rate", "merged_pareto", "pad_tile_axes", "point_dataflow",
    "points_from_genes", "prune_by_budget", "prune_genes_by_budget",
    "sample_genes", "sample_points", "search", "search_impl",
    "co_search_impl", "static_candidates", "universal_specs",
]
